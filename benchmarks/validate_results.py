"""Schema linter for the scenario-row artifacts in ``results/storage/``.

``results/storage/scenarios.json`` accumulates rows from four different
sweeps — single-stream open-loop cells, per-tenant admission-control rows,
fault-injection rows and LLM-serving rows — and PRs 2-3 established the
merge-never-
overwrite invariant: each producer replaces exactly its own rows and keeps
everything else.  That invariant is easy to break silently (a bench that
rewrites the file drops another sweep's rows; a driver bug duplicates a
cell), so this linter is run in CI and by every producer *before* writing:

* row-kind discrimination: a row carrying ``drift`` is a drift-trace row
  (per-phase windows), one carrying ``tenant`` is a multi-tenant row (it
  may *also* carry fault columns — ``run_multi_tenant(faults=...)`` emits
  per-tenant availability), one carrying ``fault`` alone is a fault row,
  else single-stream — and each kind must carry its required columns;
* no duplicate ``(cell, tenant)`` keys — the symptom of a bad merge;
* drift rows: a non-empty ``phases`` window list with per-phase
  conservation (``sum(phase n_arrived) == n_arrived``, same for
  completions/drops) and ``n_arrived == n_completed + dropped``;
* value sanity: known scheme, finite non-negative rates/percentiles,
  percentile dicts with the canonical p50..p9999 keys, admission
  conservation (``arrived == admitted + rejected + holding``), SLO
  columns (``slo_p99``/``slo_met``/``goodput``) and recovery-time SLO
  columns (``recovery_slo_s``/``recovery_slo_met``) well-typed when
  present.

Timeline artifacts (``results/storage/timelines/*.json``, written by the
``repro.obs`` metrics bus) are linted too — a timeline is a dict with
``kind == "timeline"``, an ascending ``t`` sample vector and equal-length
``series`` arrays of numbers/nulls; the CLI dispatches on shape, so
timeline files can be passed alongside row artifacts.

CLI (non-zero exit on any violation)::

  PYTHONPATH=src python -m benchmarks.validate_results            # defaults
  PYTHONPATH=src python -m benchmarks.validate_results results/storage/smoke.json
"""
from __future__ import annotations

import json
import math
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.lsm.db import SCHEMES

PCT_KEYS = ("p50", "p90", "p99", "p999", "p9999")

# columns every row kind must carry (OpenLoopResult.to_json + the cell
# metadata ScenarioMatrix.run_cell attaches)
BASE_COLUMNS = (
    "workload", "scheme", "arrival", "n_arrived", "n_measured", "duration",
    "offered_rate", "throughput", "latency_p", "queue_p", "service_p",
    "read_latency_p", "mean_latency", "mean_queue", "mean_service",
    "max_queue_depth", "op_counts", "extras", "cell", "ssd_zones",
)
TENANT_COLUMNS = ("tenant", "policy", "protected", "admission")
FAULT_COLUMNS = ("fault", "availability")
# serving rows (repro.workloads.serving) are a fourth shape: no storage
# scheme / latency decomposition, but TTFT + decode-gap percentiles and
# KV-tier traffic columns instead
SERVING_COLUMNS = (
    "workload", "arrival", "tiering", "serving_tenant", "cell",
    "admission", "n_arrived", "admitted", "rejected", "n_completed",
    "n_measured", "duration", "offered_rate", "throughput",
    "token_throughput", "tokens_out", "ttft_p", "decode_p",
    "hbm_hit_rate", "promote_pages", "demote_pages", "migrated_bytes",
    "preempt_stalls", "pauses", "hbm_zones", "host_zones", "max_batch",
)
SERVING_NUMERIC = ("n_arrived", "admitted", "rejected", "n_completed",
                   "n_measured", "duration", "offered_rate", "throughput",
                   "token_throughput", "tokens_out", "promote_pages",
                   "demote_pages", "migrated_bytes", "preempt_stalls",
                   "pauses", "hbm_zones", "host_zones", "max_batch")

# row-count columns that must be non-negative finite numbers
NUMERIC_COLUMNS = ("n_arrived", "n_measured", "duration", "offered_rate",
                   "throughput", "mean_latency", "mean_queue",
                   "mean_service", "max_queue_depth", "ssd_zones")

# per-shard sub-rows (ShardedDB.shard_stats + run_cell metadata): one per
# shard store of a sharded cell, sharing the aggregate row's cell name
SHARD_COLUMNS = ("shard", "kv_ops", "kv_completed", "availability",
                 "ssd_read_bytes", "ssd_write_bytes", "hdd_read_bytes",
                 "hdd_write_bytes", "compaction_debt", "cell", "scheme",
                 "ssd_zones", "shards", "routing")
SHARD_NUMERIC = ("kv_ops", "kv_completed", "ssd_read_bytes",
                 "ssd_write_bytes", "hdd_read_bytes", "hdd_write_bytes",
                 "compaction_debt", "shards", "ssd_zones")

# drift rows (repro.workloads.drift.run_drift): per-tenant rows carrying
# the program name and per-phase metric windows; no admission columns
DRIFT_COLUMNS = ("drift", "tenant", "phases", "n_completed", "dropped",
                 "drain_violations")
# required keys of every per-phase window entry
PHASE_KEYS = ("phase", "name", "t0", "t1", "workload", "n_arrived",
              "n_completed", "n_dropped", "n_measured", "throughput",
              "latency_p99", "queue_p99", "service_p99")
PHASE_NUMERIC = ("phase", "t0", "t1", "n_arrived", "n_completed",
                 "n_dropped", "n_measured", "throughput", "latency_p99",
                 "queue_p99", "service_p99")


def row_kind(row: Dict) -> str:
    """Discriminate the six row kinds sharing scenarios.json.

    Serving rows are checked first: a multi-tenant serving run carries
    per-tenant columns too, and must not be mistaken for a storage
    tenant row (whose required columns it does not have).  Drift rows
    carry ``tenant`` too (the drift tenant) but none of the admission
    columns, so they discriminate before the tenant kind.  A ``shard``
    column marks a per-shard sub-row (the sharded cell's aggregate row
    carries ``shards`` but never ``shard``)."""
    if "tiering" in row:
        return "serving"
    if "shard" in row:
        return "shard"
    if "drift" in row:
        return "drift"
    if "tenant" in row:
        return "tenant"
    if "fault" in row:
        return "fault"
    return "single"


def _check_pct(errors: List[str], where: str, name: str, d) -> None:
    if not isinstance(d, dict):
        errors.append(f"{where}: {name} is not a dict")
        return
    missing = [k for k in PCT_KEYS if k not in d]
    if missing:
        errors.append(f"{where}: {name} missing keys {missing}")
    bad = [k for k, v in d.items()
           if not isinstance(v, (int, float)) or not math.isfinite(v)
           or v < 0]
    if bad:
        errors.append(f"{where}: {name} non-finite/negative at {bad}")


def _check_serving(errors: List[str], where: str, row: Dict) -> None:
    missing = [c for c in SERVING_COLUMNS if c not in row]
    if missing:
        errors.append(f"{where}: missing columns {missing}")
        return
    for col in SERVING_NUMERIC:
        v = row[col]
        if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
            errors.append(f"{where}: {col}={v!r} not a non-negative "
                          f"finite number")
    for name in ("ttft_p", "decode_p"):
        _check_pct(errors, where, name, row[name])
    hr = row["hbm_hit_rate"]
    if not isinstance(hr, (int, float)) or not 0 <= hr <= 1:
        errors.append(f"{where}: hbm_hit_rate={hr!r} not in [0,1]")
    if row["n_arrived"] != row["admitted"] + row["rejected"]:
        errors.append(
            f"{where}: serving conservation violated: "
            f"n_arrived={row['n_arrived']} != admitted+rejected="
            f"{row['admitted'] + row['rejected']}")
    a = row["admission"]
    if not isinstance(a, dict):
        errors.append(f"{where}: admission must be an object")
    else:
        need = ("arrived", "admitted", "rejected", "holding")
        if all(k in a for k in need):
            if a["arrived"] != a["admitted"] + a["rejected"] + a["holding"]:
                errors.append(
                    f"{where}: admission conservation violated: "
                    f"arrived={a['arrived']} != admitted+rejected+holding="
                    f"{a['admitted'] + a['rejected'] + a['holding']}")
        else:
            errors.append(f"{where}: admission missing "
                          f"{[k for k in need if k not in a]}")
    slo = row.get("slo_p99")
    if slo is not None:
        if not isinstance(slo, (int, float)) or not math.isfinite(slo) \
                or slo <= 0:
            errors.append(f"{where}: slo_p99={slo!r} not a positive "
                          f"finite number")
        if not isinstance(row.get("slo_met"), bool):
            errors.append(f"{where}: slo_p99 rows must carry a boolean "
                          f"slo_met")
    g = row.get("goodput")
    if g is not None and (not isinstance(g, (int, float))
                          or not math.isfinite(g) or g < 0):
        errors.append(f"{where}: goodput={g!r} not a non-negative "
                      f"finite number")


def _check_drift(errors: List[str], where: str, row: Dict) -> None:
    """Drift-row specifics: the per-phase window list and conservation.

    Straddle rule: every op belongs to the phase it *arrived* in, so the
    windows partition the run's ops — per tenant row,
    ``sum(phase n_arrived) == n_arrived`` and every window closes with
    ``n_arrived == n_completed + n_dropped`` (drain-to-completion runs)."""
    for col in ("n_completed", "dropped", "drain_violations"):
        v = row[col]
        if not isinstance(v, int) or v < 0:
            errors.append(f"{where}: {col}={v!r} not a non-negative "
                          f"integer")
    rf = row.get("rank_flips")
    if rf is not None and (not isinstance(rf, int) or rf < 0):
        errors.append(f"{where}: rank_flips={rf!r} not a non-negative "
                      f"integer")
    phases = row["phases"]
    if not isinstance(phases, list) or not phases:
        errors.append(f"{where}: phases must be a non-empty list")
        return
    sums = {"n_arrived": 0, "n_completed": 0, "n_dropped": 0}
    ok = True
    for j, ph in enumerate(phases):
        pw = f"{where}.phases[{j}]"
        if not isinstance(ph, dict):
            errors.append(f"{pw}: phase entry is not an object")
            ok = False
            continue
        missing = [k for k in PHASE_KEYS if k not in ph]
        if missing:
            errors.append(f"{pw}: missing keys {missing}")
            ok = False
            continue
        for k in PHASE_NUMERIC:
            v = ph[k]
            if not isinstance(v, (int, float)) or not math.isfinite(v) \
                    or v < 0:
                errors.append(f"{pw}: {k}={v!r} not a non-negative "
                              f"finite number")
                ok = False
        if not ok:
            continue
        if ph["t1"] <= ph["t0"]:
            errors.append(f"{pw}: empty window t0={ph['t0']} "
                          f"t1={ph['t1']}")
        if ph["n_arrived"] != ph["n_completed"] + ph["n_dropped"]:
            errors.append(
                f"{pw}: window conservation violated: n_arrived="
                f"{ph['n_arrived']} != n_completed+n_dropped="
                f"{ph['n_completed'] + ph['n_dropped']}")
        for k in sums:
            sums[k] += ph[k]
    if not ok:
        return
    checks = (("n_arrived", row["n_arrived"]),
              ("n_completed", row["n_completed"]),
              ("n_dropped", row["dropped"]))
    for k, total in checks:
        if sums[k] != total:
            errors.append(
                f"{where}: per-phase conservation violated: "
                f"sum(phase {k})={sums[k]} != row total {total} — an op "
                f"straddling a boundary was double-counted or lost")
    if row["n_arrived"] != row["n_completed"] + row["dropped"]:
        errors.append(
            f"{where}: drift conservation violated: n_arrived="
            f"{row['n_arrived']} != n_completed+dropped="
            f"{row['n_completed'] + row['dropped']}")


def validate_rows(rows, path: str = "<rows>",
                  strict: bool = False) -> List[str]:
    """Validate a scenario-row list; returns human-readable violations.

    With ``strict=True`` raises ``ValueError`` on the first batch of
    violations instead — the mode producers use as a pre-write gate.
    """
    errors: List[str] = []
    if not isinstance(rows, list):
        errors = [f"{path}: top level must be a list of rows"]
        if strict:
            raise ValueError("\n".join(errors))
        return errors
    seen: Dict[tuple, int] = {}
    # sharded-cell conservation: the aggregate row's per-shard op counts
    # must sum to its kv_calls total, and the per-shard sub-rows must
    # agree with the aggregate's breakdown
    agg_shard_ops: Dict[str, Dict] = {}
    sub_shard_ops: Dict[str, Dict] = {}
    for i, row in enumerate(rows):
        where = f"{path}[{i}]"
        if not isinstance(row, dict):
            errors.append(f"{where}: row is not an object")
            continue
        kind = row_kind(row)
        where = f"{where}({kind}:{row.get('cell', '?')})"
        # duplicate-key detection: shard sub-rows share their aggregate
        # row's cell name and a sharded cell may share a name with its
        # single-DB twin in hand-built artifacts — the key must carry the
        # shard axes or those legitimate pairs collide
        key = (row.get("cell"),
               row.get("tenant") or row.get("serving_tenant"),
               row.get("shards"), row.get("shard"))
        if key in seen:
            errors.append(
                f"{where}: duplicate cell key {key} (first at row "
                f"{seen[key]}) — a merge overwrote or double-appended")
        else:
            seen[key] = i
        if kind == "serving":
            _check_serving(errors, where, row)
            continue
        if kind == "shard":
            missing = [c for c in SHARD_COLUMNS if c not in row]
            if missing:
                errors.append(f"{where}: missing columns {missing}")
                continue
            for col in SHARD_NUMERIC:
                v = row[col]
                if not isinstance(v, (int, float)) \
                        or not math.isfinite(v) or v < 0:
                    errors.append(f"{where}: {col}={v!r} not a "
                                  f"non-negative finite number")
            av = row["availability"]
            if not isinstance(av, (int, float)) or not 0 <= av <= 1:
                errors.append(f"{where}: availability={av!r} not in [0,1]")
            if row["scheme"] not in SCHEMES:
                errors.append(f"{where}: unknown scheme {row['scheme']!r}")
            if isinstance(row.get("kv_ops"), (int, float)):
                sub_shard_ops.setdefault(row["cell"], {})[
                    str(row["shard"])] = row["kv_ops"]
            continue
        required = BASE_COLUMNS + (
            TENANT_COLUMNS if kind == "tenant"
            else FAULT_COLUMNS if kind == "fault"
            else DRIFT_COLUMNS if kind == "drift" else ())
        missing = [c for c in required if c not in row]
        if missing:
            errors.append(f"{where}: missing columns {missing}")
            continue
        if kind == "tenant" and "fault" in row and "availability" not in row:
            errors.append(f"{where}: fault-injected tenant row must carry "
                          f"availability")
        if "shards" in row:
            so, kc = row.get("shard_ops"), row.get("kv_calls")
            if not isinstance(so, dict) \
                    or not isinstance(kc, (int, float)):
                errors.append(f"{where}: sharded aggregate row must carry "
                              f"shard_ops (object) and kv_calls (number)")
            else:
                if sum(so.values()) != kc:
                    errors.append(
                        f"{where}: per-shard op counts do not sum to the "
                        f"cell total: sum(shard_ops)={sum(so.values())} "
                        f"!= kv_calls={kc}")
                agg_shard_ops[row["cell"]] = so
        if row["scheme"] not in SCHEMES:
            errors.append(f"{where}: unknown scheme {row['scheme']!r}")
        for col in NUMERIC_COLUMNS:
            v = row[col]
            if not isinstance(v, (int, float)) or not math.isfinite(v) \
                    or v < 0:
                errors.append(f"{where}: {col}={v!r} not a non-negative "
                              f"finite number")
        for name in ("latency_p", "queue_p", "service_p", "read_latency_p"):
            _check_pct(errors, where, name, row[name])
        if not isinstance(row["op_counts"], dict) \
                or not isinstance(row["extras"], dict):
            errors.append(f"{where}: op_counts/extras must be objects")
        if kind == "drift":
            _check_drift(errors, where, row)
        if kind == "tenant":
            a = row["admission"]
            if not isinstance(a, dict):
                errors.append(f"{where}: admission must be an object")
            else:
                need = ("arrived", "admitted", "rejected", "holding")
                if all(k in a for k in need):
                    if a["arrived"] != a["admitted"] + a["rejected"] \
                            + a["holding"]:
                        errors.append(
                            f"{where}: admission conservation violated: "
                            f"arrived={a['arrived']} != admitted+rejected"
                            f"+holding="
                            f"{a['admitted'] + a['rejected'] + a['holding']}")
                else:
                    errors.append(f"{where}: admission missing "
                                  f"{[k for k in need if k not in a]}")
            # SLO-attainment columns (bench_control / TenantSpec.slo_p99)
            g = row.get("goodput")
            if g is not None and (not isinstance(g, (int, float))
                                  or not math.isfinite(g) or g < 0):
                errors.append(f"{where}: goodput={g!r} not a non-negative "
                              f"finite number")
            slo = row.get("slo_p99")
            if slo is not None:
                if not isinstance(slo, (int, float)) \
                        or not math.isfinite(slo) or slo <= 0:
                    errors.append(f"{where}: slo_p99={slo!r} not a "
                                  f"positive finite number")
                if not isinstance(row.get("slo_met"), bool):
                    errors.append(f"{where}: slo_p99 rows must carry a "
                                  f"boolean slo_met")
            # control-plane knob summary on feedback rows
            # (ControlPlane.knob_summary)
            ctl = row.get("control")
            if ctl is not None:
                if not isinstance(ctl, dict):
                    errors.append(f"{where}: control must be an object")
                else:
                    if ctl.get("controller") not in ("aimd", "pi"):
                        errors.append(
                            f"{where}: control.controller="
                            f"{ctl.get('controller')!r} not aimd|pi")
                    if not isinstance(ctl.get("knobs"), list) \
                            or not all(isinstance(k, str)
                                       for k in ctl.get("knobs") or []):
                        errors.append(f"{where}: control.knobs must be a "
                                      f"list of knob names")
                    for k in ("u", "pace", "migration", "cache_budget"):
                        v = ctl.get(k)
                        if not isinstance(v, (int, float)) \
                                or not math.isfinite(v):
                            errors.append(f"{where}: control.{k}={v!r} "
                                          f"not a finite number")
        if "availability" in row:
            av = row["availability"]
            if not isinstance(av, (int, float)) or not 0 <= av <= 1:
                errors.append(f"{where}: availability={av!r} not in [0,1]")
        # recovery-time SLO columns on crash rows
        rslo = row.get("recovery_slo_s")
        if rslo is not None:
            if not isinstance(rslo, (int, float)) \
                    or not math.isfinite(rslo) or rslo <= 0:
                errors.append(f"{where}: recovery_slo_s={rslo!r} not a "
                              f"positive finite number")
            if not isinstance(row.get("recovery_slo_met"), bool):
                errors.append(f"{where}: recovery_slo_s rows must carry a "
                              f"boolean recovery_slo_met")
            if "crash" not in row:
                errors.append(f"{where}: recovery_slo_s without crash "
                              f"accounting")
    for cell, subs in sub_shard_ops.items():
        agg = agg_shard_ops.get(cell)
        if agg is not None and {k: v for k, v in agg.items()} != subs:
            errors.append(
                f"{path}: cell {cell!r}: per-shard sub-row kv_ops "
                f"{subs} disagree with the aggregate row's shard_ops "
                f"{agg}")
    if strict and errors:
        raise ValueError(f"{len(errors)} schema violations:\n"
                         + "\n".join(errors))
    return errors


def validate_timeline(obj, path: str = "<timeline>",
                      strict: bool = False) -> List[str]:
    """Lint one timeline artifact (``repro.obs.MetricsRegistry.timeline``).

    Schema: ``{"kind": "timeline", "meta": {}, "sample_period": s > 0,
    "t": [ascending samples], "series": {name: [num|null] * len(t)}}``,
    plus an optional ``"marks"`` list (``[{t, label}]``, ascending ``t``)
    — the drift runner's phase-boundary markers.
    """
    errors: List[str] = []
    if not isinstance(obj, dict) or obj.get("kind") != "timeline":
        errors.append(f"{path}: not a timeline artifact "
                      f"(kind != 'timeline')")
    else:
        sp = obj.get("sample_period")
        if not isinstance(sp, (int, float)) or not math.isfinite(sp) \
                or sp <= 0:
            errors.append(f"{path}: sample_period={sp!r} not a positive "
                          f"finite number")
        if not isinstance(obj.get("meta"), dict):
            errors.append(f"{path}: meta must be an object")
        t = obj.get("t")
        if not isinstance(t, list) or not all(
                isinstance(v, (int, float)) and math.isfinite(v) and v >= 0
                for v in t):
            errors.append(f"{path}: t must be a list of non-negative "
                          f"finite numbers")
            t = []
        elif any(b < a for a, b in zip(t, t[1:])):
            errors.append(f"{path}: t must be nondecreasing")
        series = obj.get("series")
        if not isinstance(series, dict):
            errors.append(f"{path}: series must be an object")
        else:
            for name, vs in series.items():
                if not isinstance(vs, list) or len(vs) != len(t):
                    errors.append(f"{path}: series {name!r} length "
                                  f"{len(vs) if isinstance(vs, list) else '?'}"
                                  f" != len(t)={len(t)}")
                    continue
                bad = [v for v in vs
                       if v is not None
                       and (not isinstance(v, (int, float))
                            or not math.isfinite(v))]
                if bad:
                    errors.append(f"{path}: series {name!r} has non-finite "
                                  f"entries {bad[:3]}")
        marks = obj.get("marks")
        if marks is not None:
            if not isinstance(marks, list):
                errors.append(f"{path}: marks must be a list")
            else:
                ts = []
                for j, mk in enumerate(marks):
                    if not isinstance(mk, dict) \
                            or not isinstance(mk.get("t"), (int, float)) \
                            or not math.isfinite(mk["t"]) or mk["t"] < 0 \
                            or not isinstance(mk.get("label"), str) \
                            or not mk["label"]:
                        errors.append(f"{path}: marks[{j}] must be "
                                      f"{{t: number >= 0, label: str}}")
                        continue
                    ts.append(mk["t"])
                if any(b < a for a, b in zip(ts, ts[1:])):
                    errors.append(f"{path}: marks must be t-ascending")
    if strict and errors:
        raise ValueError(f"{len(errors)} timeline violations:\n"
                         + "\n".join(errors))
    return errors


TRAJECTORY_FIELDS = ("git_sha", "date", "sim_speed_geomean",
                     "read_path_speedup", "control_p99_ratio",
                     "drift_worst_phase_ratio")


def validate_trajectory(obj, path: str = "<trajectory>",
                        strict: bool = False) -> List[str]:
    """Lint the CI bench-trend artifact (``results/bench_trajectory.json``).

    Schema: ``{"kind": "bench_trajectory", "entries": [{git_sha, date,
    sim_speed_geomean, read_path_speedup, control_p99_ratio,
    drift_worst_phase_ratio}]}`` — one entry per CI run, appended by
    ``benchmarks/bench_trend.py``; the speed fields are positive finite
    numbers, ``control_p99_ratio`` / ``drift_worst_phase_ratio`` may be
    null when no control/drift rows were available to the run.
    """
    errors: List[str] = []
    if not isinstance(obj, dict) or obj.get("kind") != "bench_trajectory":
        errors.append(f"{path}: not a bench trajectory "
                      f"(kind != 'bench_trajectory')")
    elif not isinstance(obj.get("entries"), list):
        errors.append(f"{path}: entries must be a list")
    else:
        for i, e in enumerate(obj["entries"]):
            where = f"{path}.entries[{i}]"
            if not isinstance(e, dict):
                errors.append(f"{where}: entry is not an object")
                continue
            missing = [k for k in TRAJECTORY_FIELDS if k not in e]
            if missing:
                errors.append(f"{where}: missing fields {missing}")
                continue
            for k in ("git_sha", "date"):
                if not isinstance(e[k], str) or not e[k]:
                    errors.append(f"{where}: {k}={e[k]!r} not a non-empty "
                                  f"string")
            for k in ("sim_speed_geomean", "read_path_speedup"):
                v = e[k]
                if not isinstance(v, (int, float)) or not math.isfinite(v) \
                        or v <= 0:
                    errors.append(f"{where}: {k}={v!r} not a positive "
                                  f"finite number")
            for k in ("control_p99_ratio", "drift_worst_phase_ratio"):
                v = e[k]
                if v is not None and (not isinstance(v, (int, float))
                                      or not math.isfinite(v) or v <= 0):
                    errors.append(f"{where}: {k}={v!r} not a "
                                  f"positive finite number or null")
    if strict and errors:
        raise ValueError(f"{len(errors)} trajectory violations:\n"
                         + "\n".join(errors))
    return errors


def validate_file(path: Path) -> List[str]:
    try:
        data = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError) as exc:
        return [f"{path}: unreadable ({exc})"]
    # dispatch on shape: timeline/trajectory artifacts are dicts, row
    # files are lists
    if isinstance(data, dict) and data.get("kind") == "timeline":
        return validate_timeline(data, str(path))
    if isinstance(data, dict) and data.get("kind") == "bench_trajectory":
        return validate_trajectory(data, str(path))
    return validate_rows(data, str(path))


DEFAULT_TARGETS = ("scenarios.json", "multitenant.json", "faults.json",
                   "control.json", "filters.json", "serving.json",
                   "sharding.json", "drift.json")


def main(argv: Optional[Sequence[str]] = None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv:
        paths = [Path(a) for a in argv]
    else:
        d = Path("results/storage")
        paths = [d / n for n in DEFAULT_TARGETS if (d / n).exists()]
        paths += sorted((d / "timelines").glob("*.json"))
        traj = Path("results/bench_trajectory.json")
        if traj.exists():
            paths.append(traj)
    errors: List[str] = []
    for p in paths:
        errs = validate_file(p)
        errors.extend(errs)
        if errs:
            status = "FAIL"
        else:
            data = json.loads(p.read_text())
            if isinstance(data, dict) and "entries" in data:
                status = f"ok ({len(data['entries'])} entries)"
            elif isinstance(data, dict):
                status = (f"ok ({len(data['t'])} samples, "
                          f"{len(data['series'])} series)")
            else:
                status = f"ok ({len(data)} rows)"
        print(f"[validate] {p}: {status}", flush=True)
    for e in errors:
        print(f"  {e}", flush=True)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
