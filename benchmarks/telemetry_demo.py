"""Telemetry-bus demo: a small instrumented run that dumps a timeline.

Builds a small HHZS store with the metrics registry attached, drives an
open-loop bursty workload through it, and writes the run's timeline
artifact (the ``results/storage/timelines/*.json`` schema) — then lints
it with ``benchmarks.validate_results.validate_timeline``.  Fast enough
for CI (the ``bench-canary`` job runs it and uploads the artifact).

  PYTHONPATH=src python -m benchmarks.telemetry_demo
  PYTHONPATH=src python -m benchmarks.telemetry_demo --out demo.json
"""
from __future__ import annotations

import argparse
import sys

from repro.lsm import DB, ScenarioConfig
from repro.lsm.tree import LSMConfig
from repro.workloads import BurstyArrivals, YCSB, run_load, run_open_loop
from repro.zoned.device import MiB


def small_scenario() -> ScenarioConfig:
    """Demo-sized store (64-object SSTs): seconds, not minutes."""
    lsm = LSMConfig(
        obj_size=1024, block_size=4096,
        sst_size=int(0.0632 * MiB),
        memtable_size=int(0.032 * MiB),
        level_targets=(int(0.0632 * MiB),) * 2
        + (int(0.632 * MiB), int(6.32 * MiB), int(63.2 * MiB)),
        block_cache_blocks=8,
    )
    return ScenarioConfig(ssd_zones=20, ssd_zone_cap=int(0.0673 * MiB),
                          hdd_zones=4000, hdd_zone_cap=int(0.016 * MiB),
                          lsm=lsm)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="results/storage/timelines/demo.json")
    ap.add_argument("--keys", type=int, default=2000)
    ap.add_argument("--duration", type=float, default=240.0)
    args = ap.parse_args(argv)

    db = DB("HHZS", small_scenario(), telemetry=2.0)
    run_load(db, n_keys=args.keys)
    db.flush_all()
    res = run_open_loop(
        db, YCSB["A"], BurstyArrivals(2.0, 10.0, on=30.0, off=90.0),
        duration=args.duration, n_keys=args.keys, warmup=10.0, seed=7)
    db.metrics.sample_now()
    path = db.metrics.dump_timeline(
        args.out, meta={"cell": "telemetry-demo/HHZS", "scheme": "HHZS",
                        "ssd_zones": 20})

    from benchmarks.validate_results import validate_timeline
    import json
    validate_timeline(json.loads(path.read_text()), str(path), strict=True)

    tl = db.metrics.timeline()
    debt = [v for v in tl["series"]["lsm.debt"] if v is not None]
    print(f"[telemetry-demo] thpt={res.throughput:.1f}/s "
          f"p99={res.latency_p['p99']*1e3:.1f}ms")
    print(f"[telemetry-demo] {len(tl['series'])} series x {len(tl['t'])} "
          f"samples -> {path}")
    print(f"[telemetry-demo] compaction debt: max={max(debt):.0f}B "
          f"final={debt[-1]:.0f}B; write_amp final="
          f"{[v for v in tl['series']['lsm.write_amp'] if v is not None][-1]:.2f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
