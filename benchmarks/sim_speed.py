"""DES kernel speed: optimized ``repro.zoned.sim`` vs the frozen seed kernel.

The scenario-matrix sweeps (benchmarks/storage_exps.py, the open-loop
ScenarioMatrix) are bottlenecked by the event loop, not by numpy work, so
this benchmark times the kernel's hot paths head-to-head against the seed
implementation vendored in ``benchmarks/_seed_sim.py``:

  timer_churn     bench_table1-style: schedule N timeouts, drain with run()
  process_chain   closed-loop clients yielding timeouts through run_until()
  fifo_device     ZonedDevice-style busy-until FIFO I/O from processes
  sem_pool        background-job semaphore handoff (acquire/release churn)
  daemon_mix      real work interleaved with daemon pollers

  PYTHONPATH=src python -m benchmarks.sim_speed
  PYTHONPATH=src python -m benchmarks.sim_speed --repeat 5 --scale 2

Each workload goes through the kernel's best public API for the shape:
where the optimized kernel has a bulk/batched path (``Sim.schedule_many``,
``Sim.monotone_queue``) the bench uses it, and the seed kernel falls back
to per-event ``timeout()`` — the virtual-time equality assertion keeps the
comparison honest (same simulated history, different scheduling machinery).

Prints one CSV row per (bench, kernel) plus the per-bench and geometric-mean
speedups.  Exits non-zero if the geomean speedup is below the 2.0x target
so CI/driver runs notice regressions.
"""
from __future__ import annotations

import argparse
import math
import sys
import time

import benchmarks._seed_sim as seed_sim
import repro.zoned.sim as opt_sim


# ----------------------------------------------------------------------
# workloads (kernel-parametric: everything goes through the public Sim API)
# ----------------------------------------------------------------------
def timer_churn(mod, n):
    """bench_table1 shape: N pre-scheduled timeouts drained by run()."""
    sim = mod.Sim()
    many = getattr(sim, "schedule_many", None)
    if many is not None:
        many([i * 1e-6 for i in range(n)])
    else:
        t = sim.timeout
        for i in range(n):
            t(i * 1e-6)
    sim.run()
    return sim.now


def _bare_delays(mod) -> bool:
    """True when the kernel resumes a bare ``yield <delay>`` directly
    (no Event allocated); the seed kernel needs ``yield timeout(d)``."""
    return getattr(mod.Sim, "BARE_DELAY_YIELDS", False)


def process_chain(mod, n_procs, n_yields):
    """Closed-loop clients: each op is a yield through run_until()."""
    sim = mod.Sim()

    if _bare_delays(mod):
        def client():
            for _ in range(n_yields):
                yield 1e-6
    else:
        def client():
            for _ in range(n_yields):
                yield sim.timeout(1e-6)

    procs = [sim.process(client()) for _ in range(n_procs)]
    for p in procs:
        sim.run_until(p)
    return sim.now


def fifo_device(mod, n_clients, n_ops):
    """ZonedDevice-style FIFO resource: busy-until queueing per request.

    The optimized kernel rides the per-device completion batch
    (``Sim.monotone_queue`` + ``complete_at`` tickets) exactly as
    ``ZonedDevice.io`` does; the seed kernel schedules one heap timeout
    per I/O."""
    sim = mod.Sim()
    busy = 0.0
    mq = sim.monotone_queue() if hasattr(sim, "monotone_queue") else None

    def io(service):
        nonlocal busy
        now = sim.now
        end = (busy if busy > now else now) + service
        busy = end
        if mq is not None:
            return mq.complete_at(end)
        return sim.timeout(end - now)

    def client(i):
        for k in range(n_ops):
            yield io(1e-5 if (k + i) % 7 else 1e-4)

    procs = [sim.process(client(i)) for i in range(n_clients)]
    for p in procs:
        sim.run_until(p)
    return sim.now


def sem_pool(mod, n_jobs, capacity):
    """Background-job pool: semaphore acquire / timed work / release."""
    sim = mod.Sim()
    sem = mod.Semaphore(sim, capacity)

    if _bare_delays(mod):
        def job():
            yield sem.acquire()
            yield 1e-4
            sem.release()
    else:
        def job():
            yield sem.acquire()
            yield sim.timeout(1e-4)
            sem.release()

    for _ in range(n_jobs):
        sim.process(job())
    sim.run()
    return sim.now


def daemon_mix(mod, n_ops, n_pollers):
    """Real work interleaved with daemon pollers (migration-tick shape)."""
    sim = mod.Sim()

    def poller():
        while True:
            yield sim.timeout(1e-3, daemon=True)

    if _bare_delays(mod):
        def worker():
            for _ in range(n_ops):
                yield 1e-5
    else:
        def worker():
            for _ in range(n_ops):
                yield sim.timeout(1e-5)

    for _ in range(n_pollers):
        sim.process(poller())
    p = sim.process(worker())
    sim.run_until(p)
    return sim.now


def benches(scale):
    s = scale
    return [
        ("timer_churn", lambda m: timer_churn(m, 200_000 * s)),
        ("process_chain", lambda m: process_chain(m, 64, 2_000 * s)),
        ("fifo_device", lambda m: fifo_device(m, 32, 4_000 * s)),
        ("sem_pool", lambda m: sem_pool(m, 60_000 * s, 12)),
        ("daemon_mix", lambda m: daemon_mix(m, 100_000 * s, 8)),
    ]


def _time_pair(fn, repeat):
    """Best-of-``repeat`` for seed and opt, *interleaved* (seed, opt, seed,
    opt, ...): machine-load drift then hits both kernels alike instead of
    biasing whichever phase it lands on."""
    best_seed = best_opt = math.inf
    v_seed = v_opt = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        v_seed = fn(seed_sim)
        best_seed = min(best_seed, time.perf_counter() - t0)
        t0 = time.perf_counter()
        v_opt = fn(opt_sim)
        best_opt = min(best_opt, time.perf_counter() - t0)
    return best_seed, best_opt, v_seed, v_opt


def run(repeat=3, scale=1, target=2.0):
    rows = []
    speedups = []
    for name, fn in benches(scale):
        t_seed, t_opt, v_seed, v_opt = _time_pair(fn, repeat)
        assert abs(v_seed - v_opt) < 1e-9, \
            f"{name}: virtual-time divergence seed={v_seed} opt={v_opt}"
        sp = t_seed / t_opt
        speedups.append(sp)
        rows.append(f"sim_speed_{name},seed={t_seed*1e3:.1f}ms,"
                    f"opt={t_opt*1e3:.1f}ms,speedup={sp:.2f}x")
    geomean = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    rows.append(f"sim_speed_geomean,,,{geomean:.2f}x")
    return rows, geomean


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--target", type=float, default=2.0)
    args = ap.parse_args(argv)
    rows, geomean = run(args.repeat, args.scale, args.target)
    for r in rows:
        print(r)
    ok = geomean >= args.target
    print(f"[sim_speed] geomean speedup {geomean:.2f}x "
          f"({'>=' if ok else '<'} target {args.target}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
