"""Read-path speed: batched Bloom probing vs per-key gets (YCSB C).

The batched read path (``LSMTree.get_batch``) replaces per-key python
Bloom probing with one vectorized probe over every (key x candidate-SST)
pair of the batch.  This benchmark times the two paths *wall-clock* on
identically loaded stores under a YCSB C (read-only, Zipf 0.9) key
stream and asserts they return byte-identical answers:

  PYTHONPATH=src python -m benchmarks.read_path_bench
  PYTHONPATH=src python -m benchmarks.read_path_bench --reads 40000 --batch 128

Prints one CSV row per path plus the speedup; exits non-zero when the
speedup falls below ``--target`` (default 1.2x) so CI canary runs notice
read-path regressions.  Simulated (virtual-time) throughput is not the
metric here — batching changes service timestamps by design — the claim
is about host-side cost per op, which is what bounds sweep wall-clock.

The default scheme is B3: under migration-enabled schemes (HHZS) the
read-hot phase keeps the background migrator's O(n_ssts) picker busy,
and that shared cost — identical on both paths — drowns the read-path
difference in the ratio.  ``--scheme HHZS`` measures the full system.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.lsm import DB, ScenarioConfig
from repro.lsm.tree import LSMConfig
from repro.workloads import zipf_probs
from repro.zoned.device import MiB


def build_db(n_keys: int, seed: int = 42, scheme: str = "B3") -> DB:
    """A freshly loaded store with enough SSTs for multi-candidate probes
    (64-object SSTs, several levels populated)."""
    lsm = LSMConfig(
        obj_size=1024, block_size=4096,
        sst_size=int(0.0632 * MiB),
        memtable_size=int(0.032 * MiB),
        level_targets=(int(0.0632 * MiB),) * 2
        + (int(0.632 * MiB), int(6.32 * MiB), int(63.2 * MiB)),
        block_cache_blocks=64,
    )
    sc = ScenarioConfig(ssd_zones=20, ssd_zone_cap=int(0.0673 * MiB),
                        hdd_zones=8000, hdd_zone_cap=int(0.016 * MiB),
                        lsm=lsm)
    db = DB(scheme, sc)
    for k in np.random.default_rng(seed).permutation(n_keys):
        db.put(int(k))
    db.flush_all()
    db.drain()
    return db


def make_reads(n_reads: int, n_keys: int, seed: int = 7) -> np.ndarray:
    """YCSB C: 100% point reads, Zipf(0.9) over scrambled ranks."""
    rng = np.random.default_rng(seed)
    p = zipf_probs(n_keys, 0.9)
    ranks = rng.choice(n_keys, size=n_reads, p=p)
    scramble = np.random.default_rng(seed + 1).permutation(n_keys)
    return scramble[ranks].astype(np.int64)


def run(n_keys=8000, n_reads=20000, batch=64, repeat=3, target=1.2,
        scheme="B3"):
    db_per = build_db(n_keys, scheme=scheme)
    db_bat = build_db(n_keys, scheme=scheme)
    keys = make_reads(n_reads, n_keys)
    n_ssts = sum(len(lvl) for lvl in db_per.tree.levels)

    best_per = best_bat = float("inf")
    res_per = res_bat = None
    for _ in range(repeat):
        # interleaved best-of: load drift hits both paths alike
        t0 = time.perf_counter()
        res_per = [db_per.get(int(k))[0] for k in keys]
        best_per = min(best_per, time.perf_counter() - t0)
        t0 = time.perf_counter()
        res_bat = []
        for i in range(0, len(keys), batch):
            res_bat.extend(
                f for f, _ in db_bat.get_batch(
                    [int(k) for k in keys[i:i + batch]]))
        best_bat = min(best_bat, time.perf_counter() - t0)
    assert res_per == res_bat, "batched path diverged from per-key gets"
    assert all(res_per), "loaded keys must all be found"

    ops_per = n_reads / best_per
    ops_bat = n_reads / best_bat
    speedup = best_per / best_bat
    rows = [
        f"read_path_per_key,{best_per/n_reads*1e6:.2f},"
        f"{ops_per:.0f}ops/s;ssts={n_ssts}",
        f"read_path_batched,{best_bat/n_reads*1e6:.2f},"
        f"{ops_bat:.0f}ops/s;batch={batch}",
        f"read_path_speedup,,,{speedup:.2f}x",
    ]
    return rows, speedup


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--keys", type=int, default=8000)
    ap.add_argument("--reads", type=int, default=20000)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--repeat", type=int, default=3)
    ap.add_argument("--target", type=float, default=1.2)
    ap.add_argument("--scheme", default="B3")
    args = ap.parse_args(argv)
    rows, speedup = run(args.keys, args.reads, args.batch, args.repeat,
                        args.target, args.scheme)
    for r in rows:
        print(r)
    ok = speedup >= args.target
    print(f"[read_path] batched speedup {speedup:.2f}x "
          f"({'>=' if ok else '<'} target {args.target}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
