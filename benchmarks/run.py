"""Benchmark driver. One function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run                 # everything
  PYTHONPATH=src python -m benchmarks.run --exp exp1,exp6 # subset
  PYTHONPATH=src python -m benchmarks.run --quick         # smaller loads

Storage rows (table1, fig2, exp1-exp6) reproduce the paper's experiments
on the scaled simulator (see benchmarks/storage_exps.py for methodology);
kernel rows time the jnp reference paths on CPU (the Pallas kernels target
TPU and are validated in interpret mode by the tests); roofline rows
summarise results/dryrun (produced by ``python -m repro.launch.dryrun``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def bench_kernels_reference() -> list:
    """Wall-time the pure-jnp oracle paths (CPU); labels are explicit."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.selective_scan.ref import selective_scan_ref
    from repro.kernels.bloom_probe.ref import build_filter, bloom_probe_ref

    rows = []
    rng = np.random.default_rng(0)

    def timeit(fn, *args, n=5):
        fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) \
            else jax.block_until_ready(fn(*args))
        t0 = time.time()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.time() - t0) / n * 1e6

    q = jnp.array(rng.standard_normal((1, 8, 1024, 64)), jnp.float32)
    k = jnp.array(rng.standard_normal((1, 2, 1024, 64)), jnp.float32)
    f = jax.jit(lambda q, k: attention_ref(q, k, k, causal=True))
    rows.append(f"kernel_attention_ref_cpu_b1h8s1024,{timeit(f, q, k):.0f},"
                f"jnp-oracle")
    dt = jnp.abs(jnp.array(rng.standard_normal((1, 256, 512)), jnp.float32))
    bx = jnp.array(rng.standard_normal((1, 256, 512, 16)) * .1, jnp.float32)
    c = jnp.array(rng.standard_normal((1, 256, 16)), jnp.float32)
    a = -jnp.abs(jnp.array(rng.standard_normal((512, 16)), jnp.float32))
    f2 = jax.jit(selective_scan_ref)
    rows.append(f"kernel_sscan_ref_cpu_t256d512,{timeit(f2, dt, bx, c, a):.0f},"
                f"jnp-oracle")
    member = jnp.array(rng.integers(0, 2**31, 4096), jnp.uint32)
    bits = build_filter(member, num_words=8192)
    f3 = jax.jit(bloom_probe_ref)
    rows.append(f"kernel_bloom_ref_cpu_n4096,{timeit(f3, member, bits):.0f},"
                f"jnp-oracle")
    return rows


def bench_roofline_summary() -> list:
    """CSV rows from the dry-run artifacts (one per compiled cell)."""
    rows = []
    d = Path("results/dryrun")
    if not d.exists():
        return ["roofline_missing,0,run python -m repro.launch.dryrun first"]
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        tag = f"{r['arch']}__{r['shape']}__{r['mesh']}"
        if r["status"] != "ok":
            rows.append(f"roofline_{tag},0,{r['status']}")
            continue
        rl = r["roofline"]
        rows.append(
            f"roofline_{tag},{rl['bound_s'] * 1e6 if 'bound_s' in rl else max(rl['compute_s'], rl['memory_s'], rl['collective_s']) * 1e6:.0f},"
            f"dom={rl['dominant']};mfu={rl['mfu']:.3f};"
            f"comp={rl['compute_s']:.2e};mem={rl['memory_s']:.2e};"
            f"coll={rl['collective_s']:.2e}")
    return rows


def bench_serving() -> list:
    """Tokens/s of the tiered serving engine under HBM pressure (CPU)."""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, hbm_zones=6, host_zones=64,
                        pages_per_zone=2, page_size=8, max_batch=4,
                        cache_zones=1)
    rng = np.random.default_rng(0)
    for i in range(10):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               12).astype(np.int32),
                           max_new_tokens=6))
    t0 = time.time()
    st = eng.run(max_steps=120)
    wall = time.time() - t0
    return [f"serving_tiered_smoke,{wall / max(st['tokens_out'], 1) * 1e6:.0f},"
            f"tok={st['tokens_out']};demote={st['demotions']};"
            f"promote={st['promotions']};cache={st['cache_admits']}"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--exp", default="all",
                    help="comma list: table1,fig2,exp1..exp6,kernels,"
                         "roofline,serving")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    which = None if args.exp == "all" else args.exp.split(",")

    import benchmarks.storage_exps as SE
    if args.quick:
        SE.KEY_DIV = 4
        SE.SSD_SWEEP = [20, 60]

    rows = ["name,us_per_call,derived"]
    storage = [k for k in SE.ALL if which is None or k in which]
    if storage:
        rows += SE.run(storage)
    if which is None or "kernels" in which:
        rows += bench_kernels_reference()
    if which is None or "serving" in which:
        rows += bench_serving()
    if which is None or "roofline" in which:
        rows += bench_roofline_summary()
    print("\n".join(rows))


if __name__ == "__main__":
    main()
