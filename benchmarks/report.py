"""Render EXPERIMENTS.md tables from results/ JSON artifacts.

  PYTHONPATH=src python -m benchmarks.report          # print all sections

Storage sections consume the artifacts written by ``benchmarks.storage_exps``
(``results/storage/exp*.json``, ``fig2.json``) and the open-loop scenario
rows in ``results/storage/scenarios.json``.  The scenario row schema is
documented on ``repro.workloads.runner.OpenLoopResult.to_json``; rows
carrying a ``tenant`` key come from multi-tenant admission-control sweeps
(``bench_multitenant``) and are rendered as a separate per-tenant
tail-latency table, while the remaining rows form the single-stream
queueing-vs-service table.
"""
import json
from pathlib import Path

from repro.config import SHAPES
from repro.configs import get_config


def roofline_table() -> str:
    rows = ["| arch | shape | mesh | compute_s | memory_s | collective_s |"
            " dominant | MODEL/HLO | MFU | GiB/dev |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for p in sorted(Path("results/dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — "
                        f"| — | *skipped: full-attn 500k* | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} "
                        f"| ERROR: {r.get('error','')[:40]} |")
            continue
        rl = r["roofline"]
        mem = (r["argument_bytes"] + r["temp_bytes"]) / 2**30
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rl['compute_s']:.3g} | {rl['memory_s']:.3g} "
            f"| {rl['collective_s']:.3g} | {rl['dominant']} "
            f"| {rl['useful_flops_ratio']:.2f} | {rl['mfu']:.3f} "
            f"| {mem:.1f} |")
    return "\n".join(rows)


def dryrun_summary() -> str:
    ok = sk = err = 0
    worst = []
    for p in sorted(Path("results/dryrun").glob("*.json")):
        r = json.loads(p.read_text())
        if r["status"] == "ok":
            ok += 1
            worst.append((r["roofline"]["mfu"], f"{r['arch']}/{r['shape']}"
                          f"/{r['mesh']}"))
        elif r["status"] == "skipped":
            sk += 1
        else:
            err += 1
    worst.sort()
    lines = [f"cells: {ok} compiled ok, {sk} skipped by assignment rule, "
             f"{err} errors."]
    return "\n".join(lines)


def perf_logs() -> str:
    out = []
    for p in sorted(Path("results/perf").glob("*.json")):
        out.append(f"### {p.stem.replace('__', ' / ')}")
        out.append("| variant | compute_s | memory_s | collective_s |"
                   " dominant | MFU | temp GiB |")
        out.append("|---|---|---|---|---|---|---|")
        for e in json.loads(p.read_text()):
            if e.get("status") != "ok":
                out.append(f"| {e['variant']} | ERROR | | | | | |")
                continue
            out.append(f"| {e['variant']} | {e['compute_s']:.3g} "
                       f"| {e['memory_s']:.3g} | {e['collective_s']:.3g} "
                       f"| {e['dominant']} | {e['mfu']:.3f} "
                       f"| {e['temp_gib']:.1f} |")
        out.append("")
    return "\n".join(out)


def storage_tables() -> str:
    out = []
    d = Path("results/storage")
    for name in ["exp1", "exp2", "exp3", "exp4", "exp5", "exp6", "fig2"]:
        p = d / f"{name}.json"
        if not p.exists():
            continue
        out.append(f"### {name}")
        out.append("```json")
        out.append(json.dumps(json.loads(p.read_text()), indent=1)[:4000])
        out.append("```")
    gp = grid_throughput_pivot()
    if gp:
        out.append("### full grid: scheme x workload throughput "
                   "(ops/s, open-loop)")
        out.append(gp)
    gh = grid_tail_heatmap()
    if gh:
        out.append("### full grid: p99 queueing vs service tail "
                   "(ms, poisson cells)")
        out.append(gh)
    sc = scenario_matrix_table()
    if sc:
        out.append("### scenario matrix (open-loop)")
        out.append(sc)
    ft = filter_sweep_table()
    if ft:
        out.append("### Bloom filter-bits sweep (batched read path)")
        out.append(ft)
    mt = tenant_tail_table()
    if mt:
        out.append("### multi-tenant admission control (per-tenant tails)")
        out.append(mt)
    fr = fault_recovery_table()
    if fr:
        out.append("### crash/recovery + fault injection")
        out.append(fr)
    sa = slo_attainment_table()
    if sa:
        out.append("### SLO attainment: debt-aware control plane "
                   "(bench_control)")
        out.append(sa)
    sh = sharding_table()
    if sh:
        out.append("### sharded cluster: scaling, rebalancing, "
                   "per-shard faults (bench_sharding)")
        out.append(sh)
    sv = serving_table()
    if sv:
        out.append("### LLM KV-cache serving (bench_serving)")
        out.append(sv)
    dr = drift_table()
    if dr:
        out.append("### drift traces: per-phase scheme rankings "
                   "(bench_drift)")
        out.append(dr)
    tl = timeline_table()
    if tl:
        out.append("### telemetry timelines (results/storage/timelines)")
        out.append(tl)
    return "\n".join(out)


def _scenario_rows():
    p = Path("results/storage/scenarios.json")
    return json.loads(p.read_text()) if p.exists() else []


def _grid_rows():
    """Single-stream rows of the full-grid sweep (YCSB letter workloads,
    written by ``python -m repro.workloads.sweep``).  Filter-sweep rows
    (``bench_filter_sweep``) also use YCSB C but carry a ``filter_bits``
    column and render in their own pivot."""
    return [r for r in _scenario_rows()
            if "tenant" not in r and "fault" not in r
            and "filter_bits" not in r and "tiering" not in r
            and "shards" not in r and "shard" not in r
            and "drift" not in r
            and r.get("workload") in set("ABCDEF")]


def _fmt_group(vals, fmt) -> str:
    """Render a pivot entry that may hold several rows' values: a lone
    value renders plainly, several render joined — grouping instead of
    silently overwriting when rows share a pivot key."""
    return " / ".join(fmt(v) for v in vals)


def _arrival_kind(name: str) -> str:
    return name.split("(", 1)[0]


def _scheme_order(schemes):
    from repro.lsm.db import SCHEMES
    known = [s for s in SCHEMES if s in schemes]
    return known + sorted(set(schemes) - set(known))


def grid_throughput_pivot() -> str:
    """Scheme x workload throughput pivot, one table per (arrival kind,
    SSD budget) — the paper's headline "highest throughput under various
    settings" claim, readable at a glance.  Overloaded cells pin at the
    scheme's service rate, so the pivot doubles as a capacity map."""
    grid = _grid_rows()
    if not grid:
        return ""
    groups = {}
    for r in grid:
        groups.setdefault((_arrival_kind(r["arrival"]), r["ssd_zones"]),
                          {}).setdefault(
            (r["scheme"], r["workload"]), []).append(r["throughput"])
    out = []
    for (kind, z), cells in sorted(groups.items()):
        schemes = _scheme_order({s for s, _ in cells})
        workloads = sorted({w for _, w in cells})
        out.append(f"**arrival={kind}, ssd_zones={z}** "
                   f"({len(cells)} cells)")
        out.append("| scheme | " + " | ".join(workloads) + " |")
        out.append("|---" * (len(workloads) + 1) + "|")
        for s in schemes:
            vals = [_fmt_group(cells[(s, w)], "{:.1f}".format)
                    if (s, w) in cells else "—"
                    for w in workloads]
            out.append(f"| {s} | " + " | ".join(vals) + " |")
        out.append("")
    return "\n".join(out).rstrip()


def grid_tail_heatmap() -> str:
    """Queueing-vs-service p99 decomposition per scheme x workload for the
    stable (poisson) cells: each entry is ``q99/s99`` in ms.  Queueing
    dwarfing service marks a saturated cell; service dominating marks
    device-bound latency (the decomposition the closed-loop YCSB runs
    cannot see)."""
    grid = [r for r in _grid_rows()
            if _arrival_kind(r["arrival"]) == "poisson"]
    if not grid:
        return ""
    groups = {}
    for r in grid:
        groups.setdefault(r["ssd_zones"], {}).setdefault(
            (r["scheme"], r["workload"]), []).append(
                (r["queue_p"]["p99"] * 1e3, r["service_p"]["p99"] * 1e3))
    out = []
    for z, cells in sorted(groups.items()):
        schemes = _scheme_order({s for s, _ in cells})
        workloads = sorted({w for _, w in cells})
        out.append(f"**ssd_zones={z}** (entries: p99 queue ms / "
                   f"p99 service ms)")
        out.append("| scheme | " + " | ".join(workloads) + " |")
        out.append("|---" * (len(workloads) + 1) + "|")
        for s in schemes:
            vals = []
            for w in workloads:
                if (s, w) in cells:
                    vals.append(_fmt_group(
                        cells[(s, w)],
                        lambda e: f"{e[0]:.0f}/{e[1]:.0f}"))
                else:
                    vals.append("—")
            out.append(f"| {s} | " + " | ".join(vals) + " |")
        out.append("")
    return "\n".join(out).rstrip()


def scenario_matrix_table() -> str:
    """Deep single-stream open-loop cells (the calibrated long-duration
    "mix" rows from ``bench_scenarios``): queueing-delay vs service-time
    decomposition per cell.  The full-grid YCSB A-F rows are rendered by
    the pivot/heatmap tables above instead of one row per cell."""
    rows = ["| cell | offered/s | thpt/s | p50 ms | p99 ms |"
            " p99 queue ms | p99 service ms | max depth |",
            "|---|---|---|---|---|---|---|---|"]
    found = False
    for r in _scenario_rows():
        if "tenant" in r or "fault" in r or "filter_bits" in r \
                or "tiering" in r or "shards" in r or "shard" in r \
                or "drift" in r or r.get("workload") in set("ABCDEF"):
            continue
        found = True
        rows.append(
            f"| {r['cell']} | {r['offered_rate']:.1f} "
            f"| {r['throughput']:.1f} "
            f"| {r['latency_p']['p50']*1e3:.1f} "
            f"| {r['latency_p']['p99']*1e3:.1f} "
            f"| {r['queue_p']['p99']*1e3:.1f} "
            f"| {r['service_p']['p99']*1e3:.1f} "
            f"| {r['max_queue_depth']} |")
    return "\n".join(rows) if found else ""


def tenant_tail_table() -> str:
    """Per-tenant tail-latency table from the multi-tenant admission-control
    sweep (rows of results/storage/scenarios.json carrying a ``tenant``
    key).  A ``*`` marks protected (SLO) tenants; ``shed``/``delayed`` are
    the admission-controller counters, so a protected tenant's p999
    queueing delay can be read off against the policy that produced it."""
    rows = ["| cell | tenant | policy | offered/s | admitted | shed |"
            " delayed | p99 queue ms | p999 queue ms | p99 service ms |"
            " p999 total ms |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    found = False
    for r in _scenario_rows():
        if "tenant" not in r or "drift" in r:
            continue
        found = True
        a = r["admission"]
        star = "*" if r.get("protected") else ""
        rows.append(
            f"| {r['cell']} | {r['tenant']}{star} | {r['policy']} "
            f"| {r['offered_rate']:.1f} "
            f"| {int(a['admitted'])} | {int(a['rejected'])} "
            f"| {int(a['delayed'])} "
            f"| {r['queue_p']['p99']*1e3:.1f} "
            f"| {r['queue_p']['p999']*1e3:.1f} "
            f"| {r['service_p']['p99']*1e3:.1f} "
            f"| {r['latency_p']['p999']*1e3:.1f} |")
    return "\n".join(rows) if found else ""


def filter_sweep_table() -> str:
    """Bloom filter-bits x scheme pivot from the ``bench_filter_sweep``
    rows (scenarios.json rows carrying ``filter_bits``): each entry is
    throughput ops/s and the measured FP rate per probe
    (``bloom_fp / filter_probes`` from the row extras) — the
    accuracy-vs-memory trade the batched read path exposes as a sweep
    axis."""
    rows = [r for r in _scenario_rows()
            if "filter_bits" in r and "tenant" not in r and "fault" not in r]
    if not rows:
        return ""
    cells = {}
    for r in rows:
        probes = r["extras"].get("filter_probes", 0)
        fp = r["extras"].get("bloom_fp", 0) / probes if probes else 0.0
        cells.setdefault((r["scheme"], int(r["filter_bits"])),
                         []).append((r["throughput"], fp))
    schemes = _scheme_order({s for s, _ in cells})
    bits = sorted({b for _, b in cells})
    out = ["(entries: throughput ops/s / measured FP per probe)",
           "| scheme | " + " | ".join(f"{b} bits" for b in bits) + " |",
           "|---" * (len(bits) + 1) + "|"]
    for s in schemes:
        vals = []
        for b in bits:
            if (s, b) in cells:
                vals.append(_fmt_group(
                    cells[(s, b)],
                    lambda e: f"{e[0]:.1f} ({e[1]:.4f}fp)"))
            else:
                vals.append("—")
        out.append(f"| {s} | " + " | ".join(vals) + " |")
    return "\n".join(out)


def fault_recovery_table() -> str:
    """Crash/recovery + fault-injection table (rows of
    results/storage/scenarios.json carrying a ``fault`` key, written by
    ``bench_faults``).  ``avail`` is completed/offered ops; ``stall p99``
    is the tail over ops that arrived inside a stall window; the crash
    columns are the recovery accounting (downtime = crash to serving
    again, including WAL replay I/O; replayed = logical WAL records
    re-inserted; lost = in-flight ops killed + arrivals refused during
    the outage); ``rslo`` is the recovery-time SLO budget
    (``FaultSpec.recovery_slo_s``) and whether the downtime met it.
    Fault-injected multi-tenant rows (``run_multi_tenant(faults=...)``)
    appear with their tenant name."""
    rows = ["| cell | tenant | fault | offered/s | avail | p99 ms |"
            " stall p99 ms | downtime s | replayed | lost | rslo |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    found = False
    for r in _scenario_rows():
        if "fault" not in r:
            continue
        found = True
        stall = r.get("stall_p") or {}
        crash = r.get("crash") or {}
        lost = (int(crash.get("lost_in_flight", 0))
                + int(crash.get("refused", 0))) if crash else 0
        if "recovery_slo_s" in r:
            rslo = (f"{r['recovery_slo_s']:g}s "
                    f"{'met' if r['recovery_slo_met'] else 'MISSED'}")
        else:
            rslo = "—"
        rows.append(
            f"| {r['cell']} | {r.get('tenant') or '—'} | {r['fault']} "
            f"| {r['offered_rate']:.1f} "
            f"| {r['availability']:.4f} "
            f"| {r['latency_p']['p99']*1e3:.1f} "
            f"| {stall.get('p99', 0)*1e3:.1f} "
            f"| {crash.get('downtime', 0):.2f} "
            f"| {int(crash.get('replayed_records', 0))} "
            f"| {lost} | {rslo} |")
    return "\n".join(rows) if found else ""


def slo_attainment_table() -> str:
    """SLO-attainment table from ``bench_control`` (tenant rows carrying
    ``slo_p99``): per-tenant measured p99 vs target, whether it was met,
    and goodput (ops/s completing within the target) — followed by the
    policy comparison the experiment exists for: protected-tenant p99 and
    total goodput per (scheme, policy), where the debt-aware ``feedback``
    policy should dominate the static PR-2 policies and the v2 full-knob
    PI controller should beat admission-only ``feedback`` on both axes.
    Feedback rows carry the controller law and knob set
    (``ControlPlane.knob_summary``)."""
    slo_rows = [r for r in _scenario_rows()
                if "tenant" in r and r.get("slo_p99") is not None]
    if not slo_rows:
        return ""
    out = ["| cell | tenant | policy | ctl | offered/s | admitted | shed |"
           " p99 ms | slo ms | met | goodput/s |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in slo_rows:
        a = r["admission"]
        star = "*" if r.get("protected") else ""
        ctl = r.get("control")
        law = "—"
        if ctl:
            law = ctl["controller"] + ("+knobs"
                                       if len(ctl.get("knobs", [])) > 1
                                       else "")
        out.append(
            f"| {r['cell']} | {r['tenant']}{star} | {r['policy']} "
            f"| {law} "
            f"| {r['offered_rate']:.1f} "
            f"| {int(a['admitted'])} | {int(a['rejected'])} "
            f"| {r['latency_p']['p99']*1e3:.1f} "
            f"| {r['slo_p99']*1e3:.1f} "
            f"| {'yes' if r['slo_met'] else 'NO'} "
            f"| {r['goodput']:.1f} |")
    # policy comparison: protected p99 + total goodput per (scheme, policy)
    prot, total = {}, {}
    for r in slo_rows:
        key = (r["scheme"], r["policy"])
        total[key] = total.get(key, 0.0) + r.get("goodput", 0.0)
        if r.get("protected"):
            prot[key] = r["latency_p"]["p99"]
    if prot:
        out.append("")
        out.append("**policy comparison** (protected p99 / total goodput)")
        out.append("| scheme | policy | protected p99 ms | total goodput/s |")
        out.append("|---|---|---|---|")
        for (scheme, policy) in sorted(prot):
            out.append(f"| {scheme} | {policy} "
                       f"| {prot[(scheme, policy)]*1e3:.1f} "
                       f"| {total[(scheme, policy)]:.1f} |")
    return "\n".join(out)


def _sharding_rows():
    """Sharded-cell rows: prefer the dedicated ``bench_sharding``
    artifact, fall back to the merged scenarios.json rows (a ``shards``
    or ``shard`` column marks the kind either way)."""
    p = Path("results/storage/sharding.json")
    if p.exists():
        return json.loads(p.read_text())
    return [r for r in _scenario_rows() if "shards" in r or "shard" in r]


def sharding_table() -> str:
    """Sharded-cluster table from ``bench_sharding`` (rows carrying a
    ``shards`` column): throughput scaling across shard counts, static vs
    rebalanced routing under hot-key skew (splits = online shard splits
    the rebalancer performed, charged in virtual time), and per-shard
    availability under the kill-one-shard fault cell.  The per-shard
    sub-rows render indented under their cell's aggregate row."""
    rows = _sharding_rows()
    if not rows:
        return ""
    aggs = [r for r in rows if "shards" in r and "shard" not in r]
    subs = {}
    for r in rows:
        if "shard" in r:
            subs.setdefault(r["cell"], []).append(r)
    out = ["| cell | shards | routing | thpt/s | p99 ms | avail "
           "| splits | shard ops |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(aggs, key=lambda r: (r.get("workload", ""),
                                         r["shards"], r.get("cell", ""))):
        routing = r.get("routing", "?")
        if r.get("rebalance"):
            routing += "+rb"
        ops = r.get("shard_ops") or {}
        dist = "/".join(str(ops[k]) for k in sorted(ops, key=int))
        av = (f"{r['availability']:.4f}"
              if "availability" in r else "—")
        out.append(
            f"| {r['cell']} | {r['shards']} | {routing} "
            f"| {r['throughput']:.1f} "
            f"| {r['latency_p']['p99']*1e3:.1f} "
            f"| {av} | {len(r.get('splits') or [])} | {dist} |")
        for s in sorted(subs.get(r["cell"], []),
                        key=lambda s: s["shard"]):
            out.append(
                f"| &nbsp;&nbsp;└ shard {s['shard']} | | "
                f"| | | {s['availability']:.4f} | "
                f"| {s['kv_ops']} |")
    return "\n".join(out) if len(out) > 2 else ""


def _serving_rows():
    """Serving rows: prefer the dedicated artifact, fall back to the
    merged scenarios.json rows (``tiering`` marks the kind either way)."""
    p = Path("results/storage/serving.json")
    if p.exists():
        return json.loads(p.read_text())
    return [r for r in _scenario_rows() if "tiering" in r]


def serving_table() -> str:
    """Per-cell serving table from ``bench_serving`` (rows carrying a
    ``tiering`` key): decode-step p50/p99, TTFT p99 vs the tenant SLO,
    HBM hit rate and the migration traffic each tiering policy paid for
    it.  Read the three policies of one (arrival, hbm) group against each
    other: ``static`` sheds load to keep HBM-only latency, ``lru`` pages
    blindly (high migration, decode stalls), ``hhzs`` uses the paper's
    hints to keep hot sequences resident at a fraction of the traffic."""
    rows = _serving_rows()
    if not rows:
        return ""
    out = ["| cell | tiering | offered/s | admitted | shed | done "
           "| ttft p99 s | slo | decode p50/p99 ms | hbm hit "
           "| pg promo/demo | stalls |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r.get("cell", ""),)):
        if r.get("slo_p99") is not None:
            slo = "met" if r.get("slo_met") else "MISSED"
        else:
            slo = "—"
        out.append(
            f"| {r['cell']} | {r['tiering']} "
            f"| {r['offered_rate']:.2f} "
            f"| {int(r['admitted'])} | {int(r['rejected'])} "
            f"| {int(r['n_completed'])} "
            f"| {r['ttft_p']['p99']:.2f} | {slo} "
            f"| {r['decode_p']['p50']*1e3:.1f}/"
            f"{r['decode_p']['p99']*1e3:.1f} "
            f"| {r['hbm_hit_rate']:.3f} "
            f"| {int(r['promote_pages'])}/{int(r['demote_pages'])} "
            f"| {int(r['preempt_stalls'])} |")
    return "\n".join(out)


def _drift_rows():
    """Drift rows: prefer the dedicated ``bench_drift`` artifact, fall
    back to the merged scenarios.json rows (``drift`` marks the kind
    either way)."""
    p = Path("results/storage/drift.json")
    if p.exists():
        return json.loads(p.read_text())
    return [r for r in _scenario_rows() if "drift" in r]


def drift_table() -> str:
    """Per-phase pivot from ``bench_drift`` (rows carrying ``drift``):
    one table per (program, tenant, budget) group, schemes x phases, each
    entry the scheme's in-window sojourn p99 (the phase winner — lowest
    tail — in bold; per-phase throughput is arrival-bound by
    construction, so tails are what discriminate).  The headline
    question is *ranking stability*:
    a list of the windows where a baseline out-ranks HHZS leads the
    section (or a note that HHZS holds every window — see
    docs/ARCHITECTURE.md on why), and each group reports its
    ``rank_flips`` count — how many phase boundaries reshuffled the
    scheme ordering."""
    from repro.workloads.drift import phase_rankings
    rows = [r for r in _drift_rows() if "drift" in r and r.get("phases")]
    if not rows:
        return ""
    rankings = phase_rankings(rows)
    groups = {}
    for r in rows:
        key = (r["drift"], r.get("arrival"), r.get("tenant"),
               r.get("ssd_zones"))
        groups.setdefault(key, []).append(r)
    out = []
    losses = []
    for key in sorted(groups, key=str):
        drift_name, _arrival, tenant, zones = key
        rs = groups[key]
        rk = rankings.get(key, {"phases": [], "flips": 0})
        winners = {p["phase"]: (p["ranking"][0] if p["ranking"] else None)
                   for p in rk["phases"]}
        for p in rk["phases"]:
            if p["ranking"] and "HHZS" in p["ranking"] \
                    and p["ranking"][0] != "HHZS":
                losses.append(f"{drift_name}/{p['name']} "
                              f"(tenant {tenant}): {p['ranking'][0]}")
        pnames = [p["name"] for p in rs[0]["phases"]]
        out.append(f"**{drift_name}** tenant={tenant}, ssd_zones={zones} "
                   f"({rk['flips']} rank flips; entries: in-window "
                   f"sojourn p99 (s), phase winner in bold; "
                   f"drops/drain violations per scheme)")
        out.append("| scheme | " + " | ".join(pnames)
                   + " | dropped | drain viol |")
        out.append("|---" * (len(pnames) + 3) + "|")
        for r in sorted(rs, key=lambda r: _scheme_order(
                [x["scheme"] for x in rs]).index(r["scheme"])):
            vals = []
            for p in r["phases"]:
                v = f"{p['latency_p99']:.1f}"
                if winners.get(p["phase"]) == r["scheme"]:
                    v = f"**{v}**"
                vals.append(v)
            out.append(f"| {r['scheme']} | " + " | ".join(vals)
                       + f" | {r.get('dropped', 0)} "
                       f"| {r.get('drain_violations', 0)} |")
        out.append("")
    if losses:
        head = ("Windows where a baseline out-ranks HHZS: "
                + "; ".join(losses))
    else:
        head = ("HHZS leads every (program x phase) window — see "
                "docs/ARCHITECTURE.md §Drift traces on why the ranking "
                "is stable under these programs.")
    return "\n".join([head, ""] + out).rstrip()


# series worth summarizing in the report (timelines carry ~30 more);
# the ctl.u / ctl.knob.* rows make the control plane's knob trajectory
# visible next to the pressure signals that drove it
_TIMELINE_SERIES = ("lsm.debt", "lsm.write_amp", "lsm.l0_files",
                    "ssd.util", "hdd.util", "ssd.zones.open",
                    "adm.pressure", "ctl.attainment", "ctl.u",
                    "ctl.knob.pace", "ctl.knob.migration",
                    "ctl.knob.cache_budget")


def _spark(values, buckets: int = 12) -> str:
    """Downsample a series to a compact text trace (bucket means)."""
    vals = [v for v in values if v is not None]
    if not vals:
        return "—"
    chunks = []
    n = -(-len(vals) // buckets)      # ceil: never drop the series tail
    for i in range(0, len(vals), n):
        window = vals[i:i + n]
        chunks.append(sum(window) / len(window))
    return " ".join(f"{v:.3g}" for v in chunks)


def timeline_table() -> str:
    """Per-cell summaries of the timeline artifacts the telemetry bus
    (``repro.obs``) dumped into ``results/storage/timelines/``: min/mean/
    max plus a downsampled trace for the headline series (compaction debt,
    write amplification, device utilization/occupancy, admission pressure,
    SLO attainment)."""
    d = Path("results/storage/timelines")
    files = sorted(d.glob("*.json")) if d.exists() else []
    if not files:
        return ""
    out = ["| timeline | series | min | mean | max | trace (downsampled) |",
           "|---|---|---|---|---|---|"]
    for p in files:
        try:
            tl = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            continue
        if tl.get("kind") != "timeline":
            continue
        label = tl.get("meta", {}).get("cell", p.stem)
        for name in _TIMELINE_SERIES:
            vs = [v for v in tl.get("series", {}).get(name, [])
                  if v is not None]
            if not vs:
                continue
            out.append(f"| {label} | {name} | {min(vs):.4g} "
                       f"| {sum(vs)/len(vs):.4g} | {max(vs):.4g} "
                       f"| {_spark(tl['series'][name])} |")
    return "\n".join(out) if len(out) > 2 else ""


if __name__ == "__main__":
    print("## Dry-run summary\n")
    print(dryrun_summary())
    print("\n## Roofline table\n")
    print(roofline_table())
    print("\n## Perf logs\n")
    print(perf_logs())
    print("\n## Storage\n")
    print(storage_tables())
