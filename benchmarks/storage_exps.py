"""Storage benchmarks: one per paper table/figure (Fig.2, Exp#1-6).

Methodology follows §4.1: for every (scheme, workload) cell the storage is
cleared and freshly loaded (200 GiB of 1 KiB objects, scaled by 1/SCALE),
the WAL is drained (reopen semantics), and the workload runs while the
load's compaction backlog is still live — reproducing the O1 state the
paper exploits.  Reported OPS are simulated OPS (= paper OPS / SCALE since
both sizes and device rates are scaled; multiply by SCALE for paper units).
"""
from __future__ import annotations

import json
import time
from dataclasses import replace
from pathlib import Path
from typing import Dict, List, Optional

import numpy as np

from repro.lsm import DB, SCALE, ScenarioConfig
from repro.workloads import (YCSB, LevelSampler, WorkloadSpec, run_load,
                             run_workload)
from repro.zoned.device import MiB

RESULTS = Path("results/storage")

# op counts: paper's 1M (Exp#1) and 5M (Exp#2-4, #6) scaled by 1/SCALE,
# then x4 for tail-latency statistics where needed
OPS_1M = max(1_000_000 // SCALE, 5_000)
OPS_5M = max(5_000_000 // SCALE, 20_000)
# --quick: shrink the *dataset* (and proportionally the op counts) for the
# sweep experiments; relative scheme ordering is preserved at reduced
# resolution (full-scale numbers live in results/storage once the full
# suite has been run)
KEY_DIV = 1
SSD_SWEEP = [20, 40, 60, 80]


def fresh_loaded_db(scheme: str, scenario: Optional[ScenarioConfig] = None,
                    sampler_period: float = 60.0):
    sc = scenario or ScenarioConfig()
    db = DB(scheme, sc)
    sampler = LevelSampler(db, period=sampler_period)
    load = run_load(db, n_keys=sc.paper_keys // KEY_DIV)
    db.flush_all()
    return db, load, sampler


def _row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def _run(db, spec, n_ops):
    n = db.scenario.paper_keys // KEY_DIV
    return run_workload(db, spec, n_ops=n_ops // KEY_DIV, n_keys=n)


# ======================================================================
def bench_table1() -> List[str]:
    """Table 1: device model calibration (sequential MiB/s, random IOPS)."""
    from repro.zoned import Sim, ZonedDevice
    from repro.lsm.db import _scaled_timing
    from repro.zoned.device import ZN540_SSD, ST14000_HDD
    rows = []
    for name, timing, seq_ref, iops_ref in [
            ("ssd", ZN540_SSD, 1002.8, 16928.3),
            ("hdd", ST14000_HDD, 210.0, 115.0)]:
        t = _scaled_timing(timing, SCALE)
        sim = Sim()
        dev = ZonedDevice(sim, name, t, 4, int(1077 * MiB) // SCALE)
        # sequential 1 MiB-scaled writes
        chunk = int(1 * MiB) / SCALE
        n = 200
        for _ in range(n):
            dev.io(chunk, "seq_write")
        sim.run()
        seq_bw = n * chunk / sim.now * SCALE / MiB
        sim2 = Sim()
        dev2 = ZonedDevice(sim2, name, t, 4, int(1077 * MiB) // SCALE)
        for _ in range(n):
            dev2.io(4096, "rand_read")
        sim2.run()
        iops = n / sim2.now * SCALE
        rows.append(_row(f"table1_{name}_seq_write",
                         sim.now / n * 1e6,
                         f"{seq_bw:.0f}MiB/s(ref{seq_ref})"))
        rows.append(_row(f"table1_{name}_rand_read",
                         sim2.now / n * 1e6,
                         f"{iops:.0f}IOPS(ref{iops_ref})"))
    return rows


def bench_fig2() -> List[str]:
    """Fig.2 motivating analysis: O1 (level sizes vs targets), O2 (SSD write
    share), O3 implied, O4 (HDD read share / read throughput) for B1-B4."""
    rows = []
    detail = {}
    for scheme in ["B1", "B2", "B3", "B4"]:
        db, load, sampler = fresh_loaded_db(scheme)
        st = sampler.stats()
        targets = [db.scenario.lsm.target_of(i) for i in range(5)]
        over = [round(st["max"][i] / targets[i], 1) for i in range(5)] \
            if st else []
        ssd_w = db.ssd.counters.write_bytes
        hdd_w = db.hdd.counters.write_bytes
        ssd_frac = ssd_w / (ssd_w + hdd_w)
        res = _run(db, YCSB["C"], OPS_1M)
        ssd_r = db.ssd.counters.read_bytes
        hdd_r = db.hdd.counters.read_bytes
        hdd_read_frac = hdd_r / (ssd_r + hdd_r)
        rows.append(_row(f"fig2_load_{scheme}",
                         1e6 / max(load.throughput, 1e-9),
                         f"load={load.throughput:.1f}OPS"
                         f";ssd_w={ssd_frac:.2f}"
                         f";max_over_target={over}"))
        rows.append(_row(f"fig2_read_{scheme}",
                         1e6 / max(res.throughput, 1e-9),
                         f"read={res.throughput:.2f}OPS"
                         f";hdd_rd={hdd_read_frac:.2f}"))
        detail[scheme] = {"load": load.throughput, "read": res.throughput,
                          "over_target_max": over,
                          "hdd_read_frac": hdd_read_frac}
    (RESULTS / "fig2.json").write_text(json.dumps(detail, indent=1))
    return rows


def bench_exp1() -> List[str]:
    """Exp#1: YCSB A-F + load, HHZS vs B3 vs AUTO (Fig.5)."""
    rows, detail = [], {}
    for scheme in ["B3", "AUTO", "HHZS"]:
        detail[scheme] = {}
        for wl in ["load", "A", "B", "C", "D", "E", "F"]:
            db, load, _ = fresh_loaded_db(scheme)
            if wl == "load":
                thpt = load.throughput
                res = None
            else:
                res = _run(db, YCSB[wl], OPS_1M)
                thpt = res.throughput
            detail[scheme][wl] = thpt
            rows.append(_row(f"exp1_{scheme}_{wl}",
                             1e6 / max(thpt, 1e-9),
                             f"thpt={thpt:.2f}OPS"))
    for wl in ["load", "A", "B", "C", "D", "E", "F"]:
        b3 = detail["B3"][wl]
        rows.append(_row(
            f"exp1_gain_{wl}", 0.0,
            f"HHZS/B3={detail['HHZS'][wl]/b3:.2f}"
            f";HHZS/AUTO={detail['HHZS'][wl]/detail['AUTO'][wl]:.2f}"))
    (RESULTS / "exp1.json").write_text(json.dumps(detail, indent=1))
    return rows


W_SPECS = {
    "W1": WorkloadSpec("W1", read=0.1, update=0.9, alpha=0.9),
    "W2": WorkloadSpec("W2", read=0.5, update=0.5, alpha=0.9),
    "W3": WorkloadSpec("W3", read=0.5, update=0.5, alpha=1.2),
    "W4": WorkloadSpec("W4", read=1.0, alpha=1.2),
}


def bench_exp2() -> List[str]:
    """Exp#2: component breakdown B3 / B3+M / P / P+M / P+M+C on W1-W4."""
    rows, detail = [], {}
    for scheme in ["B3", "B3+M", "P", "P+M", "P+M+C"]:
        detail[scheme] = {}
        for wname, spec in W_SPECS.items():
            db, load, _ = fresh_loaded_db(scheme)
            res = _run(db, spec, OPS_5M)
            detail[scheme][wname] = res.throughput
            rows.append(_row(f"exp2_{scheme}_{wname}",
                             1e6 / max(res.throughput, 1e-9),
                             f"thpt={res.throughput:.2f}OPS"))
    for wname in W_SPECS:
        b3 = detail["B3"][wname]
        rows.append(_row(f"exp2_norm_{wname}", 0.0,
                         ";".join(f"{s}={detail[s][wname]/b3:.2f}"
                                  for s in detail)))
    (RESULTS / "exp2.json").write_text(json.dumps(detail, indent=1))
    return rows


def bench_exp3() -> List[str]:
    """Exp#3: skewness sweep (alpha 0.8-1.2, 50/50 read-write)."""
    rows, detail = [], {}
    for alpha in [0.8, 0.9, 1.0, 1.1, 1.2]:
        for scheme in ["B3", "AUTO", "HHZS"]:
            spec = WorkloadSpec(f"a{alpha}", read=0.5, update=0.5,
                                alpha=alpha)
            db, _, _ = fresh_loaded_db(scheme)
            res = _run(db, spec, OPS_5M)
            detail.setdefault(scheme, {})[alpha] = res.throughput
            rows.append(_row(f"exp3_{scheme}_a{alpha}",
                             1e6 / max(res.throughput, 1e-9),
                             f"thpt={res.throughput:.2f}OPS"))
    (RESULTS / "exp3.json").write_text(json.dumps(detail, indent=1))
    return rows


def bench_exp4() -> List[str]:
    """Exp#4: read-ratio sweep (10%-90% reads, alpha=0.9)."""
    rows, detail = [], {}
    for frac in [0.1, 0.3, 0.5, 0.7, 0.9]:
        for scheme in ["B3", "AUTO", "HHZS"]:
            spec = WorkloadSpec(f"r{frac}", read=frac, update=1 - frac,
                                alpha=0.9)
            db, _, _ = fresh_loaded_db(scheme)
            res = _run(db, spec, OPS_5M)
            detail.setdefault(scheme, {})[frac] = res.throughput
            rows.append(_row(f"exp4_{scheme}_r{int(frac*100)}",
                             1e6 / max(res.throughput, 1e-9),
                             f"thpt={res.throughput:.2f}OPS"))
    (RESULTS / "exp4.json").write_text(json.dumps(detail, indent=1))
    return rows


def bench_exp5() -> List[str]:
    """Exp#5: SSD size sweep (20-80 zones), load + 50/50 workload."""
    rows, detail = [], {}
    for zones in SSD_SWEEP:
        for scheme in ["B1", "B2", "B3", "B4", "AUTO", "P", "HHZS"]:
            sc = ScenarioConfig(ssd_zones=zones)
            db, load, _ = fresh_loaded_db(scheme, sc)
            spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
            res = _run(db, spec, OPS_1M)
            detail.setdefault(zones, {})[scheme] = {
                "load": load.throughput, "mix": res.throughput}
            rows.append(_row(f"exp5_{scheme}_z{zones}",
                             1e6 / max(res.throughput, 1e-9),
                             f"load={load.throughput:.1f}"
                             f";mix={res.throughput:.2f}OPS"))
    (RESULTS / "exp5.json").write_text(json.dumps(detail, indent=1))
    return rows


def bench_exp6() -> List[str]:
    """Exp#6: migration rate vs tail read latency (P+M, 1-64 MiB/s)."""
    rows, detail = [], {}
    for rate_mib in [1, 2, 4, 16, 64]:
        sc = ScenarioConfig(migration_rate=rate_mib * MiB / SCALE)
        db, _, _ = fresh_loaded_db("P+M", sc)
        spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
        res = _run(db, spec, OPS_5M)
        lat = res.read_latency_p
        detail[rate_mib] = {k: v for k, v in lat.items()}
        detail[rate_mib]["thpt"] = res.throughput
        rows.append(_row(
            f"exp6_rate{rate_mib}MiBps",
            lat.get("p99", 0) * 1e6,
            f"p99={lat.get('p99', 0)*1e3:.1f}ms"
            f";p999={lat.get('p999', 0)*1e3:.1f}ms"
            f";p9999={lat.get('p9999', 0)*1e3:.1f}ms"
            f";thpt={res.throughput:.2f}"))
    (RESULTS / "exp6.json").write_text(json.dumps(detail, indent=1))
    return rows


def _merge_scenarios(data: List[dict], replaces) -> None:
    """Merge rows into results/storage/scenarios.json.

    Rows matching the ``replaces`` predicate are refreshed (the bench's own
    previous rows are dropped from the file); every other row is kept.
    Row kinds: single-stream rows carry neither key, multi-tenant rows
    carry ``tenant``, fault rows carry ``fault`` — each bench replaces
    exactly its own rows, so the sweeps can be (re)run in any order.
    Single-stream rows now have two producers (the full-grid sweep driver
    on YCSB A-F, and ``bench_scenarios``'s calibrated "mix" cells), so
    predicates must discriminate by workload, not just by kind.

    The merged file is schema-linted (``benchmarks.validate_results``)
    before the write: a violation aborts with the old file intact.
    """
    from benchmarks.validate_results import validate_rows
    scen = RESULTS / "scenarios.json"
    kept = [r for r in (json.loads(scen.read_text())
                        if scen.exists() else [])
            if not replaces(r)]
    merged = kept + data
    validate_rows(merged, str(scen), strict=True)
    scen.parent.mkdir(parents=True, exist_ok=True)
    scen.write_text(json.dumps(merged, indent=1))


def bench_scenarios() -> List[str]:
    """Open-loop scenario matrix: (scheme x workload x arrival) with the
    queueing-delay / service-time decomposition the closed-loop YCSB runs
    can't see.  Offered rates are calibrated from a closed-loop probe so
    the bursty cells genuinely overload the store during bursts.

    Runs through the parallel sweep driver (``repro.workloads.sweep``) —
    the same engine as the full YCSB A-F grid (``python -m
    repro.workloads.sweep``); this bench keeps only the deep calibrated
    "mix" cells at long duration, and replaces exactly those rows."""
    from repro.workloads import (BurstyArrivals, PoissonArrivals,
                                 ScenarioMatrix)
    from repro.workloads.sweep import GridDBFactory, run_sweep

    factory = GridDBFactory(key_div=KEY_DIV, load_div=4)
    # closed-loop probe on the weakest scheme: its service rate anchors
    # base (0.5x, stable) and burst (3x, overloaded) offered rates
    probe = factory("B3", 20)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc = max(pr.throughput, 1e-6)
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"],
        workloads=[spec],
        arrivals=[PoissonArrivals(0.5 * svc),
                  BurstyArrivals(0.2 * svc, 3.0 * svc, on=60.0, off=240.0)],
        ssd_zone_budgets=[20],
        duration=1800.0, warmup=120.0,
        key_div=KEY_DIV, db_factory=factory)
    data = run_sweep(matrix, out=None, workers=2, resume=False,
                     verbose=False)
    _merge_scenarios(data, replaces=lambda r: r.get("workload") == "mix"
                     and "tenant" not in r and "fault" not in r)
    rows = []
    for r in data:
        rows.append(_row(
            f"scenarios_{r['cell']}",
            r["latency_p"]["p99"] * 1e6,
            f"offered={r['offered_rate']:.1f}/s"
            f";thpt={r['throughput']:.1f}/s"
            f";p99q={r['queue_p']['p99']*1e3:.1f}ms"
            f";p99s={r['service_p']['p99']*1e3:.1f}ms"))
    return rows


def bench_filter_sweep() -> List[str]:
    """Bloom filter-bits sweep (the batched-read-path axis): YCSB C
    open-loop cells at ``filter_bits_per_key`` in (4, 8, 10, 16) for
    B3 and HHZS, batched gets on (``read_batch=16``).  Each row carries a
    ``filter_bits`` column plus the ``filter_probes``/``bloom_fp`` extras,
    so FP-rate-per-probe x throughput renders as
    ``benchmarks/report.filter_sweep_table``.  Rows publish to
    ``results/storage/filters.json`` and merge into scenarios.json
    (replacing exactly the previous filter-sweep rows)."""
    from repro.workloads import PoissonArrivals, ScenarioMatrix
    from repro.workloads.sweep import GridDBFactory, run_sweep

    factory = GridDBFactory(key_div=KEY_DIV, load_div=8)
    # closed-loop probe anchors the offered rate (see bench_scenarios)
    probe = factory("B3", 20)
    spec = YCSB["C"]
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc = max(pr.throughput, 1e-6)
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"],
        workloads=[spec],
        arrivals=[PoissonArrivals(0.5 * svc)],
        ssd_zone_budgets=[20],
        filter_bits=[4, 8, 10, 16],
        read_batch=16,
        duration=600.0, warmup=60.0,
        key_div=KEY_DIV, db_factory=factory)
    data = run_sweep(matrix, out=None, workers=2, resume=False,
                     verbose=False)
    _merge_scenarios(data, replaces=lambda r: "filter_bits" in r)
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "filters.json", strict=True)
    (RESULTS / "filters.json").write_text(json.dumps(data, indent=1))
    rows = []
    for r in data:
        probes = r["extras"].get("filter_probes", 0)
        fps = r["extras"].get("bloom_fp", 0)
        rows.append(_row(
            f"filters_{r['cell']}",
            r["latency_p"]["p99"] * 1e6,
            f"bits={r['filter_bits']}"
            f";thpt={r['throughput']:.1f}/s"
            f";fp_per_probe={fps / probes if probes else 0.0:.4f}"
            f";hdd_rd_mb={r['extras'].get('hdd_read_bytes', 0)/MiB:.1f}"))
    return rows


def bench_multitenant() -> List[str]:
    """Multi-tenant SLO experiment: a protected steady tenant shares each
    store with a flash-crowd tenant, under admission policies none /
    reject-at-pressure / delay-at-pressure.  Emits one row per tenant per
    cell; rows are merged into results/storage/scenarios.json (alongside
    the single-stream scenario rows) for benchmarks/report.py's per-tenant
    tail-latency table.  The headline number: the protected tenant's p999
    queueing delay with shedding on vs off under the same offered load."""
    from repro.core.middleware import AdmissionConfig
    from repro.workloads import (FlashCrowdArrivals, PoissonArrivals,
                                 ScenarioMatrix, TenantSpec)

    def db_factory(scheme, ssd_zones):
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        db = DB(scheme, sc)
        n = sc.paper_keys // (4 * KEY_DIV)
        run_load(db, n_keys=n)
        db.flush_all()
        db.n_keys = n
        return db

    # closed-loop probe anchors the offered rates (see bench_scenarios)
    probe = db_factory("B3", 20)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc = max(pr.throughput, 1e-6)
    mix = [
        TenantSpec("steady", spec, PoissonArrivals(0.35 * svc),
                   protected=True),
        TenantSpec("flash", spec,
                   FlashCrowdArrivals(0.15 * svc, 4.0 * svc,
                                      at=300.0, decay=180.0)),
    ]
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"], workloads=[], arrivals=[],
        tenants=[mix],
        policies=[AdmissionConfig(policy=p, queue_threshold=96)
                  for p in ("none", "reject", "delay")],
        ssd_zone_budgets=[20],
        duration=1200.0, warmup=120.0,
        db_factory=db_factory)
    data = matrix.run()
    # replace only this bench's own tenants: bench_control publishes
    # tenant rows too (prot/bulk) and must survive a multitenant re-run
    _merge_scenarios(data, replaces=lambda r: r.get("tenant")
                     in ("steady", "flash"))
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "multitenant.json", strict=True)
    (RESULTS / "multitenant.json").write_text(json.dumps(data, indent=1))
    rows = []
    p999 = {}
    for r in data:
        a = r["admission"]
        rows.append(_row(
            f"multitenant_{r['cell']}_{r['tenant']}",
            r["queue_p"]["p999"] * 1e6,
            f"offered={r['offered_rate']:.1f}/s"
            f";admitted={int(a['admitted'])}"
            f";shed={int(a['rejected'])}"
            f";delayed={int(a['delayed'])}"
            f";p999q={r['queue_p']['p999']*1e3:.1f}ms"))
        if r["tenant"] == "steady":
            p999[(r["scheme"], r["policy"])] = r["queue_p"]["p999"]
    for scheme in ("B3", "HHZS"):
        base = p999.get((scheme, "none"))
        if base:
            rows.append(_row(
                f"multitenant_{scheme}_slo_gain", 0.0,
                ";".join(f"{p}={p999.get((scheme, p), 0)/base:.3f}x"
                         for p in ("reject", "delay"))))
    return rows


def bench_faults() -> List[str]:
    """Crash/recovery + fault-injection scenarios (beyond the paper).

    Sweeps B3 vs HHZS under (a) an SSD stall window plus a transient HDD
    slowdown and (b) a mid-run crash with WAL-replay recovery, at an
    offered load calibrated to ~50% of the weakest scheme's service rate.
    Emits availability and during-stall tail columns per cell; rows merge
    into results/storage/scenarios.json (single-stream and multi-tenant
    rows are kept) and render as benchmarks/report.py's recovery table."""
    from repro.workloads import PoissonArrivals, ScenarioMatrix
    from repro.zoned.faults import FaultSpec, SlowWindow, StallWindow

    def db_factory(scheme, ssd_zones):
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        db = DB(scheme, sc)
        n = sc.paper_keys // (4 * KEY_DIV)
        run_load(db, n_keys=n)
        db.flush_all()
        db.n_keys = n
        return db

    # closed-loop probe anchors the offered rate (see bench_scenarios)
    probe = db_factory("B3", 20)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc = max(pr.throughput, 1e-6)
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"],
        workloads=[spec],
        arrivals=[PoissonArrivals(0.5 * svc)],
        faults=[
            FaultSpec(name="stall+slow",
                      stalls=(StallWindow(at=300.0, duration=60.0,
                                          device="ssd"),),
                      slows=(SlowWindow(at=600.0, duration=120.0,
                                        factor=4.0, device="hdd"),)),
            # recovery-time SLO: the measured PR-3 downtime was 0.43-0.60s,
            # so a 5s budget is a meaningful (not vacuous) gate on the
            # WAL-replay path staying fast
            FaultSpec(name="crash", crash_at=450.0, recovery_slo_s=5.0),
        ],
        ssd_zone_budgets=[20],
        duration=900.0, warmup=60.0,
        db_factory=db_factory)
    data = matrix.run()
    _merge_scenarios(data, replaces=lambda r: "fault" in r
                     and "tenant" not in r)
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "faults.json", strict=True)
    (RESULTS / "faults.json").write_text(json.dumps(data, indent=1))
    rows = []
    for r in data:
        crash = r.get("crash") or {}
        stall = r.get("stall_p") or {}
        rslo = ""
        if "recovery_slo_s" in r:
            rslo = (f";rslo={r['recovery_slo_s']:g}s"
                    f";rslo_met={r['recovery_slo_met']}")
        rows.append(_row(
            f"faults_{r['cell']}",
            r["latency_p"]["p99"] * 1e6,
            f"avail={r['availability']:.4f}"
            f";p99={r['latency_p']['p99']*1e3:.1f}ms"
            + (f";stall_p99={stall['p99']*1e3:.1f}ms" if stall else "")
            + (f";downtime={crash['downtime']:.2f}s"
               f";replayed={int(crash['replayed_records'])}"
               f";lost={int(crash['lost_in_flight'])}" if crash else "")
            + rslo))
    return rows


def bench_sharding() -> List[str]:
    """Sharded-cluster experiment family (``repro.cluster``): scaling,
    rebalancing, and per-shard fault isolation, through the same sweep
    driver as every other scenario family.

    Three legs, all on HHZS at one SSD budget per shard:

    * **scaling** — a near-uniform 50/50 mix offered at ~8x one store's
      service capacity, on 1/2/4 hash-routed shards.  The 1-shard cell
      collapses under unbounded queueing (write stalls compound the
      overload), 2 shards absorb roughly twice one store's capacity,
      4 shards meet the offered stream — each added shard brings its
      own devices, so completed throughput climbs near-linearly in
      shard count relative to one store's standalone capacity.
    * **skew** — a drifting-hotspot workload (contiguous hot key range
      walking the keyspace in four dwell phases, ``dist="hotspot"``) on
      4 range-routed shards, static vs. the telemetry-driven rebalancer,
      offered past one shard's capacity.  Static routing pins the hot
      range to one shard (its queue is the bottleneck); the rebalancer
      detects the hot shard from the metrics bus and splits the sqrt-
      quantile head of its hottest segment — half the traffic, a cheap
      copy — to the coldest shard, charged in virtual time.  Asserts
      rebalancing >= static throughput.
    * **fault** — kill shard 1 of a 2-shard range-routed cluster mid-run
      (``FaultSpec.crash_shard``): the crashed shard replays its WAL and
      recovers while the other keeps serving.  Asserts availability < 1
      only on the killed shard (per-shard sub-rows).

    Rows publish to ``results/storage/sharding.json`` and merge into
    scenarios.json (aggregate rows carry ``shards``/``routing``/
    ``rebalance``/``kv_calls``/``shard_ops``; per-shard sub-rows carry
    ``shard``); rendered by ``benchmarks.report.sharding_table``."""
    from repro.workloads import PoissonArrivals, ScenarioMatrix
    from repro.workloads.sweep import GridDBFactory, run_sweep
    from repro.zoned.faults import FaultSpec

    # The sharding family runs on the 1/16-keyspace grid: at the full
    # keyspace the closed-loop probe is dominated by cold reads (a few
    # ops/s) while a shard serving a cached hot range is orders of
    # magnitude faster, so probe-anchored offered rates can't straddle
    # per-shard capacity.  At key_div=16 the probe and the per-shard
    # open-loop capacity are within a small factor and the multipliers
    # below land where they were calibrated: 1-2 shards saturated in
    # the scaling leg, the hot shard (and only it) overloaded in the
    # skew leg.
    sh_key_div = 16
    factory = GridDBFactory(key_div=sh_key_div, load_div=8,
                            rebalance_period=10.0)
    # closed-loop probe anchors the offered rates (see bench_scenarios)
    probe = factory("HHZS", 20)
    n_keys = probe.n_keys
    uni = WorkloadSpec("shmix", read=0.5, update=0.5, alpha=0.01)
    pr = run_workload(probe, uni, n_ops=2000, n_keys=n_keys)
    svc = max(pr.throughput, 1e-6)

    common = dict(schemes=["HHZS"], ssd_zone_budgets=[20],
                  duration=400.0, warmup=40.0,
                  key_div=sh_key_div, db_factory=factory)
    # (a) scaling: near-uniform load offered well past one store's
    # capacity (the open-loop pool serves ~1.6x the closed-loop probe,
    # and halved shards serve superlinearly — smaller trees), so the 1-
    # and 2-shard cells saturate and 4 shards approach the stream
    scaling = ScenarioMatrix(
        workloads=[uni],
        arrivals=[PoissonArrivals(round(8.0 * svc, 4))],
        shards=[1, 2, 4], routing="hash", **common)
    # (b) skew: drifting hot range offered at 9x the probe — past one
    # shard's open-loop capacity, so static range routing queues up on
    # the hot shard while the rebalancer sheds half the hot traffic.
    # Four dwell phases (the hot base advances a quarter keyspace every
    # rate*duration/4 ops) so each phase outlives the 10 s rebalance
    # period by an order of magnitude.
    skew_rate = round(9.0 * svc, 4)
    hot = WorkloadSpec("shhot", read=0.5, update=0.5, alpha=0.99,
                       dist="hotspot",
                       hotspot_period=int(skew_rate * 400.0 / 4),
                       hotspot_step=n_keys // 4)
    skew = ScenarioMatrix(
        workloads=[hot],
        arrivals=[PoissonArrivals(skew_rate)],
        shards=[4], routing="range", rebalance=[False, True], **common)
    # (c) fault: kill shard 1 mid-run; shard 0 must keep serving (rate
    # puts each shard near capacity so the crash catches a real queue
    # of in-flight ops — the killed shard's availability dips below 1,
    # the survivor's must not)
    fault = ScenarioMatrix(
        workloads=[uni],
        arrivals=[PoissonArrivals(round(4.0 * svc, 4))],
        shards=[2], routing="range",
        faults=[FaultSpec(name="crash-s1", crash_at=200.0, crash_shard=1,
                          recovery_slo_s=10.0)],
        **common)

    data: List[dict] = []
    for m in (scaling, skew, fault):
        data += run_sweep(m, out=None, workers=2, resume=False,
                          verbose=False)
    _merge_scenarios(data, replaces=lambda r: "shards" in r or "shard" in r
                     or r.get("workload") in ("shmix", "shhot"))
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "sharding.json", strict=True)
    (RESULTS / "sharding.json").write_text(json.dumps(data, indent=1))

    aggs = {r["cell"]: r for r in data if "shard" not in r}
    subs = [r for r in data if "shard" in r]
    rows = []
    for r in aggs.values():
        ops = r.get("shard_ops") or {}
        dist = "/".join(str(ops[k]) for k in sorted(ops, key=int))
        rows.append(_row(
            f"sharding_{r['cell']}",
            r["latency_p"]["p99"] * 1e6,
            f"thpt={r['throughput']:.1f}/s"
            f";p99={r['latency_p']['p99']*1e3:.1f}ms"
            + (f";avail={r['availability']:.4f}" if "availability" in r
               else "")
            + (f";splits={len(r.get('splits') or [])}"
               f";ops={dist}" if ops else "")))

    # scaling: throughput must climb with shard count (1-shard saturated)
    thpt = {r.get("shards", 1): r["throughput"]
            for r in aggs.values() if r.get("workload") == "shmix"
            and "fault" not in r}
    rows.append(_row(
        "sharding_scaling", 0.0,
        ";".join(f"x{n}={thpt[n]/thpt[1]:.2f}" for n in sorted(thpt))))
    if not (thpt.get(2, 0) > 1.5 * thpt[1]
            and thpt.get(4, 0) > 1.3 * thpt.get(2, 0)):
        raise RuntimeError(f"sharding acceptance violated: throughput "
                           f"does not scale with shard count: {thpt}")
    # skew: the rebalancer must not lose to static routing
    skew_t = {bool(r.get("rebalance")): r["throughput"]
              for r in aggs.values() if r.get("workload") == "shhot"}
    rows.append(_row(
        "sharding_rebalance_vs_static", 0.0,
        f"static={skew_t.get(False, 0):.1f}/s"
        f";rebalance={skew_t.get(True, 0):.1f}/s"
        f";x={skew_t.get(True, 0)/max(skew_t.get(False, 1e-9), 1e-9):.3f}"))
    if skew_t.get(True, 0) < skew_t.get(False, 0):
        raise RuntimeError(
            f"sharding acceptance violated: rebalancing "
            f"({skew_t.get(True)}) lost to static routing "
            f"({skew_t.get(False)}) under hot-key skew")
    # fault: availability < 1 only on the killed shard's key range
    for s in subs:
        if "crash-s1" not in s["cell"]:
            continue
        if s["shard"] != 1 and s["availability"] < 1.0:
            raise RuntimeError(
                f"sharding acceptance violated: healthy shard "
                f"{s['shard']} lost ops (availability="
                f"{s['availability']:.4f}) in {s['cell']}")
        rows.append(_row(
            f"sharding_shard{s['shard']}_avail", 0.0,
            f"avail={s['availability']:.4f};kv_ops={s['kv_ops']}"))
    return rows


def bench_control() -> List[str]:
    """SLO-attainment experiment: the compaction-debt control plane vs the
    static PR-2 admission policies (closes the ROADMAP "smarter admission"
    item).

    A protected tenant ("prot", mixed read/write, Poisson at 0.25x the
    probe's service capacity, sojourn-p99 SLO target anchored to the
    probe's measured closed-loop tail) shares each store with a bulk
    tenant running the same 50/50 mix at 1.2x capacity — its update half
    is the compaction-debt driver, its read half makes every queued op
    expensive, and the combined ~1.45x utilization grows the shared queue
    whenever bulk is not shed.  The pool is sized to the probe (16
    servers = 16 probe clients), making the probe's closed-loop
    throughput the pool's actual capacity.  Policies compared per scheme:

      reject         PR-2 reject-at-pressure (WAL stalls + backlog only)
      token_bucket   PR-2 static per-tenant budget at bulk's nominal rate
      reject+debt    reject-at-pressure with compaction debt as the third
                     pressure signal (sheds while debt builds, before
                     write stalls)
      feedback       debt-aware AIMD feedback: bulk's token-bucket rate is
                     driven by prot's measured p99 vs its SLO target and
                     by the debt threshold (repro.obs.control.ControlPlane)

    Control plane v2 adds a PI-vs-AIMD x knob-set ablation on the same
    cells:

      pi             the feedback loop under the PI law (anti-windup,
                     EWMA-smoothed measurement, per-tenant debt-share
                     bias) — admission knob only, isolating the law
      aimd+knobs     AIMD law driving the full knob set: admission +
                     SILK-style compaction pacing + migration
                     aggressiveness + hinted-cache zone budget
      pi+knobs       the PI law over the full knob set — the headline
                     v2 configuration

    The headline: feedback's protected-tenant p99 is below both static
    policies at equal-or-better total goodput (ops/s completing within
    their tenant's SLO target), and the v2 full-knob controller beats
    admission-only ``feedback`` on *both* protected p99 and total
    goodput.  Every cell runs with the telemetry bus live and dumps a
    debt/occupancy/attainment/knob-trajectory timeline into
    ``results/storage/timelines/``; rows merge into scenarios.json and
    ``control.json``, rendered by ``benchmarks/report.py``.
    """
    from repro.core.middleware import AdmissionConfig
    from repro.workloads import PoissonArrivals, ScenarioMatrix, TenantSpec

    def db_factory(scheme, ssd_zones):
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        db = DB(scheme, sc)
        n = sc.paper_keys // (4 * KEY_DIV)
        run_load(db, n_keys=n)
        db.flush_all()
        db.n_keys = n
        return db

    # closed-loop probe anchors offered rates, SLO targets and the debt
    # threshold (deterministic, so cells are reproducible)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    bspec = WorkloadSpec("bulkmix", read=0.5, update=0.5, alpha=0.9)
    probe = db_factory("B3", 20)
    pr_mix = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc_mix = max(pr_mix.throughput, 1e-6)
    # SLO target: 1.5x the probe's closed-loop p99 — feasible whenever the
    # shared queue stays short, hopeless behind a deep admission backlog
    # (a queue pinned at reject's threshold alone costs ~threshold/svc
    # seconds, well past the target)
    slo_prot = round(1.5 * pr_mix.latency_p["p99"], 4)
    # bulk's own target is 1.5x the protected one — lax but real (2x its
    # service-time tail): goodput must not credit ops crammed through a
    # 30-second admission queue
    slo_bulk = round(1.5 * slo_prot, 4)
    # debt threshold: above the standing post-load compaction backlog, so
    # it fires on *growth* under the bulk tenant's update stream
    debt0 = float(probe.tree.compaction_debt())
    debt_th = round(1.5 * debt0 + 256 * MiB / SCALE, 1)
    bulk_rate = round(1.2 * svc_mix, 4)
    mix = [
        TenantSpec("prot", spec, PoissonArrivals(round(0.25 * svc_mix, 4)),
                   protected=True, slo_p99=slo_prot),
        TenantSpec("bulk", bspec, PoissonArrivals(bulk_rate),
                   slo_p99=slo_bulk),
    ]
    bucket = {"bulk": (bulk_rate, 20.0)}
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"], workloads=[], arrivals=[],
        tenants=[mix],
        policies=[
            AdmissionConfig(policy="reject", queue_threshold=96),
            AdmissionConfig(policy="token_bucket", bucket_rates=bucket),
            AdmissionConfig(policy="reject", queue_threshold=96,
                            debt_threshold=debt_th, label="reject+debt"),
            # feedback: fast control period, short p99 window (a stale
            # window holds MD long after the queue drains — windup), a
            # tight internal queue trigger (the plane's fast signal), and
            # a gentle additive step so probing back up does not re-spike
            # the queue
            AdmissionConfig(policy="feedback", bucket_rates=bucket,
                            debt_threshold=debt_th, label="feedback",
                            queue_threshold=8, feedback_interval=2.5,
                            feedback_window=60, feedback_increase=0.04),
            # v2 ablation: law x knob set.  PI gains tuned on these
            # cells: high gains (kp=2, ki=0.5, unsmoothed) cut the bulk
            # rate to the floor within ~2 control periods of a transient
            # — the protected tail is set by how fast the overload is
            # cut — while the asymmetric rise limit (0.08/period, ~2x
            # AIMD's additive step) keeps one good p99 window from
            # re-admitting a full burst
            AdmissionConfig(policy="feedback", bucket_rates=bucket,
                            debt_threshold=debt_th, label="pi",
                            queue_threshold=8, feedback_interval=2.5,
                            feedback_window=60,
                            feedback_controller="pi",
                            feedback_kp=2.0, feedback_ki=0.5,
                            feedback_smooth=1.0, feedback_rise=0.08),
            AdmissionConfig(policy="feedback", bucket_rates=bucket,
                            debt_threshold=debt_th, label="aimd+knobs",
                            queue_threshold=8, feedback_interval=2.5,
                            feedback_window=60, feedback_increase=0.04,
                            feedback_knobs=("admission", "compaction",
                                            "migration", "cache")),
            AdmissionConfig(policy="feedback", bucket_rates=bucket,
                            debt_threshold=debt_th, label="pi+knobs",
                            queue_threshold=8, feedback_interval=2.5,
                            feedback_window=60,
                            feedback_controller="pi",
                            feedback_kp=2.0, feedback_ki=0.5,
                            feedback_smooth=1.0, feedback_rise=0.08,
                            feedback_knobs=("admission", "compaction",
                                            "migration", "cache")),
        ],
        ssd_zone_budgets=[20],
        duration=900.0, warmup=90.0,
        # 16 servers to match the 16-client probe: the probe's closed-loop
        # throughput is then the pool's actual service capacity, so the
        # 1.2x combined offered load genuinely overloads the store
        max_concurrency=16,
        db_factory=db_factory,
        telemetry=True, timeline_dir=RESULTS / "timelines")
    data = matrix.run()
    _merge_scenarios(data, replaces=lambda r: r.get("tenant")
                     in ("prot", "bulk"))
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "control.json", strict=True)
    (RESULTS / "control.json").write_text(json.dumps(data, indent=1))
    rows = []
    prot_p99: Dict = {}
    goodput: Dict = {}
    for r in data:
        key = (r["scheme"], r["policy"])
        goodput[key] = goodput.get(key, 0.0) + r["goodput"]
        if r["tenant"] == "prot":
            prot_p99[key] = r["latency_p"]["p99"]
        a = r["admission"]
        rows.append(_row(
            f"control_{r['cell']}_{r['tenant']}",
            r["latency_p"]["p99"] * 1e6,
            f"offered={r['offered_rate']:.1f}/s"
            f";admitted={int(a['admitted'])}"
            f";shed={int(a['rejected'])}"
            f";p99={r['latency_p']['p99']*1e3:.1f}ms"
            f";slo={r['slo_p99']*1e3:.1f}ms"
            f";met={r['slo_met']}"
            f";goodput={r['goodput']:.1f}/s"))
    for scheme in ("B3", "HHZS"):
        fb = (scheme, "feedback")
        for base in ("reject", "token_bucket", "reject+debt"):
            k = (scheme, base)
            if fb in prot_p99 and k in prot_p99:
                rows.append(_row(
                    f"control_{scheme}_feedback_vs_{base}", 0.0,
                    f"p99x={prot_p99[fb]/max(prot_p99[k], 1e-12):.3f}"
                    f";goodputx={goodput[fb]/max(goodput[k], 1e-12):.3f}"))
        # the v2 ablation rows, each vs the admission-only AIMD baseline
        # (<1.0 p99x and >1.0 goodputx = strictly better on both axes)
        for v2 in ("pi", "aimd+knobs", "pi+knobs"):
            k = (scheme, v2)
            if fb in prot_p99 and k in prot_p99:
                rows.append(_row(
                    f"control_{scheme}_{v2}_vs_feedback", 0.0,
                    f"p99x={prot_p99[k]/max(prot_p99[fb], 1e-12):.3f}"
                    f";goodputx={goodput[k]/max(goodput[fb], 1e-12):.3f}"))
    return rows


def bench_serving() -> List[str]:
    """LLM KV-cache serving grid: tiering policy x arrival process x HBM
    pool size, all through the same sweep driver as the storage cells.

    Each cell replays an open-loop chat trace (lognormal prompt/output
    lengths, pause/resume churn) against a paged KV cache split across an
    HBM pool and a host pool, under one of three placement policies:
    ``static`` (HBM-only, reject what doesn't fit), ``lru`` (hint-blind
    paging) and ``hhzs`` (the paper's write-guided placement + cold-only
    migration + eviction-driven prefix caching, transplanted to the
    KV-cache tiering problem).  Rows publish to
    ``results/storage/serving.json`` and merge into scenarios.json; the
    bench asserts the paper's claim at serving granularity — in *every*
    cell the hinted policy beats hint-blind LRU on decode p99 or HBM hit
    rate."""
    from repro.workloads.serving import build_serving_grid
    from repro.workloads.sweep import run_sweep

    matrix = build_serving_grid(
        policies=("static", "lru", "hhzs"),
        arrival_kinds=("poisson", "bursty"),
        hbm_zones=(10, 16),
        rate=2.5, duration=400.0, warmup=40.0, seed=1,
        telemetry=True, timeline_dir=RESULTS / "timelines")
    data = run_sweep(matrix, out=None, workers=2, resume=False,
                     verbose=False)
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "serving.json", strict=True)
    (RESULTS / "serving.json").write_text(json.dumps(data, indent=1))
    _merge_scenarios(data, replaces=lambda r: "tiering" in r)

    by_cell: Dict = {}
    for r in data:
        key = (r["workload"], r["arrival"], r["hbm_zones"])
        by_cell.setdefault(key, {})[r["tiering"]] = r
    rows = []
    for r in data:
        rows.append(_row(
            f"serving_{r['cell']}",
            r["decode_p"]["p99"] * 1e6,
            f"offered={r['offered_rate']:.2f}/s"
            f";admitted={int(r['admitted'])}"
            f";shed={int(r['rejected'])}"
            f";ttft_p99={r['ttft_p']['p99']:.2f}s"
            f";decode_p99={r['decode_p']['p99']*1e3:.2f}ms"
            f";hbm_hit={r['hbm_hit_rate']:.3f}"
            f";migrated_mb={r['migrated_bytes']/MiB:.1f}"
            f";stalls={int(r['preempt_stalls'])}"))
    for key, pol in sorted(by_cell.items()):
        if "hhzs" not in pol or "lru" not in pol:
            continue
        h, l = pol["hhzs"], pol["lru"]
        wins_p99 = h["decode_p"]["p99"] < l["decode_p"]["p99"]
        wins_hit = h["hbm_hit_rate"] > l["hbm_hit_rate"]
        rows.append(_row(
            f"serving_hinted_vs_lru_{key[1].split('(')[0]}_h{key[2]}", 0.0,
            f"decode_p99x="
            f"{h['decode_p']['p99']/max(l['decode_p']['p99'], 1e-12):.3f}"
            f";hitx={h['hbm_hit_rate']/max(l['hbm_hit_rate'], 1e-12):.3f}"
            f";migratedx={h['migrated_bytes']/max(l['migrated_bytes'], 1):.3f}"
            f";win={'p99' if wins_p99 else 'hit' if wins_hit else 'NONE'}"))
        if not (wins_p99 or wins_hit):
            raise RuntimeError(
                f"serving acceptance violated in cell {key}: hinted hhzs "
                f"beats LRU on neither decode p99 "
                f"({h['decode_p']['p99']:.4f} vs {l['decode_p']['p99']:.4f})"
                f" nor HBM hit rate ({h['hbm_hit_rate']:.3f} vs "
                f"{l['hbm_hit_rate']:.3f})")
    return rows


def bench_drift() -> List[str]:
    """Phase-programmed drift traces: non-stationary workloads with
    per-phase scheme rankings (closes the ROADMAP drift item).

    Two ``TraceProgram``\\ s x two arrival shapes, each run on four
    schemes (B3, B3+M, AUTO, HHZS — basic, basic+migration, the SpanDB
    baseline, the full system) through the sweep driver:

    * **rotate** — a single tenant whose key chooser rotates every
      phase: skewed reads -> virtual-time hotspot walk -> scan-burst
      analytics -> working-set growth (``latest`` inserts into a 1.5x
      keyspace).  Stresses the §3.4-3.5 popularity/capacity migration
      under drift: hinted placement that paid off in one phase can be
      wrong in the next.
    * **churn** — a persistent read-heavy tenant plus a write/scan batch
      tenant that arrives for the middle phase and departs (queued ops
      dropped at the boundary, in-service ops drain against
      ``drain_s``).

    Every per-tenant row carries per-phase metric windows (``phases``)
    and, attached here after the sweep, the run-level ``rank_flips``
    count — how many phase boundaries changed the cross-scheme
    throughput ordering.  Rows publish to ``results/storage/drift.json``
    and merge into scenarios.json; ``benchmarks.report.drift_table``
    renders the per-phase pivot and highlights the windows where a
    baseline out-ranks HHZS.  The determinism contract (same program ->
    byte-identical rows for any worker count / telemetry setting) is
    enforced by the CI grid-smoke drift leg, not here."""
    from repro.workloads import ScenarioMatrix
    from repro.workloads.drift import build_program, phase_rankings
    from repro.workloads.sweep import GridDBFactory, run_sweep

    factory = GridDBFactory(key_div=KEY_DIV, load_div=8)
    # closed-loop probe anchors every program's offered rates (see
    # bench_scenarios); seeded, so programs are reproducible
    probe = factory("B3", 20)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys)
    svc = max(pr.throughput, 1e-6)
    phase_s = 150.0
    progs = [build_program(name, svc=round(svc, 4), n_keys=probe.n_keys,
                           arrival_kind=kind, phase_s=phase_s)
             for name in ("rotate", "churn")
             for kind in ("poisson", "bursty")]
    matrix = ScenarioMatrix(
        schemes=["B3", "B3+M", "AUTO", "HHZS"],
        workloads=[], arrivals=[],
        drift_programs=progs,
        ssd_zone_budgets=[20],
        warmup=15.0,
        key_div=KEY_DIV, db_factory=factory,
        telemetry=True, timeline_dir=RESULTS / "timelines")
    data = run_sweep(matrix, out=None, workers=2, resume=False,
                     verbose=False)
    # run-level rank-flip summary: cross-scheme, so it exists only after
    # the whole sweep (raw sweep rows stay comparable across worker
    # counts; the published family carries the summary)
    rankings = phase_rankings(data)
    for r in data:
        key = (r["drift"], r.get("arrival"), r.get("tenant"),
               r.get("ssd_zones"))
        if key in rankings:
            r["rank_flips"] = rankings[key]["flips"]
    from benchmarks.validate_results import validate_rows
    validate_rows(data, "drift.json", strict=True)
    (RESULTS / "drift.json").write_text(json.dumps(data, indent=1))
    _merge_scenarios(data, replaces=lambda r: "drift" in r)

    rows = []
    for r in data:
        per_phase = ";".join(
            f"{p['name']}={p['throughput']:.1f}/s" for p in r["phases"])
        rows.append(_row(
            f"drift_{r['cell']}_{r['tenant']}",
            r["latency_p"]["p99"] * 1e6,
            f"offered={r['offered_rate']:.1f}/s"
            f";thpt={r['throughput']:.1f}/s"
            f";dropped={r['dropped']}"
            f";drain_viol={r['drain_violations']}"
            f";flips={r.get('rank_flips', 0)}"
            f";{per_phase}"))
    # acceptance probe: count the (group x phase) windows where a
    # baseline out-ranks HHZS.  Not a hard gate — a zero count is a
    # legitimate finding, documented in docs/ARCHITECTURE.md — but the
    # count is recorded so the report and the docs can't drift apart.
    outranked = 0
    for key, g in rankings.items():
        for p in g["phases"]:
            if p["ranking"] and p["ranking"][0] != "HHZS":
                outranked += 1
    rows.append(_row(
        "drift_hhzs_outranked_windows", 0.0,
        f"windows={outranked}"
        f";flips_total={sum(g['flips'] for g in rankings.values())}"))
    return rows


ALL = {
    "table1": bench_table1,
    "fig2": bench_fig2,
    "exp1": bench_exp1,
    "exp2": bench_exp2,
    "exp3": bench_exp3,
    "exp4": bench_exp4,
    "exp5": bench_exp5,
    "exp6": bench_exp6,
    "scenarios": bench_scenarios,
    "filters": bench_filter_sweep,
    "multitenant": bench_multitenant,
    "faults": bench_faults,
    "sharding": bench_sharding,
    "control": bench_control,
    "serving": bench_serving,
    "drift": bench_drift,
}


def _rows_from_json(name: str, data) -> List[str]:
    """Flatten a saved experiment JSON into CSV rows (cache hit path)."""
    rows = []

    def walk(prefix, node):
        if isinstance(node, dict):
            for k, v in node.items():
                walk(f"{prefix}_{k}", v)
        elif isinstance(node, list):
            for i, v in enumerate(node):
                walk(f"{prefix}_{i}", v)
        elif isinstance(node, (int, float)):
            rows.append(_row(f"{name}{prefix}", 0.0, f"{node:.4g}"))
        else:
            rows.append(_row(f"{name}{prefix}", 0.0, str(node)))

    walk("", data)
    return rows


def run(which: Optional[List[str]] = None) -> List[str]:
    RESULTS.mkdir(parents=True, exist_ok=True)
    rows = []
    for name, fn in ALL.items():
        if which and name not in which:
            continue
        cached = RESULTS / f"{name}.json"
        if cached.exists():
            rows.extend(_rows_from_json(name, json.loads(cached.read_text())))
            rows.append(_row(f"{name}_wall", 0.0, "cached(results/storage)"))
            print(f"[storage] {name} cached", flush=True)
            continue
        t0 = time.time()
        rows.extend(fn())
        rows.append(_row(f"{name}_wall", (time.time() - t0) * 1e6, "bench"))
        print(f"[storage] {name} done in {time.time()-t0:.0f}s", flush=True)
    return rows
