"""§Perf hillclimbing lab: lower cell variants, compare roofline terms.

Each variant is a (name, ParallelConfig, kwargs) tuple; results append to
results/perf/<cell>.json so EXPERIMENTS.md §Perf can show the full
hypothesis -> change -> before/after log.

  PYTHONPATH=src python -m benchmarks.perf_lab --cell minitron-4b/train_4k
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
from pathlib import Path

from repro.config import ParallelConfig
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

VARIANTS = {
    "baseline": lambda: None,   # dryrun defaults
    "no_seqshard_accum4": lambda: ParallelConfig(
        seq_shard_activations=False, grad_accum=4),
    "no_seqshard_accum8": lambda: ParallelConfig(
        seq_shard_activations=False, grad_accum=8),
    "seqshard_accum2": lambda: ParallelConfig(grad_accum=2),
    "seqshard_accum4": lambda: ParallelConfig(grad_accum=4),
    "no_remat_accum4": lambda: ParallelConfig(
        seq_shard_activations=False, grad_accum=4, remat=False),
    # kernel-substituted variants (see repro.models.layers.STUB_KERNELS)
    "kernel_attn": lambda: _with_stubs(
        ParallelConfig(seq_shard_activations=False, grad_accum=4),
        attention=True),
    "kernel_attn_seqshard": lambda: _with_stubs(ParallelConfig(),
                                                attention=True),
    "kernel_ssm": lambda: _with_stubs(ParallelConfig(), ssm=True),
    "kernel_ssm_accum2": lambda: _with_stubs(ParallelConfig(grad_accum=2),
                                             ssm=True),
    "kernel_attn_accum2": lambda: _with_stubs(ParallelConfig(grad_accum=2),
                                              attention=True),
    "kernel_attn_ssm": lambda: _with_stubs(ParallelConfig(),
                                           attention=True, ssm=True),
    # no tensor parallelism: the model axis joins data parallelism
    "dp_only": lambda: _dp_only(ParallelConfig(
        seq_shard_activations=False)),
    "dp_only_kernel_attn": lambda: _dp_only(_with_stubs(
        ParallelConfig(seq_shard_activations=False), attention=True)),
}


def _dp_only(parallel):
    import repro.sharding as SH
    SH.MODE = "dp_only"
    return parallel


def _with_stubs(parallel, attention=False, ssm=False):
    from repro.models import layers as L
    L.STUB_KERNELS["attention"] = attention
    L.STUB_KERNELS["ssm"] = ssm
    return parallel


def run(cell: str, variants, out_dir="results/perf"):
    arch, shape = cell.split("/")
    mesh = make_production_mesh()
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{arch}__{shape}.json"
    log = json.loads(path.read_text()) if path.exists() else []
    done = {e["variant"] for e in log}
    for name in variants:
        if name in done:
            print(f"[cached] {name}")
            continue
        from repro.models import layers as L
        import repro.sharding as SH
        L.STUB_KERNELS["attention"] = False
        L.STUB_KERNELS["ssm"] = False
        SH.MODE = "2d"
        parallel = VARIANTS[name]()
        print(f"[variant] {name} ...", flush=True)
        try:
            rec = lower_cell(arch, shape, mesh, "single",
                             parallel=parallel, extra_tag=name)
        except Exception as e:
            print(f"  ERROR {e}")
            log.append({"variant": name, "status": "error",
                        "error": str(e)[:500]})
            path.write_text(json.dumps(log, indent=1))
            continue
        rl = rec["roofline"]
        entry = {"variant": name, "status": rec["status"],
                 "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
                 "collective_s": rl["collective_s"],
                 "dominant": rl["dominant"], "mfu": rl["mfu"],
                 "temp_gib": rec["temp_bytes"] / 2**30,
                 "arg_gib": rec["argument_bytes"] / 2**30,
                 "collectives_by_op": rec["collectives_by_op"]}
        log.append(entry)
        path.write_text(json.dumps(log, indent=1))
        print(f"  comp={rl['compute_s']:.2f} mem={rl['memory_s']:.2f} "
              f"coll={rl['collective_s']:.2f} dom={rl['dominant']} "
              f"mfu={rl['mfu']:.3f} temp={entry['temp_gib']:.1f}GiB")
    return log


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True)
    ap.add_argument("--variants", default="baseline,no_seqshard_accum4")
    args = ap.parse_args()
    run(args.cell, args.variants.split(","))
