"""Bench-trend tracker: append canary results to a committed trajectory.

The CI ``bench-trend`` job feeds this module the CSVs produced by the
``bench-canary`` job (``sim_speed.csv`` / ``read_path.csv``) plus the
published control-plane rows, and it appends one entry to
``results/bench_trajectory.json``::

    {"kind": "bench_trajectory",
     "entries": [{"git_sha": ..., "date": ...,
                  "sim_speed_geomean": ..., "read_path_speedup": ...,
                  "control_p99_ratio": ...,
                  "drift_worst_phase_ratio": ...}, ...]}

* ``sim_speed_geomean`` — DES-kernel speedup vs the frozen seed kernel
  (geomean over scales), parsed from the ``sim_speed_geomean,,,X.XXx``
  marker row of ``benchmarks/sim_speed.py``.
* ``read_path_speedup`` — batched vs per-key read path, parsed from the
  ``read_path_speedup,,,X.XXx`` marker row of
  ``benchmarks/read_path_bench.py``.
* ``control_p99_ratio`` — control-plane quality: best-controller
  protected-tenant p99 divided by the open-loop ``reject`` baseline's
  on B3, from ``results/storage/control.json`` (lower is better; null
  when the bench artifact is absent, e.g. on PR CI which does not run
  the 900 s control bench).
* ``drift_worst_phase_ratio`` — non-stationary robustness: across the
  published drift rows (``results/storage/drift.json``), the *worst*
  per-phase ratio of the best baseline's in-window sojourn p99 to the
  paper scheme's (HHZS) in the same (program, arrival, tenant, zones,
  phase) window (>= 1 means HHZS holds the lowest tail in every phase;
  null when the drift bench has not been published).

**Trend gate:** the append *fails* (exit 1) when the new sim-speed
geomean regresses more than ``--regression`` (default 20%) below the
best of the last ``--window`` (default 5) committed entries — a slow
drift across several PRs trips it even when each individual PR passes
the absolute ``--target`` floor of the canary itself.

The artifact is linted with ``benchmarks.validate_results`` before every
write; same-sha re-runs replace their old entry (idempotent).
"""
from __future__ import annotations

import argparse
import datetime
import json
import re
import subprocess
import sys
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.validate_results import validate_trajectory

_MARKER = re.compile(r"^(?P<key>[a-z_]+),,,(?P<val>[0-9.]+)x\s*$")


def parse_marker_csv(path: Path, key: str) -> float:
    """Extract the ``key,,,X.XXx`` summary row from a canary CSV."""
    for line in path.read_text().splitlines():
        m = _MARKER.match(line.strip())
        if m and m.group("key") == key:
            return float(m.group("val"))
    raise ValueError(f"{path}: no '{key},,,<X.XX>x' marker row")


def control_p99_ratio(path: Path, scheme: str = "B3") -> Optional[float]:
    """Best-controller prot p99 / open-loop ``reject`` prot p99.

    Reads the published multi-tenant rows of ``bench_control`` and takes
    the best (lowest) protected-tenant p99 across the feedback-family
    policies, normalised by the ``reject`` baseline on the same scheme.
    Returns ``None`` when the artifact (or either row) is missing, so
    PR CI — which never runs the 900 s control bench — records null.
    """
    if not path.exists():
        return None
    rows = json.loads(path.read_text())
    p99: Dict[str, float] = {}
    for r in rows:
        if (r.get("scheme") == scheme and r.get("tenant") == "prot"
                and r.get("latency_p")):
            p99[r.get("policy")] = r["latency_p"]["p99"]
    controllers = [v for k, v in p99.items()
                   if k in ("feedback", "pi", "aimd+knobs", "pi+knobs")]
    if not controllers or "reject" not in p99:
        return None
    return round(min(controllers) / p99["reject"], 4)


def drift_worst_phase_ratio(path: Path,
                            scheme: str = "HHZS") -> Optional[float]:
    """Worst per-phase tail ratio of the best baseline vs ``scheme``.

    Per-phase *throughput* is arrival-bound in the drift runs (every op
    scores in the phase it arrived in and the run drains), so the
    discriminating quantity is the in-window sojourn tail.  Groups the
    published drift rows by (program, arrival, tenant, zones) and within
    every phase window divides the best (lowest) competing scheme's
    ``latency_p99`` by the paper scheme's.  The minimum over all windows
    is the trend metric: >= 1 means HHZS holds the lowest tail in every
    phase; below 1 quantifies its worst non-stationary window.  Returns
    ``None`` when the artifact is absent or carries no comparable phase.
    """
    if not path.exists():
        return None
    rows = json.loads(path.read_text())
    groups: Dict[tuple, List[Dict]] = {}
    for r in rows:
        if "drift" in r and isinstance(r.get("phases"), list):
            key = (r["drift"], r.get("arrival"), r.get("tenant"),
                   r.get("ssd_zones"))
            groups.setdefault(key, []).append(r)
    worst = None
    for rs in groups.values():
        per_phase: Dict[int, Dict[str, float]] = {}
        for r in rs:
            for p in r["phases"]:
                if p.get("n_measured"):
                    per_phase.setdefault(p["phase"], {})[r["scheme"]] = \
                        p["latency_p99"]
        for vals in per_phase.values():
            if vals.get(scheme, 0) <= 0:
                continue
            rivals = [v for s, v in vals.items() if s != scheme and v > 0]
            if not rivals:
                continue
            ratio = min(rivals) / vals[scheme]
            if worst is None or ratio < worst:
                worst = ratio
    return None if worst is None else round(worst, 4)


def append_entry(traj_path: Path, entry: Dict, *, window: int = 5,
                 regression: float = 0.2) -> int:
    """Append ``entry``, enforce the trend gate, rewrite the artifact.

    Returns a process exit code: 0 on pass, 1 when the new sim-speed
    geomean is below ``(1 - regression) *`` the best geomean of the last
    ``window`` previously committed entries.  The entry is written
    either way — a failing run must still leave the data point in the
    artifact so the regression is visible in the committed history.
    """
    doc = {"kind": "bench_trajectory", "entries": []}
    if traj_path.exists():
        doc = json.loads(traj_path.read_text())
    entries: List[Dict] = [e for e in doc.get("entries", [])
                           if e.get("git_sha") != entry["git_sha"]]
    recent = entries[-window:]
    best = max((e["sim_speed_geomean"] for e in recent), default=None)
    entries.append(entry)
    doc = {"kind": "bench_trajectory", "entries": entries}
    validate_trajectory(doc, str(traj_path), strict=True)
    traj_path.parent.mkdir(parents=True, exist_ok=True)
    traj_path.write_text(json.dumps(doc, indent=1) + "\n")

    ok = True
    if best is not None:
        floor = (1.0 - regression) * best
        ok = entry["sim_speed_geomean"] >= floor
        print(f"[bench_trend] sim_speed_geomean {entry['sim_speed_geomean']:.2f}x "
              f"vs best-of-last-{len(recent)} {best:.2f}x "
              f"(floor {floor:.2f}x): {'ok' if ok else 'REGRESSION'}")
    else:
        print(f"[bench_trend] sim_speed_geomean "
              f"{entry['sim_speed_geomean']:.2f}x (first entry, no gate)")
    print(f"[bench_trend] {len(entries)} entries in {traj_path}")
    return 0 if ok else 1


def git_sha() -> str:
    try:
        return subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"],
            text=True, stderr=subprocess.DEVNULL).strip()
    except (OSError, subprocess.CalledProcessError):
        return "unknown"


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="append canary results to the bench trajectory and "
                    "gate on trend regressions")
    ap.add_argument("--sim-csv", default="sim_speed.csv",
                    help="CSV from benchmarks.sim_speed (tee'd in CI)")
    ap.add_argument("--read-csv", default="read_path.csv",
                    help="CSV from benchmarks.read_path_bench")
    ap.add_argument("--control", default="results/storage/control.json",
                    help="published bench_control rows (ratio is null "
                         "when absent)")
    ap.add_argument("--drift", default="results/storage/drift.json",
                    help="published bench_drift rows (ratio is null "
                         "when absent)")
    ap.add_argument("--out", default="results/bench_trajectory.json")
    ap.add_argument("--sha", default=None,
                    help="commit sha to record (default: git rev-parse)")
    ap.add_argument("--date", default=None,
                    help="ISO date to record (default: today, UTC)")
    ap.add_argument("--window", type=int, default=5,
                    help="trend window: compare vs best of last N entries")
    ap.add_argument("--regression", type=float, default=0.2,
                    help="allowed fractional drop vs the window best")
    args = ap.parse_args(argv)

    entry = {
        "git_sha": args.sha or git_sha(),
        "date": args.date or datetime.datetime.now(
            datetime.timezone.utc).date().isoformat(),
        "sim_speed_geomean": parse_marker_csv(Path(args.sim_csv),
                                              "sim_speed_geomean"),
        "read_path_speedup": parse_marker_csv(Path(args.read_csv),
                                              "read_path_speedup"),
        "control_p99_ratio": control_p99_ratio(Path(args.control)),
        "drift_worst_phase_ratio": drift_worst_phase_ratio(
            Path(args.drift)),
    }
    return append_entry(Path(args.out), entry, window=args.window,
                        regression=args.regression)


if __name__ == "__main__":
    sys.exit(main())
