"""Frozen copy of the SEED DES kernel (pre-optimization), used only by
benchmarks/sim_speed.py as the baseline for the speedup measurement.
Do not import from production code.

The storage substrate of the HHZS reproduction runs on virtual time: devices
are FIFO resources, foreground clients and background jobs (flush, compaction,
migration) are generator processes that ``yield`` events.  This keeps the
LSM-tree / HHZS logic an exact, inspectable reproduction of the paper's
control flow while producing throughput / latency numbers from the device
timing model (Table 1 of the paper).

Daemon events: periodic background pollers (migration ticks, AUTO's
throughput monitor) schedule *daemon* timeouts that do not keep ``run()``
alive — ``run()`` returns once only daemon events remain, i.e. when all real
work (client ops, flush/compaction/migration I/O) has settled.
"""
from __future__ import annotations

import heapq
from typing import Any, Callable, Generator, List, Optional, Tuple


class Event:
    """One-shot event; processes wait on it by ``yield``-ing it."""

    __slots__ = ("sim", "triggered", "value", "_waiters")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self._waiters: List[Callable[[Any], None]] = []

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        waiters, self._waiters = self._waiters, []
        for cb in waiters:
            cb(value)
        return self

    def add_callback(self, cb: Callable[[Any], None]) -> None:
        if self.triggered:
            cb(self.value)
        else:
            self._waiters.append(cb)


class Process(Event):
    """Drives a generator; the Process itself is an Event that fires on return."""

    __slots__ = ("gen",)

    def __init__(self, sim: "Sim", gen: Generator):
        super().__init__(sim)
        self.gen = gen
        sim._immediate(self._step, None)

    def _step(self, send_value: Any) -> None:
        try:
            ev = self.gen.send(send_value)
        except StopIteration as stop:
            self.succeed(getattr(stop, "value", None))
            return
        if not isinstance(ev, Event):
            raise TypeError(f"process yielded non-event: {ev!r}")
        ev.add_callback(self._step)


class Sim:
    """Event loop over virtual seconds."""

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[Tuple[float, int, bool, Callable[[], None]]] = []
        self._seq = 0
        self._live = 0  # non-daemon entries in the heap

    # -- scheduling -------------------------------------------------------
    def _push(self, at: float, fn: Callable[[], None], daemon: bool) -> None:
        self._seq += 1
        if not daemon:
            self._live += 1
        heapq.heappush(self._heap, (at, self._seq, daemon, fn))

    def _immediate(self, fn: Callable[[Any], None], value: Any) -> None:
        self._push(self.now, lambda: fn(value), daemon=False)

    def timeout(self, delay: float, value: Any = None,
                daemon: bool = False) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        ev = Event(self)
        self._push(self.now + delay, lambda: ev.succeed(value), daemon)
        return ev

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    # -- running ----------------------------------------------------------
    def _pop(self) -> Callable[[], None]:
        at, _, daemon, fn = heapq.heappop(self._heap)
        if not daemon:
            self._live -= 1
        self.now = at
        return fn

    def run(self, until: Optional[float] = None) -> None:
        """Run until no *non-daemon* work remains (or virtual ``until``)."""
        while self._heap and self._live > 0:
            at = self._heap[0][0]
            if until is not None and at > until:
                self.now = until
                return
            self._pop()()
        if until is not None:
            self.now = until

    def run_until(self, ev: Event) -> Any:
        """Run until ``ev`` triggers (used by the synchronous KV facade)."""
        daemon_only = 0
        while not ev.triggered:
            if not self._heap:
                raise RuntimeError("deadlock: event never triggers")
            if self._live == 0:
                daemon_only += 1
                if daemon_only > 1_000_000:
                    raise RuntimeError(
                        "livelock: only daemon events remain but the awaited "
                        "event never triggers")
            else:
                daemon_only = 0
            self._pop()()
        return ev.value


class Semaphore:
    """Counting semaphore for background job thread pools."""

    def __init__(self, sim: Sim, capacity: int):
        self.sim = sim
        self.capacity = capacity
        self.in_use = 0
        self._queue: List[Event] = []

    def acquire(self) -> Event:
        ev = self.sim.event()
        if self.in_use < self.capacity:
            self.in_use += 1
            ev.succeed()
        else:
            self._queue.append(ev)
        return ev

    def release(self) -> None:
        if self._queue:
            ev = self._queue.pop(0)
            ev.succeed()
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("semaphore released below zero")
