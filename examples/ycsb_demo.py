"""YCSB head-to-head: HHZS vs the best basic scheme vs SpanDB-AUTO.

A reduced version of Exp#1 (paper Fig.5): fresh load per scheme, then
workloads A and C.  Expect HHZS highest throughput, with the gap widest
on read-heavy workloads (migration + hinted cache).

Then an *open-loop* burst scenario: the same stores face on-off Poisson
arrivals whose burst rate exceeds the service rate.  Closed-loop clients
can never see this regime — the open-loop runner decomposes the resulting
tail latency into queueing delay vs service time per scheme.

  PYTHONPATH=src python examples/ycsb_demo.py
"""
from repro.lsm import DB, ScenarioConfig
from repro.workloads import (BurstyArrivals, YCSB, run_load, run_open_loop,
                             run_workload)


def _fresh(scheme, n):
    db = DB(scheme)
    load = run_load(db, n_keys=n)
    db.flush_all()
    return db, load


def main():
    n = ScenarioConfig().paper_keys // 4          # quick demo sizing
    results = {}
    for scheme in ["B3", "AUTO", "HHZS"]:
        db, load = _fresh(scheme, n)
        row = {"load": load.throughput}
        for wl in ["A", "C"]:
            r = run_workload(db, YCSB[wl], n_ops=4000, n_keys=n)
            row[wl] = r.throughput
        results[scheme] = row
        print(f"{scheme:5s} load={row['load']:8.1f}  "
              f"A={row['A']:6.2f}  C={row['C']:6.2f}  (sim OPS)")
    for wl in ["A", "C"]:
        gain = results["HHZS"][wl] / results["B3"][wl] - 1
        print(f"HHZS vs B3 on {wl}: {gain*100:+.0f}%")

    # ---- open-loop burst scenario ------------------------------------
    # bursts at 3x the weakest scheme's closed-loop service rate, base at
    # 0.3x: queues build during the minute-long burst and drain (or not)
    # during the off phase
    svc = min(results[s]["A"] for s in results)
    arrival = BurstyArrivals(base_rate=0.3 * svc, burst_rate=3.0 * svc,
                             on=60.0, off=240.0)
    print(f"\nopen-loop burst ({arrival.name}, virtual 20 min):")
    for scheme in ["B3", "HHZS"]:
        db, _ = _fresh(scheme, n)
        res = run_open_loop(db, YCSB["A"], arrival, duration=1200.0,
                            n_keys=n, warmup=60.0)
        print(res.row())


if __name__ == "__main__":
    main()
