"""YCSB head-to-head: HHZS vs the best basic scheme vs SpanDB-AUTO.

A reduced version of Exp#1 (paper Fig.5): fresh load per scheme, then
workloads A and C.  Expect HHZS highest throughput, with the gap widest
on read-heavy workloads (migration + hinted cache).

Then an *open-loop* burst scenario: the same stores face on-off Poisson
arrivals whose burst rate exceeds the service rate.  Closed-loop clients
can never see this regime — the open-loop runner decomposes the resulting
tail latency into queueing delay vs service time per scheme.

Finally a *multi-tenant* flash-crowd scenario: a protected steady tenant
shares one store with a flash-crowd tenant, and the admission controller
is switched from `none` to `reject` — watch the protected tenant's p999
queueing delay collapse while the crowd is shed.

  PYTHONPATH=src python examples/ycsb_demo.py           # full demo
  PYTHONPATH=src python examples/ycsb_demo.py --quick   # CI smoke sizing
"""
import sys

from repro.core.middleware import AdmissionConfig
from repro.lsm import DB, ScenarioConfig
from repro.workloads import (BurstyArrivals, FlashCrowdArrivals,
                             PoissonArrivals, TenantSpec, YCSB, run_load,
                             run_multi_tenant, run_open_loop, run_workload)

QUICK = "--quick" in sys.argv[1:]


def _fresh(scheme, n):
    db = DB(scheme)
    load = run_load(db, n_keys=n)
    db.flush_all()
    return db, load


def main():
    # quick mode: CI smoke sizing (same code paths, reduced dataset/runs)
    div, n_ops = (64, 800) if QUICK else (4, 4000)
    n = ScenarioConfig().paper_keys // div
    results = {}
    for scheme in ["B3", "AUTO", "HHZS"]:
        db, load = _fresh(scheme, n)
        row = {"load": load.throughput}
        for wl in ["A", "C"]:
            r = run_workload(db, YCSB[wl], n_ops=n_ops, n_keys=n)
            row[wl] = r.throughput
        results[scheme] = row
        print(f"{scheme:5s} load={row['load']:8.1f}  "
              f"A={row['A']:6.2f}  C={row['C']:6.2f}  (sim OPS)")
    for wl in ["A", "C"]:
        gain = results["HHZS"][wl] / results["B3"][wl] - 1
        print(f"HHZS vs B3 on {wl}: {gain*100:+.0f}%")

    # ---- open-loop burst scenario ------------------------------------
    # bursts at 3x the weakest scheme's closed-loop service rate, base at
    # 0.3x: queues build during the minute-long burst and drain (or not)
    # during the off phase
    svc = min(results[s]["A"] for s in results)
    arrival = BurstyArrivals(base_rate=0.3 * svc, burst_rate=3.0 * svc,
                             on=60.0, off=240.0)
    burst_dur = 300.0 if QUICK else 1200.0
    print(f"\nopen-loop burst ({arrival.name}, "
          f"virtual {burst_dur/60:.0f} min):")
    for scheme in ["B3", "HHZS"]:
        db, _ = _fresh(scheme, n)
        res = run_open_loop(db, YCSB["A"], arrival, duration=burst_dur,
                            n_keys=n, warmup=60.0)
        print(res.row())

    # ---- multi-tenant flash crowd + admission control ----------------
    # a protected steady tenant and a flash-crowd tenant share one HHZS
    # store; shedding off (none) vs on (reject-at-pressure)
    mt_dur = 300.0 if QUICK else 900.0
    tenants = [
        TenantSpec("steady", YCSB["A"], PoissonArrivals(0.3 * svc),
                   protected=True),
        TenantSpec("crowd", YCSB["A"],
                   FlashCrowdArrivals(0.1 * svc, 4.0 * svc,
                                      at=mt_dur / 5, decay=mt_dur / 6)),
    ]
    print(f"\nmulti-tenant flash crowd (virtual {mt_dur/60:.0f} min, "
          f"steady tenant protected):")
    p999 = {}
    for policy in ["none", "reject"]:
        db, _ = _fresh("HHZS", n)
        res = run_multi_tenant(
            db, tenants, duration=mt_dur, n_keys=n, warmup=30.0,
            max_concurrency=16,
            policy=AdmissionConfig(policy=policy, queue_threshold=32))
        steady = res.by_tenant("steady")
        crowd = res.by_tenant("crowd")
        p999[policy] = steady.queue_p["p999"]
        print(f"  policy={policy:6s} steady p999 queue "
              f"{steady.queue_p['p999']*1e3:9.1f}ms  "
              f"(crowd shed={int(crowd.admission['rejected'])}"
              f"/{crowd.n_arrived})")
    if p999["reject"] > 0:
        ratio = p999["none"] / p999["reject"]
        if ratio >= 1.05:
            print(f"  shedding cuts the protected tenant's p999 queueing "
                  f"delay {ratio:.1f}x")
        else:
            print(f"  shedding did not improve the protected tenant's "
                  f"p999 queueing delay here ({ratio:.2f}x)")
    elif p999["none"] > 0:
        print("  shedding eliminates the protected tenant's p999 "
              "queueing delay entirely")


if __name__ == "__main__":
    main()
