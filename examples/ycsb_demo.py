"""YCSB head-to-head: HHZS vs the best basic scheme vs SpanDB-AUTO.

A reduced version of Exp#1 (paper Fig.5): fresh load per scheme, then
workloads A and C.  Expect HHZS highest throughput, with the gap widest
on read-heavy workloads (migration + hinted cache).

  PYTHONPATH=src python examples/ycsb_demo.py
"""
from repro.lsm import DB, ScenarioConfig
from repro.workloads import YCSB, run_load, run_workload


def main():
    n = ScenarioConfig().paper_keys // 4          # quick demo sizing
    results = {}
    for scheme in ["B3", "AUTO", "HHZS"]:
        db = DB(scheme)
        load = run_load(db, n_keys=n)
        db.flush_all()
        row = {"load": load.throughput}
        for wl in ["A", "C"]:
            r = run_workload(db, YCSB[wl], n_ops=4000, n_keys=n)
            row[wl] = r.throughput
        results[scheme] = row
        print(f"{scheme:5s} load={row['load']:8.1f}  "
              f"A={row['A']:6.2f}  C={row['C']:6.2f}  (sim OPS)")
    for wl in ["A", "C"]:
        gain = results["HHZS"][wl] / results["B3"][wl] - 1
        print(f"HHZS vs B3 on {wl}: {gain*100:+.0f}%")


if __name__ == "__main__":
    main()
