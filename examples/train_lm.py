"""Train a ~100M-param qwen3-family model for a few hundred steps on the
local mesh, with mid-run checkpoint + restore (kill-resume drill).

Default runs a reduced step count on CPU; --full does the whole thing.

  PYTHONPATH=src python examples/train_lm.py [--full]
"""
import argparse
import dataclasses
import tempfile

from repro.configs import get_config
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="json 100M params x 300 steps (slow on CPU)")
    args = ap.parse_args()

    base = get_config("qwen3-1.7b")
    if args.full:
        cfg = dataclasses.replace(base, name="qwen3-100m", num_layers=8,
                                  d_model=512, num_heads=8, num_kv_heads=4,
                                  head_dim=64, d_ff=2048,
                                  vocab_size=151936)   # ~100M params
        steps, batch, seq = 300, 4, 256
    else:
        cfg = dataclasses.replace(base, name="qwen3-20m", num_layers=4,
                                  d_model=256, num_heads=8, num_kv_heads=4,
                                  head_dim=32, d_ff=1024, vocab_size=32768)
        steps, batch, seq = 200, 8, 128
    print(f"model {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params, "
          f"{steps} steps")

    with tempfile.TemporaryDirectory() as d:
        # train the first half, "crash", resume from the checkpoint
        out1 = train_loop(cfg, steps=steps, batch=batch, seq=seq,
                          ckpt_dir=d, save_every=steps // 4,
                          fail_at=steps // 2)
        print(f"restarts: {out1['restarts']}  events: {out1['events']}")
        first = out1["losses"][0][1]
        last = out1["losses"][-1][1]
        print(f"loss {first:.3f} -> {last:.3f} over {out1['final_step']} "
              f"steps ({out1['wall_s']:.0f}s)")
        assert last < first, "loss should decrease"


if __name__ == "__main__":
    main()
