"""Quickstart: an LSM-tree KV store on simulated hybrid zoned storage.

Creates a small HHZS-managed store (ZNS-SSD + HM-SMR HDD, paper timing
model scaled 1/100), writes and reads KV pairs, runs a skewed read phase,
and prints where data ended up + what the hints did.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.lsm import DB, ScenarioConfig
from repro.workloads import zipf_probs

def main():
    db = DB("HHZS", store_values=True)
    print(f"scheme={db.scheme}  ssd zones={len(db.ssd.zones)} "
          f"(x{db.ssd.zone_capacity >> 20} MiB)  "
          f"hdd zones={len(db.hdd.zones)}")

    n = 30_000
    print(f"loading {n} KV objects ...")
    rng = np.random.default_rng(0)
    for k in rng.permutation(n):
        db.put(int(k), value=b"value-%d" % k)
    db.flush_all()

    found, val = db.get(1234)
    assert found and val == b"value-1234"
    db.delete(1234)
    assert not db.get(1234)[0]
    print("point reads + delete OK; scanning [5000, 5030) ...")
    db.scan(5000, 30)

    print("skewed read phase (zipf a=1.1) ...")
    p = zipf_probs(n, 1.1)
    keys = rng.permutation(n)[rng.choice(n, size=4000, p=p)]
    for k in keys:
        db.get(int(k))
    db.drain()

    t = db.tree
    be = db.backend
    lvl = [f"L{i}={s/1e6:.1f}MB" for i, s in enumerate(t.level_sizes()[:5])]
    print(f"levels: {' '.join(lvl)}")
    print(f"flushes={t.stats['flushes']:.0f} "
          f"compactions={t.stats['compactions']:.0f} "
          f"bloom_fps={t.stats['bloom_fp']:.0f}")
    ssd_lv = {}
    for s in be.ssd_ssts():
        ssd_lv[s.level] = ssd_lv.get(s.level, 0) + 1
    print(f"SSD SSTs by level: {dict(sorted(ssd_lv.items()))}  "
          f"(tiering level {be.placement.tiering_level()})")
    if be.cache:
        print(f"hinted cache: admitted={be.cache.admitted} "
              f"hits={be.cache.hits}")
    if be.migrator:
        m = be.migrator
        print(f"migration: popularity={m.popularity_moves} "
              f"capacity={m.capacity_moves} "
              f"bytes={m.bytes_moved/1e6:.1f}MB")
    print(f"virtual time: {db.sim.now:.1f}s")


if __name__ == "__main__":
    main()
