"""Serve a small model with batched requests over HHZS-tiered paged KV.

Deliberately undersizes the HBM pool so the tier manager must demote /
promote / prefix-cache sequences mid-flight — the serving-side analogue of
the paper's placement, migration, and caching (DESIGN.md §Adaptation).

  PYTHONPATH=src python examples/serve_paged.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Request, ServingEngine


def main():
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, hbm_zones=6, host_zones=64,
                        pages_per_zone=2, page_size=8, max_batch=4,
                        cache_zones=2)
    rng = np.random.default_rng(7)
    n_req = 12
    for i in range(n_req):
        plen = int(rng.integers(8, 24))
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size,
                                               plen).astype(np.int32),
                           max_new_tokens=int(rng.integers(4, 10))))
    t0 = time.time()
    stats = eng.run(max_steps=200)
    wall = time.time() - t0
    print(f"served {stats['done']}/{n_req} requests, "
          f"{stats['tokens_out']} tokens in {stats['steps']} engine steps "
          f"({stats['tokens_out']/wall:.1f} tok/s wall)")
    print(f"KV placement: hbm={stats['hbm_placements']} "
          f"host={stats['host_placements']}")
    print(f"tiering: demotions={stats['demotions']} "
          f"promotions={stats['promotions']} "
          f"migrated={stats['bytes_migrated']/1e6:.2f}MB")
    print(f"prefix cache: admits={stats['cache_admits']} "
          f"hits={stats['cache_hits']}")
    assert stats["done"] == n_req


if __name__ == "__main__":
    main()
