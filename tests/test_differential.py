"""Differential correctness: every scheme returns identical KV results.

Placement, migration and caching decide *where* bytes live and how long
ops take — they must never change *what* a get/scan returns.  The same
randomized put/get/delete/scan sequence runs through every scheme in
``SCHEMES``; all answer streams — including exact scan counts, which
dedupe shadowed versions and skip tombstones — must be byte-identical
(and match a plain dict model).
"""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.lsm import DB, SCHEMES


def _op_sequence(seed, n_ops=450, key_space=350):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        key = int(rng.integers(key_space))
        if r < 0.45:
            ops.append(("put", key, b"v%d-%d" % (key, int(rng.integers(1 << 16)))))
        elif r < 0.70:
            ops.append(("get", key, None))
        elif r < 0.85:
            ops.append(("del", key, None))
        else:
            ops.append(("scan", key, int(rng.integers(1, 30))))
    return ops


def _run_sequence(scheme, ops):
    db = DB(scheme, tiny_scenario(), store_values=True)
    out = []
    scans = []
    for op, key, arg in ops:
        if op == "put":
            db.put(key, arg)
        elif op == "del":
            db.delete(key)
        elif op == "get":
            out.append(("get", key, db.get(key)))
        else:
            scans.append((key, arg, db.scan(key, arg)))
    db.drain()
    # post-drain read-back: compaction/migration settled, answers unchanged
    for key in range(0, 350, 7):
        out.append(("final", key, db.get(key)))
    return out, scans


def _model_answers(ops):
    model = {}
    out = []
    scan_live = []
    for op, key, arg in ops:
        if op == "put":
            model[key] = arg
        elif op == "del":
            model.pop(key, None)
        elif op == "get":
            out.append(("get", key,
                        (key in model, model.get(key))))
        else:
            cnt = sum(1 for k in model if key <= k < key + arg)
            scan_live.append((key, arg, cnt))
    for key in range(0, 350, 7):
        out.append(("final", key, (key in model, model.get(key))))
    return out, scan_live


def _run_sequence_batched(scheme, ops, batch=8):
    """Same sequence, but gets are accumulated and serviced through the
    vectorized ``get_batch`` path (flushing pending gets before any
    mutation so read-your-writes ordering is preserved)."""
    db = DB(scheme, tiny_scenario(), store_values=True)
    out = []
    scans = []
    pending = []

    def flush_gets():
        if pending:
            for key, res in zip(pending, db.get_batch(pending)):
                out.append(("get", key, res))
            pending.clear()

    for op, key, arg in ops:
        if op == "get":
            pending.append(key)
            if len(pending) >= batch:
                flush_gets()
            continue
        flush_gets()
        if op == "put":
            db.put(key, arg)
        elif op == "del":
            db.delete(key)
        else:
            scans.append((key, arg, db.scan(key, arg)))
    flush_gets()
    db.drain()
    keys = list(range(0, 350, 7))
    for key, res in zip(keys, db.get_batch(keys)):
        out.append(("final", key, res))
    return out, scans


@pytest.mark.parametrize("seed", [0, 1])
def test_batched_gets_identical_to_per_key(seed):
    """Tentpole invariant: the batched Bloom-probe read path is result-
    identical to per-key ``get`` under every placement scheme (filter
    false positives may change I/O, never answers)."""
    ops = _op_sequence(seed, n_ops=300, key_space=250)
    expected, scan_live = _model_answers(ops)
    for scheme in SCHEMES:
        got, scans = _run_sequence_batched(scheme, ops)
        assert len(got) == len(expected)
        for g, e in zip(got, expected):
            assert g == e, (f"scheme {scheme} batched read diverges at "
                            f"{g[0]}({g[1]}): got {g[2]!r}, expected {e[2]!r}")
        assert [s[2] for s in scans] == [s[2] for s in scan_live]


@pytest.mark.parametrize("seed", [0, 1])
def test_all_schemes_agree_and_match_model(seed):
    ops = _op_sequence(seed)
    expected, scan_live = _model_answers(ops)
    for scheme in SCHEMES:
        got, scans = _run_sequence(scheme, ops)
        assert len(got) == len(expected)
        for g, e in zip(got, expected):
            assert g == e, (f"scheme {scheme} diverges at {g[0]}({g[1]}): "
                            f"got {g[2]!r}, expected {e[2]!r}")
        # scans return exactly the live keys in range: shadowed versions
        # deduped, tombstones skipped — identical across every scheme
        assert len(scans) == len(scan_live)
        for (k, n, seen), (k2, n2, live) in zip(scans, scan_live):
            assert (k, n) == (k2, n2)
            assert seen == live, (f"scheme {scheme} scan({k},{n}) saw "
                                  f"{seen}, model says {live} live keys")
