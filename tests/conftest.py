"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 real device;
only the dry-run sets xla_force_host_platform_device_count (in a
subprocess for its integration test)."""
import numpy as np
import pytest

from repro.lsm import DB, ScenarioConfig
from repro.lsm.tree import LSMConfig
from repro.zoned.device import MiB


def tiny_scenario(ssd_zones: int = 20, **kw) -> ScenarioConfig:
    """Small fast scenario for correctness tests (64-object SSTs)."""
    lsm = LSMConfig(
        obj_size=1024, block_size=4096,
        sst_size=int(0.0632 * MiB),
        memtable_size=int(0.032 * MiB),
        level_targets=(int(0.0632 * MiB),) * 2
        + (int(0.632 * MiB), int(6.32 * MiB), int(63.2 * MiB)),
        store_values=True, block_cache_blocks=8,
    )
    return ScenarioConfig(ssd_zones=ssd_zones,
                          ssd_zone_cap=int(0.0673 * MiB),
                          hdd_zones=4000, hdd_zone_cap=int(0.016 * MiB),
                          lsm=lsm, **kw)


@pytest.fixture
def tiny_db():
    return DB("HHZS", tiny_scenario(), store_values=True)


@pytest.fixture(params=["B1", "B3", "AUTO", "P", "HHZS"])
def any_db(request):
    return DB(request.param, tiny_scenario(), store_values=True)
