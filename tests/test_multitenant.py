"""Multi-tenant open-loop serving + admission control.

Covers the accounting invariants the per-tenant rows rely on:
per-tenant op counts sum to the run total, queueing + service recompose
the end-to-end latency, admission counters are conserved under every
policy, and — the differential anchor — one tenant under policy ``none``
reproduces the single-stream ``run_open_loop`` results exactly.
"""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.core.middleware import AdmissionConfig
from repro.lsm import DB
from repro.workloads import (FlashCrowdArrivals, PoissonArrivals,
                             ScenarioMatrix, TenantSpec, YCSB,
                             run_load, run_multi_tenant, run_open_loop)


def _loaded(scheme="HHZS", n=1200, **db_kw):
    db = DB(scheme, tiny_scenario(), store_values=True, **db_kw)
    run_load(db, n_keys=n)
    db.flush_all()
    return db, n


def _two_tenants(steady_rate=3.0, peak=60.0):
    return [
        TenantSpec("steady", YCSB["A"], PoissonArrivals(steady_rate),
                   protected=True),
        TenantSpec("crowd", YCSB["A"],
                   FlashCrowdArrivals(1.0, peak, at=60.0, decay=60.0)),
    ]


# ---------------------------------------------------------------------
# differential: multi-tenant engine vs PR 1's single-stream engine
# ---------------------------------------------------------------------
def test_single_tenant_none_reproduces_open_loop():
    db1, n = _loaded()
    ref = run_open_loop(db1, YCSB["A"], PoissonArrivals(10.0),
                        duration=60.0, n_keys=n, warmup=10.0, seed=9)
    db2, _ = _loaded()
    mt = run_multi_tenant(db2, [TenantSpec("only", YCSB["A"],
                                           PoissonArrivals(10.0))],
                          duration=60.0, n_keys=n, warmup=10.0, seed=9)
    t = mt.tenants[0]
    # event-for-event identical: every statistic matches exactly
    assert t.n_arrived == ref.n_arrived
    assert t.n_measured == ref.n_measured
    assert t.latency_p == ref.latency_p
    assert t.queue_p == ref.queue_p
    assert t.service_p == ref.service_p
    assert t.read_latency_p == ref.read_latency_p
    assert t.op_counts == ref.op_counts
    assert t.max_queue_depth == ref.max_queue_depth
    assert t.throughput == ref.throughput
    assert mt.n_arrived == ref.n_arrived
    # the tenant row is annotated; the single-stream row is not
    assert t.tenant == "only" and t.policy == "none"
    assert ref.tenant is None


# ---------------------------------------------------------------------
# per-tenant accounting
# ---------------------------------------------------------------------
def test_per_tenant_counts_sum_to_total():
    db, n = _loaded()
    res = run_multi_tenant(db, _two_tenants(peak=20.0), duration=200.0,
                           n_keys=n, warmup=20.0)
    assert res.n_arrived == sum(t.n_arrived for t in res.tenants)
    assert res.n_completed == sum(
        sum(t.op_counts.values()) for t in res.tenants)
    # policy none + drain: everything arrived gets executed
    assert res.n_completed == res.n_arrived
    assert sum(t.n_measured for t in res.tenants) <= res.n_completed


def test_per_tenant_latency_decomposition():
    db, n = _loaded()
    res = run_multi_tenant(db, _two_tenants(peak=30.0), duration=200.0,
                           n_keys=n, warmup=20.0, max_concurrency=8)
    for t in res.tenants:
        assert t.n_measured > 0
        # queueing + service recompose the end-to-end sojourn
        assert t.mean_latency == pytest.approx(
            t.mean_queue + t.mean_service, rel=1e-9)
        for k in t.latency_p:
            assert t.latency_p[k] >= t.queue_p[k] - 1e-9
            assert t.latency_p[k] >= t.service_p[k] - 1e-9


def test_results_deterministic_across_runs():
    rows = []
    for _ in range(2):
        db, n = _loaded("B3")
        res = run_multi_tenant(db, _two_tenants(), duration=150.0,
                               n_keys=n, warmup=10.0, max_concurrency=8,
                               policy=AdmissionConfig(policy="reject",
                                                      queue_threshold=16))
        rows.append([(t.tenant, t.n_arrived, t.latency_p, t.admission)
                     for t in res.tenants])
    assert rows[0] == rows[1]


# ---------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["none", "reject", "delay"])
def test_admission_counters_conserved(policy):
    db, n = _loaded("B3")
    cfg = AdmissionConfig(policy=policy, queue_threshold=16)
    res = run_multi_tenant(db, _two_tenants(), duration=250.0, n_keys=n,
                           warmup=20.0, max_concurrency=8, policy=cfg)
    for t in res.tenants:
        a = t.admission
        assert a["arrived"] == t.n_arrived
        assert a["arrived"] == a["admitted"] + a["rejected"] + a["holding"]
        assert a["holding"] == 0, "drained run must resolve every hold"
        assert a["delayed"] <= a["admitted"]
        # executed ops == admitted ops (shed ops never run)
        assert sum(t.op_counts.values()) == a["admitted"]
        if t.protected:
            assert a["rejected"] == 0 and a["delayed"] == 0
    crowd = res.by_tenant("crowd").admission
    if policy == "reject":
        assert crowd["rejected"] > 0
    if policy == "delay":
        assert crowd["rejected"] == 0
        assert crowd["delayed"] > 0 and crowd["delay_time"] > 0


def test_shedding_protects_p999_queueing_delay():
    """Acceptance criterion: with shedding on, the protected tenant's p999
    queueing delay is strictly lower than under policy `none` at the same
    offered load."""
    p999 = {}
    for policy in ["none", "reject"]:
        db, n = _loaded("B3")
        cfg = AdmissionConfig(policy=policy, queue_threshold=16)
        res = run_multi_tenant(db, _two_tenants(), duration=300.0,
                               n_keys=n, warmup=30.0, max_concurrency=8,
                               policy=cfg)
        p999[policy] = res.by_tenant("steady").queue_p["p999"]
    assert p999["reject"] < p999["none"], p999


def test_token_bucket_limits_tenant_rate():
    db, n = _loaded()
    cfg = AdmissionConfig(policy="token_bucket",
                          bucket_rates={"crowd": (2.0, 5.0)})
    res = run_multi_tenant(db, _two_tenants(peak=40.0), duration=200.0,
                           n_keys=n, warmup=20.0, max_concurrency=8,
                           policy=cfg)
    crowd = res.by_tenant("crowd").admission
    steady = res.by_tenant("steady").admission
    # sustained rate 2/s + burst 5 over 200s
    assert crowd["admitted"] <= 2.0 * 200.0 + 5.0
    assert crowd["rejected"] > 0
    # no budget configured for steady: unlimited
    assert steady["rejected"] == 0


def test_token_bucket_sub_unit_burst_not_starved():
    """Regression: a tenant configured with burst < 1.0 could never
    accumulate the full token an admit costs, so it was rejected forever
    regardless of its rate.  Bursts are normalized to >= 1 token."""
    cfg = AdmissionConfig(policy="token_bucket",
                          bucket_rates={"t": (5.0, 0.2)})
    assert cfg.bucket_rates["t"] == (5.0, 1.0)
    db = DB("HHZS", tiny_scenario(), store_values=True, admission=cfg)

    def op():
        yield db.sim.timeout(0.001)

    admitted = 0
    for _ in range(20):
        admitted += db.submit(op(), tenant="t") is not None
        db.run_for(0.25)       # rate 5/s: a full token well within 0.25 s
    assert admitted == 20, "normalized burst must admit at the token rate"
    # the default burst is normalized too
    assert AdmissionConfig(policy="token_bucket",
                           bucket_burst=0.01).bucket_burst == 1.0


def test_db_submit_routes_through_admission():
    db = DB("HHZS", tiny_scenario(), store_values=True,
            admission=AdmissionConfig(policy="token_bucket",
                                      bucket_rates={"t": (0.001, 1.0)}))

    def op():
        yield db.sim.timeout(0.01)

    first = db.submit(op(), tenant="t")
    second = db.submit(op(), tenant="t")   # bucket empty: shed
    assert first is not None and second is None
    db.drain()
    c = db.admission.tenant_counters("t")
    assert c["arrived"] == 2 and c["admitted"] == 1 and c["rejected"] == 1
    # untagged submissions bypass admission entirely
    assert db.submit(op()) is not None
    db.drain()
    assert db.admission.tenant_counters("t")["arrived"] == 2


def test_shared_admission_config_not_mutated_across_runs():
    """A caller may reuse one AdmissionConfig across runs/cells with
    different tenant mixes: protected names from one run must not leak
    into the config (or the next run's controller)."""
    cfg = AdmissionConfig(policy="reject", queue_threshold=16)
    db, n = _loaded("B3")
    run_multi_tenant(db, _two_tenants(), duration=50.0, n_keys=n,
                     max_concurrency=8, policy=cfg)
    assert cfg.protected == frozenset()
    # a second mix where "steady" is NOT protected must actually shed it
    db2, _ = _loaded("B3")
    mix = [TenantSpec("steady", YCSB["A"],
                      FlashCrowdArrivals(1.0, 60.0, at=30.0, decay=60.0))]
    res = run_multi_tenant(db2, mix, duration=200.0, n_keys=n,
                           max_concurrency=8, policy=cfg)
    assert "steady" not in db2.admission.cfg.protected
    assert res.by_tenant("steady").admission["rejected"] > 0


def test_back_to_back_runs_on_same_db_get_fresh_admission_state():
    """policy=None keeps the DB's configured policy but must not carry the
    previous run's counters, protected-set widening, or queue gauge."""
    db, n = _loaded("B3", admission=AdmissionConfig(policy="reject",
                                                    queue_threshold=16))
    mix1 = [TenantSpec("x", YCSB["A"], PoissonArrivals(2.0),
                       protected=True)]
    run_multi_tenant(db, mix1, duration=50.0, n_keys=n, max_concurrency=8)
    # second run on the same DB: same tenant name, no longer protected
    mix2 = [TenantSpec("x", YCSB["A"],
                       FlashCrowdArrivals(1.0, 60.0, at=10.0, decay=60.0))]
    res = run_multi_tenant(db, mix2, duration=200.0, n_keys=n,
                           max_concurrency=8)
    t = res.by_tenant("x")
    assert t.admission["arrived"] == t.n_arrived
    assert "x" not in db.admission.cfg.protected
    assert t.admission["rejected"] > 0
    # the run's queue gauge must not outlive the run
    assert db.admission.queue_gauge is None


def test_per_run_policy_override_does_not_replace_db_default():
    db, n = _loaded("B3", admission=AdmissionConfig(policy="delay",
                                                    queue_threshold=16))
    mix = [TenantSpec("x", YCSB["A"], PoissonArrivals(2.0))]
    run_multi_tenant(db, mix, duration=30.0, n_keys=n, max_concurrency=8,
                     policy="none")
    assert db.admission.cfg.policy == "none"     # override active this run
    # a later policy=None run must rebuild from the constructor's config
    run_multi_tenant(db, mix, duration=30.0, n_keys=n, max_concurrency=8)
    assert db.admission.cfg.policy == "delay"


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        DB("HHZS", tiny_scenario(), admission="drop-everything")
    with pytest.raises(ValueError):
        db, n = _loaded()
        run_multi_tenant(db, _two_tenants(), duration=10.0, n_keys=n,
                         policy="bogus")


def test_duplicate_tenant_names_rejected():
    db, n = _loaded()
    tenants = [TenantSpec("t", YCSB["A"], PoissonArrivals(1.0)),
               TenantSpec("t", YCSB["C"], PoissonArrivals(1.0))]
    with pytest.raises(ValueError):
        run_multi_tenant(db, tenants, duration=10.0, n_keys=n)


# ---------------------------------------------------------------------
# scenario matrix in multi-tenant mode
# ---------------------------------------------------------------------
def test_scenario_matrix_tenant_policy_sweep(tmp_path):
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=800)
        db.flush_all()
        db.n_keys = 800
        return db

    mix = _two_tenants(steady_rate=2.0, peak=30.0)
    matrix = ScenarioMatrix(
        schemes=["B3"], workloads=[], arrivals=[],
        tenants=[mix],
        policies=["none", AdmissionConfig(policy="reject",
                                          queue_threshold=16)],
        ssd_zone_budgets=[20],
        duration=150.0, warmup=10.0, max_concurrency=8,
        db_factory=db_factory)
    cells = matrix.cells()
    assert len(cells) == 2
    assert len({c.name for c in cells}) == 2
    out = tmp_path / "scenarios.json"
    rows = matrix.run(out=out, verbose=False)
    assert out.exists()
    # one row per tenant per cell
    assert len(rows) == 4
    for r in rows:
        for key in ("cell", "ssd_zones", "tenant", "policy", "protected",
                    "admission", "queue_p", "service_p", "latency_p",
                    "op_counts"):
            assert key in r, f"tenant row missing {key}"
        a = r["admission"]
        assert a["arrived"] == a["admitted"] + a["rejected"] + a["holding"]
    by_cell = {}
    for r in rows:
        by_cell.setdefault(r["cell"], []).append(r["tenant"])
    assert all(sorted(t) == ["crowd", "steady"]
               for t in by_cell.values())
