"""shard_map MoE == pure-jnp MoE, numerically, on a real multi-device mesh.

Runs in a subprocess (needs >1 fake CPU device before jax init).  Covers
both internal strategies: expert-parallel a2a (E divisible by the model
axis) and the Megatron-style TP fallback (E not divisible).
"""
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.slow  # multi-device subprocess compile, ~8 min; run with -m slow

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import get_config
    from repro.models import layers as L
    from repro.models.moe_sharded import moe_shard_map

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    base = get_config("olmoe-1b-7b").smoke()
    for tag, e in [("EP", 4), ("TP", 3)]:     # 4 % 4 == 0 -> a2a; 3 -> TP
        cfg = dataclasses.replace(base, num_experts=e, top_k=2,
                                  capacity_factor=8.0)   # no drops: exact
        p = L.init_moe(jax.random.PRNGKey(0), cfg)
        x = jnp.array(np.random.default_rng(1).standard_normal(
            (4, 16, cfg.d_model)), jnp.float32).astype(jnp.bfloat16)
        ref = L.moe(p, cfg, x)

        pspec = {"router": P("data", None),
                 "we_gate": P("model", "data", None) if e % 4 == 0
                 else P(None, "data", "model"),
                 "we_up": P("model", "data", None) if e % 4 == 0
                 else P(None, "data", "model"),
                 "we_down": P("model", None, "data") if e % 4 == 0
                 else P(None, "model", "data")}
        put = lambda a, s: jax.device_put(a, NamedSharding(mesh, s))
        ps = {k: put(v, pspec[k]) for k, v in p.items()}
        xs = put(x, P("data", "model", None))
        with mesh:
            out = jax.jit(lambda p_, x_: moe_shard_map(p_, cfg, x_, mesh,
                                                       ("data",)))(ps, xs)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        print(f"{tag} max_err {err}")
        assert err < 0.15, f"{tag} mismatch: {err}"
    print("MOE SHARDED OK")
""")


@pytest.mark.slow
def test_moe_shard_map_matches_reference():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-3000:]
    assert "MOE SHARDED OK" in r.stdout
