"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")   # tier-1 runs a no-jax matrix leg
import jax.numpy as jnp            # noqa: E402

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.selective_scan.ops import mamba_scan
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.bloom_probe.ops import probe
from repro.kernels.bloom_probe.ref import build_filter, bloom_probe_ref

RNG = np.random.default_rng(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,h,kv,s,d", [
    (1, 4, 4, 256, 64),        # MHA
    (2, 8, 2, 512, 64),        # GQA 4:1
    (1, 8, 1, 256, 128),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, None), (False, None),
                                           (True, 64)])
def test_flash_attention_sweep(b, h, kv, s, d, dtype, causal, window):
    q = jnp.array(RNG.standard_normal((b, h, s, d)), dtype)
    k = jnp.array(RNG.standard_normal((b, kv, s, d)), dtype)
    v = jnp.array(RNG.standard_normal((b, kv, s, d)), dtype)
    out = flash_attention(q, k, v, causal, window, True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grad_matches_ref():
    q = jnp.array(RNG.standard_normal((1, 4, 128, 64)), jnp.float32)
    k = jnp.array(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
    v = jnp.array(RNG.standard_normal((1, 2, 128, 64)), jnp.float32)
    gk = jax.grad(lambda q_: jnp.sum(
        flash_attention(q_, k, v, True, None, True) ** 2))(q)
    gr = jax.grad(lambda q_: jnp.sum(
        attention_ref(q_, k, v, causal=True) ** 2))(q)
    np.testing.assert_allclose(gk, gr, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,kv,g,pages,ps,mp,d", [
    (2, 4, 2, 16, 16, 4, 64),
    (3, 2, 4, 32, 8, 8, 128),
    (1, 1, 8, 8, 16, 2, 64),    # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_sweep(b, kv, g, pages, ps, mp, d, dtype):
    h = kv * g
    q = jnp.array(RNG.standard_normal((b, h, d)), dtype)
    kp = jnp.array(RNG.standard_normal((pages, ps, kv, d)), dtype)
    vp = jnp.array(RNG.standard_normal((pages, ps, kv, d)), dtype)
    tables = jnp.array(RNG.integers(0, pages, (b, mp)), jnp.int32)
    lens = jnp.array(RNG.integers(1, mp * ps, (b,)), jnp.int32)
    out = paged_attention(q, kp, vp, tables, lens, interpret=True)
    ref = paged_attention_ref(q, kp, vp, tables, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ----------------------------------------------------------------------
@pytest.mark.parametrize("b,t,di,n", [
    (1, 64, 256, 8), (2, 128, 512, 16), (1, 256, 256, 4),
])
def test_selective_scan_sweep(b, t, di, n):
    dt = jnp.array(np.abs(RNG.standard_normal((b, t, di))) * 0.1,
                   jnp.float32)
    bx = jnp.array(RNG.standard_normal((b, t, di, n)) * 0.1, jnp.float32)
    c = jnp.array(RNG.standard_normal((b, t, n)), jnp.float32)
    a = jnp.array(-np.abs(RNG.standard_normal((di, n))), jnp.float32)
    out = mamba_scan(dt, bx, c, a, interpret=True)
    ref = selective_scan_ref(dt, bx, c, a)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


def test_selective_scan_matches_model_layer():
    """The kernel oracle agrees with the model's chunked associative scan."""
    from repro.models.layers import _ssm_scan_chunked
    b, t, di, n = 2, 128, 64, 8
    dt = jnp.array(np.abs(RNG.standard_normal((b, t, di))) * 0.1,
                   jnp.float32)
    bx = jnp.array(RNG.standard_normal((b, t, di, n)) * 0.1, jnp.float32)
    c = jnp.array(RNG.standard_normal((b, t, n)), jnp.float32)
    a = jnp.array(-np.abs(RNG.standard_normal((di, n))), jnp.float32)
    got = _ssm_scan_chunked(dt, a, dt[..., None] * 0 + bx, c, chunk=32)
    ref = selective_scan_ref(dt, bx, c, a)
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("n_member,n_query,words", [
    (1024, 2048, 1024), (4096, 1024, 8192),
])
def test_bloom_probe_sweep(n_member, n_query, words):
    # keys are uint64; hashing happens host-side (splitmix64 -> lo/hi
    # uint32 halves, shared with repro.lsm.filters)
    from repro.lsm.filters import split_hash
    member = RNG.integers(0, 2**63, n_member).astype(np.uint64)
    mlo, mhi = split_hash(member)
    bits = build_filter(jnp.array(mlo), jnp.array(mhi), num_words=words)
    queries = np.concatenate([
        member[:n_query // 2],
        RNG.integers(2**63, 2**64, n_query // 2, dtype=np.uint64)])
    qlo, qhi = split_hash(queries)
    qlo, qhi = jnp.array(qlo), jnp.array(qhi)
    out = probe(qlo, qhi, bits, interpret=True)
    ref = bloom_probe_ref(qlo, qhi, bits)
    assert jnp.array_equal(out, ref)
    # no false negatives, bounded false positives
    assert int(out[:n_query // 2].sum()) == n_query // 2
    assert float(out[n_query // 2:].mean()) < 0.2
