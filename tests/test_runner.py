"""Open-loop workload engine: arrival processes, latency decomposition,
warm-up/time-limit semantics, and the declarative ScenarioMatrix."""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.lsm import DB
from repro.workloads import (BurstyArrivals, DiurnalArrivals,
                             FlashCrowdArrivals, PoissonArrivals,
                             RampArrivals, ScenarioMatrix, WorkloadSpec,
                             YCSB, run_load, run_open_loop, run_workload)


# ---------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------
@pytest.mark.parametrize("arrival,expected", [
    (PoissonArrivals(50.0), 50.0 * 200),
    (BurstyArrivals(10.0, 100.0, on=20.0, off=30.0), 200 * (100.0 * 0.4 + 10.0 * 0.6)),
    (RampArrivals(20.0, 80.0), 200 * 50.0),
    # piecewise-linear through knots incl. wrap: mean of segment trapezoids
    (DiurnalArrivals((20.0, 80.0, 40.0)), 200 * (50.0 + 60.0 + 30.0) / 3),
    # base load + spike mass (peak-base)*tau*(1-exp(-(T-at)/tau))
    (FlashCrowdArrivals(5.0, 100.0, at=50.0, decay=30.0),
     5.0 * 200 + 95.0 * 30.0 * (1 - np.exp(-150.0 / 30.0))),
])
def test_arrival_processes_rate_and_ordering(arrival, expected):
    rng = np.random.default_rng(7)
    ts = arrival.times(rng, 200.0)
    assert np.all(np.diff(ts) >= 0), "arrival times must be sorted"
    assert ts[0] >= 0.0 and ts[-1] < 200.0, "times within [0, duration)"
    # counts within 6 sigma of the expected Poisson mass
    assert abs(len(ts) - expected) < 6 * np.sqrt(expected) + 10, \
        f"{arrival.name}: {len(ts)} arrivals, expected ~{expected:.0f}"


def test_ramp_arrivals_actually_ramp():
    rng = np.random.default_rng(3)
    ts = RampArrivals(5.0, 100.0).times(rng, 400.0)
    first, second = np.sum(ts < 200.0), np.sum(ts >= 200.0)
    assert second > 1.5 * first, "second half must see much higher rate"


def test_bursty_arrivals_concentrate_in_bursts():
    rng = np.random.default_rng(4)
    a = BurstyArrivals(2.0, 80.0, on=10.0, off=40.0)
    ts = a.times(rng, 500.0)
    phase = np.mod(ts, 50.0)
    in_burst = np.sum(phase < 10.0)
    assert in_burst > 0.75 * len(ts), "most arrivals must land in bursts"


def test_arrivals_are_deterministic_per_seed():
    a = PoissonArrivals(30.0)
    t1 = a.times(np.random.default_rng(11), 100.0)
    t2 = a.times(np.random.default_rng(11), 100.0)
    assert np.array_equal(t1, t2)


def test_flash_crowd_spikes_then_decays():
    rng = np.random.default_rng(5)
    a = FlashCrowdArrivals(2.0, 80.0, at=100.0, decay=40.0)
    ts = a.times(rng, 400.0)
    pre = np.sum(ts < 100.0) / 100.0              # ops/s before the event
    spike = np.sum((ts >= 100.0) & (ts < 140.0)) / 40.0
    late = np.sum(ts >= 300.0) / 100.0            # long after: back to base
    assert spike > 10 * pre, "spike must dwarf the base rate"
    assert late < 3 * pre, "rate must decay back toward base"


def test_diurnal_arrivals_follow_the_profile():
    rng = np.random.default_rng(6)
    a = DiurnalArrivals((5.0, 100.0, 5.0), period=300.0)
    ts = a.times(rng, 300.0)
    # knots at t=0,100,200,300: the middle third straddles the peak knot
    lo = np.sum(ts < 50.0)
    hi = np.sum((ts >= 75.0) & (ts < 125.0))
    assert hi > 2 * lo, "arrivals must concentrate around the peak knot"


def test_diurnal_profile_repeats_across_periods():
    rng = np.random.default_rng(12)
    a = DiurnalArrivals((5.0, 60.0), period=100.0)
    ts = a.times(rng, 400.0)
    per_period = [np.sum((ts >= p * 100.0) & (ts < (p + 1) * 100.0))
                  for p in range(4)]
    mean = np.mean(per_period)
    assert all(abs(c - mean) < 6 * np.sqrt(mean) + 10 for c in per_period)


# ---------------------------------------------------------------------
# open-loop runner
# ---------------------------------------------------------------------
def _loaded(scheme="HHZS", n=1200):
    db = DB(scheme, tiny_scenario(), store_values=True)
    run_load(db, n_keys=n)
    db.flush_all()
    return db, n


def test_open_loop_underload_queueing_negligible():
    db, n = _loaded()
    # probe the service rate, then offer well below it
    probe = run_workload(db, YCSB["C"], n_ops=300, n_keys=n)
    res = run_open_loop(db, YCSB["C"], PoissonArrivals(0.2 * probe.throughput),
                        duration=400.0, n_keys=n, warmup=20.0)
    assert res.n_measured > 50
    # underloaded: median sojourn is dominated by service, not queueing
    assert res.queue_p["p50"] <= res.service_p["p50"]
    assert res.latency_p["p50"] >= res.service_p["p50"]


def test_open_loop_burst_overload_shows_queueing():
    db, n = _loaded("B3")
    probe = run_workload(db, YCSB["A"], n_ops=300, n_keys=n)
    svc = probe.throughput
    res = run_open_loop(
        db, YCSB["A"],
        BurstyArrivals(0.2 * svc, 6.0 * svc, on=30.0, off=60.0),
        duration=300.0, n_keys=n, warmup=10.0, max_concurrency=8)
    # bursts exceed the service rate: tail latency must be queueing-dominated
    assert res.max_queue_depth > 5
    assert res.queue_p["p99"] > res.service_p["p99"], \
        f"queue p99 {res.queue_p['p99']} vs service {res.service_p['p99']}"
    # all arrived ops completed (drain=True)
    assert res.n_arrived >= res.n_measured > 0


def test_open_loop_warmup_excluded_and_accounting_consistent():
    db, n = _loaded()
    res_all = run_open_loop(db, YCSB["C"], PoissonArrivals(20.0),
                            duration=100.0, n_keys=n, warmup=50.0, seed=5)
    # warm-up excludes roughly the first half of arrivals
    assert res_all.n_measured < res_all.n_arrived
    assert res_all.n_measured == pytest.approx(res_all.n_arrived / 2,
                                               rel=0.35)
    # sojourn >= each component at every reported percentile
    for k in res_all.latency_p:
        assert res_all.latency_p[k] >= res_all.queue_p[k] - 1e-9
        assert res_all.latency_p[k] >= res_all.service_p[k] - 1e-9


def test_open_loop_time_limited_no_drain():
    db, n = _loaded("B3")
    t0 = db.now
    probe = run_workload(db, YCSB["A"], n_ops=200, n_keys=n)
    t1 = db.now
    res = run_open_loop(db, YCSB["A"],
                        PoissonArrivals(3.0 * probe.throughput),
                        duration=120.0, n_keys=n, max_concurrency=4,
                        drain=False)
    # hard stop at the end of the arrival window
    assert db.now == pytest.approx(t1 + 120.0)
    # overloaded + truncated: some arrived ops never completed
    assert res.n_measured < res.n_arrived
    assert res.n_measured > 0


def test_open_loop_results_deterministic():
    r = []
    for _ in range(2):
        db, n = _loaded()
        r.append(run_open_loop(db, YCSB["A"], PoissonArrivals(10.0),
                               duration=60.0, n_keys=n, seed=9))
    assert r[0].n_arrived == r[1].n_arrived
    assert r[0].latency_p == r[1].latency_p
    assert r[0].op_counts == r[1].op_counts


# ---------------------------------------------------------------------
# scenario matrix
# ---------------------------------------------------------------------
def test_scenario_matrix_sweeps_and_emits_rows(tmp_path):
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=800)
        db.flush_all()
        db.n_keys = 800
        return db

    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"],
        workloads=[spec],
        arrivals=[PoissonArrivals(8.0),
                  BurstyArrivals(2.0, 40.0, on=20.0, off=40.0)],
        ssd_zone_budgets=[20],
        duration=120.0, warmup=10.0,
        db_factory=db_factory)
    assert len(matrix.cells()) == 4
    out = tmp_path / "scenarios.json"
    rows = matrix.run(out=out, verbose=False)
    assert out.exists() and len(rows) == 4
    cells = {r["cell"] for r in rows}
    assert len(cells) == 4, "every cell must be distinct"
    for r in rows:
        for key in ("scheme", "workload", "arrival", "ssd_zones",
                    "offered_rate", "throughput", "latency_p", "queue_p",
                    "service_p", "max_queue_depth"):
            assert key in r, f"row missing {key}"
        assert r["n_measured"] > 0
        assert r["latency_p"]["p99"] >= r["latency_p"]["p50"]
    # p99 queue-vs-service reported for both schemes (acceptance criterion)
    for scheme in ("B3", "HHZS"):
        srows = [r for r in rows if r["scheme"] == scheme]
        assert srows and all("p99" in r["queue_p"] and "p99" in r["service_p"]
                             for r in srows)
