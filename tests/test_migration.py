"""Workload-aware migration: priorities, triggers, preemption, rate limit."""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.core.migration import priority_key
from repro.lsm import DB
from repro.lsm.sstable import SST


def _sst(sid, level, reads, birth=0.0):
    keys = np.arange(sid * 100, sid * 100 + 10, dtype=np.uint64)
    s = SST(sid=sid, level=level, keys=keys,
            tombs=np.zeros(10, bool), obj_size=1024, block_size=4096,
            birth=birth)
    s.num_reads = reads
    return s


def test_priority_order():
    now = 100.0
    low_level_cold = _sst(1, 0, reads=0)
    low_level_hot = _sst(2, 0, reads=500)
    high_level_hot = _sst(3, 3, reads=9999)
    ks = sorted([low_level_cold, low_level_hot, high_level_hot],
                key=lambda s: priority_key(s, now))
    # level dominates; within a level, read rate breaks ties
    assert [s.sid for s in ks] == [2, 1, 3]


def test_popularity_migration_promotes_hot_ssts():
    db = DB("HHZS", tiny_scenario())
    for k in np.random.default_rng(0).permutation(4000):
        db.put(int(k))
    db.flush_all()
    # hammer HDD-resident data with reads until the trigger fires
    from repro.workloads import zipf_probs
    p = zipf_probs(4000, 1.0)
    keys = np.random.default_rng(1).choice(4000, size=8000, p=p)
    for k in keys:
        db.get(int(k))
    db.drain()
    m = db.backend.migrator
    assert m.popularity_moves + m.capacity_moves > 0


def test_migration_preempted_by_compaction():
    """A locked (compaction-selected) SST aborts an in-flight migration."""
    db = DB("HHZS", tiny_scenario())
    be = db.backend
    sst = _sst(900, 3, reads=0)
    sst.tier = "hdd"
    sst.zones = be.alloc_sst_zones("hdd", sst.size_bytes, "sst:900")
    be._register(sst)
    m = be.migrator
    proc = db.sim.process(m._migrate(sst, "ssd"))
    db.sim.run(until=db.sim.now + 1e-4)
    sst.locked = True          # compaction takes it mid-flight
    ok = db.sim.run_until(proc)
    assert ok is False and m.aborted >= 1
    assert sst.tier == "hdd"
    # destination zones were rolled back
    assert be.ssd_empty_sst_zones() == be.c_ssd()


def test_rate_limit_paces_migration():
    db = DB("HHZS", tiny_scenario())
    be = db.backend
    sst = _sst(901, 3, reads=0)
    sst.keys = np.arange(0, 64, dtype=np.uint64)   # 64 KiB SST
    sst.tombs = np.zeros(64, bool)
    sst.tier = "hdd"
    sst.zones = be.alloc_sst_zones("hdd", sst.size_bytes, "sst:901")
    be._register(sst)
    m = be.migrator
    t0 = db.sim.now
    ok = db.sim.run_until(db.sim.process(m._migrate(sst, "ssd")))
    assert ok is True and sst.tier == "ssd"
    elapsed = db.sim.now - t0
    expect = sst.size_bytes / m.rate_limit
    assert elapsed >= expect * 0.9, "migration must respect the rate limit"


def test_cache_hot_sst_counts_logical_reads():
    """Regression: num_reads (the §3.4 popularity signal) was only
    incremented on block-cache *misses*, so a fully cache-resident hot
    SST looked cold and became the demotion victim.  Logical reads must
    count whether or not the block cache absorbs the I/O."""
    db = DB("HHZS", tiny_scenario(), store_values=True)
    for k in range(64):
        db.put(k, b"v%d" % k)
    db.flush_all()
    db.drain()
    sst = next(s for lvl in db.tree.levels for s in lvl
               if s.min_key <= 5 <= s.max_key)
    base = sst.num_reads
    dev_reads_before = db.ssd.counters.read_ops + db.hdd.counters.read_ops
    for _ in range(50):
        assert db.get(5) == (True, b"v5")
    dev_reads = (db.ssd.counters.read_ops + db.hdd.counters.read_ops
                 - dev_reads_before)
    # the block cache absorbed almost everything...
    assert dev_reads <= 2, "repeated point reads should be cache hits"
    # ...yet every logical read counted toward popularity
    assert sst.num_reads - base >= 50
    # victim selection: the migrator must now demote an idle sibling,
    # not the cache-hot SST (pre-fix the hot SST's rate was ~1/age and
    # it lost SSD residency)
    now = db.sim.now
    idle = _sst(990, sst.level, reads=5, birth=sst.birth)
    victim = max([sst, idle], key=lambda s: priority_key(s, now))
    assert victim is idle


def test_swap_hysteresis_blocks_marginal_swaps():
    db = DB("HHZS", tiny_scenario())
    be = db.backend
    now = 1000.0
    db.sim.now = now
    hot = _sst(910, 3, reads=100, birth=0.0)
    cold = _sst(911, 3, reads=95, birth=0.0)
    hot.tier, cold.tier = "hdd", "ssd"
    m = be.migrator
    assert not (hot.level < cold.level
                or hot.read_rate(now) > cold.read_rate(now)
                * m.swap_hysteresis)
