"""Serving engine + KV tiering: invariants and correctness vs dense decode."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")   # tier-1 runs a no-jax matrix leg
import jax.numpy as jnp            # noqa: E402

from repro.configs import get_config
from repro.models import init_params, model as M
from repro.serving import HHZSKVManager, PagedPool, Request, ServingEngine

pytestmark = pytest.mark.slow  # serving-engine e2e decode, ~1 min; run with -m slow


def _pools(layers=2, kv=2, d=16, hbm=4, host=16, ppz=2, ps=8):
    mk = lambda name, zones, host_: PagedPool(name, layers, zones, ppz, ps,
                                              kv, d, host=host_)
    return mk("hbm", hbm, False), mk("host", host, True)


def test_zone_semantics():
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=1)
    assert z.remaining(hbm.page_size) == 16
    lk = jnp.ones((2, 2, 16))
    for i in range(16):
        hbm.write_token(z, lk, lk)
    assert z.remaining(hbm.page_size) == 0
    hbm.reset_zone(z)
    assert hbm.num_free() == 4


def test_tier_manager_demotes_under_pressure():
    hbm, host = _pools(hbm=2)
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    lk = jnp.ones((2, 2, 16))
    seqs = []
    for sid in range(4):
        seq = mgr.on_prefill(sid, tokens=16)
        for _ in range(16):
            zone = mgr.writable_zone(seq)
            mgr.pool_of(seq).write_token(zone, lk, lk)
            seq.length += 1
        seqs.append(seq)
    tiers = [s.tier for s in seqs]
    assert "host" in tiers, "pressure must push sequences to the host tier"
    # zones conserved: every allocated zone owned by a live sequence
    owned = sum(len(s.zones) for s in mgr.seqs.values())
    used_hbm = hbm.zones and sum(1 for z in hbm.zones if z.owner not in
                                 (None, -1))
    assert owned == used_hbm + sum(1 for z in host.zones if z.owner
                                   is not None)


def test_release_reclaims_zones():
    hbm, host = _pools()
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    lk = jnp.ones((2, 2, 16))
    seq = mgr.on_prefill(0, tokens=20)
    for _ in range(20):
        mgr.pool_of(seq).write_token(mgr.writable_zone(seq), lk, lk)
        seq.length += 1
    free_before = hbm.num_free()
    mgr.release(0)
    assert hbm.num_free() > free_before
    assert 0 not in mgr.seqs


def test_engine_matches_dense_decode_without_pressure():
    """With ample HBM the paged engine must generate the same tokens as
    the dense-cache decode path (bookkeeping correctness)."""
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.array([5, 9, 2, 7, 1, 3, 8, 4], np.int32)
    gen = 5

    eng = ServingEngine(cfg, params, hbm_zones=16, host_zones=16,
                        pages_per_zone=4, page_size=8, max_batch=1,
                        cache_zones=0)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=gen))
    eng.run(max_steps=20)
    got = eng.done[0].out_tokens

    # dense reference
    caches = M.init_caches(cfg, 1, 64)
    toks = jnp.asarray(prompt)[None]
    logits = M.forward(cfg, params, {"tokens": toks}, remat=False)
    nxt = int(jnp.argmax(logits[0, -1]))
    ref = [nxt]
    clen = len(prompt)
    # replay prompt through decode to fill the cache, then continue
    caches = M.init_caches(cfg, 1, 64)
    for t in range(len(prompt)):
        _, caches = M.decode_step(cfg, params, toks[:, t:t + 1],
                                  jnp.array([t], jnp.int32), caches)
    cur = nxt
    for i in range(gen - 1):
        lg, caches = M.decode_step(cfg, params,
                                   jnp.array([[cur]], jnp.int32),
                                   jnp.array([clen + i], jnp.int32), caches)
        cur = int(jnp.argmax(lg[0, -1]))
        ref.append(cur)
    assert got == ref


def test_engine_completes_under_pressure_with_migrations():
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, hbm_zones=3, host_zones=48,
                        pages_per_zone=2, page_size=8, max_batch=4,
                        cache_zones=1)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
            max_new_tokens=4))
    stats = eng.run(max_steps=80)
    assert stats["done"] == 6
    assert stats["demotions"] + stats["host_placements"] > 0
    # all zones returned after completion
    assert eng.hbm.num_free() + len(eng.mgr.cache_pool) == 3 * 1 + 0 \
        or eng.hbm.num_free() >= 2
