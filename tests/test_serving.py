"""Serving-stack correctness suite: paged KV zones, tier managers,
placement policies and the open-loop serving runner.

Layout mirrors the stack:

* PagedPool zone semantics — alloc/reset conservation, double-free
  detection, write/read round-trips, partial-zone migration;
* HHZSKVManager — demand-fits placement, cold-only demotion,
  all-or-nothing promotion, §3.5 prefix-cache consistency (each
  regression test here encodes a bug found in the zone-accounting
  audit: the pre-fix code fails it);
* policy baselines — static admission reservations, LRU recency
  eviction;
* run_serving differentials — every policy under ``verify="step"``
  (full KV readback each decode step), cross-policy arrival/churn
  equality, byte-identical rows with telemetry attached;
* a property test over random submit/step/pause/release schedules
  (hypothesis when installed, fixed-seed fallback otherwise — the
  convention of tests/test_lsm.py);
* jax-gated engine tests (`_gather_kv` vs a dense reference; the e2e
  decode equivalence stays behind ``-m slow``).

Everything above the jax section runs honestly on the no-jax CI leg:
the pools fall back to numpy and the serving runner never imports the
model stack.
"""
import json
from types import SimpleNamespace

import numpy as np
import pytest

from repro.serving import (HHZSKVManager, LRUKVManager, PagedPool,
                           StaticHBMManager, make_manager)
from repro.workloads import TenantSpec
from repro.workloads.serving import (ServingCosts, ServingPool,
                                     ServingWorkload, _payload,
                                     build_serving_grid, run_serving,
                                     serving_arrivals)

try:
    import jax
    import jax.numpy as jnp
    HAVE_JAX = True
except ImportError:
    jax = jnp = None
    HAVE_JAX = False

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

L, KV, D = 2, 2, 8
SHAPE = (L, KV, D)


def _pools(hbm=4, host=16, ppz=2, ps=4, materialize=True):
    mk = lambda name, zones, host_: PagedPool(
        name, L, zones, ppz, ps, KV, D, host=host_, materialize=materialize)
    return mk("hbm", hbm, False), mk("host", host, True)


def _fill(mgr, seq, tokens, materialized=True):
    for _ in range(tokens):
        z = mgr.writable_zone(seq)
        if materialized:
            pl = _payload(seq.sid, seq.length, SHAPE)
            mgr.pool_of(seq).write_token(z, pl, pl)
        else:
            mgr.pool_of(seq).write_token(z)
        seq.length += 1


# ======================================================================
# PagedPool zone semantics
# ======================================================================
def test_alloc_reset_conservation():
    hbm, _ = _pools()
    zs = [hbm.alloc_zone(owner=i) for i in range(4)]
    assert all(z is not None for z in zs)
    assert hbm.num_free() == 0 and hbm.alloc_zone(owner=9) is None
    for z in zs:
        hbm.reset_zone(z)
    assert hbm.num_free() == 4
    assert all(z.owner is None and z.write_ptr == 0 for z in hbm.zones)


def test_double_reset_raises():
    """Audit regression: a double reset would enqueue the zone on the
    free list twice and hand it to two owners later."""
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=0)
    hbm.reset_zone(z)
    with pytest.raises(RuntimeError, match="reset twice"):
        hbm.reset_zone(z)
    assert hbm.num_free() == 4          # not double-counted


def test_corrupted_free_list_detected():
    hbm, _ = _pools()
    hbm.zones[hbm._free[0]].owner = 7   # corrupt: free zone with an owner
    with pytest.raises(RuntimeError, match="accounting corrupted"):
        hbm.alloc_zone(owner=1)


def test_write_read_roundtrip():
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=0)
    for pos in range(8):                # ppz*ps = full zone
        pl = _payload(0, pos, SHAPE)
        hbm.write_token(z, pl, pl)
    assert z.remaining(hbm.page_size) == 0
    for pos in range(8):
        k, v = hbm.read_token(z, pos)
        want = _payload(0, pos, SHAPE)
        np.testing.assert_array_equal(k, want)
        np.testing.assert_array_equal(v, want)


def test_read_unwritten_token_raises():
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=0)
    pl = _payload(0, 0, SHAPE)
    hbm.write_token(z, pl, pl)
    with pytest.raises(IndexError):
        hbm.read_token(z, 1)


def test_write_past_zone_capacity_rejected():
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=0)
    pl = _payload(0, 0, SHAPE)
    for _ in range(8):
        hbm.write_token(z, pl, pl)
    with pytest.raises(AssertionError):
        hbm.write_token(z, pl, pl)


def test_accounting_only_pool():
    hbm, _ = _pools(materialize=False)
    z = hbm.alloc_zone(owner=0)
    hbm.write_token(z)                  # no tensors needed
    assert z.write_ptr == 1
    assert hbm.bytes_written == hbm.token_bytes
    with pytest.raises(ValueError, match="no data"):
        hbm.read_token(z, 0)


def test_materialized_pool_requires_tensors():
    hbm, _ = _pools()
    z = hbm.alloc_zone(owner=0)
    with pytest.raises(ValueError, match="needs K/V"):
        hbm.write_token(z)


def test_copy_zone_partial_fill():
    """Audit regression: only pages covered by the source write pointer
    move, and the bytes charged are the written tokens — a half-full
    zone must not pay for (or read) its empty tail."""
    hbm, host = _pools()
    src = hbm.alloc_zone(owner=0)
    for pos in range(5):                # 5 of 8 tokens -> 2 pages touched
        pl = _payload(0, pos, SHAPE)
        hbm.write_token(src, pl, pl)
    dst = host.alloc_zone(owner=0)
    moved = host.copy_zone_from(hbm, src, dst)
    assert moved == 5 * hbm.token_bytes
    assert dst.write_ptr == 5
    for pos in range(5):
        k, _ = host.read_token(dst, pos)
        np.testing.assert_array_equal(k, _payload(0, pos, SHAPE))


def test_copy_zone_page_size_mismatch_raises():
    hbm, _ = _pools(ps=4)
    other = PagedPool("odd", L, 2, 2, 8, KV, D, host=True)
    src = other.alloc_zone(owner=0)
    dst = hbm.alloc_zone(owner=0)
    with pytest.raises(ValueError, match="page-size mismatch"):
        hbm.copy_zone_from(other, src, dst)


def test_copy_zone_overflow_raises():
    big = PagedPool("big", L, 2, 4, 4, KV, D, host=True)
    small = PagedPool("small", L, 2, 2, 4, KV, D, host=True)
    src = big.alloc_zone(owner=0)
    pl = _payload(0, 0, SHAPE)
    for _ in range(12):                 # 12 tokens > small's 8-token zone
        big.write_token(src, pl, pl)
    dst = small.alloc_zone(owner=0)
    with pytest.raises(ValueError, match="overflow"):
        small.copy_zone_from(big, src, dst)


def test_num_free_matches_owner_recount():
    hbm, _ = _pools(hbm=6)
    rng = np.random.default_rng(0)
    held = []
    for _ in range(200):
        if held and rng.random() < 0.5:
            hbm.reset_zone(held.pop(rng.integers(len(held))))
        else:
            z = hbm.alloc_zone(owner=int(rng.integers(100)))
            if z is not None:
                held.append(z)
        free_ids = list(hbm._free)
        assert len(free_ids) == len(set(free_ids))
        assert hbm.num_free() == sum(1 for z in hbm.zones
                                     if z.owner is None)


# ======================================================================
# HHZSKVManager: placement, migration, prefix cache
# ======================================================================
def test_pressure_pushes_sequences_to_host():
    hbm, host = _pools(hbm=2)
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    seqs = []
    for sid in range(4):
        seq = mgr.on_prefill(sid, tokens=8)
        _fill(mgr, seq, 8)
        seqs.append(seq)
    assert "host" in {s.tier for s in seqs}
    owned = sum(len(s.zones) for s in mgr.seqs.values())
    used = sum(1 for p in (hbm, host) for z in p.zones
               if z.owner not in (None, -1))
    assert owned == used


def test_release_reclaims_zones():
    hbm, host = _pools()
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    seq = mgr.on_prefill(0, tokens=10)
    _fill(mgr, seq, 10)
    free_before = hbm.num_free()
    mgr.release(0)
    assert hbm.num_free() > free_before
    assert 0 not in mgr.seqs


def test_prefill_demotes_cold_not_active():
    """§3.3 write-guided placement: the hot prefill claims HBM by
    demoting a *cold* resident; residents active this step stay put.
    (3 zones: one per resident plus the active one's growth demand —
    §3.3 reserves that slack, so only the cold zone is reclaimable.)"""
    hbm, host = _pools(hbm=3)
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    cold = mgr.on_prefill(0, tokens=8)
    _fill(mgr, cold, 8)
    warm = mgr.on_prefill(1, tokens=8)
    _fill(mgr, warm, 8)
    mgr.tick([1])                       # seq 1 active, seq 0 cold
    fresh = mgr.on_prefill(2, tokens=8)
    assert fresh.tier == "hbm"
    assert mgr.seqs[0].tier == "host"   # the cold one paid
    assert mgr.seqs[1].tier == "hbm"    # the active one did not


def test_prefill_lands_on_host_when_only_active_residents():
    hbm, host = _pools(hbm=2)
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    for sid in range(2):
        _fill(mgr, mgr.on_prefill(sid, tokens=8), 8)
    mgr.tick([0, 1])                    # both residents active
    fresh = mgr.on_prefill(2, tokens=8)
    assert fresh.tier == "host"
    assert all(mgr.seqs[s].tier == "hbm" for s in (0, 1))


def test_promotion_is_all_or_nothing():
    """Audit regression: a promotion that cannot reserve every
    destination zone must abort cleanly — the pre-fix code freed host
    zones one by one and stranded the sequence on partial copies."""
    hbm, host = _pools(hbm=2)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)   # 1 free HBM zone left
    seq = mgr.on_prefill(0, tokens=8)
    _fill(mgr, seq, 8)
    mgr._seq_to_host(seq)
    _fill(mgr, seq, 8)                  # grow to 2 host zones
    assert seq.tier == "host" and len(seq.zones) == 2
    free_hbm, free_host = hbm.num_free(), host.num_free()
    assert mgr._promote(seq) == 0       # 2 zones needed, 1 free
    assert seq.tier == "host" and len(seq.zones) == 2
    assert all(z.owner == 0 for z in seq.zones)
    assert (hbm.num_free(), host.num_free()) == (free_hbm, free_host)


def test_demote_promote_demote_no_leak():
    hbm, host = _pools(hbm=4)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)
    seq = mgr.on_prefill(0, tokens=16)
    _fill(mgr, seq, 16)
    total_free = hbm.num_free() + host.num_free()
    for _ in range(3):
        mgr._seq_to_host(seq)
        assert seq.tier == "host"
        assert mgr._promote(seq) > 0
        assert seq.tier == "hbm"
        assert hbm.num_free() + host.num_free() == total_free
    for pos in range(16):               # data survived six migrations
        k, _ = _read_seq(mgr, seq, pos)
        np.testing.assert_array_equal(k, _payload(0, pos, SHAPE))


def _read_seq(mgr, seq, pos):
    pool = mgr.pool_of(seq)
    for z in seq.zones:
        if pos < z.write_ptr:
            return pool.read_token(z, pos)
        pos -= z.write_ptr
    raise IndexError(pos)


def test_cache_admitted_before_source_reset():
    """Audit regression (§3.5 ordering): the prefix copy must happen
    while the demoting sequence's HBM zones still hold valid data —
    admitting after the reset cached an empty zone."""
    hbm, host = _pools(hbm=4)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)
    seq = mgr.on_prefill(0, tokens=8)
    _fill(mgr, seq, 8)
    mgr._seq_to_host(seq)
    cz = mgr.prefix_cache[0]
    assert cz.write_ptr == 8            # not an empty post-reset copy
    for pos in range(8):
        k, _ = mgr.hbm.read_token(cz, pos)
        np.testing.assert_array_equal(k, _payload(0, pos, SHAPE))
    assert seq.prefix_cached


def test_cache_fifo_eviction_reuses_evicted_zone():
    """Audit regression: the FIFO evictee's zone (not an occupancy-indexed
    one) must back the new entry, and the evicted sequence's
    ``prefix_cached`` flag must clear."""
    hbm, host = _pools(hbm=8)
    mgr = HHZSKVManager(hbm, host, cache_zones=2)
    for sid in range(3):
        seq = mgr.on_prefill(sid, tokens=8)
        _fill(mgr, seq, 8)
        mgr._seq_to_host(seq)
    assert 0 not in mgr.prefix_cache            # FIFO evicted the oldest
    assert not mgr.seqs[0].prefix_cached
    assert mgr.seqs[1].prefix_cached and mgr.seqs[2].prefix_cached
    zids = {z.zid for z in mgr.prefix_cache.values()}
    assert len(zids) == 2                        # no zone collision
    assert zids <= {z.zid for z in mgr.cache_pool}
    for sid in (1, 2):                           # survivors read back clean
        cz = mgr.prefix_cache[sid]
        for pos in range(cz.write_ptr):
            k, _ = mgr.hbm.read_token(cz, pos)
            np.testing.assert_array_equal(k, _payload(sid, pos, SHAPE))


def test_promote_drops_cache_entry():
    hbm, host = _pools(hbm=6)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)
    seq = mgr.on_prefill(0, tokens=8)
    _fill(mgr, seq, 8)
    mgr._seq_to_host(seq)
    assert 0 in mgr.prefix_cache
    assert mgr._promote(seq) > 0
    assert 0 not in mgr.prefix_cache and not seq.prefix_cached


def test_residency_accounting():
    hbm, host = _pools(hbm=6)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)
    seq = mgr.on_prefill(0, tokens=12)
    _fill(mgr, seq, 12)
    assert mgr.residency(seq) == (12, 0)
    mgr._seq_to_host(seq)
    h, c = mgr.residency(seq)
    assert h + c == 12
    assert h == min(mgr.prefix_cache[0].write_ptr, 12) == 8  # 1 zone cached
    assert mgr.stats["cache_hits"] >= 1


def test_preempt_stall_counter():
    hbm, host = _pools(hbm=2)
    mgr = HHZSKVManager(hbm, host, cache_zones=0)
    for sid in range(2):
        _fill(mgr, mgr.on_prefill(sid, tokens=8), 8)
    mgr.tick([0, 1])                    # both decoded this step
    before = mgr.stats["preempt_stalls"]
    assert mgr._demote_one(exclude=0)   # forced to evict an active seq
    assert mgr.stats["preempt_stalls"] == before + 1


# ======================================================================
# policy baselines
# ======================================================================
def test_static_admission_reservations():
    hbm, host = _pools(hbm=4)           # 4 zones x 8 tokens
    mgr = StaticHBMManager(hbm, host)
    assert mgr.admit(0, 16)             # 2 zones
    assert mgr.admit(1, 8)              # 1 zone
    assert not mgr.admit(2, 16)         # 2 zones > 4 - 3 outstanding
    assert mgr.admit(3, 8)              # the last zone
    for sid, toks in ((0, 16), (1, 8), (3, 8)):
        seq = mgr.on_prefill(sid, toks)
        _fill(mgr, seq, toks)           # reservations guarantee room
        assert seq.tier == "hbm"
    mgr.release(0)
    assert mgr.admit(4, 16)             # freed zones re-admittable


def test_static_never_migrates():
    hbm, host = _pools(hbm=4)
    mgr = StaticHBMManager(hbm, host)
    assert mgr.admit(0, 8)
    seq = mgr.on_prefill(0, 8)
    _fill(mgr, seq, 8)
    mgr.tick([0])
    assert seq.tier == "hbm"
    assert host.num_free() == 16        # host tier untouched
    assert mgr.stats["demotions"] == mgr.stats["promotions"] == 0


def test_lru_victim_is_least_recently_used():
    hbm, host = _pools(hbm=2)
    mgr = LRUKVManager(hbm, host)
    for sid in range(2):
        _fill(mgr, mgr.on_prefill(sid, tokens=8), 8)
    mgr.tick([1])                       # seq 0 goes stale
    mgr.tick([1])
    assert mgr._demote_one(exclude=-1)
    assert mgr.seqs[0].tier == "host"   # recency, not level, chose it
    assert mgr.seqs[1].tier == "hbm"


def test_lru_prefill_always_starts_in_hbm():
    hbm, host = _pools(hbm=2)
    mgr = LRUKVManager(hbm, host)
    for sid in range(2):
        _fill(mgr, mgr.on_prefill(sid, tokens=8), 8)
    mgr.tick([0, 1])                    # both residents active
    fresh = mgr.on_prefill(2, tokens=8)
    assert fresh.tier == "hbm"          # hint-blind: evicts actives anyway
    _fill(mgr, fresh, 8)
    assert "host" in {mgr.seqs[s].tier for s in (0, 1)}


def test_make_manager_dispatch():
    hbm, host = _pools()
    assert isinstance(make_manager("static", hbm, host), StaticHBMManager)
    hbm2, host2 = _pools()
    assert isinstance(make_manager("lru", hbm2, host2), LRUKVManager)
    hbm3, host3 = _pools()
    mgr = make_manager("hhzs", hbm3, host3, cache_zones=1)
    assert type(mgr) is HHZSKVManager
    with pytest.raises(ValueError, match="unknown serving policy"):
        make_manager("fifo", hbm, host)


# ======================================================================
# run_serving differentials
# ======================================================================
_TEST_WL = ServingWorkload(name="chat", prompt_med=24, prompt_max=64,
                           out_med=12, out_max=32, pause_prob=0.02,
                           pause_mean=2.0, slo_ttft=2.0)


def _run(policy, *, verify=False, materialize=False, duration=25.0,
         registry=None, sim=None, seed=3, hbm=6):
    arr = serving_arrivals(("poisson",), 2.0)[0]
    return run_serving(
        [TenantSpec("t0", _TEST_WL, arr, protected=True, slo_p99=2.0)],
        policy, pool=ServingPool(hbm_zones=hbm, host_zones=48),
        duration=duration, warmup=5.0, seed=seed, verify=verify,
        materialize=materialize, registry=registry, sim=sim)


@pytest.mark.parametrize("policy", ["static", "lru", "hhzs"])
def test_verify_step_differential(policy):
    """Full resident-KV readback after every decode step: any migration
    or cache admit that corrupts, drops or aliases a page fails here."""
    res = _run(policy, verify="step", materialize=True)
    r = res.rows[0]
    assert r["n_completed"] > 0
    if policy != "static":
        assert r["demote_pages"] > 0    # the differential saw migrations


def test_arrival_and_churn_streams_policy_independent():
    """The seeded draws (arrivals, lengths, pause churn) must not depend
    on the policy, or cross-policy comparisons are meaningless."""
    rows = {p: _run(p).rows[0] for p in ("lru", "hhzs")}
    for key in ("n_arrived", "admitted", "tokens_out", "pauses",
                "offered_rate"):
        assert rows["lru"][key] == rows["hhzs"][key], key


def test_all_admitted_sequences_complete_and_zones_return():
    from repro.zoned.sim import Sim
    sim = Sim()
    res = _run("hhzs", sim=sim)
    r = res.rows[0]
    assert r["n_completed"] == r["admitted"] == r["n_arrived"]
    assert r["rejected"] == 0
    spool = ServingPool(hbm_zones=6, host_zones=48)
    assert res.stats["hbm_free_zones"] == spool.hbm_zones - spool.cache_zones
    assert res.stats["host_free_zones"] == spool.host_zones


def test_static_conservation_under_rejection():
    res = _run("static", hbm=3, duration=40.0)
    r = res.rows[0]
    assert r["rejected"] > 0            # tiny pool must shed
    assert r["n_arrived"] == r["admitted"] + r["rejected"]
    assert r["n_completed"] == r["admitted"]
    assert r["hbm_hit_rate"] == 1.0     # never touches the host tier
    assert r["migrated_bytes"] == 0


def test_rows_byte_identical_with_telemetry():
    """Telemetry is pull-only: attaching the metrics registry must not
    change a single row byte (the grid-smoke CI invariant)."""
    from repro.obs.metrics import MetricsRegistry
    from repro.zoned.sim import Sim
    base = json.dumps(_run("hhzs").rows, sort_keys=True)
    sim = Sim()
    reg = MetricsRegistry(sim, 5.0)
    res = _run("hhzs", sim=sim, registry=reg)
    assert json.dumps(res.rows, sort_keys=True) == base
    reg.sample_now()
    tl = reg.timeline()
    assert any(s.startswith("serving.") for s in tl["series"])


def test_slo_columns_present():
    r = _run("hhzs").rows[0]
    assert r["slo_p99"] == 2.0
    assert isinstance(r["slo_met"], bool)
    assert r["goodput"] >= 0.0
    assert set(r["ttft_p"]) == {"p50", "p90", "p99", "p999", "p9999"}


def test_unknown_policy_and_arrival_rejected():
    arr = serving_arrivals(("poisson",), 1.0)[0]
    with pytest.raises(ValueError, match="unknown policy"):
        run_serving([TenantSpec("t", _TEST_WL, arr)], "mru")
    with pytest.raises(ValueError, match="unknown arrival"):
        serving_arrivals(("sawtooth",), 1.0)
    with pytest.raises(ValueError, match="materialize"):
        run_serving([TenantSpec("t", _TEST_WL, arr)], "hhzs", verify=True)


def test_serving_grid_cells_and_matrix_cell():
    matrix = build_serving_grid(
        ("lru", "hhzs"), ("poisson", "bursty"), (6, 8),
        rate=1.5, duration=15.0, warmup=3.0, workload=_TEST_WL)
    cells = matrix.cells()
    assert len(cells) == 2 * 2 * 2
    names = [c.name for c in cells]
    assert len(set(names)) == len(names)
    assert all(n.startswith("serving/") for n in names)
    _, rows = matrix.run_cell(cells[0])
    assert rows and all(r["cell"] == cells[0].name for r in rows)
    assert rows[0]["tiering"] == cells[0].policy


def test_serving_rows_pass_schema_lint():
    pytest.importorskip("benchmarks.validate_results")
    from benchmarks.validate_results import row_kind, validate_rows
    rows = _run("hhzs").rows
    for r in rows:
        r["cell"] = "serving/test"
    assert row_kind(rows[0]) == "serving"
    assert validate_rows(rows, "test") == []
    bad = dict(rows[0], n_arrived=rows[0]["n_arrived"] + 1)
    assert any("conservation" in e
               for e in validate_rows([bad], "test"))


# ======================================================================
# property test: random schedules keep zone accounting consistent
# ======================================================================
def _check_zone_invariants(mgr, hbm, host):
    for pool in (hbm, host):
        free = set(pool._free)
        assert len(free) == len(pool._free), "free-list duplicate"
        for z in pool.zones:
            assert (z.owner is None) == (z.zid in free), \
                f"{pool.name} zone {z.zid}: owner {z.owner} vs free list"
    seen = set()
    for sid, seq in mgr.seqs.items():
        pool = mgr.pool_of(seq)
        for z in seq.zones:
            assert pool.zones[z.zid] is z, "zone mapped in the wrong tier"
            assert z.owner == sid, \
                f"zone {z.zid} owned by {z.owner}, mapped by {sid}"
            key = (pool.name, z.zid)
            assert key not in seen, f"zone {key} mapped twice"
            seen.add(key)
    for z in mgr.cache_pool:
        assert z.owner == -1 and mgr.hbm.zones[z.zid] is z
    assert {z.zid for z in mgr.prefix_cache.values()} <= \
        {z.zid for z in mgr.cache_pool}


def _apply_schedule(policy, ops):
    hbm, host = _pools(hbm=4, host=24, materialize=False)
    mgr = make_manager(policy, hbm, host, cache_zones=1)
    live, next_sid = [], 0
    for op, arg in ops:
        if op == "submit":
            tokens = 1 + arg % 20
            if not mgr.admit(next_sid, tokens):
                continue
            seq = mgr.on_prefill(next_sid, tokens)
            _fill(mgr, seq, tokens, materialized=False)
            live.append(next_sid)
            next_sid += 1
        elif op == "step" and live:
            active = live[:1 + arg % 4]
            mgr.tick(active)
            for sid in active:
                _fill(mgr, mgr.seqs[sid], 1, materialized=False)
        elif op == "rotate" and live:   # churn: demote the head manually
            live.append(live.pop(0))
        elif op == "release" and live:
            mgr.release(live.pop(arg % len(live)))
        _check_zone_invariants(mgr, hbm, host)
    for sid in live:
        mgr.release(sid)
    _check_zone_invariants(mgr, hbm, host)
    assert hbm.num_free() == 4 - len(mgr.cache_pool)
    assert host.num_free() == 24


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(policy=st.sampled_from(["static", "lru", "hhzs"]),
           ops=st.lists(
               st.tuples(st.sampled_from(["submit", "step", "rotate",
                                          "release"]),
                         st.integers(min_value=0, max_value=40)),
               min_size=5, max_size=80))
    def test_zone_accounting_property(policy, ops):
        _apply_schedule(policy, ops)


@pytest.mark.parametrize("policy", ["static", "lru", "hhzs"])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_zone_accounting_property_deterministic(policy, seed):
    """Fallback for environments without hypothesis: fixed-seed
    schedules through the same invariant checker."""
    rng = np.random.default_rng(seed)
    ops = [(("submit", "step", "rotate", "release")[int(rng.integers(4))],
            int(rng.integers(0, 40))) for _ in range(120)]
    _apply_schedule(policy, ops)


# ======================================================================
# jax-gated: the real engine against dense references
# ======================================================================
@pytest.mark.skipif(not HAVE_JAX, reason="needs jax")
def test_gather_kv_matches_dense_reference():
    """`_gather_kv` must return exactly the tokens written, in order,
    before and after a tier migration."""
    from repro.serving import ServingEngine
    hbm, host = _pools(hbm=4, ps=4)
    mgr = HHZSKVManager(hbm, host, cache_zones=1)
    seq = mgr.on_prefill(0, tokens=13)
    ref = []
    for pos in range(13):
        pl = _payload(0, pos, SHAPE)
        mgr.pool_of(seq).write_token(mgr.writable_zone(seq), pl, pl)
        seq.length += 1
        ref.append(pl)
    eng = SimpleNamespace(
        mgr=mgr, page_size=hbm.page_size,
        cfg=SimpleNamespace(num_kv_heads=KV, head_dim_=D))
    req = SimpleNamespace(rid=0)
    for layer in range(L):
        k, v = ServingEngine._gather_kv(eng, req, layer)
        want = np.stack([p[layer] for p in ref])
        np.testing.assert_array_equal(np.asarray(k), want)
        np.testing.assert_array_equal(np.asarray(v), want)
    mgr._seq_to_host(seq)               # migrate, then re-check
    k, _ = ServingEngine._gather_kv(eng, req, 0)
    np.testing.assert_array_equal(np.asarray(k),
                                  np.stack([p[0] for p in ref]))


@pytest.mark.skipif(not HAVE_JAX, reason="needs jax")
@pytest.mark.slow
def test_engine_matches_dense_decode_without_pressure():
    """With ample HBM the paged engine must generate the same tokens as
    the dense-cache decode path (bookkeeping correctness)."""
    from repro.configs import get_config
    from repro.models import init_params
    from repro.models import model as M
    from repro.serving import Request, ServingEngine
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = np.array([5, 9, 2, 7, 1, 3, 8, 4], np.int32)
    gen = 5

    eng = ServingEngine(cfg, params, hbm_zones=16, host_zones=16,
                        pages_per_zone=4, page_size=8, max_batch=1,
                        cache_zones=0)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=gen))
    eng.run(max_steps=20)
    got = eng.done[0].out_tokens

    toks = jnp.asarray(prompt)[None]
    logits = M.forward(cfg, params, {"tokens": toks}, remat=False)
    nxt = int(jnp.argmax(logits[0, -1]))
    ref = [nxt]
    clen = len(prompt)
    caches = M.init_caches(cfg, 1, 64)
    for t in range(len(prompt)):
        _, caches = M.decode_step(cfg, params, toks[:, t:t + 1],
                                  jnp.array([t], jnp.int32), caches)
    cur = nxt
    for i in range(gen - 1):
        lg, caches = M.decode_step(cfg, params,
                                   jnp.array([[cur]], jnp.int32),
                                   jnp.array([clen + i], jnp.int32), caches)
        cur = int(jnp.argmax(lg[0, -1]))
        ref.append(cur)
    assert got == ref


@pytest.mark.skipif(not HAVE_JAX, reason="needs jax")
@pytest.mark.slow
def test_engine_completes_under_pressure_with_migrations():
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serving import Request, ServingEngine
    cfg = get_config("qwen3-1.7b").smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, hbm_zones=3, host_zones=48,
                        pages_per_zone=2, page_size=8, max_batch=4,
                        cache_zones=1)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, 10).astype(np.int32),
            max_new_tokens=4))
    stats = eng.run(max_steps=80)
    assert stats["done"] == 6
    assert stats["demotions"] + stats["host_placements"] > 0
    assert eng.hbm.num_free() >= 2
