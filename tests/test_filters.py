"""Real Bloom-filter stack: cross-implementation differential + FP bounds.

The hash family is unified across three implementations — the pure-numpy
fallback (``repro.lsm.filters``), the jnp oracle
(``repro.kernels.bloom_probe.ref``) and the Pallas kernel (interpret
mode) — all fed by the same host-side splitmix64 pre-hash.  They must
agree bit-for-bit on hit masks, including on adversarial key sets
(duplicates, 0, 2**64 - 1).
"""
import math

import numpy as np
import pytest

from repro.lsm import filters


def _adversarial_keys(rng, n):
    keys = rng.integers(0, 2**63, n).astype(np.uint64)
    keys[0] = np.uint64(0)
    keys[1] = np.uint64(2**64 - 1)
    keys[2] = np.uint64(2**64 - 1)          # duplicate extreme
    keys[3:6] = keys[6]                     # duplicate run
    return keys


# ----------------------------------------------------------------------
@pytest.mark.parametrize("bits_per_key,n", [(10, 1024), (4, 2048), (16, 512)])
def test_numpy_build_probe_no_false_negatives(bits_per_key, n):
    rng = np.random.default_rng(0)
    keys = _adversarial_keys(rng, n)
    nw, k = filters.filter_params(n, bits_per_key)
    lo, hi = filters.split_hash(keys)
    bits = filters.build_filter_np(lo, hi, nw, k)
    assert filters.probe_np(lo, hi, bits, k).all(), \
        "a Bloom filter must never produce false negatives"


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("bits_per_key", [4, 10, 16])
def test_build_filter_fp_rate_within_tolerance(seed, bits_per_key):
    """Measured FP rate tracks the theoretical (1 - e^{-kn/m})^k."""
    rng = np.random.default_rng(seed)
    n = 4096
    member = rng.integers(0, 2**62, n).astype(np.uint64)
    nw, k = filters.filter_params(n, bits_per_key)
    lo, hi = filters.split_hash(member)
    bits = filters.build_filter_np(lo, hi, nw, k)
    # disjoint non-member population
    non = rng.integers(2**62, 2**63, 20_000).astype(np.uint64)
    qlo, qhi = filters.split_hash(non)
    fp = float(filters.probe_np(qlo, qhi, bits, k).mean())
    theory = (1.0 - math.exp(-k * n / (nw * 32.0))) ** k
    assert theory * 0.5 <= fp <= theory * 2.0 + 1e-4, (fp, theory)


def test_scalar_probe_matches_vectorized():
    """The per-key `get` fast path (python ints) is bitwise-identical to
    the vectorized numpy probe."""
    rng = np.random.default_rng(7)
    member = _adversarial_keys(rng, 512)
    nw, k = filters.filter_params(len(member), 10)
    lo, hi = filters.split_hash(member)
    bits = filters.build_filter_np(lo, hi, nw, k)
    queries = np.concatenate([member[:256],
                              rng.integers(0, 2**64, 1024, dtype=np.uint64)])
    qlo, qhi = filters.split_hash(queries)
    vec = filters.probe_np(qlo, qhi, bits, k)
    sca = np.array([filters.probe_one_np(int(q), bits, k) for q in queries])
    assert (vec == sca).all()


def test_pairs_probe_matches_single_filter():
    """The ragged (key x filter) pairs probe equals per-filter probes."""
    rng = np.random.default_rng(11)
    sets = [rng.integers(0, 2**63, n).astype(np.uint64)
            for n in (64, 300, 1000)]
    built = []
    for keys in sets:
        nw, k = filters.filter_params(len(keys), 10)
        lo, hi = filters.split_hash(keys)
        built.append((filters.build_filter_np(lo, hi, nw, k), nw, k))
    k = built[0][2]
    queries = rng.integers(0, 2**64, 512, dtype=np.uint64)
    qlo, qhi = filters.split_hash(queries)
    # pairs: every query against every filter
    bits_concat = np.concatenate([b for b, _, _ in built])
    offs, cur = [], 0
    for _, nw, _ in built:
        offs.append(cur)
        cur += nw
    p_lo = np.tile(qlo, len(built))
    p_hi = np.tile(qhi, len(built))
    p_off = np.repeat(np.array(offs, np.int64), len(queries))
    p_nw = np.repeat(np.array([nw for _, nw, _ in built], np.int64),
                     len(queries))
    pairs = filters.probe_pairs_np(p_lo, p_hi, p_off, p_nw, bits_concat, k)
    singles = np.concatenate([filters.probe_np(qlo, qhi, b, k)
                              for b, _, _ in built])
    assert (pairs == singles).all()


# ----------------------------------------------------------------------
def test_numpy_vs_jnp_vs_pallas_bit_identical():
    """All three implementations agree exactly on hit masks (adversarial
    keys: duplicates, 0, 2**64-1).  Skip-guarded: the no-jax tier-1 leg
    still exercises every numpy test above."""
    pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels.bloom_probe.ops import probe
    from repro.kernels.bloom_probe.ref import (build_filter,
                                               bloom_probe_pairs_ref,
                                               bloom_probe_ref)

    rng = np.random.default_rng(3)
    member = _adversarial_keys(rng, 4096)
    nw, k = filters.filter_params(len(member), 10)
    lo, hi = filters.split_hash(member)
    bits_np = filters.build_filter_np(lo, hi, nw, k)
    bits_j = np.asarray(build_filter(jnp.array(lo), jnp.array(hi), nw,
                                     k_hashes=k))
    assert (bits_np == bits_j).all(), "builders diverge"

    queries = np.concatenate([
        member[:1024],
        np.array([0, 2**64 - 1, 2**64 - 1, 1], dtype=np.uint64),
        rng.integers(0, 2**64, 1020, dtype=np.uint64)])
    qlo, qhi = filters.split_hash(queries)
    h_np = filters.probe_np(qlo, qhi, bits_np, k)
    h_ref = np.asarray(bloom_probe_ref(jnp.array(qlo), jnp.array(qhi),
                                       jnp.array(bits_np),
                                       k_hashes=k)).astype(bool)
    h_ker = np.asarray(probe(jnp.array(qlo), jnp.array(qhi),
                             jnp.array(bits_np), k_hashes=k,
                             interpret=True)).astype(bool)
    assert (h_np == h_ref).all(), "numpy fallback != jnp oracle"
    assert (h_np == h_ker).all(), "numpy fallback != pallas kernel"
    assert h_np[:1024].all(), "false negative"

    # ragged pairs probe: jnp route == numpy route
    off = np.zeros(len(queries), np.int64)
    nws = np.full(len(queries), nw, np.int64)
    p_ref = np.asarray(bloom_probe_pairs_ref(
        jnp.array(qlo), jnp.array(qhi), jnp.array(off.astype(np.int32)),
        jnp.array(nws.astype(np.uint32)), jnp.array(bits_np),
        k_hashes=k)).astype(bool)
    assert (p_ref == h_np).all()


def test_tree_jax_impl_matches_numpy_impl():
    """A store probing through the kernel package returns identical
    results to the numpy-fallback store (filter_impl is I/O-invisible)."""
    pytest.importorskip("jax")
    from dataclasses import replace

    from conftest import tiny_scenario
    from repro.lsm import DB

    answers = []
    for impl in ("numpy", "jax"):
        sc = tiny_scenario()
        sc = replace(sc, lsm=replace(sc.lsm, filter_impl=impl))
        db = DB("HHZS", sc, store_values=True)
        rng = np.random.default_rng(5)
        model = {}
        for i, k in enumerate(rng.integers(0, 200, size=400)):
            v = b"v%d-%d" % (k, i)
            db.put(int(k), v)
            model[int(k)] = v
        db.drain()
        keys = list(range(0, 250))
        answers.append(db.get_batch(keys))
        for key, got in zip(keys, answers[-1]):
            assert got == (key in model, model.get(key))
    assert answers[0] == answers[1]
