"""HHZS core: demand accounting, tiering level, placement, cache, WAL."""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.core.hints import (CompactionDoneHint, CompactionOutputHint,
                              CompactionTriggerHint)
from repro.core.placement import HHZSPlacement
from repro.lsm import DB


def test_demand_accounting_balances():
    db = DB("HHZS", tiny_scenario())
    pl = db.backend.placement
    pl.on_hint(CompactionTriggerHint(cid=1, selected_sst_ids=(1, 2, 3),
                                     target_level=2))
    assert pl.demand_of(2) == 3
    pl.on_hint(CompactionOutputHint(cid=1, sst_id=9, level=2))
    assert pl.demand_of(2) == 2
    pl.on_hint(CompactionDoneHint(cid=1, target_level=2, num_selected=3,
                                  num_generated=1))
    assert pl.demand_of(2) == 0


def test_demand_no_phantom_when_overgenerating():
    """A compaction generating more SSTs than selected must not leak."""
    db = DB("HHZS", tiny_scenario())
    pl = db.backend.placement
    pl.on_hint(CompactionTriggerHint(cid=7, selected_sst_ids=(1, 2),
                                     target_level=1))
    for sid in range(5):      # generated (5) > selected (2)
        pl.on_hint(CompactionOutputHint(cid=7, sst_id=sid, level=1))
    pl.on_hint(CompactionDoneHint(cid=7, target_level=1, num_selected=2,
                                  num_generated=5))
    assert pl.demand_of(1) == 0


def test_demand_quiesces_after_load():
    db = DB("HHZS", tiny_scenario())
    for k in np.random.default_rng(0).permutation(3000):
        db.put(int(k))
    db.drain()
    pl = db.backend.placement
    for lvl in range(1, 5):
        assert pl.demand_of(lvl) == 0, "no live compactions -> no demand"


def test_tiering_level_math():
    db = DB("HHZS", tiny_scenario())
    pl = db.backend.placement
    c = db.backend.c_ssd()
    # no SSTs, no demand: everything fits -> tiering level = num_levels
    assert pl.tiering_level() == pl.num_levels
    # inject demand exceeding the SSD at L1
    pl.on_hint(CompactionTriggerHint(cid=1, selected_sst_ids=tuple(range(c + 1)),
                                     target_level=1))
    assert pl.tiering_level() == 1
    assert pl.reserved_for_tiering(1) <= c


def test_flush_always_prefers_ssd():
    db = DB("HHZS", tiny_scenario())
    pl = db.backend.placement
    assert pl.choose_tier(0, "flush") == "ssd"


def test_reserved_zones_not_used_for_ssts():
    db = DB("HHZS", tiny_scenario())
    for k in np.random.default_rng(1).permutation(4000):
        db.put(int(k))
    db.drain()
    be = db.backend
    for sst in be.ssts.values():
        if sst.tier == "ssd":
            for z in sst.zones:
                assert z.zid not in be.reserve_zids


def test_wal_fits_in_reserved_zones():
    db = DB("HHZS", tiny_scenario())
    for k in np.random.default_rng(2).permutation(3000):
        db.put(int(k))
    # every WAL record lives in a reserved zone on the SSD
    for rec in db.backend._wal_records:
        assert rec["zone"].zid in db.backend.reserve_zids


def test_basic_scheme_spills_wal_when_ssd_full():
    db = DB("B3", tiny_scenario(ssd_zones=3))
    for k in np.random.default_rng(3).permutation(3000):
        db.put(int(k))
    db.drain()
    assert db.hdd.counters.by_tag_write.get("wal", 0) > 0


def test_hinted_cache_admission_and_fifo():
    db = DB("HHZS", tiny_scenario())
    for k in np.random.default_rng(4).permutation(4000):
        db.put(int(k))
    db.flush_all()
    # skewed reads to drive block-cache evictions -> SSD cache admissions
    from repro.workloads import zipf_probs
    p = zipf_probs(4000, 1.2)
    keys = np.random.default_rng(5).choice(4000, size=6000, p=p)
    for k in keys:
        db.get(int(k))
    db.drain()
    c = db.backend.cache
    assert c.admitted > 0
    # mapping consistency: every mapped block's zone is a live cache zone
    live = {z.zid for z in c.zones}
    for (sid, blk), zid in c.mapping.items():
        assert zid in live


def test_cache_dropped_on_sst_death():
    db = DB("HHZS", tiny_scenario())
    c = db.backend.cache
    # fabricate a mapping, then delete the SST id
    c.mapping[(123, 0)] = 99
    c.by_sst[123] = {0}
    c.drop_sst(123)
    assert (123, 0) not in c.mapping


def test_hdd_read_rate_excludes_partial_current_second():
    """Regression: the rate window included the partial current-second
    bucket, diluting the rate (and delaying popularity migration) right
    after a read burst."""
    db = DB("HHZS", tiny_scenario())
    be = db.backend
    db.sim.now = 100.7
    w = int(be._hdd_window)
    for s in range(100 - w, 100):
        be._hdd_buckets[s] = 5          # complete seconds: 5 reads/s
    be._hdd_buckets[100] = 1            # partial current second: excluded
    assert be.hdd_read_rate() == pytest.approx(5.0)


def test_hdd_read_rate_prunes_stale_buckets():
    """Regression: buckets in (now-2w, now-w] were retained forever while
    the dict stayed small."""
    db = DB("HHZS", tiny_scenario())
    be = db.backend
    w = int(be._hdd_window)
    db.sim.now = 50.0
    for s in range(40, 50):
        be._hdd_buckets[s] = 3
    assert be.hdd_read_rate() == pytest.approx(3.0)
    db.sim.now = 50.0 + w + 3            # whole old window is now stale
    assert be.hdd_read_rate() == 0.0
    assert all(k >= int(db.sim.now) - w for k in be._hdd_buckets), \
        "stale buckets must be pruned even when the dict is small"


def test_auto_space_guards():
    db = DB("AUTO", tiny_scenario())
    pl = db.backend.placement
    pl.max_level = 4
    # exhaust SSD zones -> below 8% remaining -> no SST writes to SSD
    while db.ssd.num_empty() > 1:
        z = db.ssd.alloc_zone("x")
    assert pl.choose_tier(0, "flush") == "hdd"
