"""Telemetry bus (repro.obs) + compaction-debt control plane.

Covers the registry primitives (counters, gauges, collectors, windowed
rates, bounded ring buffers, the daemon sampler), the layer
instrumentation wired by ``DB.enable_telemetry``, the two contracts the
subsystem ships with — telemetry-on runs are *event-for-event identical*
to telemetry-off runs, and the timeline artifact validates against the
schema linter — and the ControlPlane's AIMD feedback (including the
acceptance shape: feedback beats a static token bucket on protected-tenant
p99 at equal-or-better total goodput).
"""
import importlib.util
import sys
from pathlib import Path

import numpy as np
import pytest

from conftest import tiny_scenario
from repro.core.middleware import AdmissionConfig, AdmissionController
from repro.lsm import DB
from repro.obs import ControlPlane, MetricsRegistry
from repro.workloads import (PoissonArrivals, ScenarioMatrix, TenantSpec,
                             WorkloadSpec, YCSB, run_load, run_multi_tenant,
                             run_open_loop)
from repro.zoned import Sim


def _load_validator():
    """Load benchmarks/validate_results.py by path (the benchmarks dir is
    a namespace package only importable from the repo root)."""
    p = Path(__file__).resolve().parent.parent / "benchmarks" \
        / "validate_results.py"
    spec = importlib.util.spec_from_file_location("_validate_results", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _loaded(scheme="HHZS", n=1200, **db_kw):
    db = DB(scheme, tiny_scenario(), store_values=True, **db_kw)
    run_load(db, n_keys=n)
    db.flush_all()
    return db, n


# ---------------------------------------------------------------------
# registry primitives
# ---------------------------------------------------------------------
def test_counter_gauge_and_series():
    sim = Sim()
    reg = MetricsRegistry(sim, sample_period=1.0)
    c = reg.counter("ops")
    state = {"v": 10.0}
    reg.gauge("depth", lambda: state["v"])
    c.add(3)
    reg.sample_now()
    c.add(2)
    state["v"] = 7.0
    sim.timeout(1.0)
    sim.run()
    reg.sample_now()
    assert reg.times() == [0.0, 1.0]
    assert reg.series("ops") == [3.0, 5.0]
    assert reg.series("depth") == [10.0, 7.0]
    assert reg.latest("depth") == 7.0
    assert reg.latest("nonexistent") is None


def test_ring_buffer_bounded_and_ordered():
    sim = Sim()
    reg = MetricsRegistry(sim, sample_period=1.0, capacity=4)
    reg.gauge("t2", lambda: 2 * sim.now)
    for k in range(10):
        sim.timeout(1.0)
        sim.run()
        reg.sample_now()
    ts = reg.times()
    assert len(ts) == 4 and ts == [7.0, 8.0, 9.0, 10.0]   # oldest dropped
    assert reg.series("t2") == [14.0, 16.0, 18.0, 20.0]
    assert reg.latest("t2") == 20.0


def test_windowed_rate_collector():
    sim = Sim()
    reg = MetricsRegistry(sim, sample_period=1.0)
    total = {"n": 0.0}
    reg.collector(lambda: {"arr.rate": total["n"]}, rate=True)
    reg.sample_now()                 # first sample: no previous -> 0
    total["n"] = 50.0
    sim.timeout(2.0)
    sim.run()
    reg.sample_now()                 # 50 in 2s -> 25/s
    total["n"] = 50.0
    sim.timeout(2.0)
    sim.run()
    reg.sample_now()                 # no growth -> 0/s
    assert reg.series("arr.rate") == [0.0, 25.0, 0.0]


def test_named_collector_rebinds():
    sim = Sim()
    reg = MetricsRegistry(sim, sample_period=1.0)
    reg.collector(lambda: {"x": 1.0}, name="src")
    reg.sample_now()
    reg.collector(lambda: {"x": 9.0}, name="src")   # replaces, not appends
    reg.sample_now()
    assert reg.series("x") == [1.0, 9.0]


def test_sampler_is_daemon_and_late_series_pad():
    sim = Sim()
    reg = MetricsRegistry(sim, sample_period=1.0)
    reg.gauge("a", lambda: 1.0)
    reg.start()
    sim.timeout(3.0)                 # the only non-daemon work
    sim.run()
    # the sampler never keeps the run alive
    assert sim.now == 3.0
    n0 = reg.samples
    assert n0 >= 2
    # a series registered late is None-padded for earlier samples
    reg.gauge("b", lambda: 5.0)
    reg.sample_now()
    sb = reg.series("b")
    assert sb[-1] == 5.0 and all(v is None for v in sb[:-1])


def test_registry_rejects_bad_config():
    sim = Sim()
    with pytest.raises(ValueError):
        MetricsRegistry(sim, sample_period=0.0)
    with pytest.raises(ValueError):
        MetricsRegistry(sim, capacity=0)


# ---------------------------------------------------------------------
# layer instrumentation (DB.enable_telemetry)
# ---------------------------------------------------------------------
def test_enable_telemetry_signals_plausible():
    db, n = _loaded(telemetry=2.0)
    res = run_open_loop(db, YCSB["A"], PoissonArrivals(8.0), duration=60.0,
                        n_keys=n, warmup=5.0, max_concurrency=8)
    reg = db.metrics
    reg.sample_now()
    assert res.n_measured > 0 and reg.samples > 10
    names = set(reg.names())
    for required in ("ssd.qdepth_s", "ssd.util", "ssd.zones.empty",
                     "ssd.zones.open", "ssd.zones.full", "hdd.util",
                     "lsm.debt", "lsm.l0_files", "lsm.flush_backlog",
                     "lsm.write_amp", "mw.wal_pressure", "mw.wal_zones",
                     "adm.pressure", "ssd.write_rate"):
        assert required in names, f"missing signal {required}"
    # value sanity on the final sample
    assert 0.0 <= reg.latest("ssd.util") <= 1.0
    assert reg.latest("lsm.debt") >= 0.0
    assert reg.latest("lsm.write_amp") > 1.0      # flush+compaction > user
    occ = (reg.latest("ssd.zones.empty") + reg.latest("ssd.zones.open")
           + reg.latest("ssd.zones.full"))
    assert occ == len(db.ssd.zones)
    assert db.enable_telemetry() is reg           # idempotent


def test_telemetry_identical_rows_open_loop():
    """The satellite contract: a registry-on run publishes exactly the
    rows a registry-off run does — sampling is pull-only and daemon-only,
    so the virtual-time history cannot change."""
    rows = []
    for telemetry in (False, True):
        db, n = _loaded(telemetry=telemetry)
        res = run_open_loop(db, YCSB["A"], PoissonArrivals(10.0),
                            duration=90.0, n_keys=n, warmup=10.0,
                            max_concurrency=8, seed=9)
        rows.append(res.to_json())
    assert rows[0] == rows[1]


def test_telemetry_identical_rows_multi_tenant():
    rows = []
    mix = [TenantSpec("a", YCSB["A"], PoissonArrivals(4.0), protected=True),
           TenantSpec("b", YCSB["C"], PoissonArrivals(6.0))]
    for telemetry in (False, True):
        db, n = _loaded("B3", telemetry=telemetry)
        res = run_multi_tenant(
            db, mix, duration=90.0, n_keys=n, warmup=10.0,
            max_concurrency=8,
            policy=AdmissionConfig(policy="reject", queue_threshold=16))
        rows.append([t.to_json() for t in res.tenants])
    assert rows[0] == rows[1]


def test_telemetry_survives_crash_reopen():
    db, n = _loaded(telemetry=1.0)
    db.run_for(5.0)
    before = db.metrics.samples
    db.crash()
    db.reopen()
    db.run_for(10.0)
    db.sim.timeout(10.0)
    db.drain()
    assert db.metrics.samples > before, "sampler must resume after reopen"
    # gauges rebound to the recovered tree: sampling still works
    db.metrics.sample_now()
    assert db.metrics.latest("lsm.debt") is not None
    # regression: the tree's rate collector must REBIND on reopen (named
    # registration), not duplicate — a stale collector over the dead tree
    # stamps _prev first each sample, zeroing the live one's deltas
    flushes0 = db.tree.stats["flushes"]
    for k in range(400):
        db.put(k + 10_000_000)
    db.flush_all()
    db.run_for(2.0)
    db.metrics.sample_now()
    assert db.tree.stats["flushes"] > flushes0
    post = [v for v in db.metrics.series("lsm.flush_rate") if v]
    assert post, "post-recovery flushes must show up in the rate series"


# ---------------------------------------------------------------------
# timeline artifacts
# ---------------------------------------------------------------------
def test_matrix_timeline_artifact_validates(tmp_path):
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=800)
        db.flush_all()
        db.n_keys = 800
        return db

    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    kw = dict(schemes=["B3"], workloads=[spec],
              arrivals=[PoissonArrivals(6.0)], ssd_zone_budgets=[20],
              duration=60.0, warmup=5.0, max_concurrency=8,
              db_factory=db_factory)
    plain = ScenarioMatrix(**kw).run(verbose=False)
    tl_dir = tmp_path / "timelines"
    instrumented = ScenarioMatrix(**kw, telemetry=2.0,
                                  timeline_dir=tl_dir).run(verbose=False)
    # byte-identical rows with the bus on (the grid-smoke CI contract)
    assert plain == instrumented
    files = list(tl_dir.glob("*.json"))
    assert len(files) == 1
    import json
    tl = json.loads(files[0].read_text())
    v = _load_validator()
    assert v.validate_timeline(tl, str(files[0])) == []
    assert v.validate_file(files[0]) == []        # CLI dispatch path
    assert tl["meta"]["cell"] == "B3/mix/poisson(6)/z20"
    assert len(tl["t"]) >= 10
    assert "lsm.debt" in tl["series"]
    # a malformed timeline is rejected
    bad = dict(tl, t=tl["t"][:-1])
    assert v.validate_timeline(bad, "bad") != []


# ---------------------------------------------------------------------
# control plane: debt pressure + AIMD feedback
# ---------------------------------------------------------------------
def test_debt_threshold_is_third_pressure_signal():
    sim = Sim()
    ctrl = AdmissionController(
        sim, None, AdmissionConfig(policy="reject", debt_threshold=100.0))
    debt = {"v": 0.0}
    ctrl.debt_gauge = lambda: debt["v"]
    assert not ctrl.under_pressure()
    debt["v"] = 101.0
    assert ctrl.under_pressure()
    assert ctrl.decide("t") == "reject"
    debt["v"] = 0.0
    assert ctrl.decide("t") == "admit"
    # without a threshold the gauge is ignored
    ctrl2 = AdmissionController(sim, None, AdmissionConfig(policy="reject"))
    ctrl2.debt_gauge = lambda: 1e18
    assert not ctrl2.under_pressure()


def test_control_plane_aimd_decrease_and_increase():
    sim = Sim()
    cfg = AdmissionConfig(policy="feedback", protected=frozenset(["a"]),
                          bucket_rates={"b": (100.0, 5.0)},
                          feedback_decrease=0.5, feedback_increase=0.1,
                          feedback_headroom=0.8, feedback_floor=0.05)
    ctrl = AdmissionController(sim, None, cfg)
    ctrl.tenant_counters("a")
    ctrl.tenant_counters("b")
    plane = ControlPlane(sim, ctrl, targets={"a": 0.1})
    # over target: multiplicative decrease of the non-protected tenant
    for _ in range(16):
        plane.observe("a", 1.0)
    plane._tick()
    assert ctrl.rate_overrides["b"] == pytest.approx(50.0)
    plane._tick()
    assert ctrl.rate_overrides["b"] == pytest.approx(25.0)
    # floor: never below feedback_floor * base
    for _ in range(20):
        plane._tick()
    assert ctrl.rate_overrides["b"] >= 0.05 * 100.0 - 1e-9
    # back under target with headroom: additive increase (0.1 * base)
    plane._lat["a"].clear()
    for _ in range(16):
        plane.observe("a", 0.01)
    before = ctrl.rate_overrides["b"]
    plane._tick()
    assert ctrl.rate_overrides["b"] == pytest.approx(before + 10.0)
    # protected tenants are never throttled
    assert "a" not in ctrl.rate_overrides
    assert plane.attainment() == 1.0


def test_control_plane_debt_override_forces_decrease():
    sim = Sim()
    cfg = AdmissionConfig(policy="feedback", protected=frozenset(["a"]),
                          bucket_rates={"b": (100.0, 5.0)},
                          debt_threshold=1000.0, feedback_decrease=0.5)
    ctrl = AdmissionController(sim, None, cfg)
    ctrl.tenant_counters("b")
    plane = ControlPlane(sim, ctrl, targets={"a": 0.1},
                         debt_gauge=lambda: 5000.0)
    # no latency measurements at all, but debt above threshold: decrease
    plane._tick()
    assert ctrl.rate_overrides["b"] == pytest.approx(50.0)
    assert plane.debt_over()


def test_feedback_policy_rejects_when_bucket_empty():
    db = DB("HHZS", tiny_scenario(), store_values=True,
            admission=AdmissionConfig(policy="feedback",
                                      bucket_rates={"t": (0.001, 1.0)}))

    def op():
        yield db.sim.timeout(0.01)

    assert db.submit(op(), tenant="t") is not None
    assert db.submit(op(), tenant="t") is None      # shed like token_bucket
    db.drain()
    c = db.admission.tenant_counters("t")
    assert c["arrived"] == 2 and c["rejected"] == 1
    # the live override is consulted before the configured rate
    db.admission.rate_overrides["t"] = float("inf")
    assert db.submit(op(), tenant="t") is not None
    db.drain()


def test_feedback_beats_static_bucket_on_protected_p99():
    """The bench_control acceptance shape at test scale: under an
    overloading neighbour, the feedback policy yields a lower
    protected-tenant p99 than the same token bucket left static, at
    equal-or-better total goodput (ops within SLO).

    Sizing: the tiny store serves reads at ~2.5 ops/s closed-loop and the
    light-load sojourn p99 of YCSB A is ~2s (compaction-stall excursions),
    so bulk reads at 8/s are a genuine sustained overload and a 5s
    protected target is feasible once the neighbour is throttled — but
    hopeless behind the static bucket's unbounded queue."""
    mix = [TenantSpec("prot", YCSB["A"], PoissonArrivals(2.0),
                      protected=True, slo_p99=5.0),
           TenantSpec("bulk", YCSB["C"], PoissonArrivals(8.0),
                      slo_p99=10.0)]
    results = {}
    for policy in ("token_bucket", "feedback"):
        db, n = _loaded("B3")
        cfg = AdmissionConfig(policy=policy,
                              bucket_rates={"bulk": (8.0, 5.0)},
                              feedback_interval=2.0)
        results[policy] = run_multi_tenant(
            db, mix, duration=300.0, n_keys=n, warmup=30.0,
            max_concurrency=8, policy=cfg)
    p99 = {p: r.by_tenant("prot").latency_p["p99"]
           for p, r in results.items()}
    goodput = {p: sum(t.goodput for t in r.tenants)
               for p, r in results.items()}
    assert p99["feedback"] < p99["token_bucket"], (p99, goodput)
    assert goodput["feedback"] >= goodput["token_bucket"], (p99, goodput)
    # rows carry the SLO columns and validate against the schema
    rows = []
    for r in results["feedback"].tenants:
        row = r.to_json()
        row["cell"] = "t/feedback"
        row["ssd_zones"] = 20
        rows.append(row)
    assert rows[0]["slo_p99"] == 5.0 and "slo_met" in rows[0]
    assert _load_validator().validate_rows(rows) == []


# ---------------------------------------------------------------------
# overhead: the sim_speed gate with the kernel under an instrumented repo
# ---------------------------------------------------------------------
@pytest.mark.slow
def test_sim_speed_gate_holds_with_instrumentation_live():
    """The registry is pull-only, so the DES kernel hot paths are exactly
    as fast as before the telemetry subsystem landed: the geomean speedup
    vs the frozen seed kernel must stay above the CI canary floor."""
    root = Path(__file__).resolve().parent.parent
    sys.path.insert(0, str(root))
    try:
        from benchmarks.sim_speed import run as sim_speed_run
        rows, geomean = sim_speed_run(repeat=2, scale=1)
    finally:
        sys.path.remove(str(root))
    assert geomean >= 1.55, rows
