"""Dry-run integration: lower+compile on a fake multi-device mesh.

Runs in a subprocess because xla_force_host_platform_device_count must be
set before jax initialises (the main pytest process keeps 1 device).
"""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax
    from repro.launch.dryrun import lower_cell

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    rec = lower_cell("qwen3-1.7b", "train_4k", mesh, "test4x4")
    print("RESULT " + json.dumps({
        "status": rec["status"],
        "dominant": rec["roofline"]["dominant"],
        "flops": rec["roofline"]["flops_per_device"],
        "colls": rec["collectives_by_op"],
    }))
""")


@pytest.mark.slow
def test_dryrun_cell_on_fake_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    rec = json.loads(line[len("RESULT "):])
    assert rec["status"] == "ok"
    assert rec["flops"] > 1e12
    assert any(op in rec["colls"] for op in
               ("all-reduce", "reduce-scatter", "all-gather"))


@pytest.mark.slow
def test_production_mesh_shapes():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("MESH OK")
    """)
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert r.returncode == 0, r.stderr[-2000:]
    assert "MESH OK" in r.stdout
