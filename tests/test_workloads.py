"""Workload generation + runner."""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.lsm import DB
from repro.workloads import (YCSB, WorkloadSpec, generate_ops, run_load,
                             run_workload, zipf_probs, READ, UPDATE, SCAN)


def test_zipf_probs_normalised_and_skewed():
    p = zipf_probs(1000, 0.9)
    assert p.sum() == pytest.approx(1.0)
    assert p[0] > p[99] > p[999]
    # higher alpha -> more head mass
    assert zipf_probs(1000, 1.2)[:10].sum() > p[:10].sum()


def test_generate_ops_mix_and_determinism():
    spec = YCSB["A"]
    ops1 = generate_ops(spec, 10_000, 1000, seed=3)
    ops2 = generate_ops(spec, 10_000, 1000, seed=3)
    assert np.array_equal(ops1.codes, ops2.codes)
    assert np.array_equal(ops1.args, ops2.args)
    frac_read = (ops1.codes == READ).mean()
    assert 0.45 < frac_read < 0.55
    e = generate_ops(YCSB["E"], 5000, 1000, seed=1)
    assert (e.codes == SCAN).mean() > 0.9


def test_run_workload_end_to_end():
    db = DB("HHZS", tiny_scenario())
    n = 2000
    load = run_load(db, n_keys=n, num_clients=8)
    assert load.throughput > 0
    db.flush_all()
    res = run_workload(db, YCSB["B"], n_ops=500, n_keys=n, num_clients=8)
    assert res.n_ops == 500
    assert res.duration > 0
    assert res.op_counts["read"] > 400
    assert res.latency_p["p99"] >= res.latency_p["p50"] >= 0


def test_latest_distribution_reads_recent():
    db = DB("B3", tiny_scenario())
    n = 2000
    run_load(db, n_keys=n, num_clients=4)
    db.flush_all()
    res = run_workload(db, YCSB["D"], n_ops=400, n_keys=n, num_clients=4)
    assert res.op_counts["read"] + res.op_counts["insert"] == 400
