"""Checkpoint / data pipeline / optimizer / fault-tolerance units."""
import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")   # tier-1 runs a no-jax matrix leg
import jax.numpy as jnp            # noqa: E402

from repro.checkpoint import ckpt
from repro.config import TrainConfig
from repro.data import FileTokens, Prefetcher, SyntheticLM
from repro.ft import HeartbeatRegistry, TrainSupervisor, plan_elastic_mesh
from repro.optim import adamw


# ---------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.zeros((), jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(t, 7, str(tmp_path))
    like = jax.eval_shape(lambda: _tree())
    restored, step = ckpt.restore(like, str(tmp_path))
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_last_and_latest(tmp_path):
    for s in (1, 2, 3, 4, 5):
        ckpt.save(_tree(), s, str(tmp_path), keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 5
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [4, 5]


def test_checkpoint_async(tmp_path):
    t = _tree()
    th = ckpt.save_async(t, 3, str(tmp_path))
    th.join(timeout=30)
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ckpt.save(_tree(), 1, str(tmp_path))
    bad = {"a": jax.ShapeDtypeStruct((4, 4), jnp.float32),
           "b": {"c": jax.ShapeDtypeStruct((2,), jnp.bfloat16),
                 "d": jax.ShapeDtypeStruct((), jnp.int32)}}
    with pytest.raises(ValueError):
        ckpt.restore(bad, str(tmp_path))


# ---------------------------------------------------------------------
def test_synthetic_data_deterministic_resume():
    d1 = SyntheticLM(1000, batch=4, seq_len=16, seed=5)
    d2 = SyntheticLM(1000, batch=4, seq_len=16, seed=5)
    stream1 = [d1.batch_at(s) for s in range(10)]
    resumed = [d2.batch_at(s) for s in range(5, 10)]
    for a, b in zip(stream1[5:], resumed):
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["targets"], b["targets"])


def test_file_tokens(tmp_path):
    toks = np.arange(10_000, dtype=np.uint16) % 97
    f = tmp_path / "toks.bin"
    toks.tofile(f)
    ds = FileTokens(str(f), batch=4, seq_len=32)
    b = ds.batch_at(0)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_prefetcher_preserves_order():
    ds = SyntheticLM(100, batch=2, seq_len=8)
    pf = Prefetcher(ds.iter_from(0), depth=2)
    got = [next(pf) for _ in range(4)]
    pf.close()
    for i, g in enumerate(got):
        np.testing.assert_array_equal(g["tokens"], ds.batch_at(i)["tokens"])


# ---------------------------------------------------------------------
def test_adamw_matches_numpy_reference():
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0,
                     total_steps=10**9,   # cosine ~ flat at step 1
                     weight_decay=0.0, grad_clip=1e9)
    params = {"w": jnp.array([1.0, -2.0, 3.0], jnp.bfloat16)}
    state = adamw.init(params)
    grads = {"w": jnp.array([0.1, -0.2, 0.3], jnp.float32)}
    new_p, new_s, m = adamw.update(grads, state, tc)
    # numpy reference (step 1, cosine(0 prog)=lr)
    g = np.array([0.1, -0.2, 0.3])
    mu = 0.1 * g
    nu = 0.05 * g * g
    mh = mu / (1 - 0.9)
    vh = nu / (1 - 0.95)
    ref = np.array([1.0, -2.0, 3.0]) - 1e-2 * mh / (np.sqrt(vh) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_s.master["w"]), ref,
                               rtol=1e-5)


def test_grad_clip_limits_update():
    tc = TrainConfig(learning_rate=1e-2, warmup_steps=0, grad_clip=0.1)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init(params)
    big = {"w": jnp.full((4,), 1e3, jnp.float32)}
    _, _, m = adamw.update(big, state, tc)
    assert float(m["grad_norm"]) > 0.1   # reported raw norm


# ---------------------------------------------------------------------
def test_heartbeats_detect_dead_and_stragglers():
    hb = HeartbeatRegistry(timeout_s=10, straggle_steps=3)
    hb.report("w0", step=100, t=0.0)
    hb.report("w1", step=100, t=9.0)
    hb.report("w2", step=96, t=9.5)
    assert hb.dead(now=11.0) == ["w0"]
    assert hb.stragglers() == ["w2"]


def test_elastic_mesh_plan():
    shape, scale = plan_elastic_mesh(256, model_parallel=16)
    assert shape == (16, 16)
    shape, scale = plan_elastic_mesh(240, model_parallel=16)
    assert shape == (15, 16)      # one DP group lost
    with pytest.raises(RuntimeError):
        plan_elastic_mesh(8, model_parallel=16)


def test_supervisor_restarts_and_restores():
    calls = {"fail": True, "saved": 0}

    def run_steps(frm, to):
        if calls["fail"] and to >= 20:
            calls["fail"] = False
            raise RuntimeError("boom")
        return to

    def save(step):
        calls["saved"] = step

    sup = TrainSupervisor(save_every=10)
    final = sup.run(total_steps=40, start_step=0, run_steps=run_steps,
                    save=save, restore=lambda: calls["saved"])
    assert final == 40
    assert sup.restarts == 1
    assert any("restored" in e for e in sup.events)
