"""Sweep driver: worker-count determinism, resume, selection, merging.

The driver's contract (``repro.workloads.sweep``): rows depend only on the
matrix spec — never on worker count, completion order, or what else sits
in the output file — and a rerun over an existing file skips completed
cells while preserving every foreign row byte-for-byte.
"""
import json
from pathlib import Path

import pytest

from repro.workloads import PoissonArrivals, ScenarioMatrix
from repro.workloads.sweep import (GridDBFactory, parse_cell_selector,
                                   run_sweep)

# tiny but real cells: ~1k keys loaded per cell, 20 virtual seconds of
# arrivals at a stable offered rate
FACTORY = GridDBFactory(key_div=512, load_div=4)


def tiny_matrix(schemes=("B3", "HHZS"), workloads=("A", "B")):
    return ScenarioMatrix(
        schemes=list(schemes), workloads=list(workloads),
        arrivals=[PoissonArrivals(50.0)], ssd_zone_budgets=[20],
        duration=20.0, warmup=5.0, key_div=512, seed=7,
        db_factory=FACTORY)


# ---------------------------------------------------------------------
def test_rows_identical_for_any_worker_count(tmp_path):
    """Same seed -> byte-identical output for 1 process vs a 2-worker pool."""
    out0 = tmp_path / "w0.json"
    out2 = tmp_path / "w2.json"
    rows0 = run_sweep(tiny_matrix(), out=out0, workers=0, verbose=False)
    rows2 = run_sweep(tiny_matrix(), out=out2, workers=2, verbose=False)
    assert rows0 == rows2
    assert out0.read_bytes() == out2.read_bytes()
    assert len(rows0) == 4 and [r["cell"] for r in rows0] == \
        [c.name for c in tiny_matrix().cells()]


def test_resume_skips_completed_cells(tmp_path):
    """Cells already in the output file are not re-run: a tampered value
    in a completed row survives the rerun, and only missing cells run."""
    out = tmp_path / "grid.json"
    m = tiny_matrix()
    first = [c.name for c in m.cells()][:2]
    run_sweep(m, out=out, workers=0, verbose=False, cells="0-1")
    rows = json.loads(out.read_text())
    assert [r["cell"] for r in rows] == first
    # tamper: if resume re-ran these cells the sentinel would be recomputed
    rows[0]["throughput"] = 123456.0
    out.write_text(json.dumps(rows, indent=1))
    final = run_sweep(tiny_matrix(), out=out, workers=0, verbose=False)
    assert len(final) == 4
    by_cell = {r["cell"]: r for r in final}
    assert by_cell[first[0]]["throughput"] == 123456.0
    # canonical order regardless of completion order
    assert [r["cell"] for r in final] == \
        [c.name for c in tiny_matrix().cells()]
    # fresh=False twice in a row: nothing to do, file unchanged
    before = out.read_bytes()
    run_sweep(tiny_matrix(), out=out, workers=0, verbose=False)
    assert out.read_bytes() == before


def test_fresh_rerun_keeps_unselected_and_unreached_rows(tmp_path):
    """resume=False re-runs selected cells but must never drop published
    rows for cells it was not asked to (or did not get to) re-run."""
    out = tmp_path / "grid.json"
    m = tiny_matrix()
    names = [c.name for c in m.cells()]
    run_sweep(m, out=out, workers=0, verbose=False)          # all 4 cells
    rows = json.loads(out.read_text())
    for r in rows:
        r["throughput"] = 7777.0                              # sentinel
    out.write_text(json.dumps(rows, indent=1))
    # fresh re-run of cell 0 only: cell 0 recomputed, others untouched
    final = run_sweep(tiny_matrix(), out=out, workers=0, verbose=False,
                      resume=False, cells="0")
    by_cell = {r["cell"]: r for r in final}
    assert by_cell[names[0]]["throughput"] != 7777.0
    assert all(by_cell[n]["throughput"] == 7777.0 for n in names[1:])
    # fresh run with a zero budget: nothing recomputed, nothing lost
    final = run_sweep(tiny_matrix(), out=out, workers=0, verbose=False,
                      resume=False, budget_s=0.0)
    assert len(final) == 4 and {r["cell"] for r in final} == set(names)


def test_foreign_rows_preserved(tmp_path):
    """Rows whose cell is not part of the running matrix (other sweeps,
    tenant/fault rows) survive untouched — merge-never-overwrite."""
    out = tmp_path / "grid.json"
    foreign = [{"cell": "X/other/sweep/z9", "tenant": "steady",
                "marker": "do-not-touch"}]
    out.write_text(json.dumps(foreign, indent=1))
    rows = run_sweep(tiny_matrix(schemes=("B3",), workloads=("A",)),
                     out=out, workers=0, verbose=False)
    final = json.loads(out.read_text())
    assert final[0] == foreign[0]          # foreign rows first, untouched
    assert len(final) == 1 + len(rows)


def test_budget_stops_dispatch(tmp_path):
    """budget_s=0: nothing is dispatched; completed rows are kept."""
    out = tmp_path / "grid.json"
    rows = run_sweep(tiny_matrix(), out=out, workers=0, verbose=False,
                     budget_s=0.0)
    assert rows == [] and json.loads(out.read_text()) == []


def test_cell_selector():
    sel = parse_cell_selector("0,2-3")
    assert [i for i in range(5) if sel(i, "x")] == [0, 2, 3]
    sel = parse_cell_selector("HHZS/*/z20")
    assert sel(0, "HHZS/A/poisson(50)/z20")
    assert not sel(0, "B3/A/poisson(50)/z20")
    sel = parse_cell_selector(None)
    assert sel(17, "anything")


def test_duplicate_cell_names_rejected(tmp_path):
    m = tiny_matrix(schemes=("B3", "B3"), workloads=("A",))
    with pytest.raises(ValueError, match="duplicate cell names"):
        run_sweep(m, out=tmp_path / "g.json", workers=0, verbose=False)


def test_validate_hook_gates_writes(tmp_path):
    """A failing validate callback aborts before anything is written."""
    out = tmp_path / "grid.json"

    def reject(rows):
        raise ValueError("schema says no")

    with pytest.raises(ValueError, match="schema says no"):
        run_sweep(tiny_matrix(schemes=("B3",), workloads=("A",)),
                  out=out, workers=0, verbose=False, validate=reject)
    assert not out.exists()
