"""Storage-substrate invariants under real workloads.

Property-style tests that instrument the zoned devices and middleware while
a randomized workload runs, then assert:

* the zone state machine only ever takes legal steps
  (EMPTY -> OPEN -> FULL -> reset; resets allowed from OPEN/FULL),
* reserved WAL/cache zones never leak after ``wal_flushed``,
* ``_ssd_level_counts`` always matches the SST registry across
  flush / compaction / migration.
"""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.lsm import DB, SCHEMES
from repro.workloads import (BurstyArrivals, YCSB, run_load, run_open_loop,
                             run_workload)
from repro.zoned.device import ZoneState


# ---------------------------------------------------------------------
# zone state machine
# ---------------------------------------------------------------------
LEGAL = {
    (ZoneState.EMPTY, ZoneState.OPEN),    # alloc / first append
    (ZoneState.EMPTY, ZoneState.FULL),    # single append fills the zone
    (ZoneState.OPEN, ZoneState.FULL),     # append fills / finish
    (ZoneState.OPEN, ZoneState.EMPTY),    # reset (ZNS allows any state)
    (ZoneState.FULL, ZoneState.EMPTY),    # reset after full
}


class TransitionRecorder:
    """Wraps a device's mutating entry points; records state transitions."""

    def __init__(self, dev):
        self.dev = dev
        self.transitions = []
        self.illegal = []
        for name in ("alloc_zone", "reset_zone", "finish_zone", "append"):
            self._wrap(name)
        # alloc_sst_zones in the middleware flips states directly; catch
        # those with snapshots instead (see snapshot())
        self._states = {z.zid: z.state for z in dev.zones}

    def _wrap(self, name):
        dev = self.dev
        orig = getattr(dev, name)

        def wrapped(*args, **kw):
            before = {z.zid: z.state for z in dev.zones}
            out = orig(*args, **kw)
            for z in dev.zones:
                b = before[z.zid]
                if z.state != b:
                    self.transitions.append((z.zid, b, z.state))
                    if (b, z.state) not in LEGAL:
                        self.illegal.append((name, z.zid, b, z.state))
            return out

        setattr(dev, name, wrapped)

    def snapshot_check(self):
        """States flipped outside the wrapped calls must still be legal."""
        for z in self.dev.zones:
            b = self._states[z.zid]
            if z.state != b and (b, z.state) not in LEGAL:
                self.illegal.append(("snapshot", z.zid, b, z.state))
            self._states[z.zid] = z.state


def _churn(db, n=2500, seed=0):
    run_load(db, n_keys=n, seed=seed)
    db.flush_all()
    run_workload(db, YCSB["A"], n_ops=1200, n_keys=n, seed=seed + 1)
    db.drain()


@pytest.mark.parametrize("scheme", ["B3", "AUTO", "HHZS"])
def test_zone_state_machine_legal_transitions(scheme):
    db = DB(scheme, tiny_scenario(), store_values=True)
    recs = [TransitionRecorder(db.ssd), TransitionRecorder(db.hdd)]
    _churn(db)
    for r in recs:
        r.snapshot_check()
        assert r.transitions, "workload must actually exercise zones"
        assert not r.illegal, f"illegal zone transitions: {r.illegal[:5]}"


def test_zone_static_invariants_after_churn(any_db):
    db = any_db
    _churn(db)
    for dev in (db.ssd, db.hdd):
        for z in dev.zones:
            assert 0 <= z.write_ptr <= z.capacity
            if z.state == ZoneState.EMPTY:
                assert z.write_ptr == 0 and z.owner is None
            if z.write_ptr == z.capacity:
                assert z.state == ZoneState.FULL


def test_append_to_full_zone_raises(tiny_db):
    dev = tiny_db.ssd
    z = dev.alloc_zone("t")
    dev.append(z, z.capacity)
    assert z.state == ZoneState.FULL
    with pytest.raises(RuntimeError):
        dev.append(z, 1)
    with pytest.raises(RuntimeError):
        dev.append(dev.alloc_zone("t2"), dev.zone_capacity + 1)


# ---------------------------------------------------------------------
# reserved WAL/cache zones
# ---------------------------------------------------------------------
@pytest.mark.parametrize("scheme", ["P", "HHZS"])
def test_reserved_zones_never_leak(scheme):
    db = DB(scheme, tiny_scenario(), store_values=True)
    be = db.backend
    assert be.reserve_zids, "HHZS-family schemes reserve WAL/cache zones"
    _churn(db)
    db.flush_all()      # kill remaining live generations, then settle
    db.drain()
    # everything flushed + drained: every reserved zone is either EMPTY or
    # legitimately owned by the WAL (current zone) / cache — never orphaned
    wal_zids = {rec["zone"].zid for rec in be._wal_records}
    cache_zids = {z.zid for z in be.cache.zones} if be.cache else set()
    for zid in be.reserve_zids:
        z = db.ssd.zones[zid]
        if z.state == ZoneState.EMPTY:
            assert z.owner is None and z.write_ptr == 0
        else:
            assert z.owner in ("wal", "cache"), \
                f"reserved zone {zid} leaked to owner {z.owner!r}"
            if z.owner == "wal":
                assert zid in wal_zids, f"orphaned WAL zone {zid}"
            else:
                assert zid in cache_zids, f"orphaned cache zone {zid}"
    # after a full flush at most the current WAL zone stays live
    assert be.wal_zones_in_use() <= 1


def test_wal_flushed_reclaims_dead_zones():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    be = db.backend
    for k in range(1500):
        db.put(k, b"x" * 8)
    peak = be.wal_zones_in_use()
    db.flush_all()
    db.drain()
    assert peak >= 1
    assert be.wal_zones_in_use() <= 1
    # reclaimed zones are EMPTY again, write pointers rewound
    free = [db.ssd.zones[zid] for zid in be.reserve_zids
            if db.ssd.zones[zid].state == ZoneState.EMPTY]
    assert all(z.write_ptr == 0 for z in free)


# ---------------------------------------------------------------------
# SSD level-count accounting vs the SST registry
# ---------------------------------------------------------------------
def _assert_level_counts_match(db, when):
    be = db.backend
    actual = {}
    for s in be.ssts.values():
        if s.tier == "ssd":
            actual[s.level] = actual.get(s.level, 0) + 1
    for lvl in set(actual) | set(be._ssd_level_counts):
        assert be._ssd_level_counts.get(lvl, 0) == actual.get(lvl, 0), \
            (f"{when}: _ssd_level_counts[{lvl}]="
             f"{be._ssd_level_counts.get(lvl, 0)} but registry has "
             f"{actual.get(lvl, 0)}")


@pytest.mark.parametrize("scheme", ["B3", "P+M", "HHZS"])
def test_ssd_level_counts_match_registry(scheme):
    """Counts stay consistent across flush, compaction and migration."""
    db = DB(scheme, tiny_scenario(), store_values=True)
    n = 2500
    run_load(db, n_keys=n)
    _assert_level_counts_match(db, "after load")
    db.flush_all()
    _assert_level_counts_match(db, "after flush_all")
    run_workload(db, YCSB["A"], n_ops=1200, n_keys=n)
    _assert_level_counts_match(db, "after workload")
    db.drain()
    _assert_level_counts_match(db, "after drain")


def test_ssd_level_counts_under_open_loop_burst():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    n = 1500
    run_load(db, n_keys=n)
    db.flush_all()
    run_open_loop(db, YCSB["A"], BurstyArrivals(2.0, 50.0, on=20.0, off=40.0),
                  duration=120.0, n_keys=n, max_concurrency=8)
    db.drain()
    _assert_level_counts_match(db, "after open-loop burst")
    # registry zones all owned and resident on the right device
    for sst in db.backend.ssts.values():
        dev = db.backend.device_of(sst.tier)
        for z in sst.zones:
            assert z.owner == f"sst:{sst.sid}"
            assert dev.zones[z.zid] is z
