"""LSM-tree correctness: model-based property tests + structural invariants.

The hypothesis-driven property test only runs when the package is
installed; a deterministic randomized fallback keeps the dict-model
invariant covered either way.
"""
from dataclasses import replace

import numpy as np
import pytest

from conftest import tiny_scenario
from repro.lsm import DB
from repro.lsm.block_cache import BlockCache
from repro.zoned.device import MiB

try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------
# model-based property test: the store behaves like a dict
# ---------------------------------------------------------------------
def _check_ops_against_model(ops):
    db = DB("HHZS", tiny_scenario(), store_values=True)
    model = {}
    for op, key in ops:
        if op == "put":
            val = b"v%d" % key
            db.put(key, val)
            model[key] = val
        elif op == "del":
            db.delete(key)
            model.pop(key, None)
        else:
            found, val = db.get(key)
            assert found == (key in model)
            if found:
                assert val == model[key]
    db.drain()
    for key in list(model)[:50]:
        found, val = db.get(key)
        assert found and val == model[key]


if HAVE_HYPOTHESIS:
    @settings(max_examples=20, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(ops=st.lists(
        st.tuples(st.sampled_from(["put", "get", "del"]),
                  st.integers(min_value=0, max_value=400)),
        min_size=50, max_size=400))
    def test_store_matches_dict_model(ops):
        _check_ops_against_model(ops)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_store_matches_dict_model_deterministic(seed):
    """Fallback for environments without hypothesis: fixed-seed op streams."""
    rng = np.random.default_rng(seed)
    ops = [(("put", "get", "del")[int(rng.integers(3))],
            int(rng.integers(0, 400))) for _ in range(300)]
    _check_ops_against_model(ops)


# ---------------------------------------------------------------------
def _load(db, n, seed=0):
    for k in np.random.default_rng(seed).permutation(n):
        db.put(int(k), b"v%d" % k)
    db.drain()


def test_structural_invariants_after_compaction(any_db):
    db = any_db
    _load(db, 4000)
    t = db.tree
    for lvl in range(1, len(t.levels)):
        ssts = sorted(t.levels[lvl], key=lambda s: s.min_key)
        for s in ssts:
            assert np.all(np.diff(s.keys.astype(np.int64)) > 0), \
                "keys sorted+unique inside SST"
        for a, b in zip(ssts, ssts[1:]):
            assert a.max_key < b.min_key, f"L{lvl} ranges must be disjoint"
    # level byte accounting matches reality
    for lvl, lb in enumerate(t.level_sizes()):
        assert lb == sum(s.size_bytes for s in t.levels[lvl])


def test_zone_accounting_no_leaks(any_db):
    db = any_db
    _load(db, 3000)
    be = db.backend
    # every non-empty SSD zone has an owner; every SST's zones belong to it
    for z in db.ssd.zones:
        if z.write_ptr > 0 and z.zid not in be.reserve_zids:
            assert z.owner is not None
    for sst in be.ssts.values():
        dev = be.device_of(sst.tier)
        for z in sst.zones:
            assert z.owner == f"sst:{sst.sid}"
            assert dev.zones[z.zid] is z


def test_concurrent_burst_keeps_levels_disjoint():
    """Regression: while one L0 compaction ran, a second one could start
    over the leftover (overlapping) L0 files and install overlapping L1
    SSTs — the read path then returned stale versions."""
    db = DB("HHZS", tiny_scenario(), store_values=True)
    rng = np.random.default_rng(7)
    ops = [(int(k), b"v%d-%d" % (k, i))
           for i, k in enumerate(rng.integers(0, 250, size=500))]
    for k, v in ops:               # open-loop burst: compactions overlap
        db.submit(db.tree.put(k, v))
    db.drain()
    model = {}
    for k, v in ops:
        model[k] = v
    for lvl in range(1, len(db.tree.levels)):
        ssts = sorted(db.tree.levels[lvl], key=lambda s: s.min_key)
        for a, b in zip(ssts, ssts[1:]):
            assert a.max_key < b.min_key, \
                f"L{lvl} ranges overlap: {a.sid} and {b.sid}"
    for k in sorted(model):
        assert db.get(k) == (True, model[k])


def test_overwrite_returns_latest():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    for ver in range(5):
        for k in range(0, 500, 3):
            db.put(k, b"v%d-%d" % (k, ver))
    db.drain()
    for k in range(0, 500, 30):
        found, val = db.get(k)
        assert found and val == b"v%d-4" % k


def test_tombstones_survive_compaction():
    db = DB("B3", tiny_scenario(), store_values=True)
    _load(db, 2000)
    for k in range(0, 2000, 2):
        db.delete(k)
    db.drain()
    assert not db.get(100)[0]
    assert db.get(101)[0]


def test_scan_counts():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    _load(db, 2000)
    seen = db.scan(500, 40)
    assert seen >= 40          # every key in [500, 540) exists


def test_post_recovery_l0_reads_survive_list_reorder():
    """Regression: `get` trusted L0 *list position* (reversed()) for
    recency while compaction/scan sort by -birth.  ``reopen_gen``
    installs L0 in ascending-sid order — accidentally newest-last — but
    nothing guarantees that, so reads must order L0 candidates by birth,
    not by list position."""
    sc = tiny_scenario()
    big = int(100 * MiB)            # L0 target huge: no compaction
    sc = replace(sc, lsm=replace(sc.lsm, level_targets=(big,) * 5))
    db = DB("HHZS", sc, store_values=True)
    for k in range(40):
        db.put(k, b"old-%d" % k)
    db.flush_all()
    for k in range(40):
        db.put(k, b"new-%d" % k)
    db.flush_all()
    db.drain()
    db.crash()
    db.reopen()
    l0 = db.tree.levels[0]
    assert len(l0) >= 2 and not any(db.tree.levels[i]
                                    for i in range(1, len(db.tree.levels)))
    # read back under adversarial list orders (newest-first is the one a
    # reversed()-based read path gets exactly backwards)
    for perm in (sorted(l0, key=lambda s: -s.birth),
                 sorted(l0, key=lambda s: s.birth)):
        db.tree.levels[0] = list(perm)
        for k in range(40):
            assert db.get(k) == (True, b"new-%d" % k), \
                "stale read: L0 recency must come from birth, not list order"


def test_zero_capacity_cache_fires_no_evictions():
    """Regression: insert() into a capacity<=0 cache fired on_evict for a
    block that was never cached."""
    evicted = []
    bc = BlockCache(0, on_evict=lambda sid, blk: evicted.append((sid, blk)))
    for i in range(16):
        bc.insert(7, i)
        assert not bc.get(7, i)
    assert not evicted and len(bc) == 0


def test_cacheless_config_emits_no_cache_hints():
    """Integration for the same bug: with block_cache_blocks=0 under a
    hint-driven scheme, reads must produce zero cache-hint traffic and
    zero SSD cache admissions."""
    sc = tiny_scenario()
    sc = replace(sc, lsm=replace(sc.lsm, block_cache_blocks=0))
    db = DB("HHZS", sc, store_values=True)
    _load(db, 2000)
    hints = []
    orig = db.tree.block_cache.on_evict
    db.tree.block_cache.on_evict = \
        lambda sid, blk: (hints.append((sid, blk)), orig(sid, blk))
    for k in range(0, 2000, 7):
        assert db.get(k)[0]
    db.drain()
    assert not hints
    assert db.backend.cache.admitted == 0


def test_wal_group_commit_batches_writers():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    sim, tree = db.sim, db.tree
    procs = [sim.process(tree.put(k)) for k in range(64)]
    for p in procs:
        sim.run_until(p)
    # group commit: far fewer WAL I/Os than appends
    assert db.ssd.counters.write_ops < 64
