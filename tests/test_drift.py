"""Phase-programmed drift traces (repro.workloads.drift).

Covers the subsystem's contracts:

* virtual-time hotspot drift — schemes at different service rates see
  the same hot range at the same virtual time (`hotspot_period_s`), the
  explicit `hotspot_step=0` stationary mode and the `"auto"` sentinel;
* straddle accounting — every op is counted in exactly one phase window
  (the phase it arrived in), so per-phase counts conserve exactly, on
  every scheme;
* tenant departure — a departed tenant's queued ops are dropped at the
  boundary and nothing completes past the drain deadline;
* determinism — identical rows with telemetry on vs off, and across
  repeated runs (the property the CI grid-smoke drift leg checks
  end-to-end across sweep worker counts);
* `phase_rankings` / `rank_flips` on synthetic rows.
"""
import json

import pytest

from conftest import tiny_scenario
from repro.lsm import DB
from repro.lsm.db import SCHEMES
from repro.workloads import (READ, DriftTenant, OpStream, Phase,
                             PoissonArrivals, ScenarioMatrix, TraceProgram,
                             WorkloadSpec, build_program, phase_rankings,
                             rank_flips, run_drift, run_load)

MIX = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
READMIX = WorkloadSpec("readmix", read=0.9, update=0.1, alpha=0.99)


def _loaded(scheme="HHZS", n=1000):
    db = DB(scheme, tiny_scenario(), store_values=True)
    run_load(db, n_keys=n)
    db.flush_all()
    return db, n


def _advance(db, dt):
    def waiter():
        yield dt
    db.sim.run_until(db.sim.process(waiter()))


# ---------------------------------------------------------------------
# virtual-time hotspot drift (ycsb satellite)
# ---------------------------------------------------------------------
def test_hotspot_virtual_time_same_range_across_schemes():
    """Two schemes (different service rates) must see the same hot range
    at the same *virtual time* — the walk no longer advances with the
    stream's own op index."""
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period_s=10.0, hotspot_step=50)
    streams = []
    for scheme in ("B1", "HHZS"):
        db = DB(scheme, tiny_scenario(), store_values=True)
        st = OpStream(db, spec, n_ops=100, n_keys=1000)
        _advance(db, 25.0)            # both at virtual t=25 -> epoch 2
        streams.append(st)
    a, b = streams
    # same virtual time => same hot range, regardless of op index
    assert [a.resolve(READ, r, i=7) for r in range(16)] \
        == [b.resolve(READ, r, i=9731) for r in range(16)] \
        == [(r + 2 * 50) % 1000 for r in range(16)]


def test_hotspot_virtual_time_walks_with_the_clock():
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period_s=5.0, hotspot_step=100)
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=10, n_keys=1000)
    assert st.resolve(READ, 0, i=0) == 0
    _advance(db, 12.0)                # epoch 2 at the same op index
    assert st.resolve(READ, 0, i=0) == 200


def test_hotspot_virtual_time_origin_is_stream_creation():
    """Drift is measured from stream creation, not absolute sim time —
    a long load phase must not offset the walk schedule."""
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period_s=5.0, hotspot_step=100)
    db = DB("HHZS", tiny_scenario(), store_values=True)
    _advance(db, 123.0)               # pre-existing virtual time
    st = OpStream(db, spec, n_ops=10, n_keys=1000)
    assert st.resolve(READ, 0, i=0) == 0


def test_latest_dist_with_keyspace_growth_override():
    """A stream may declare a keyspace larger than the loaded prefix (the
    drift "grow" phase): the insert frontier must start at the loaded
    count and "latest" reads must never index past load_order."""
    db, n = _loaded("B3", n=400)
    spec = WorkloadSpec("grow", read=0.6, insert=0.4, dist="latest",
                        alpha=0.9)
    st = OpStream(db, spec, n_ops=50, n_keys=int(1.5 * n))
    assert st.frontier == n
    # in-range offsets map through load_order; deep ranks clamp to 0
    assert st.resolve(READ, 0) == int(db.load_order[n - 1])
    assert st.resolve(READ, 10 * n) == int(db.load_order[0])
    # inserts advance the frontier past the loaded prefix; reads of the
    # freshly inserted keys resolve to their raw ids, not via load_order
    st.frontier = n + 25
    assert st.resolve(READ, 0) == n + 24


def test_hotspot_step_zero_is_stationary():
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period=10, hotspot_step=0)
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=100, n_keys=1000)
    # _hot_step floors at 1 but a 0-key walk means epoch never moves the
    # range in op-index mode only when step=0 -> stationary
    assert [st.resolve(READ, 3, i=i) for i in (0, 55, 999)] == [3, 3, 3]


def test_hotspot_auto_sentinel_derives_step():
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period=50, hotspot_step="auto")
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=100, n_keys=800)
    assert st._hot_step == 800 // 8
    assert st.resolve(READ, 0, i=50) == 100


# ---------------------------------------------------------------------
# straddle accounting + conservation
# ---------------------------------------------------------------------
def _two_phase(rate=30.0, phase_s=20.0):
    return TraceProgram(
        "p2", (Phase("a", phase_s, MIX), Phase("b", phase_s, READMIX)),
        (DriftTenant("t0", PoissonArrivals(rate)),))


def test_straddlers_counted_in_exactly_one_window():
    """Overload a 1-server pool so a backlog straddles the boundary:
    per-phase counts must still conserve exactly (an op double-counted
    or lost at the boundary breaks the sums)."""
    db, n = _loaded("B3")
    rows = run_drift(db, _two_phase(rate=60.0), n_keys=n,
                     max_concurrency=1)
    assert len(rows) == 1
    r = rows[0]
    ph = r.phases
    assert len(ph) == 2
    assert sum(p["n_arrived"] for p in ph) == r.n_arrived
    assert sum(p["n_completed"] for p in ph) == r.n_completed
    assert sum(p["n_dropped"] for p in ph) == r.dropped == 0
    assert r.n_arrived == r.n_completed
    # genuinely overloaded: the backlog crossed the boundary
    assert r.max_queue_depth > 5
    for p in ph:
        assert p["n_arrived"] == p["n_completed"] + p["n_dropped"]


@pytest.mark.parametrize("scheme", list(SCHEMES))
def test_per_phase_conservation_all_schemes(scheme):
    db, n = _loaded(scheme, n=600)
    prog = TraceProgram(
        "mini", (Phase("a", 10.0, MIX), Phase("b", 10.0, READMIX)),
        (DriftTenant("t0", PoissonArrivals(20.0)),
         DriftTenant("t1", PoissonArrivals(10.0))))
    rows = run_drift(db, prog, n_keys=n)
    assert {r.tenant for r in rows} == {"t0", "t1"}
    for r in rows:
        assert sum(p["n_arrived"] for p in r.phases) == r.n_arrived
        assert sum(p["n_completed"] for p in r.phases) == r.n_completed
        assert r.n_arrived == r.n_completed + r.dropped
        assert r.drift == "mini"


# ---------------------------------------------------------------------
# tenant departure
# ---------------------------------------------------------------------
def test_departed_tenant_drains_and_queued_ops_drop():
    db, n = _loaded("B3")
    prog = TraceProgram(
        "churn-mini",
        (Phase("both", 20.0, MIX, tenants=("base", "batch")),
         Phase("solo", 20.0, READMIX, tenants=("base",))),
        (DriftTenant("base", PoissonArrivals(10.0)),
         # heavy enough that batch has queued ops at the boundary
         DriftTenant("batch", PoissonArrivals(80.0))),
        drain_s=30.0)
    rows = {r.tenant: r for r in run_drift(db, prog, n_keys=n,
                                           max_concurrency=2)}
    batch, base = rows["batch"], rows["base"]
    # batch only lives in phase 0; its queued ops dropped at the boundary
    assert [p["phase"] for p in batch.phases] == [0]
    assert batch.dropped > 0
    assert batch.n_arrived == batch.n_completed + batch.dropped
    # nothing from the departed tenant completed past the drain deadline
    assert batch.drain_violations == 0
    # the surviving tenant is untouched by the reaper
    assert base.dropped == 0
    assert base.n_arrived == base.n_completed


# ---------------------------------------------------------------------
# determinism
# ---------------------------------------------------------------------
def _matrix_rows(telemetry):
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=600)
        db.flush_all()
        db.n_keys = 600
        return db

    prog = TraceProgram(
        "det", (Phase("a", 15.0, MIX), Phase("b", 15.0, READMIX)),
        (DriftTenant("t0", PoissonArrivals(15.0)),))
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"], workloads=[], arrivals=[],
        drift_programs=[prog], ssd_zone_budgets=[20],
        warmup=2.0, db_factory=db_factory, telemetry=telemetry)
    return matrix.run(verbose=False)


def test_rows_identical_with_telemetry_on_and_off():
    """The telemetry sampler and the phase-boundary marker process ride
    daemon timeouts — they must never perturb the measured rows."""
    off = _matrix_rows(telemetry=False)
    on = _matrix_rows(telemetry=True)
    assert json.dumps(off, sort_keys=True) == json.dumps(on, sort_keys=True)


def test_run_drift_deterministic_across_runs():
    a, b = [], []
    for dst in (a, b):
        db, n = _loaded("HHZS", n=600)
        dst.extend(r.to_json() for r in run_drift(
            db, _two_phase(rate=15.0, phase_s=15.0), n_keys=n, seed=7))
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)


def test_timeline_carries_phase_marks():
    db, n = _loaded("HHZS", n=600)
    db.enable_telemetry(5.0)
    run_drift(db, _two_phase(rate=15.0, phase_s=15.0), n_keys=n)
    tl = db.metrics.timeline(meta={})
    labels = [m["label"] for m in tl.get("marks", [])]
    assert labels == ["phase:a", "phase:b"]


# ---------------------------------------------------------------------
# named programs + rankings
# ---------------------------------------------------------------------
def test_build_program_shapes():
    p = build_program("rotate", svc=100.0, n_keys=1000,
                      arrival_kind="bursty", phase_s=50.0)
    assert p.name == "rotate~bursty"
    assert [ph.name for ph in p.phases] == ["warm", "shift", "analytics",
                                            "grow"]
    assert p.duration == pytest.approx(200.0)
    c = build_program("churn", svc=100.0, n_keys=1000)
    assert [ph.name for ph in c.phases] == ["solo", "contend", "after"]
    assert not c.live_in(c.phases[0], "batch")
    assert c.live_in(c.phases[1], "batch")
    with pytest.raises(ValueError):
        build_program("nope", svc=1.0, n_keys=10)


def _synth_row(scheme, p99s, measured=10):
    return {"drift": "p", "arrival": "poisson(1)", "tenant": "t0",
            "ssd_zones": 20, "scheme": scheme,
            "phases": [{"phase": k, "name": f"ph{k}", "latency_p99": v,
                        "throughput": 1.0, "n_measured": measured}
                       for k, v in enumerate(p99s)]}


def test_phase_rankings_and_flips():
    """Default metric is the in-window tail (lower is better): per-phase
    throughput is arrival-bound by construction, so it cannot rank."""
    rows = [_synth_row("A1", [1.0, 10.0, 5.0]),
            _synth_row("B2", [2.0, 5.0, 6.0])]
    out = phase_rankings(rows)
    (key, g), = out.items()
    assert key == ("p", "poisson(1)", "t0", 20)
    assert [p["ranking"] for p in g["phases"]] \
        == [["A1", "B2"], ["B2", "A1"], ["A1", "B2"]]
    assert g["flips"] == 2
    assert rank_flips(rows) == {key: 2}


def test_phase_rankings_throughput_metric_ranks_descending():
    rows = [_synth_row("A1", [1.0]), _synth_row("B2", [2.0])]
    rows[0]["phases"][0]["throughput"] = 5.0
    rows[1]["phases"][0]["throughput"] = 9.0
    (_, g), = phase_rankings(rows, metric="throughput").items()
    assert g["phases"][0]["ranking"] == ["B2", "A1"]


def test_phase_rankings_ties_break_by_scheme_name():
    rows = [_synth_row("Z", [3.0]), _synth_row("A", [3.0])]
    (_, g), = phase_rankings(rows).items()
    assert g["phases"][0]["ranking"] == ["A", "Z"]


def test_phase_rankings_skips_unmeasured_windows():
    """A scheme whose window has no measured op (e.g. fully inside
    warmup) must not "win" on an empty percentile of 0.0."""
    rows = [_synth_row("A1", [3.0]), _synth_row("B2", [0.0], measured=0)]
    (_, g), = phase_rankings(rows).items()
    assert g["phases"][0]["ranking"] == ["A1"]
