"""End-to-end training: loss decreases; kill/resume produces a working run."""
import dataclasses

import pytest

from repro.config import TrainConfig
from repro.configs import get_config
from repro.launch.train import train_loop

pytestmark = pytest.mark.slow  # full training loops, 1+ min; run with -m slow


def _tiny():
    return dataclasses.replace(
        get_config("qwen3-1.7b").smoke(), name="tiny", num_layers=2,
        d_model=128, num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
        vocab_size=512)


def _tc(steps):
    return TrainConfig(learning_rate=1e-3, warmup_steps=10,
                       total_steps=steps)


def test_loss_decreases():
    out = train_loop(_tiny(), steps=150, batch=8, seq=64, tc=_tc(150),
                     log=lambda *a: None)
    first = out["losses"][0][1]
    last = out["losses"][-1][1]
    assert last < first - 0.3, f"loss should drop: {first} -> {last}"


def test_kill_and_resume_via_checkpoints(tmp_path):
    out = train_loop(_tiny(), steps=80, batch=4, seq=32,
                     ckpt_dir=str(tmp_path), save_every=20, fail_at=50,
                     log=lambda *a: None)
    assert out["restarts"] == 1
    assert out["final_step"] == 80
    assert any("restored at 40" in e for e in out["events"])


def test_grad_accum_equivalent_loss_scale():
    from repro.config import ParallelConfig
    cfg = _tiny()
    out1 = train_loop(cfg, steps=20, batch=8, seq=32, log=lambda *a: None)
    out2 = train_loop(cfg, steps=20, batch=8, seq=32,
                      parallel=ParallelConfig(seq_shard_activations=False,
                                              grad_accum=4),
                      log=lambda *a: None)
    # same data, same init: microbatched loss ~= full-batch loss
    l1 = dict(out1["losses"])
    l2 = dict(out2["losses"])
    assert abs(l1[10] - l2[10]) < 0.2
