"""Control plane v2: PI law, anti-windup, knob actuation, and per-tenant
compaction-debt attribution.

Covers the PR's tentpole contracts:

* :class:`repro.obs.PIController` — step response, clamping, and
  conditional-integration anti-windup (the integral must freeze under
  saturation so recovery is prompt once pressure clears).
* PI vs AIMD on the same synthetic pressure trace: both converge, the PI
  trajectory is smoother (no multiplicative-decrease cliff).
* Knob mapping: ``u = 1`` is neutral for every actuator; ``u = 0`` pins
  compaction pace at its floor, migration at its minimum scale, the
  cache budget at zero; ``stop()`` restores neutral.
* Debt attribution: ``LSMTree.debt_by_tenant`` conserves
  ``compaction_debt()`` exactly (tagged shares + untagged remainder)
  through flushes, compactions, and crash/recovery, and the write-volume
  shares order correctly.
* Crash semantics: ``DB.reopen`` clears the control plane's
  ``rate_overrides`` (volatile controller state must not survive a
  restart-from-scratch of the loop).
"""
import math

import numpy as np
import pytest

from conftest import tiny_scenario
from repro.core.middleware import AdmissionConfig, AdmissionController
from repro.lsm import DB
from repro.obs import ControlPlane, Ewma, PIController
from repro.obs.control import CACHE_RELEASE_U, MIGRATION_SCALE, PACE_FLOOR
from repro.workloads import run_load
from repro.zoned import Sim


# ---------------------------------------------------------------------
# PIController unit behaviour
# ---------------------------------------------------------------------
def test_pi_step_response_tracks_setpoint():
    pi = PIController(kp=0.6, ki=0.15, setpoint=1.0, lo=0.05, hi=1.0)
    # at setpoint: stays at the neutral output
    assert pi.update(1.0, 1.0) == pytest.approx(1.0)
    # step overload (measurement 1.5x the target): monotone decrease
    us = [pi.update(1.5, 1.0) for _ in range(12)]
    assert us[0] < 1.0
    assert all(b <= a + 1e-12 for a, b in zip(us, us[1:]))
    assert us[-1] < 0.5
    # step back under the target: monotone recovery to the ceiling
    us = [pi.update(0.5, 1.0) for _ in range(60)]
    assert all(b >= a - 1e-12 for a, b in zip(us, us[1:]))
    assert us[-1] == pytest.approx(1.0)
    # output always clamped
    assert all(0.05 <= u <= 1.0 for u in us)


def test_pi_anti_windup_freezes_integral_and_recovers_fast():
    pi = PIController(kp=0.6, ki=0.15, setpoint=1.0, lo=0.05, hi=1.0)
    # mild sustained overload: the integral accumulates for ~9 steps,
    # walking u down to the floor ...
    for _ in range(20):
        pi.update(1.5, 1.0)
    assert pi.last_u == pytest.approx(0.05)
    frozen = pi.integral
    assert frozen < 0.0
    # ... and conditional integration freezes it there: 200 more
    # saturated steps must not wind it any further
    for _ in range(200):
        pi.update(1.5, 1.0)
    assert pi.integral == pytest.approx(frozen)
    # pressure clears: recovery completes within a handful of steps
    # instead of the windup lag (an unconditional integral would first
    # have to unwind 200 * e * dt before u moved at all)
    us = [pi.update(0.5, 1.0) for _ in range(10)]
    assert us[0] > 0.5          # off the floor on the very first step
    assert us[-1] == pytest.approx(1.0)


def test_pi_validates_bounds_and_resets():
    with pytest.raises(ValueError):
        PIController(kp=1.0, ki=0.1, lo=1.0, hi=1.0)
    pi = PIController(kp=0.6, ki=0.15, lo=0.0, hi=1.0)
    pi.update(2.0, 1.0)
    assert pi.integral != 0.0
    pi.reset()
    assert pi.integral == 0.0 and pi.last_u == pytest.approx(1.0)


def test_ewma_filter():
    f = Ewma(alpha=0.5)
    assert f.update(2.0) == pytest.approx(2.0)     # first sample passes
    assert f.update(0.0) == pytest.approx(1.0)
    assert f.update(0.0) == pytest.approx(0.5)
    f.reset()
    assert f.value is None
    with pytest.raises(ValueError):
        Ewma(alpha=0.0)


# ---------------------------------------------------------------------
# PI vs AIMD on a synthetic pressure trace
# ---------------------------------------------------------------------
def _plane(controller: str) -> ControlPlane:
    sim = Sim()
    cfg = AdmissionConfig(policy="feedback", protected=frozenset(["a"]),
                          bucket_rates={"b": (100.0, 5.0)},
                          feedback_controller=controller,
                          feedback_interval=1.0,
                          feedback_decrease=0.5, feedback_increase=0.1,
                          feedback_headroom=0.8, feedback_floor=0.05,
                          feedback_kp=0.6, feedback_ki=0.15,
                          feedback_smooth=0.5)
    ctrl = AdmissionController(sim, None, cfg)
    ctrl.tenant_counters("a")
    ctrl.tenant_counters("b")
    return ControlPlane(sim, ctrl, targets={"a": 0.1})


def test_pi_vs_aimd_on_square_wave_pressure():
    """Square wave: 30 ticks at 1.5x the target, 30 ticks at 0.6x, twice.

    Both laws must throttle under overload and recover in the lull; the
    PI trajectory must be smoother — its largest single-tick move stays
    below AIMD's multiplicative-decrease cliff (u -> u/2)."""
    trace = ([1.5] * 30 + [0.6] * 30) * 2
    traj = {}
    for law in ("aimd", "pi"):
        plane = _plane(law)
        us = []
        for worst in trace:
            if law == "pi":
                plane._tick_pi(worst)
            else:
                plane._tick_aimd(worst, worst > 1.0)
            us.append(plane._u)
        traj[law] = np.asarray(us)
    for law, us in traj.items():
        # throttled by the end of each overload phase ...
        assert us[29] < 0.3, (law, us[:30])
        assert us[89] < 0.3, (law, us[60:90])
        # ... recovered by the end of each lull
        assert us[59] > 0.9, (law, us[30:60])
        assert us[119] > 0.9, (law, us[90:])
        # throttling also drives the controlled tenant's rate override
        assert plane.ctrl.rate_overrides or law == "aimd"
    steps = {law: float(np.abs(np.diff(us)).max())
             for law, us in traj.items()}
    assert steps["pi"] < steps["aimd"], steps
    # AIMD's first decrease is the u -> u/2 cliff
    assert steps["aimd"] == pytest.approx(0.5)


def test_pi_rate_override_biased_by_debt_share():
    """With a db binding faked to attribute debt 3:1 between the two
    controlled tenants, the bigger debtor gets the harder throttle
    (u ** (1 + share) ordering)."""
    plane = _plane("pi")
    plane.ctrl.tenant_counters("c")
    plane.ctrl.cfg = plane.ctrl.cfg  # cfg read-through stays live

    class _FakeTree:
        def debt_by_tenant(self):
            return {"b": 300.0, "c": 100.0, "": 50.0}

    class _FakeDB:
        tree = _FakeTree()

    plane.db = _FakeDB()
    plane.ctrl.cfg.bucket_rates["c"] = (100.0, 5.0)
    shares = plane.debt_shares()
    assert shares["b"] == pytest.approx(0.75)
    assert shares["c"] == pytest.approx(0.25)
    assert "" not in shares
    for _ in range(4):
        plane._tick_pi(1.5)
    rates = plane.ctrl.rate_overrides
    assert rates["b"] < rates["c"] < 100.0, rates


# ---------------------------------------------------------------------
# knob actuation against a real store
# ---------------------------------------------------------------------
def test_knob_mapping_neutral_floor_and_stop():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    cfg = AdmissionConfig(policy="feedback", protected=frozenset(["a"]),
                          feedback_knobs=("admission", "compaction",
                                          "migration", "cache"))
    ctrl = db.fresh_admission(cfg)
    plane = ControlPlane(db.sim, ctrl, targets={"a": 0.1},
                         debt_gauge=ctrl.debt_gauge, db=db)
    mig_base = db.backend.migrator.rate_limit
    # u = 1: pace/cache neutral, migration boosted to its lull maximum
    plane._apply_knobs(1.0)
    assert db.tree.compaction_pace == pytest.approx(1.0)
    assert db.backend.migrator.rate_limit \
        == pytest.approx(mig_base * MIGRATION_SCALE[1])
    assert db.backend.cache_zone_budget is None
    # u = 0 pins every knob at its pressure extreme
    plane._apply_knobs(0.0)
    assert db.tree.compaction_pace == pytest.approx(PACE_FLOOR)
    assert db.backend.migrator.rate_limit \
        == pytest.approx(mig_base * MIGRATION_SCALE[0])
    assert db.backend.cache_zone_budget == 0
    # mid-range: partial budget, partial pace
    plane._apply_knobs(0.5)
    assert PACE_FLOOR < db.tree.compaction_pace < 1.0
    assert isinstance(db.backend.cache_zone_budget, int)
    assert db.backend.cache_zone_budget >= 0
    assert 0.5 < CACHE_RELEASE_U  # below the release point: budget stays
    # stop() restores neutral so the next run starts from default state
    plane.stop()
    assert db.tree.compaction_pace == pytest.approx(1.0)
    assert db.backend.migrator.rate_limit == pytest.approx(mig_base)
    assert db.backend.cache_zone_budget is None
    assert plane.knob_summary()["pace"] == pytest.approx(1.0)


def test_compaction_pace_defers_background_io():
    """Paced compaction (pace < 1) takes longer in virtual time than the
    same compaction unpaced — the SILK-style deferral — and the default
    pace of 1.0 adds zero delay (event-identical to pre-v2 runs)."""
    spans = {}
    for pace in (1.0, 0.3):
        db = DB("B3", tiny_scenario(), store_values=True)
        db.tree.compaction_pace = pace
        run_load(db, n_keys=1500)
        t0 = db.sim.now
        db.flush_all()
        db.drain()                      # drain all compactions
        spans[pace] = db.sim.now - t0
        assert db.tree.compaction_debt() == 0
    assert spans[0.3] > spans[1.0] * 1.2, spans


# ---------------------------------------------------------------------
# per-tenant debt attribution lineage
# ---------------------------------------------------------------------
def _write_tenants(db, plan):
    """Interleave tagged writes per ``plan = {tenant: n_objs}``."""
    tree, sim = db.tree, db.sim

    def writer(tenant, lo, n):
        for k in range(lo, lo + n):
            yield from tree.put(k, tenant=tenant)

    lo, procs = 0, []
    for tenant, n in plan.items():
        procs.append(sim.process(writer(tenant, lo, n)))
        lo += n
    for p in procs:
        sim.run_until(p)


def _assert_conserved(tree):
    by = tree.debt_by_tenant()
    assert sum(by.values()) == pytest.approx(float(tree.compaction_debt()))
    assert all(v >= 0.0 for v in by.values()), by
    return by


def test_debt_attribution_conservation_and_ordering():
    db = DB("B3", tiny_scenario(), store_values=True)
    # 3:1 write volume between the tenants — no untagged load phase, so
    # nearly all debt should attribute (the remainder bucket stays small)
    _write_tenants(db, {"x": 4500, "y": 1500})
    # mid-flight: flushes queued, compactions running — conservation must
    # hold at any instant, not just at quiescence
    _assert_conserved(db.tree)
    db.flush_all()
    by = _assert_conserved(db.tree)
    if db.tree.compaction_debt() > 0:
        assert by.get("x", 0.0) > by.get("y", 0.0), by
    db.drain()
    _assert_conserved(db.tree)          # drained: debt (and shares) -> 0


def test_debt_attribution_survives_crash_recovery():
    db = DB("B3", tiny_scenario(), store_values=True)

    # interleave the tenants 3:1 within one stream so the live WAL tail
    # (what the crash keeps) contains records from both
    def writer():
        for k in range(4000):
            yield from db.tree.put(k, tenant="x" if k % 4 else "y")

    db.sim.run_until(db.sim.process(writer()))
    db.crash()
    info = db.reopen()
    assert info["replayed_records"] > 0
    # WAL replay re-attributed the records into the rebuilt MemTables
    tallies = {}
    for mt in [db.tree.memtable] + list(db.tree.immutables):
        for t, n in mt.tenant_objs.items():
            tallies[t] = tallies.get(t, 0) + n
    assert tallies.get("x", 0) > tallies.get("y", 0) > 0, tallies
    db.flush_all()
    by = _assert_conserved(db.tree)
    if db.tree.compaction_debt() > 0:
        assert by.get("x", 0.0) > by.get("y", 0.0), by
    db.drain()
    _assert_conserved(db.tree)


def test_untagged_writes_fall_into_remainder_bucket():
    db = DB("B3", tiny_scenario(), store_values=True)
    run_load(db, n_keys=3000)           # load phase is untagged
    db.flush_all()
    by = _assert_conserved(db.tree)
    if db.tree.compaction_debt() > 0:
        # everything unattributed: the "" bucket carries all of it
        assert set(by) == {""}, by


# ---------------------------------------------------------------------
# crash semantics of the control plane's volatile state
# ---------------------------------------------------------------------
def test_rate_overrides_cleared_on_reopen():
    db = DB("B3", tiny_scenario(), store_values=True,
            admission=AdmissionConfig(policy="feedback",
                                      protected=frozenset(["prot"])))
    _write_tenants(db, {"bulk": 200})
    # simulate a converged controller mid-run
    db.admission.rate_overrides["bulk"] = 3.0
    db.crash()
    db.reopen()
    # the overrides are volatile controller memory: a restarted
    # ControlPlane must re-derive its trajectory, not inherit throttles
    assert db.admission.rate_overrides == {}
    # and a restarted plane starts from neutral actuation
    plane = ControlPlane(db.sim, db.admission, targets={"prot": 1.0},
                         db=db)
    plane._u = 0.2
    plane._pi.integral = -5.0
    plane.start()
    assert plane._u == 1.0 and plane._pi.integral == 0.0
    plane.stop()
    db.drain()
