"""Zoned device + simulation kernel invariants."""
import pytest

from repro.zoned import Sim, Semaphore, ZonedDevice, ZoneState
from repro.zoned.device import DeviceTiming, MiB

T = DeviceTiming(seq_read_bw=100 * MiB, seq_write_bw=100 * MiB,
                 rand_read_iops=1000.0, seq_overhead=10e-6)


def make_dev(sim=None, zones=4, cap=1 << 20):
    sim = sim or Sim()
    return sim, ZonedDevice(sim, "d", T, zones, cap)


# ---------------------------------------------------------------------
def test_zone_append_only_and_reset():
    sim, dev = make_dev()
    z = dev.alloc_zone("x")
    dev.append(z, 512 * 1024)
    assert z.write_ptr == 512 * 1024 and z.state == ZoneState.OPEN
    dev.append(z, 512 * 1024)
    assert z.state == ZoneState.FULL
    with pytest.raises(RuntimeError):
        dev.append(z, 1)
    dev.reset_zone(z)
    assert z.write_ptr == 0 and z.state == ZoneState.EMPTY
    assert dev.resets == 1


def test_zone_overfill_rejected():
    sim, dev = make_dev()
    z = dev.alloc_zone("x")
    with pytest.raises(RuntimeError):
        dev.append(z, (1 << 20) + 1)


def test_alloc_exhaustion():
    sim, dev = make_dev(zones=2)
    dev.alloc_zone("a")
    dev.alloc_zone("b")
    with pytest.raises(RuntimeError):
        dev.alloc_zone("c")


# ---------------------------------------------------------------------
def test_service_times_match_table1_model():
    sim, dev = make_dev()
    # 4 KiB random read = 1/IOPS exactly
    assert dev._service_time(4096, "rand_read") == pytest.approx(1e-3)
    # sequential = overhead + bytes/bw
    assert dev._service_time(MiB, "seq_write") == pytest.approx(
        10e-6 + 1.0 / 100)


def test_fifo_queueing():
    sim, dev = make_dev()
    ev1 = dev.io(MiB, "seq_write")
    ev2 = dev.io(MiB, "seq_write")
    done = []
    ev1.add_callback(lambda _: done.append(sim.now))
    ev2.add_callback(lambda _: done.append(sim.now))
    sim.run()
    assert done[1] == pytest.approx(2 * done[0], rel=1e-6)


def test_background_io_consumes_capacity_without_queueing():
    sim, dev = make_dev()
    bg = dev.io(MiB, "seq_write", background=True)
    fg = dev.io(4096, "rand_read")
    t = {}
    bg.add_callback(lambda _: t.setdefault("bg", sim.now))
    fg.add_callback(lambda _: t.setdefault("fg", sim.now))
    sim.run()
    # foreground queues behind the capacity the background op consumed
    assert t["fg"] > 1e-3
    # but background completes on its own track (not behind foreground)
    assert t["bg"] == pytest.approx(10e-6 + 0.01, rel=1e-3)


# ---------------------------------------------------------------------
def test_run_until_in_the_past_never_rewinds_time():
    """Regression: run(until=t) with t < now used to set now = t, moving
    virtual time backwards and corrupting every later timestamp."""
    sim = Sim()
    sim.timeout(5.0)
    sim.run()
    assert sim.now == 5.0
    sim.run(until=1.0)                 # target already in the past: no-op
    assert sim.now == 5.0
    # early-return branch: next event beyond a past target must not rewind
    sim.timeout(10.0)                  # scheduled at t=15
    sim.run(until=3.0)
    assert sim.now == 5.0
    sim.run(until=20.0)
    assert sim.now == 20.0


# ---------------------------------------------------------------------
def test_daemon_events_do_not_block_run():
    sim = Sim()
    ticks = []

    def pump():
        while True:
            yield sim.timeout(1.0, daemon=True)
            ticks.append(sim.now)

    sim.process(pump())
    sim.timeout(2.5)           # non-daemon work until t=2.5
    sim.run()
    assert sim.now == pytest.approx(2.5)


def test_semaphore_limits_concurrency():
    sim = Sim()
    sem = Semaphore(sim, 2)
    running = []
    peak = []

    def job(i):
        yield sem.acquire()
        running.append(i)
        peak.append(len(running))
        yield sim.timeout(1.0)
        running.remove(i)
        sem.release()

    for i in range(5):
        sim.process(job(i))
    sim.run()
    assert max(peak) == 2
