"""Zoned device + simulation kernel invariants."""
import pytest

from repro.zoned import Sim, Semaphore, ZonedDevice, ZoneState
from repro.zoned.device import DeviceTiming, MiB

T = DeviceTiming(seq_read_bw=100 * MiB, seq_write_bw=100 * MiB,
                 rand_read_iops=1000.0, seq_overhead=10e-6)


def make_dev(sim=None, zones=4, cap=1 << 20):
    sim = sim or Sim()
    return sim, ZonedDevice(sim, "d", T, zones, cap)


# ---------------------------------------------------------------------
def test_zone_append_only_and_reset():
    sim, dev = make_dev()
    z = dev.alloc_zone("x")
    dev.append(z, 512 * 1024)
    assert z.write_ptr == 512 * 1024 and z.state == ZoneState.OPEN
    dev.append(z, 512 * 1024)
    assert z.state == ZoneState.FULL
    with pytest.raises(RuntimeError):
        dev.append(z, 1)
    dev.reset_zone(z)
    assert z.write_ptr == 0 and z.state == ZoneState.EMPTY
    assert dev.resets == 1


def test_zone_overfill_rejected():
    sim, dev = make_dev()
    z = dev.alloc_zone("x")
    with pytest.raises(RuntimeError):
        dev.append(z, (1 << 20) + 1)


def test_alloc_exhaustion():
    sim, dev = make_dev(zones=2)
    dev.alloc_zone("a")
    dev.alloc_zone("b")
    with pytest.raises(RuntimeError):
        dev.alloc_zone("c")


# ---------------------------------------------------------------------
def test_service_times_match_table1_model():
    sim, dev = make_dev()
    # 4 KiB random read = 1/IOPS exactly
    assert dev._service_time(4096, "rand_read") == pytest.approx(1e-3)
    # sequential = overhead + bytes/bw
    assert dev._service_time(MiB, "seq_write") == pytest.approx(
        10e-6 + 1.0 / 100)


def test_fifo_queueing():
    sim, dev = make_dev()
    done = []

    def waiter(ev):
        yield ev
        done.append(sim.now)

    sim.process(waiter(dev.io(MiB, "seq_write")))
    sim.process(waiter(dev.io(MiB, "seq_write")))
    sim.run()
    assert done[1] == pytest.approx(2 * done[0], rel=1e-6)


def test_background_io_consumes_capacity_without_queueing():
    sim, dev = make_dev()
    t = {}

    def waiter(key, ev):
        yield ev
        t.setdefault(key, sim.now)

    sim.process(waiter("bg", dev.io(MiB, "seq_write", background=True)))
    sim.process(waiter("fg", dev.io(4096, "rand_read")))
    sim.run()
    # foreground queues behind the capacity the background op consumed
    assert t["fg"] > 1e-3
    # but background completes on its own track (not behind foreground)
    assert t["bg"] == pytest.approx(10e-6 + 0.01, rel=1e-3)


# ---------------------------------------------------------------------
def test_run_until_in_the_past_never_rewinds_time():
    """Regression: run(until=t) with t < now used to set now = t, moving
    virtual time backwards and corrupting every later timestamp."""
    sim = Sim()
    sim.timeout(5.0)
    sim.run()
    assert sim.now == 5.0
    sim.run(until=1.0)                 # target already in the past: no-op
    assert sim.now == 5.0
    # early-return branch: next event beyond a past target must not rewind
    sim.timeout(10.0)                  # scheduled at t=15
    sim.run(until=3.0)
    assert sim.now == 5.0
    sim.run(until=20.0)
    assert sim.now == 20.0


# ---------------------------------------------------------------------
def test_daemon_events_do_not_block_run():
    sim = Sim()
    ticks = []

    def pump():
        while True:
            yield sim.timeout(1.0, daemon=True)
            ticks.append(sim.now)

    sim.process(pump())
    sim.timeout(2.5)           # non-daemon work until t=2.5
    sim.run()
    assert sim.now == pytest.approx(2.5)


# ---------------------------------------------------------------------
# batched device queue + kernel bulk paths (PR 4)
# ---------------------------------------------------------------------
def _drive_trace(batched):
    """Run a fixed mixed fg/bg I/O trace (with a mid-trace restart, which
    breaks the monotone invariant) and return every completion time."""
    sim = Sim()
    dev = ZonedDevice(sim, "d", T, 4, 1 << 20, batched=batched)
    times = []

    def client(i):
        for k in range(30):
            yield dev.io(4096 * (1 + (i + k) % 5),
                         "rand_read" if (i + k) % 3 else "seq_write",
                         background=(k % 7 == 0))
            times.append(sim.now)

    def restarter():
        yield sim.timeout(0.02)
        dev.restart()       # pending completions now postdate new ends
        yield dev.io(4096, "rand_read")
        times.append(sim.now)

    for i in range(4):
        sim.process(client(i))
    sim.process(restarter())
    sim.run()
    return times


def test_batched_vs_unbatched_device_identical():
    """The per-device completion batch is a pure scheduling optimization:
    a fixed op trace yields bit-identical virtual completion times with
    batching on and off (including across a restart() that forces the
    non-monotone heap fallback)."""
    assert _drive_trace(batched=True) == _drive_trace(batched=False)


def test_monotone_queue_fallback_keeps_order():
    sim = Sim()
    q = sim.monotone_queue()
    fired = []
    for at in [1.0, 2.0, 1.5, 3.0, 0.5]:   # 1.5 and 0.5 break monotonicity
        def waiter(ev, at=at):
            yield ev
            fired.append((at, sim.now))
        sim.process(waiter(q.schedule_at(at)))
    sim.run()
    assert fired == sorted(fired, key=lambda x: x[0])
    assert all(at == now for at, now in fired)


def test_completion_ticket_unawaited_is_silent():
    """A ticket nobody yields completes without firing anything — the
    fire-and-forget background-I/O shape."""
    sim = Sim()
    q = sim.monotone_queue()
    q.complete_at(1.0)
    done = []

    def waiter(ev):
        yield ev
        done.append(sim.now)

    sim.process(waiter(q.complete_at(2.0)))
    sim.run()
    assert done == [2.0] and sim.now == 2.0


def test_completion_ticket_yielded_after_fire_resumes_immediately():
    """A ticket first yielded after its completion time must resume the
    process at once (the already-triggered-Event semantics), not strand
    it; awaiting the same ticket twice is an error."""
    sim = Sim()
    q = sim.monotone_queue()
    marks = []

    def proc():
        t = q.complete_at(1.0, value="v")
        yield sim.timeout(2.0)       # the ticket fires while we sleep
        got = yield t
        marks.append((sim.now, got))

    sim.run_until(sim.process(proc()))
    assert marks == [(2.0, "v")]

    def awaiter(t):
        yield t

    def double():
        t = q.complete_at(sim.now + 1.0)
        sim.process(awaiter(t))      # first awaiter
        yield sim.timeout(0.5)
        yield t                      # second awaiter: error

    with pytest.raises(RuntimeError, match="already awaited"):
        sim.run_until(sim.process(double()))


def test_schedule_many_matches_individual_timeouts():
    delays = [0.003, 0.001, 0.004, 0.001, 0.005]   # deliberately unsorted
    order_many, order_one = [], []
    for order, use_many in [(order_many, True), (order_one, False)]:
        sim = Sim()
        if use_many:
            evs = sim.schedule_many(delays, value="v")
        else:
            evs = [sim.timeout(d, value="v") for d in delays]

        def waiter(i, ev, order=order, sim=sim):
            got = yield ev
            order.append((i, sim.now, got))

        for i, ev in enumerate(evs):
            sim.process(waiter(i, ev))
        sim.run()
    assert order_many == order_one
    assert [i for i, _, _ in order_many] == [1, 3, 0, 2, 4]  # time, then seq
    assert all(v == "v" for _, _, v in order_many)


def test_schedule_many_sorted_batch_and_daemon():
    sim = Sim()
    evs = sim.schedule_many(i * 0.01 for i in range(100))
    sim.run()
    assert sim.now == pytest.approx(0.99) and all(e.triggered for e in evs)
    # daemon batches do not keep run() alive
    sim2 = Sim()
    sim2.schedule_many([1.0, 2.0], daemon=True)
    sim2.timeout(0.5)
    sim2.run()
    assert sim2.now == 0.5
    with pytest.raises(ValueError):
        sim2.schedule_many([0.1, -0.2])


def test_bare_delay_yield_matches_timeout():
    def run(bare):
        sim = Sim()
        marks = []

        def proc():
            for d in [0.25, 0.5, 0.125]:
                if bare:
                    yield d
                else:
                    yield sim.timeout(d)
                marks.append(sim.now)

        sim.run_until(sim.process(proc()))
        return marks

    assert run(True) == run(False) == [0.25, 0.75, 0.875]


def test_bare_delay_negative_raises():
    sim = Sim()

    def proc():
        yield -1.0

    with pytest.raises(ValueError, match="negative delay"):
        sim.run_until(sim.process(proc()))


def test_numpy_scalar_bare_delay_yields():
    """Regression: ``yield np.float64(0.25)`` raised TypeError — numpy
    scalars are not exactly ``float``/``int``, so they missed the bare-
    delay fast path.  Any ``numbers.Real`` is now accepted (converted
    once, same schedule); non-real yields fail with a pointed message."""
    import numpy as np
    sim = Sim()
    marks = []

    def proc():
        yield np.float64(0.25)
        marks.append(sim.now)
        yield np.int64(1)
        marks.append(sim.now)
        yield np.float32(0.5)
        marks.append(sim.now)

    sim.run_until(sim.process(proc()))
    assert marks == [0.25, 1.25, 1.75]

    def negative():
        yield np.float64(-0.5)

    with pytest.raises(ValueError, match="negative delay"):
        sim.run_until(sim.process(negative()))

    def not_a_delay():
        yield "0.25"

    with pytest.raises(TypeError, match="real-number delay"):
        sim.run_until(sim.process(not_a_delay()))


def test_run_until_with_device_queue_and_until_clamp():
    """run(until=...) stops on time with completions still pending in a
    device queue, then finishes them on the next run()."""
    sim = Sim()
    dev = ZonedDevice(sim, "d", T, 4, 1 << 20)
    done = []

    def client():
        for _ in range(3):
            yield dev.io(MiB, "seq_write")     # ~10ms each
            done.append(sim.now)

    sim.process(client())
    sim.run(until=0.015)
    assert sim.now == 0.015 and len(done) == 1
    sim.run()
    assert len(done) == 3


def test_semaphore_limits_concurrency():
    sim = Sim()
    sem = Semaphore(sim, 2)
    running = []
    peak = []

    def job(i):
        yield sem.acquire()
        running.append(i)
        peak.append(len(running))
        yield sim.timeout(1.0)
        running.remove(i)
        sem.release()

    for i in range(5):
        sim.process(job(i))
    sim.run()
    assert max(peak) == 2
