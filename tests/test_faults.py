"""Fault injection: stall windows, bandwidth degradation, zone-reset
faults (with middleware repair), and the open-loop runner's fault rows."""
import numpy as np
import pytest

from conftest import tiny_scenario
from test_invariants import _assert_level_counts_match
from repro.lsm import DB
from repro.workloads import (PoissonArrivals, ScenarioMatrix, WorkloadSpec,
                             YCSB, run_load, run_open_loop)
from repro.zoned import Sim, ZonedDevice
from repro.zoned.device import DeviceTiming, MiB, ZoneState
from repro.zoned.faults import (FaultInjector, FaultSpec, SlowWindow,
                                StallWindow, ZoneReset)

T = DeviceTiming(seq_read_bw=100 * MiB, seq_write_bw=100 * MiB,
                 rand_read_iops=1000.0, seq_overhead=10e-6)


def _loaded(scheme="HHZS", n=1200):
    db = DB(scheme, tiny_scenario(), store_values=True)
    run_load(db, n_keys=n)
    db.flush_all()
    db.drain()
    return db, n


# ---------------------------------------------------------------------
# device hooks
# ---------------------------------------------------------------------
def _when(sim, t, key, completion):
    """Record in ``t[key]`` the virtual time the completion fires."""
    def waiter():
        yield completion
        t.setdefault(key, sim.now)
    sim.process(waiter())


def test_stall_freezes_io():
    sim = Sim()
    dev = ZonedDevice(sim, "d", T, 4, 1 << 20)
    dev.stall(10.0)
    t = {}
    _when(sim, t, "fg", dev.io(4096, "rand_read"))
    _when(sim, t, "bg", dev.io(4096, "rand_read", background=True))
    sim.run()
    # both tracks queue behind the stall window
    assert t["fg"] >= 10.0 and t["bg"] >= 10.0


def test_degrade_scales_service_inside_window_only():
    sim = Sim()
    dev = ZonedDevice(sim, "d", T, 4, 1 << 20)
    dev.degrade(5.0, 4.0)
    t = {}
    # base service = 1/IOPS = 1 ms
    _when(sim, t, "slow", dev.io(4096, "rand_read"))
    sim.run()
    assert t["slow"] == pytest.approx(4e-3, rel=1e-6)
    # submissions after the window are back to full speed
    sim2 = Sim()
    dev2 = ZonedDevice(sim2, "d", T, 4, 1 << 20)
    dev2.degrade(5.0, 4.0)
    sim2.timeout(6.0)
    sim2.run()
    t2 = {}
    _when(sim2, t2, "t", dev2.io(4096, "rand_read"))
    sim2.run()
    assert t2["t"] == pytest.approx(6.0 + 1e-3, rel=1e-6)


def test_restart_clears_queue_and_degradation():
    sim = Sim()
    dev = ZonedDevice(sim, "d", T, 4, 1 << 20)
    dev.stall(100.0)
    dev.degrade(100.0, 8.0)
    dev.restart()
    t = {}
    _when(sim, t, "t", dev.io(4096, "rand_read"))
    sim.run()
    assert t["t"] == pytest.approx(1e-3, rel=1e-6)


def test_fault_injector_fires_on_schedule():
    db, _ = _loaded("B3")
    t0 = db.sim.now
    spec = FaultSpec(
        stalls=(StallWindow(at=1.0, duration=2.0, device="both"),),
        slows=(SlowWindow(at=0.5, duration=1.0, factor=8.0, device="hdd"),))
    inj = FaultInjector(db, spec)
    inj.arm()
    # fault timers are daemons (they never keep a drain alive): anchor the
    # window with live foreground work, as any real run has
    db.sim.timeout(6.0)
    db.run_for(6.0)
    assert inj.fired == {"stalls": 1, "slows": 1, "zone_resets": 0}
    assert db.ssd._busy_until >= t0 + 3.0
    assert db.hdd._slow_factor == 8.0


def test_fault_injector_rearm_skips_fired_windows():
    db, _ = _loaded("B3")
    spec = FaultSpec(stalls=(StallWindow(at=1.0, duration=1.0),
                             StallWindow(at=10.0, duration=1.0)))
    inj = FaultInjector(db, spec)
    inj.arm(t0=db.sim.now, after=5.0)    # only the second window arms
    db.sim.timeout(12.0)
    db.run_for(12.0)
    assert inj.fired["stalls"] == 1


# ---------------------------------------------------------------------
# zone-reset faults + middleware repair
# ---------------------------------------------------------------------
def test_zone_reset_fault_repairs_sst():
    db, n = _loaded("HHZS")
    be = db.backend
    sst = next(s for s in be.ssts.values() if s.zones)
    victim = sst.zones[0]
    nzones = len(sst.zones)
    be.on_zone_fault(sst.tier, victim)
    db.drain()
    assert be.stats["zone_faults"] == 1
    assert be.stats.get("repaired_ssts", 0) >= 1
    # the SST is whole again: fresh zones, all owned, right device
    assert sst.sid in be.ssts and len(sst.zones) == nzones
    assert victim not in sst.zones
    dev = be.device_of(sst.tier)
    for z in sst.zones:
        assert z.owner == f"sst:{sst.sid}"
        assert dev.zones[z.zid] is z
    _assert_level_counts_match(db, "after sst repair")
    # reads still correct
    for k in range(0, n, 97):
        assert db.get(k)[0]


def test_zone_reset_fault_on_wal_forces_reflush():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    for k in range(60):
        db.put(k, b"w%d" % k)
    be = db.backend
    assert be._wal_records, "live WAL expected"
    zone = be._wal_records[0]["zone"]
    be.on_zone_fault("ssd", zone)
    db.drain()
    # the torn record is gone and the data was made durable again
    assert all(r["zone"] is not zone for r in be._wal_records)
    for k in range(60):
        assert db.get(k) == (True, b"w%d" % k)
    # durably: a crash after the repair flush must not lose anything
    db.crash()
    db.reopen()
    for k in range(60):
        assert db.get(k) == (True, b"w%d" % k)


def test_zone_reset_fault_on_cache_zone_drops_mappings():
    db = DB("HHZS", tiny_scenario(), store_values=True)
    for k in np.random.default_rng(4).permutation(4000):
        db.put(int(k))
    db.flush_all()
    from repro.workloads import zipf_probs
    p = zipf_probs(4000, 1.2)
    for k in np.random.default_rng(5).choice(4000, size=6000, p=p):
        db.get(int(k))
    db.drain()
    c = db.backend.cache
    assert c.zones, "cache zones must be populated"
    victim = c.zones[0]
    before = c.cached_blocks()
    db.backend.on_zone_fault("ssd", victim)
    assert victim not in c.zones
    assert c.cached_blocks() < before or before == 0
    # mapping consistency: every surviving block points at a live zone
    live = {z.zid for z in c.zones}
    for (sid, blk), zid in c.mapping.items():
        assert zid in live


def test_zone_reset_fault_via_injector_picks_sst_zone():
    db, _ = _loaded("B3")
    spec = FaultSpec(zone_resets=(ZoneReset(at=0.5, device="ssd"),))
    inj = FaultInjector(db, spec)
    inj.arm()
    db.sim.timeout(1.0)
    db.run_for(1.0)
    db.drain()
    assert inj.fired["zone_resets"] == 1
    assert db.backend.stats["zone_faults"] == 1
    _assert_level_counts_match(db, "after injected zone fault")


# ---------------------------------------------------------------------
# open-loop runner fault rows
# ---------------------------------------------------------------------
def test_open_loop_stall_reports_during_stall_tail():
    db, n = _loaded("B3")
    from repro.workloads import run_workload
    probe = run_workload(db, YCSB["A"], n_ops=300, n_keys=n)
    spec = FaultSpec(name="stall",
                     stalls=(StallWindow(at=30.0, duration=10.0,
                                         device="both"),))
    res = run_open_loop(db, YCSB["A"],
                        PoissonArrivals(0.3 * probe.throughput),
                        duration=90.0, n_keys=n, warmup=5.0,
                        max_concurrency=8, faults=spec)
    assert res.fault == spec.label
    assert res.availability == 1.0            # drained run: nothing lost
    assert res.stall_p is not None
    # ops arriving inside the stall wait out the window: their median
    # sojourn dwarfs the undisturbed median
    assert res.stall_p["p50"] > 10 * res.latency_p["p50"]


def test_open_loop_crash_recovers_and_accounts():
    db, n = _loaded("B3")
    spec = FaultSpec(name="crash", crash_at=30.0)
    res = run_open_loop(db, YCSB["A"], PoissonArrivals(10.0), duration=90.0,
                        n_keys=n, warmup=5.0, max_concurrency=8,
                        faults=spec)
    assert res.fault == "crash@30"
    assert res.crash is not None
    assert res.crash["downtime"] > 0.0
    lost = res.crash["lost_in_flight"] + res.crash["refused"]
    assert res.availability == pytest.approx(
        1.0 - lost / res.n_arrived, abs=1e-9)
    assert res.availability < 1.0 or lost == 0
    # the run completed the rest of the stream after recovery
    assert res.n_measured > 0
    assert sum(res.op_counts.values()) < res.n_arrived
    _assert_level_counts_match(db, "after crash cell")
    # row serialization carries the fault fields
    row = res.to_json()
    assert row["fault"] == "crash@30" and "crash" in row


def test_scenario_matrix_fault_dimension(tmp_path):
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=800)
        db.flush_all()
        db.n_keys = 800
        return db

    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    matrix = ScenarioMatrix(
        schemes=["B3"], workloads=[spec],
        arrivals=[PoissonArrivals(6.0)],
        ssd_zone_budgets=[20],
        faults=[None,
                FaultSpec(name="stall",
                          stalls=(StallWindow(at=20.0, duration=8.0,
                                              device="both"),)),
                FaultSpec(name="crash", crash_at=30.0)],
        duration=60.0, warmup=5.0, max_concurrency=8,
        db_factory=db_factory)
    cells = matrix.cells()
    assert len(cells) == 3 and len({c.name for c in cells}) == 3
    rows = matrix.run(out=tmp_path / "scenarios.json", verbose=False)
    assert len(rows) == 3
    baseline = [r for r in rows if "fault" not in r]
    faulty = [r for r in rows if "fault" in r]
    assert len(baseline) == 1 and len(faulty) == 2
    for r in faulty:
        assert 0.0 <= r["availability"] <= 1.0
    stall_row = next(r for r in faulty if r["fault"].startswith("stall"))
    crash_row = next(r for r in faulty if r["fault"].startswith("crash"))
    assert "stall_p" in stall_row
    assert crash_row["crash"]["downtime"] > 0.0


# ---------------------------------------------------------------------
# fault injection inside multi-tenant runs (run_multi_tenant(faults=...))
# ---------------------------------------------------------------------
def _mt_mix(steady_rate=3.0, crowd_rate=6.0):
    from repro.workloads import TenantSpec
    return [TenantSpec("steady", YCSB["A"], PoissonArrivals(steady_rate),
                       protected=True),
            TenantSpec("crowd", YCSB["A"], PoissonArrivals(crowd_rate))]


def test_multitenant_stall_emits_per_tenant_availability():
    from repro.workloads import run_multi_tenant
    db, n = _loaded("B3")
    spec = FaultSpec(name="stall",
                     stalls=(StallWindow(at=30.0, duration=10.0,
                                         device="both"),))
    # stable offered load: the during-stall tail must stand out against
    # an otherwise-uncongested baseline
    res = run_multi_tenant(db, _mt_mix(2.0, 2.0), duration=90.0, n_keys=n,
                           warmup=5.0, max_concurrency=8, faults=spec)
    for t in res.tenants:
        row = t.to_json()
        assert row["fault"] == spec.label
        assert row["availability"] == 1.0      # drained run: nothing lost
        # ops arriving inside the stall wait out the window: their median
        # sojourn exceeds the overall median (the tiny store's baseline
        # already has multi-second compaction excursions, so only the
        # ordering — not a large ratio — is stable at this scale)
        assert row["stall_p"]["p50"] > row["latency_p"]["p50"]
        assert row["stall_p"]["p50"] > 1.0
        assert "tenant" in row and "admission" in row


def test_multitenant_crash_accounts_per_tenant():
    from repro.workloads import run_multi_tenant
    db, n = _loaded("B3")
    spec = FaultSpec(name="crash", crash_at=40.0, recovery_slo_s=5.0)
    res = run_multi_tenant(db, _mt_mix(), duration=90.0, n_keys=n,
                           warmup=5.0, max_concurrency=8, faults=spec)
    total_lost = 0
    for t in res.tenants:
        row = t.to_json()
        assert row["crash"]["downtime"] > 0.0
        lost = row["crash"]["lost_in_flight"] + row["crash"]["refused"]
        total_lost += lost
        served = row["n_arrived"]      # policy none: nothing shed
        assert row["availability"] == pytest.approx(
            1.0 - lost / served, abs=1e-9)
        # recovery-time SLO columns (downtime was ~sub-second in PR 3)
        assert row["recovery_slo_s"] == 5.0
        assert row["recovery_slo_met"] == (row["crash"]["downtime"] <= 5.0)
        a = row["admission"]
        assert a["arrived"] == a["admitted"] + a["rejected"] + a["holding"]
        # the run resumed this tenant's stream after recovery
        assert t.n_measured > 0
    assert total_lost > 0, "a mid-run crash must lose something"
    _assert_level_counts_match(db, "after multi-tenant crash")


def test_multitenant_crash_under_admission_policy():
    """Shedding and crashes compose: availability excludes policy-shed
    ops (shedding is policy, not unavailability) and admission counters
    stay conserved through the outage."""
    from repro.core.middleware import AdmissionConfig
    from repro.workloads import run_multi_tenant
    db, n = _loaded("B3")
    spec = FaultSpec(name="crash", crash_at=40.0)
    res = run_multi_tenant(
        db, _mt_mix(crowd_rate=20.0), duration=90.0, n_keys=n,
        warmup=5.0, max_concurrency=8,
        policy=AdmissionConfig(policy="token_bucket",
                               bucket_rates={"crowd": (4.0, 5.0)}),
        faults=spec)
    crowd = res.by_tenant("crowd").to_json()
    assert crowd["admission"]["rejected"] > 0
    assert 0.0 < crowd["availability"] <= 1.0
    a = crowd["admission"]
    assert a["arrived"] == a["admitted"] + a["rejected"] + a["holding"]


def test_scenario_matrix_multitenant_fault_dimension(tmp_path):
    from repro.workloads import ScenarioMatrix
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=800)
        db.flush_all()
        db.n_keys = 800
        return db

    matrix = ScenarioMatrix(
        schemes=["B3"], workloads=[], arrivals=[],
        tenants=[_mt_mix()], policies=["none"],
        ssd_zone_budgets=[20],
        faults=[None, FaultSpec(name="crash", crash_at=30.0,
                                recovery_slo_s=5.0)],
        duration=60.0, warmup=5.0, max_concurrency=8,
        db_factory=db_factory)
    cells = matrix.cells()
    assert len(cells) == 2
    assert cells[1].name.endswith("/f:crash")
    rows = matrix.run(out=tmp_path / "scenarios.json", verbose=False)
    assert len(rows) == 4              # 2 cells x 2 tenants
    faulty = [r for r in rows if "fault" in r]
    assert len(faulty) == 2
    for r in faulty:
        assert "tenant" in r and 0.0 <= r["availability"] <= 1.0
        assert "recovery_slo_met" in r


# ---------------------------------------------------------------------
# long fault-sweep e2e (tier 2)
# ---------------------------------------------------------------------
@pytest.mark.slow
def test_fault_sweep_e2e(tmp_path):
    """Full (scheme x fault) sweep at realistic durations: availability
    stays high under stalls, crashes bound the damage to the outage."""
    def db_factory(scheme, ssd_zones):
        db = DB(scheme, tiny_scenario(ssd_zones=ssd_zones),
                store_values=True)
        run_load(db, n_keys=2000)
        db.flush_all()
        db.n_keys = 2000
        return db

    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    # calibrate the offered rate below the weakest scheme's service rate:
    # at overload the queue at crash time (all lost) dominates availability
    from repro.workloads import run_workload
    probe = db_factory("B3", 20)
    svc = run_workload(probe, spec, n_ops=500, n_keys=2000).throughput
    matrix = ScenarioMatrix(
        schemes=["B3", "HHZS"], workloads=[spec],
        arrivals=[PoissonArrivals(0.4 * svc)],
        faults=[None,
                FaultSpec(name="stall+slow",
                          stalls=(StallWindow(at=120.0, duration=30.0,
                                              device="ssd"),),
                          slows=(SlowWindow(at=300.0, duration=60.0,
                                            factor=4.0, device="hdd"),)),
                FaultSpec(name="crash", crash_at=240.0)],
        duration=600.0, warmup=30.0, max_concurrency=16,
        db_factory=db_factory)
    rows = matrix.run(out=tmp_path / "scenarios.json", verbose=False)
    assert len(rows) == 6
    for r in rows:
        if "fault" not in r:
            continue
        assert r["availability"] > 0.9, r["cell"]
        if r["fault"].startswith("crash"):
            assert r["crash"]["replayed_records"] >= 0
            assert r["crash"]["downtime"] < 60.0
