"""Sharded-cluster correctness (``repro.cluster``).

Covers the acceptance bar for the cluster layer:

* a 1-shard ``ShardedDB`` under hash routing is event-for-event
  identical to a bare ``DB`` — same answers AND same virtual clock —
  for every placement scheme;
* router units (splitmix64 spread, range reassign/coalesce/clipping);
* the drifting-hotspot key chooser actually moves its hot set;
* online-split edge cases: ops in flight during the split, an
  empty-range move, and a source-shard crash mid-split (rolls back,
  never half-routes);
* per-shard crash isolation: the survivor keeps serving while the
  crashed shard's ops park and drain after recovery;
* the router conservation invariant ``sum(routed) == calls``.
"""
import numpy as np
import pytest

from conftest import tiny_scenario
from repro.cluster import (INF, HashRouter, RangeRouter, ShardedDB,
                           live_keys_in_range)
from repro.lsm import DB, SCHEMES
from repro.workloads.ycsb import READ, OpStream, WorkloadSpec


# ---------------------------------------------------------------------------
# routers


def test_hash_router_spreads_and_is_stable():
    r = HashRouter(4)
    owners = [r.route(k) for k in range(4000)]
    assert owners == [r.route(k) for k in range(4000)]
    counts = np.bincount(owners, minlength=4)
    assert counts.min() > 0.8 * counts.max()  # splitmix64 is well mixed
    assert set(owners) == {0, 1, 2, 3}


def test_range_router_initial_partition_covers_keyspace():
    r = RangeRouter(4, 1000)
    assert [r.route(k) for k in (0, 249, 250, 499, 500, 749, 750, 999)] \
        == [0, 0, 1, 1, 2, 2, 3, 3]
    # keys past the nominal keyspace still route (last segment to +inf)
    assert r.route(10 ** 9) == 3


def test_range_router_reassign_splits_and_coalesces():
    r = RangeRouter(2, 100)    # [0,50)->0, [50,inf)->1
    r.reassign(10, 20, 1)
    assert [r.route(k) for k in (9, 10, 19, 20)] == [0, 1, 1, 0]
    # covering_segments clips to the query and merges same-owner runs
    segs = r.covering_segments(0, 50)
    assert segs == [(0, 10, 0), (10, 20, 1), (20, 50, 0)]
    # handing the range back re-coalesces to the original partition
    r.reassign(10, 20, 0)
    assert r.covering_segments(0, 100) == [(0, 50, 0), (50, 100, 1)]
    assert len(r.segments_of(0)) == 1


def test_range_router_reassign_to_inf():
    r = RangeRouter(2, 100)
    r.reassign(80, INF, 0)
    assert r.route(80) == 0 and r.route(10 ** 12) == 0
    assert r.shards_for_range(50, 80) == [1]


# ---------------------------------------------------------------------------
# 1-shard equivalence: ShardedDB(shards=1, hash) vs bare DB


def _kv_sequence(seed=7, n_ops=260, key_space=300):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        key = int(rng.integers(key_space))
        if r < 0.45:
            ops.append(("put", key,
                        b"v%d-%d" % (key, int(rng.integers(1 << 16)))))
        elif r < 0.70:
            ops.append(("get", key, None))
        elif r < 0.85:
            ops.append(("del", key, None))
        else:
            ops.append(("scan", key, int(rng.integers(1, 20))))
    return ops


def _drive(store, ops):
    out = []
    for op, key, arg in ops:
        if op == "put":
            store.put(key, arg)
        elif op == "del":
            store.delete(key)
        elif op == "get":
            out.append(("get", key, store.get(key)))
        else:
            out.append(("scan", key, store.scan(key, arg)))
    store.drain()
    out.append(("now", store.sim.now if isinstance(store, ShardedDB)
                else store.sim.now, None))
    return out


@pytest.mark.parametrize("scheme", SCHEMES)
def test_one_shard_is_event_identical_to_bare_db(scheme):
    """The router adds zero yields on the unblocked path, so a 1-shard
    cluster must replay the bare store exactly — answers and clock."""
    ops = _kv_sequence()
    bare = _drive(DB(scheme, tiny_scenario(), store_values=True), ops)
    one = _drive(ShardedDB(scheme, tiny_scenario(), shards=1,
                           routing="hash", store_values=True), ops)
    assert one == bare


def test_one_shard_range_routing_also_identical():
    ops = _kv_sequence(seed=11)
    bare = _drive(DB("HHZS", tiny_scenario(), store_values=True), ops)
    one = _drive(ShardedDB("HHZS", tiny_scenario(), shards=1,
                           routing="range", key_space=300,
                           store_values=True), ops)
    assert one == bare


# ---------------------------------------------------------------------------
# multi-shard answers + routing conservation


def _model(ops):
    m = {}
    for op, key, arg in ops:
        if op == "put":
            m[key] = arg
        elif op == "del":
            m.pop(key, None)
    return m


@pytest.mark.parametrize("routing", ["hash", "range"])
def test_multi_shard_answers_match_model(routing):
    ops = _kv_sequence(seed=3, n_ops=300)
    db = ShardedDB("HHZS", tiny_scenario(), shards=3, routing=routing,
                   key_space=300, store_values=True)
    m = {}
    for op, key, arg in ops:
        if op == "put":
            db.put(key, arg)
            m[key] = arg
        elif op == "del":
            db.delete(key)
            m.pop(key, None)
        elif op == "get":
            assert db.get(key) == (key in m, m.get(key))
        else:
            found = db.scan(key, arg)
            assert found == sum(1 for k in m if key <= k < key + arg)
    db.drain()
    calls, routed, completed = db.kv.snapshot()
    assert sum(routed) == calls
    assert completed == routed  # everything drained
    if routing == "range":
        assert all(n > 0 for n in routed)  # keyspace actually partitioned


# ---------------------------------------------------------------------------
# drifting hotspot (workloads satellite)


def test_hotspot_hot_set_moves():
    spec = WorkloadSpec("hot", read=1.0, alpha=0.99, dist="hotspot",
                        hotspot_period=100, hotspot_step=250)
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=400, n_keys=1000)
    phases = []
    for phase in range(4):
        keys = {st.resolve(READ, rank, i=phase * 100 + j)
                for j, rank in enumerate(range(64))}
        phases.append(keys)
    # each dwell phase is the same contiguous range, shifted by step
    for p, keys in enumerate(phases):
        assert keys == {(rank + p * 250) % 1000 for rank in range(64)}
    assert phases[0].isdisjoint(phases[1])


def test_hotspot_default_step_is_eighth_of_keyspace():
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period=50)     # hotspot_step left at "auto"
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=100, n_keys=800)
    assert st._hot_step == 100
    assert st.resolve(READ, 0, i=0) == 0
    assert st.resolve(READ, 0, i=50) == 100


def test_hotspot_keys_are_contiguous_not_scrambled():
    spec = WorkloadSpec("hot", read=1.0, dist="hotspot",
                        hotspot_period=10 ** 9)
    db = DB("HHZS", tiny_scenario(), store_values=True)
    st = OpStream(db, spec, n_ops=100, n_keys=1000)
    assert [st.resolve(READ, r, i=0) for r in range(10)] == list(range(10))


# ---------------------------------------------------------------------------
# online splits


def _loaded_cluster(shards=2, n=200):
    db = ShardedDB("HHZS", tiny_scenario(), shards=shards, routing="range",
                   key_space=n, store_values=True)
    for k in range(n):
        db.put(k, b"v%d" % k)
    db.drain()
    return db


def test_split_moves_range_and_preserves_answers():
    db = _loaded_cluster()
    assert db.router.route(10) == 0
    proc = db.split(0, 50, 1)
    res = db.sim.run_until(proc)
    assert res["completed"] and res["moved_keys"] == 50
    assert db.router.route(10) == 1 and db.router.route(50) == 0
    for k in range(0, 200, 7):
        assert db.get(k) == (True, b"v%d" % k)
    assert db.splits and db.splits[-1]["completed"]


def test_split_with_ops_in_flight_drains_then_flips():
    db = _loaded_cluster()
    answers = []

    def reader(k):
        got = yield from db.kv.get(k)
        answers.append((k, got))

    # in-flight ops overlapping the moving range force the drain phase;
    # ops arriving *during* the split park and are released at the flip
    for k in (1, 2, 3):
        db.submit(reader(k))
    proc = db.split(0, 50, 1)
    for k in (4, 5, 48, 49, 150):
        db.submit(reader(k))
    res = db.sim.run_until(proc)
    db.drain()
    assert res["completed"]
    assert sorted(answers) == [(k, (True, b"v%d" % k))
                               for k in (1, 2, 3, 4, 5, 48, 49, 150)]
    calls, routed, completed = db.kv.snapshot()
    assert sum(routed) == calls and completed == routed


def test_split_of_empty_range_completes():
    db = ShardedDB("HHZS", tiny_scenario(), shards=2, routing="range",
                   key_space=200, store_values=True)
    for k in range(100, 200):       # shard 1 only; shard 0 stays empty
        db.put(k, b"x")
    db.drain()
    res = db.sim.run_until(db.split(0, 100, 1))
    assert res["completed"] and res["moved_keys"] == 0
    assert db.router.route(0) == 1
    assert db.get(0) == (False, None)
    assert db.get(150) == (True, b"x")


def test_split_rejects_range_spanning_shards():
    db = _loaded_cluster()
    res = db.sim.run_until(db.split(50, 150, 1))
    assert not res["completed"] and "spans" in res["reason"]


def test_source_crash_mid_split_rolls_back_routing():
    db = _loaded_cluster()
    before = db.router.describe()
    db.split(0, 50, 1)
    db.run_for(1e-6)                # let the split start copying
    db.crash_shard(0)
    assert db.router.describe() == before      # never half-routed
    assert db._split_state is None
    assert db.splits and not db.splits[-1]["completed"]
    # survivor keeps answering its own range while shard 0 is down
    assert db.get(150) == (True, b"v150")
    db.sim.run_until(db.sim.process(db.reopen_shard_gen(0)))
    db.drain()
    # WAL replay restored the source shard; answers intact
    for k in range(0, 50, 7):
        assert db.get(k) == (True, b"v%d" % k)
    # and the range can be re-split successfully afterwards
    res = db.sim.run_until(db.split(0, 50, 1))
    assert res["completed"]
    assert db.get(10) == (True, b"v10")


# ---------------------------------------------------------------------------
# per-shard crash isolation


def test_crashed_shard_parks_ops_while_survivor_serves():
    db = _loaded_cluster()
    db.crash_shard(0)
    served, parked = [], []

    def reader(k, sink):
        got = yield from db.kv.get(k)
        sink.append((k, got))

    db.submit(reader(150, served))   # survivor's range
    db.submit(reader(10, parked))    # crashed shard's range: parks
    db.run_for(5.0)
    assert served == [(150, (True, b"v150"))]
    assert parked == []              # still parked, not lost, not failed
    db.sim.run_until(db.sim.process(db.reopen_shard_gen(0)))
    db.drain()
    assert parked == [(10, (True, b"v10"))]
    calls, routed, completed = db.kv.snapshot()
    assert sum(routed) == calls


def test_crash_shard_reports_killed_inflight():
    db = _loaded_cluster()

    def reader(k):
        yield from db.kv.get(k)

    db.submit(reader(10))
    db.run_for(1e-6)                # op enters the shard, still in flight
    rep = db.crash_shard(0)
    assert rep["shard"] == 0 and rep["lost_in_flight"] >= 1
    # the kill force-cleared shard 0's inflight tokens: a fresh split of
    # the survivor's range must not wait on ghosts
    assert not db.kv.inflight[0]


def test_crash_all_shards_then_reopen_roundtrip():
    db = _loaded_cluster()
    db.crash()
    db.reopen()
    db.drain()
    for k in range(0, 200, 11):
        assert db.get(k) == (True, b"v%d" % k)
