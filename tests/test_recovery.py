"""Crash/recovery: differential correctness + post-recovery invariants.

``DB.crash()`` at an arbitrary mid-run point discards everything volatile
(MemTables, in-flight ops, background jobs, device queues); ``DB.reopen()``
rebuilds the zone map / SST registry / level counts from durable state and
replays the live WAL generations.  The acceptance invariant: for every
scheme, every *acknowledged* write (a put/delete whose op completed before
the crash) must read back exactly as a dict model predicts — unacknowledged
in-flight writes may be lost, acknowledged ones never.
"""
import numpy as np
import pytest

from conftest import tiny_scenario
from test_invariants import _assert_level_counts_match
from repro.lsm import DB, SCHEMES
from repro.zoned.device import ZoneState


def _mixed_ops(seed, n_ops, key_space=300):
    rng = np.random.default_rng(seed)
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        key = int(rng.integers(key_space))
        if r < 0.7:
            ops.append(("put", key,
                        b"v%d-%d" % (key, int(rng.integers(1 << 16)))))
        else:
            ops.append(("del", key, None))
    return ops


def _submit_all(db, ops, completed, delta=0.0):
    """Dispatch every op open-loop; acknowledged ops land in ``completed``
    in completion order (the order WAL replay must reproduce)."""

    def op_proc(op):
        kind, key, val = op
        if kind == "put":
            yield from db.tree.put(key, val)
        else:
            yield from db.tree.delete(key)

    def dispatcher():
        for op in ops:
            p = db.submit(op_proc(op))
            p.add_callback(lambda _v, op=op: completed.append(op))
            if delta > 0:
                yield db.sim.timeout(delta)

    if delta > 0:
        db.submit(dispatcher())
    else:
        for op in ops:
            p = db.submit(op_proc(op))
            p.add_callback(lambda _v, op=op: completed.append(op))


def _model_of(acked):
    model = {}
    for kind, key, val in acked:
        if kind == "put":
            model[key] = val
        else:
            model.pop(key, None)
    return model


def _assert_reads_match(db, acked):
    model = _model_of(acked)
    for key in sorted({k for _, k, _ in acked}):
        found, val = db.get(key)
        assert found == (key in model), \
            f"key {key}: found={found}, model has it: {key in model}"
        if found:
            assert val == model[key], \
                f"key {key}: read {val!r}, acknowledged {model[key]!r}"


def _assert_zone_static_invariants(db):
    for dev in (db.ssd, db.hdd):
        for z in dev.zones:
            assert 0 <= z.write_ptr <= z.capacity
            if z.state == ZoneState.EMPTY:
                assert z.write_ptr == 0 and z.owner is None
            if z.write_ptr == z.capacity:
                assert z.state == ZoneState.FULL


# ---------------------------------------------------------------------
# the recovery differential, all 10 schemes
# ---------------------------------------------------------------------
@pytest.mark.parametrize("scheme", SCHEMES)
def test_crash_recovery_differential(scheme):
    """Crash mid-run: acknowledged writes survive, invariants hold."""
    db = DB(scheme, tiny_scenario(), store_values=True)
    ops = _mixed_ops(seed=0, n_ops=400)
    completed = []
    _submit_all(db, ops, completed, delta=0.003)
    db.run_for(0.7)                    # arbitrary mid-run crash point
    acked = list(completed)
    assert 0 < len(acked) < len(ops), \
        "crash point must leave both acknowledged and in-flight ops"
    db.crash()
    rec = db.reopen()
    assert rec["replayed_records"] >= 0
    _assert_reads_match(db, acked)
    _assert_level_counts_match(db, "post-recovery")
    _assert_zone_static_invariants(db)
    # the store keeps serving after recovery, and survives a clean drain
    for k in range(5):
        db.put(10_000 + k, b"post")
        assert db.get(10_000 + k) == (True, b"post")
    db.flush_all()
    db.drain()
    _assert_reads_match(db, acked)
    _assert_level_counts_match(db, "post-recovery drain")
    _assert_zone_static_invariants(db)


def test_crash_after_burst_replays_wal():
    """A write burst crashed before its flush settles must be recovered
    from the WAL payloads (this is the path with real replay volume)."""
    db = DB("HHZS", tiny_scenario(), store_values=True)
    ops = _mixed_ops(seed=1, n_ops=300)
    completed = []
    _submit_all(db, ops, completed)    # all at once: deep WAL backlog
    db.run_for(2.0)
    acked = list(completed)
    assert len(acked) > 100
    db.crash()
    rec = db.reopen()
    assert rec["replayed_records"] > 0, "burst crash must exercise replay"
    _assert_reads_match(db, acked)
    _assert_level_counts_match(db, "post-burst recovery")


def test_crash_with_clean_state_recovers_from_ssts():
    """After flush_all + drain nothing is volatile: recovery is a pure
    manifest rebuild (no WAL replay) and reads come from SSTs."""
    db = DB("HHZS", tiny_scenario(), store_values=True)
    for k in range(600):
        db.put(k, b"v%d" % k)
    db.flush_all()
    db.drain()
    db.crash()
    rec = db.reopen()
    assert rec["replayed_records"] == 0
    for k in range(0, 600, 13):
        assert db.get(k) == (True, b"v%d" % k)
    _assert_level_counts_match(db, "clean-state recovery")


def test_repeated_crashes_converge():
    """Crash -> reopen -> crash again (before any flush): the WAL payloads
    must survive the first replay so the second recovery still works."""
    db = DB("P", tiny_scenario(), store_values=True)
    ops = _mixed_ops(seed=2, n_ops=200)
    completed = []
    _submit_all(db, ops, completed)
    db.run_for(1.0)
    acked = list(completed)
    for _ in range(3):
        db.crash()
        db.reopen()
    _assert_reads_match(db, acked)
    db.flush_all()
    db.drain()
    _assert_reads_match(db, acked)


def test_recovery_replay_costs_virtual_time():
    """Reading the live WAL zones during reopen is charged as real I/O."""
    db = DB("B3", tiny_scenario(), store_values=True)
    for k in range(200):
        db.put(k, b"x")
    assert db.backend.wal_zones_in_use() >= 1
    db.crash()
    t0 = db.sim.now
    db.reopen()
    assert db.sim.now > t0, "WAL replay must advance virtual time"


def test_reopen_requires_crash():
    db = DB("B3", tiny_scenario(), store_values=True)
    with pytest.raises(RuntimeError):
        db.reopen()


def test_crash_discards_unacknowledged_inflight_writes():
    """Ops still queued in the WAL group commit at crash time were never
    acknowledged; recovery must NOT resurrect them."""
    db = DB("HHZS", tiny_scenario(), store_values=True)
    db.put(1, b"committed")
    db.flush_all()
    db.drain()
    completed = []
    p = db.submit(db.tree.put(2, b"in-flight"))
    p.add_callback(lambda _v: completed.append(True))
    # crash immediately: the put sits in the group-commit queue, unacked
    db.crash()
    db.reopen()
    assert not completed
    assert db.get(1) == (True, b"committed")
    assert db.get(2) == (False, None)
