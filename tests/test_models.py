"""Per-architecture smoke tests (reduced configs): forward/train/decode."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")   # tier-1 runs a no-jax matrix leg
import jax.numpy as jnp            # noqa: E402

from repro.config import ParallelConfig, TrainConfig
from repro.configs import get_config, list_configs
from repro.models import model as M
from repro.models import steps as S

pytestmark = pytest.mark.slow  # 24 arch jit compiles, 1+ min; run with -m slow

TC = TrainConfig(total_steps=10)
PC = ParallelConfig()


def _batch(cfg, b=2, s=32):
    # random targets: the untrained-CE check below averages log-probs over
    # many vocab entries, so it concentrates near ln(V).  (With a single
    # repeated target id the loss is one ~N(0, logit_std) draw away from
    # ln(V) and fails for whichever arch draws unluckily.)
    tgt = np.random.default_rng(0).integers(0, cfg.vocab_size, (b, s))
    batch = {"tokens": jnp.zeros((b, s), jnp.int32),
             "targets": jnp.asarray(tgt, jnp.int32)}
    if cfg.encoder_layers:
        batch["frames"] = jnp.ones((b, cfg.encoder_seq, cfg.d_model),
                                   jnp.bfloat16)
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.ones((b, cfg.vision_prefix,
                                           cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", list_configs())
def test_arch_smoke_train_step(arch):
    cfg = get_config(arch).smoke()
    state = S.init_state(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    state2, metrics = jax.jit(S.make_train_step(cfg, TC, PC))(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    assert abs(loss - np.log(cfg.vocab_size)) < 2.0, \
        "untrained CE should be near ln(V)"
    # some parameter actually changed
    changed = any(not jnp.array_equal(a, b) for a, b in
                  zip(jax.tree.leaves(state["params"]),
                      jax.tree.leaves(state2["params"])))
    assert changed


@pytest.mark.parametrize("arch", list_configs())
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).smoke()
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    b = 2
    caches = M.init_caches(cfg, b, 64)
    if cfg.encoder_layers:
        frames = jnp.ones((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        ekv = M.encoder_kv(cfg, params, M._encode(cfg, params, frames))
        caches["cross_k"], caches["cross_v"] = ekv[0], ekv[1]
    step = jax.jit(S.make_serve_step(cfg))
    tok = jnp.zeros((b, 1), jnp.int32)
    clen = jnp.zeros((b,), jnp.int32)
    for i in range(3):
        tok, logits, caches = step(params, tok, clen, caches)
        clen = clen + 1
        assert bool(jnp.all(jnp.isfinite(logits)))
    assert tok.shape == (b, 1)


def test_decode_matches_prefill_logits():
    """Teacher-forced decode must reproduce the forward pass logits."""
    cfg = get_config("qwen3-1.7b").smoke()
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    b, s = 1, 8
    toks = jnp.array(np.random.default_rng(0).integers(0, cfg.vocab_size,
                                                       (b, s)), jnp.int32)
    full_logits = M.forward(cfg, params, {"tokens": toks}, remat=False)
    caches = M.init_caches(cfg, b, 32)
    for t in range(s):
        logits, caches = M.decode_step(cfg, params, toks[:, t:t + 1],
                                       jnp.full((b,), t, jnp.int32), caches)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32),
            np.asarray(full_logits[:, t], np.float32),
            rtol=3e-2, atol=3e-2)


def test_sliding_window_restricts_attention():
    """SWA must differ from full attention once seq > window."""
    import dataclasses
    base = get_config("qwen3-1.7b").smoke()
    swa = dataclasses.replace(base, sliding_window=8)
    params = M.init_params(jax.random.PRNGKey(2), base)
    toks = jnp.array(np.random.default_rng(1).integers(
        0, base.vocab_size, (1, 32)), jnp.int32)
    full = M.forward(base, params, {"tokens": toks}, remat=False)
    win = M.forward(swa, params, {"tokens": toks}, remat=False)
    # early positions identical (window covers them), late ones differ
    np.testing.assert_allclose(np.asarray(full[:, 3], np.float32),
                               np.asarray(win[:, 3], np.float32),
                               rtol=1e-3, atol=1e-3)
    assert not np.allclose(np.asarray(full[:, -1], np.float32),
                           np.asarray(win[:, -1], np.float32),
                           rtol=1e-3, atol=1e-3)


def test_moe_routes_topk():
    cfg = get_config("olmoe-1b-7b").smoke()
    from repro.models import layers as L
    p = L.init_moe(jax.random.PRNGKey(3), cfg)
    x = jnp.array(np.random.default_rng(2).standard_normal((2, 16,
                                                            cfg.d_model)),
                  jnp.bfloat16)
    out = L.moe(p, cfg, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))


def test_param_count_sane():
    cfg = get_config("qwen3-1.7b")
    n = cfg.param_count()
    assert 1.5e9 < n < 2.5e9
    moe = get_config("mixtral-8x22b")
    assert 1.2e11 < moe.param_count() < 1.6e11
    assert moe.active_param_count() < 0.45 * moe.param_count()
