"""HLO analyzer: flop/byte/collective parsing with loop trip scaling."""
import numpy as np
import pytest

jax = pytest.importorskip("jax")   # tier-1 runs a no-jax matrix leg
import jax.numpy as jnp            # noqa: E402

from repro.roofline import Roofline, analyze_hlo


def _compile(f, *shapes):
    return jax.jit(f).lower(*shapes).compile()


def test_flops_scale_with_scan_trips():
    def make(L):
        def f(ws, x):
            def body(c, w):
                return jnp.tanh(c @ w), ()
            y, _ = jax.lax.scan(jax.checkpoint(body), x, ws)
            return jnp.sum(y ** 2)
        return jax.grad(f)

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    flops = {}
    for L in (4, 16):
        ws = jax.ShapeDtypeStruct((L, 256, 256), jnp.float32)
        st = analyze_hlo(_compile(make(L), ws, x).as_text(), 1,
                         default_trip=L)
        flops[L] = st.flops
    assert flops[16] == pytest.approx(4 * flops[4], rel=0.05)
    # ~4 matmuls (fwd + remat-fwd + 2 bwd) x 2*256^3 per layer
    assert flops[4] == pytest.approx(4 * 4 * 2 * 256 ** 3, rel=0.3)


def test_single_dot_flops_exact():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((128, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 64), jnp.float32)
    st = analyze_hlo(_compile(f, a, b).as_text(), 1)
    assert st.flops == pytest.approx(2 * 128 * 512 * 64)


def test_bytes_counted_on_control_path():
    def f(a):
        return jnp.sum(a * 2.0)
    a = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    st = analyze_hlo(_compile(f, a).as_text(), 1)
    # at least one read of the input
    assert st.bytes_hbm >= 4 * (1 << 20)


def test_roofline_terms_and_dominance():
    rl = Roofline(arch="x", shape="train_4k", mesh="single", chips=256,
                  flops_per_device=197e12,         # exactly 1 s of compute
                  bytes_per_device=819e9 * 0.5,    # 0.5 s of memory
                  collective_bytes=50e9 * 0.25,    # 0.25 s of collective
                  model_flops_total=197e12 * 256 * 0.8)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.5)
    assert rl.collective_s == pytest.approx(0.25)
    assert rl.dominant == "compute"
    assert rl.mfu == pytest.approx(0.8)
    assert rl.useful_flops_ratio == pytest.approx(0.8)
