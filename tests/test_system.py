"""End-to-end behaviour: the paper's headline claims, in miniature.

These run the full stack (load + workload per scheme, fresh store each
time, scaled scenario) and assert the *qualitative* results of §2.3/§4:
O1 (levels blow past targets mid-load), O4 (basic schemes read mostly from
HDD under skew), and HHZS >= B3 on skewed reads.
"""
import numpy as np
import pytest

from repro.lsm import DB, ScenarioConfig
from repro.workloads import (LevelSampler, WorkloadSpec, YCSB, run_load,
                             run_workload)

pytestmark = pytest.mark.slow  # full load+workload per scheme, ~1 min; run with -m slow

N = ScenarioConfig().paper_keys // 4      # small but same proportions


def _fresh(scheme):
    db = DB(scheme)
    sampler = LevelSampler(db, period=60.0)
    run_load(db, n_keys=N)
    db.flush_all()
    return db, sampler


def test_o1_actual_sizes_exceed_targets():
    db, sampler = _fresh("B3")
    st = sampler.stats()
    assert st is not None
    targets = [db.scenario.lsm.target_of(i) for i in range(3)]
    over = [st["max"][i] / targets[i] for i in range(3)]
    # the paper reports 4x-40x; any >2x confirms the phenomenon
    assert max(over) > 2.0, f"levels should overshoot targets, got {over}"


def test_o4_basic_scheme_reads_mostly_hdd():
    db, _ = _fresh("B3")
    run_workload(db, YCSB["C"], n_ops=1500, n_keys=N)
    ssd_r = db.ssd.counters.read_bytes
    hdd_r = db.hdd.counters.read_bytes
    assert hdd_r / (ssd_r + hdd_r) > 0.5


def test_hhzs_beats_b3_on_skewed_reads():
    w4 = WorkloadSpec("W4", read=1.0, alpha=1.2)
    results = {}
    for scheme in ["B3", "HHZS"]:
        db, _ = _fresh(scheme)
        r = run_workload(db, w4, n_ops=3000, n_keys=N)
        results[scheme] = r.throughput
    assert results["HHZS"] > results["B3"] * 1.02, \
        f"HHZS should win on skewed reads: {results}"


def test_hinted_cache_serves_reads_under_skew():
    w4 = WorkloadSpec("W4", read=1.0, alpha=1.2)
    db, _ = _fresh("HHZS")
    r = run_workload(db, w4, n_ops=3000, n_keys=N)
    assert r.extras.get("ssd_cache_hits", 0) > 0
