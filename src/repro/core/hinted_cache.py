"""Application-hinted caching (§3.5).

Data blocks evicted from the LSM-tree's in-memory block cache are admitted
into SSD *cache zones* when they live on the HDD and are not already cached.
Cache zones are carved from the reserved WAL/cache zone pool and filled
append-only; eviction is FIFO at *zone* granularity (reset the oldest cache
zone, drop its mappings).  An in-memory mapping table (HDD location ->
SSD cache location) serves lookups; an in-memory FIFO queue identifies the
blocks in the evicted zone.
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional, Set, Tuple, TYPE_CHECKING

from ..zoned.device import Zone

if TYPE_CHECKING:
    from .middleware import HybridZonedBackend

BlockKey = Tuple[int, int]  # (sst_id, block_idx)


class HintedCache:
    def __init__(self, backend: "HybridZonedBackend", block_size: int):
        self.backend = backend
        self.block_size = block_size
        self.mapping: Dict[BlockKey, int] = {}     # block -> zone id
        self.fifo: Deque[Tuple[int, int, int]] = deque()  # (sst, blk, zone id)
        self.by_sst: Dict[int, Set[int]] = defaultdict(set)
        self.zones: List[Zone] = []                # FIFO order, oldest first
        self.active: Optional[Zone] = None
        # stats
        self.admitted = 0
        self.rejected = 0
        self.hits = 0
        self.zone_evictions = 0

    # ------------------------------------------------------------------
    def lookup(self, sst_id: int, block_idx: int) -> bool:
        return (sst_id, block_idx) in self.mapping

    def record_hit(self) -> None:
        self.hits += 1

    # ------------------------------------------------------------------
    def admit(self, sst_id: int, block_idx: int, sst_tier: str):
        """Generator: admit an evicted data block (cache hint path, Fig. 4)."""
        be = self.backend
        key = (sst_id, block_idx)
        if sst_tier != "hdd" or key in self.mapping:
            self.rejected += 1
            return
        zone = self._writable_zone()
        if zone is None:
            self.rejected += 1
            return
        yield be.ssd.append(zone, self.block_size, tag="cache", background=True)
        self.mapping[key] = zone.zid
        self.by_sst[sst_id].add(block_idx)
        self.fifo.append((sst_id, block_idx, zone.zid))
        self.admitted += 1

    def _writable_zone(self) -> Optional[Zone]:
        if self.active is not None and self.active.remaining >= self.block_size:
            return self.active
        # Controller-driven reservation knob (repro.obs.control): when the
        # backend caps cache_zone_budget, stay within it by recycling our
        # own oldest zone instead of claiming another reserved zone.
        budget = self.backend.cache_zone_budget
        if budget is not None and len(self.zones) >= budget:
            if budget <= 0 or not self.zones:
                return None
            self.evict_oldest_zone()
        # Need a fresh zone from the reserved WAL/cache pool.
        zone = self.backend.acquire_reserved_zone("cache")
        if zone is None:
            # All reserved zones busy: FIFO-evict the oldest cache zone and
            # retry (if *we* hold a zone); otherwise the WAL owns everything
            # and the block is simply dropped.
            if self.zones:
                self.evict_oldest_zone()
                zone = self.backend.acquire_reserved_zone("cache")
            if zone is None:
                return None
        self.active = zone
        self.zones.append(zone)
        return zone

    # ------------------------------------------------------------------
    def evict_oldest_zone(self) -> None:
        """FIFO policy (§3.5): reset the oldest cache zone, drop its blocks."""
        if not self.zones:
            return
        victim = self.zones.pop(0)
        if victim is self.active:
            self.active = None
        # Dequeue the location info of every block in the evicted zone.
        while self.fifo and self.fifo[0][2] == victim.zid:
            sst_id, blk, _ = self.fifo.popleft()
            self.mapping.pop((sst_id, blk), None)
            s = self.by_sst.get(sst_id)
            if s is not None:
                s.discard(blk)
                if not s:
                    del self.by_sst[sst_id]
        self.backend.release_reserved_zone(victim)
        self.zone_evictions += 1

    def on_zone_fault(self, zone: Zone) -> None:
        """A cache zone was reset by a device fault: its blocks are gone.

        Cache zones hold clean copies of HDD-resident blocks, so nothing
        needs repair — drop the zone and the mapping entries pointing at
        it (reads fall back to the HDD)."""
        if zone is self.active:
            self.active = None
        if zone in self.zones:
            self.zones.remove(zone)
        kept: Deque[Tuple[int, int, int]] = deque()
        for sst_id, blk, zid in self.fifo:
            if zid == zone.zid:
                self.mapping.pop((sst_id, blk), None)
                s = self.by_sst.get(sst_id)
                if s is not None:
                    s.discard(blk)
                    if not s:
                        del self.by_sst[sst_id]
            else:
                kept.append((sst_id, blk, zid))
        self.fifo = kept

    def clear_volatile(self) -> None:
        """Crash recovery: the in-memory mapping table is gone, so every
        cached block is unreachable — the recovery zone-map rebuild has
        already reset the zones; drop all bookkeeping (stats survive)."""
        self.mapping.clear()
        self.fifo.clear()
        self.by_sst.clear()
        self.zones = []
        self.active = None

    def drop_sst(self, sst_id: int) -> None:
        """An SST died (compaction/migration): its cached blocks are stale."""
        blocks = self.by_sst.pop(sst_id, None)
        if not blocks:
            return
        for blk in blocks:
            self.mapping.pop((sst_id, blk), None)
        # fifo entries become stale; they are skipped when their mapping is
        # already gone at zone-eviction time (cheap lazy deletion).

    def cached_blocks(self) -> int:
        return len(self.mapping)
