"""Data placement policies for hybrid zoned storage.

Implements the paper's §2.3 basic schemes (Bh), the SpanDB automated
placement (AUTO, §4.1), and HHZS write-guided data placement (§3.3):

  Step 1  storage demands per level from flushing/compaction hints
  Step 2  tiering level  t = argmin_t Σ_{j<=t} (A_j + D_j) >= C_ssd
  Step 3  SSD zones reserved for L_t = C_ssd - Σ_{j<t} (A_j + D_j)
  Step 4  zone selection for each written SST
"""
from __future__ import annotations

from collections import defaultdict
from typing import Optional, TYPE_CHECKING

from .hints import (CacheHint, CompactionDoneHint, CompactionOutputHint,
                    CompactionTriggerHint, FlushHint)

if TYPE_CHECKING:
    from .middleware import HybridZonedBackend

SSD, HDD = "ssd", "hdd"


class PlacementPolicy:
    """Decides the tier for each written SST; consumes LSM hints."""

    name = "base"
    reserves_wal = False    # carve WAL(+cache) zones out of the SSD pool?

    def __init__(self) -> None:
        self.backend: Optional["HybridZonedBackend"] = None

    def attach(self, backend: "HybridZonedBackend") -> None:
        self.backend = backend

    def on_hint(self, hint) -> None:  # pragma: no cover - default no-op
        pass

    def start(self) -> None:
        """Spawn any background processes (AUTO's throughput monitor)."""

    def on_reopen(self) -> None:
        """Crash recovery: drop volatile state (hint-derived demand from
        compactions that died with the crash, stale monitor samples)."""

    def choose_tier(self, level: int, source: str) -> str:
        raise NotImplementedError

    # HHZS exposes its tiering level to the migrator; others don't tier.
    def tiering_level(self) -> int:
        return 10**9


class BasicScheme(PlacementPolicy):
    """Bh (§2.3): WAL + SSTs at levels < h go to the SSD when space allows."""

    reserves_wal = False

    def __init__(self, h: int):
        super().__init__()
        self.h = h
        self.name = f"B{h}"

    def choose_tier(self, level: int, source: str) -> str:
        if level < self.h and self.backend.ssd_has_empty_sst_zone():
            return SSD
        return HDD

    def tiering_level(self) -> int:
        return self.h


class AutoPlacement(PlacementPolicy):
    """SpanDB's automated placement (re-implemented per §4.1).

    A monitor samples SSD write throughput once per second: below 40% of
    the device's sequential-write bandwidth the max level is raised, above
    65% it is lowered.  Remaining-space guards: < 13.3% -> max level pinned
    to 1; < 8% -> no SST writes to the SSD at all.  WAL zones are reserved,
    as in HHZS.
    """

    name = "AUTO"
    reserves_wal = True

    def __init__(self, lo_frac: float = 0.40, hi_frac: float = 0.65,
                 space_pin_frac: float = 0.133, space_stop_frac: float = 0.08,
                 period: float = 1.0, max_level_cap: int = 6):
        super().__init__()
        self.lo_frac = lo_frac
        self.hi_frac = hi_frac
        self.space_pin_frac = space_pin_frac
        self.space_stop_frac = space_stop_frac
        self.period = period
        self.max_level = 1
        self.max_level_cap = max_level_cap
        self._last_write_bytes = 0.0

    def start(self) -> None:
        self.backend.sim.process(self._monitor())

    def on_reopen(self) -> None:
        # device counters survive a crash but the monitor didn't sample
        # during the outage: resync so the first delta isn't inflated
        self._last_write_bytes = self.backend.ssd.counters.write_bytes

    def _monitor(self):
        be = self.backend
        while True:
            yield be.sim.timeout(self.period, daemon=True)
            wb = be.ssd.counters.write_bytes
            thpt = (wb - self._last_write_bytes) / self.period
            self._last_write_bytes = wb
            peak = be.ssd.timing.seq_write_bw
            if thpt < self.lo_frac * peak:
                self.max_level = min(self.max_level + 1, self.max_level_cap)
            elif thpt > self.hi_frac * peak:
                self.max_level = max(self.max_level - 1, 0)

    def _remaining_frac(self) -> float:
        be = self.backend
        total = len(be.ssd.zones)
        return be.ssd.num_empty() / max(total, 1)

    def choose_tier(self, level: int, source: str) -> str:
        rem = self._remaining_frac()
        if rem < self.space_stop_frac:
            return HDD
        max_level = 1 if rem < self.space_pin_frac else self.max_level
        if level <= max_level and self.backend.ssd_has_empty_sst_zone():
            return SSD
        return HDD

    def tiering_level(self) -> int:
        return self.max_level + 1


class HHZSPlacement(PlacementPolicy):
    """Write-guided data placement (§3.3)."""

    name = "HHZS-P"
    reserves_wal = True

    def __init__(self, num_levels: int = 7):
        super().__init__()
        self.num_levels = num_levels
        self.demand = defaultdict(float)   # D_i, i >= 1, from compaction hints
        self._live_compactions = {}        # cid -> target level (sanity)

    # -- Step 1: storage demands from hints ---------------------------------
    def on_hint(self, hint) -> None:
        # demand is tracked per live compaction so that a compaction which
        # generates *more* SSTs than it selected (possible when many small
        # L0 files merge) cannot leave phantom demand behind: each cid's
        # remaining demand is clamped >= 0 and zeroed at completion.
        if isinstance(hint, CompactionTriggerHint):
            self._live_compactions[hint.cid] = (
                hint.target_level, float(len(hint.selected_sst_ids)))
        elif isinstance(hint, CompactionOutputHint):
            if hint.cid in self._live_compactions:
                lvl, rem = self._live_compactions[hint.cid]
                self._live_compactions[hint.cid] = (lvl, max(0.0, rem - 1.0))
        elif isinstance(hint, CompactionDoneHint):
            self._live_compactions.pop(hint.cid, None)

    def on_reopen(self) -> None:
        # the compactions behind these demands died with the crash; their
        # cids will never emit a Done hint, so the demand must be dropped
        # here or it pins the tiering level forever
        self._live_compactions.clear()

    def demand_of(self, level: int) -> float:
        if level == 0:
            # D_0 = number of WAL zones currently in use (§3.3 Step 1): every
            # MemTable KV object has a WAL copy, so live WAL zones are a proxy
            # for the flush backlog HHZS cannot observe directly.
            return float(self.backend.wal_zones_in_use())
        return sum(rem for lvl, rem in self._live_compactions.values()
                   if lvl == level)

    def allocated_of(self, level: int) -> int:
        """A_i: SSD zones currently allocated to SSTs at level i."""
        return self.backend.ssd_sst_count_at_level(level)

    # -- Step 2: tiering level ----------------------------------------------
    def tiering_level(self) -> int:
        c_ssd = self.backend.c_ssd()
        cum = 0.0
        for lvl in range(self.num_levels):
            cum += self.allocated_of(lvl) + self.demand_of(lvl)
            if cum >= c_ssd:
                return lvl
        return self.num_levels

    # -- Step 3: reservation for L_t ----------------------------------------
    def reserved_for_tiering(self, t: int) -> float:
        c_ssd = self.backend.c_ssd()
        below = sum(self.allocated_of(j) + self.demand_of(j) for j in range(t))
        return c_ssd - below

    # -- Step 4: zone selection ---------------------------------------------
    def choose_tier(self, level: int, source: str) -> str:
        be = self.backend
        if not be.ssd_has_empty_sst_zone():
            return HDD
        if source == "flush":
            return SSD
        t = self.tiering_level()
        if level < t:
            return SSD
        if level == t and self.allocated_of(t) < self.reserved_for_tiering(t):
            return SSD
        return HDD
