"""The paper's contribution: hinted data management for hybrid zoned storage.

- ``hints``         hint vocabulary (§3.1)
- ``placement``     write-guided data placement + baselines (§3.3, §2.3, §4.1)
- ``migration``     workload-aware migration (§3.4)
- ``hinted_cache``  application-hinted caching (§3.5)
- ``middleware``    the HHZS middleware gluing the above onto zoned devices,
                    plus the multi-tenant admission-control layer
                    (``AdmissionController``: none / reject-at-pressure /
                    delay-at-pressure / per-tenant token bucket)

The same placement/migration/caching machinery is reused by
``repro.serving.tiering`` to manage paged KV-cache blocks across HBM and
host memory on TPU — see DESIGN.md §Hardware-adaptation.
"""
from .hints import (FlushHint, CompactionTriggerHint, CompactionOutputHint,
                    CompactionDoneHint, CacheHint)
from .placement import (PlacementPolicy, BasicScheme, AutoPlacement,
                        HHZSPlacement)
from .migration import Migrator, priority_key
from .hinted_cache import HintedCache
from .middleware import (ADMISSION_POLICIES, AdmissionConfig,
                         AdmissionController, HybridZonedBackend)

__all__ = [
    "FlushHint", "CompactionTriggerHint", "CompactionOutputHint",
    "CompactionDoneHint", "CacheHint",
    "PlacementPolicy", "BasicScheme", "AutoPlacement", "HHZSPlacement",
    "Migrator", "priority_key", "HintedCache", "HybridZonedBackend",
    "ADMISSION_POLICIES", "AdmissionConfig", "AdmissionController",
]
