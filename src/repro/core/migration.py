"""Workload-aware migration (§3.4).

Two migration types refine placement in the background:

  capacity migration   SSD -> HDD when the tiering level over-occupies its
                       reservation or SSTs above the tiering level sit in
                       the SSD (write-guided placement changed its mind);
  popularity migration HDD -> SSD when the aggregate HDD read rate exceeds
                       half the device's random-read IOPS (the HDD is the
                       read bottleneck); promotes the highest-priority HDD
                       SST, swapping with the lowest-priority SSD SST when
                       no zone is free.

SST priority: lower level first, then higher read rate (reads / age).  SSTs
locked by a running compaction (known from compaction hints) or by another
migration are never selected.  All migration I/O is rate-limited (default
4 MiB/s) to bound interference with foreground traffic.
"""
from __future__ import annotations

from typing import List, Optional, Tuple, TYPE_CHECKING

from ..zoned.device import MiB

if TYPE_CHECKING:
    from ..lsm.sstable import SST
    from .middleware import HybridZonedBackend


def priority_key(sst: "SST", now: float) -> Tuple[int, float]:
    """Smaller tuple == higher priority (§3.4)."""
    return (sst.level, -sst.read_rate(now))


class Migrator:
    def __init__(self, backend: "HybridZonedBackend",
                 rate_limit: float = 4 * MiB,
                 chunk_bytes: int = int(1 * MiB),
                 tick: float = 0.25,
                 popularity_frac: float = 0.5,
                 swap_hysteresis: float = 1.5,
                 basic_low_levels: Optional[int] = None):
        self.backend = backend
        self.rate_limit = rate_limit
        self.chunk_bytes = chunk_bytes
        self.tick = tick
        self.popularity_frac = popularity_frac
        self.swap_hysteresis = swap_hysteresis
        # basic_low_levels=h: "B3+M" mode — only promote HDD SSTs at levels
        # < h; no capacity migration (the basic scheme statically pins levels).
        self.basic_low_levels = basic_low_levels
        # stats
        self.capacity_moves = 0
        self.popularity_moves = 0
        self.swaps = 0
        self.aborted = 0
        self.bytes_moved = 0.0

    # ------------------------------------------------------------------
    def start(self) -> None:
        self.backend.sim.process(self._run())

    def _run(self):
        be = self.backend
        while True:
            job = self._pick_job()
            if job is None:
                yield be.sim.timeout(self.tick, daemon=True)
                continue
            sst, dst, swap_victim = job
            moved = False
            if swap_victim is not None:
                ok = yield from self._migrate(swap_victim, "hdd")
                if ok:
                    self.swaps += 1
                    moved = True
            ok = yield from self._migrate(sst, dst)
            if not (ok or moved):
                # the picked job made no progress (preempted, no zones):
                # re-picking immediately would spin without advancing
                # virtual time, so back off one tick
                yield be.sim.timeout(self.tick, daemon=True)

    # ------------------------------------------------------------------
    def _unlocked(self, ssts: List["SST"]) -> List["SST"]:
        return [s for s in ssts if not s.locked and not s.migrating]

    def _pick_job(self):
        be = self.backend
        now = be.sim.now
        if self.basic_low_levels is None:
            # --- capacity migration (HHZS mode only) ----------------------
            t = be.placement.tiering_level()
            all_ssd = be.ssd_ssts()
            ssd_ssts = self._unlocked(all_ssd)
            at_t = [s for s in all_ssd if s.level == t]
            over_t = [s for s in all_ssd if s.level > t]
            reserved_t = be.placement.reserved_for_tiering(t) \
                if hasattr(be.placement, "reserved_for_tiering") else float("inf")
            # evict only when lower levels actually lack zones for their
            # demand — otherwise transient demand spikes (every compaction
            # trigger) cause chronic SSD<->HDD churn
            demands_below = sum(be.placement.demand_of(j) for j in range(t)) \
                if hasattr(be.placement, "demand_of") else 0.0
            starved = be.ssd_empty_sst_zones() < demands_below
            if (len(at_t) > reserved_t or over_t) and starved and ssd_ssts:
                victim = max(ssd_ssts, key=lambda s: priority_key(s, now))
                self.capacity_moves += 1
                return (victim, "hdd", None)
        # --- popularity migration ----------------------------------------
        hdd_iops = be.hdd.timing.rand_read_iops
        if be.hdd_read_rate() <= self.popularity_frac * hdd_iops:
            return None
        cands = self._unlocked(be.hdd_ssts())
        if self.basic_low_levels is not None:
            cands = [s for s in cands if s.level < self.basic_low_levels]
        if not cands:
            return None
        best = min(cands, key=lambda s: priority_key(s, now))
        if self._room_for_promotion():
            self.popularity_moves += 1
            return (best, "ssd", None)
        ssd_ssts = self._unlocked(be.ssd_ssts())
        if not ssd_ssts:
            return None
        victim = max(ssd_ssts, key=lambda s: priority_key(s, now))
        # hysteresis: swapping equal-level SSTs requires a clearly higher
        # read rate, otherwise marginal rate differences cause swap churn
        better = (best.level < victim.level
                  or (best.level == victim.level
                      and best.read_rate(now) >
                      victim.read_rate(now) * self.swap_hysteresis))
        if better:
            self.popularity_moves += 1
            return (best, "ssd", victim)
        return None

    def _room_for_promotion(self) -> bool:
        """Empty SSD zones must exceed total demands below the tiering level."""
        be = self.backend
        empty = be.ssd_empty_sst_zones()
        pl = be.placement
        if hasattr(pl, "reserved_for_tiering"):
            t = pl.tiering_level()
            demands_below = sum(pl.demand_of(j) + 0 for j in range(t))
            return empty > demands_below
        return empty > 0

    # ------------------------------------------------------------------
    def _migrate(self, sst: "SST", dst: str):
        """Move one SST between tiers, rate-limited. Returns True on success.

        Compaction preempts migration: if the SST is selected by a compaction
        (locked) or deleted while the copy is in flight, the migration aborts
        and its destination zones are reset.  The paper only states the
        converse (migration never selects compaction-selected SSTs, §3.4);
        letting the foreground-critical compaction win the race is the
        RocksDB-faithful resolution.
        """
        be = self.backend
        if sst.locked or sst.migrating or sst.tier == dst:
            return False
        sst.migrating = True
        new_zones = None
        try:
            new_zones = be.alloc_sst_zones(dst, sst.size_bytes, f"sst:{sst.sid}")
            if new_zones is None:
                return False
            src_dev = be.device_of(sst.tier)
            dst_dev = be.device_of(dst)
            start = be.sim.now
            done = 0
            total = sst.size_bytes
            zi = 0
            while done < total:
                if sst.locked or sst.sid not in be.ssts:
                    # preempted by compaction (or already compacted away)
                    self.aborted += 1
                    for z in new_zones:
                        be.device_of(dst).reset_zone(z)
                    new_zones = None
                    return False
                n = min(self.chunk_bytes, total - done)
                yield src_dev.read(n, random=False, tag="migr", background=True)
                rem = n
                while rem > 0:
                    zone = new_zones[zi]
                    take = min(rem, zone.remaining)
                    if take == 0:
                        zi += 1
                        continue
                    yield dst_dev.append(zone, take, tag="migr", background=True)
                    rem -= take
                done += n
                self.bytes_moved += n
                # rate limiting: pace the *aggregate* migration stream
                target = start + done / self.rate_limit
                if be.sim.now < target:
                    yield target - be.sim.now   # bare-delay: no Event
            if sst.locked or sst.sid not in be.ssts:
                self.aborted += 1
                for z in new_zones:
                    be.device_of(dst).reset_zone(z)
                new_zones = None
                return False
            be.relocate(sst, dst, new_zones)
            return True
        finally:
            sst.migrating = False
