"""HHZS middleware: bridges the LSM-tree KV store and hybrid zoned storage.

Owns both zoned devices, the zone organization of §3.2 (reserved WAL/cache
zones on the SSD, SST zones elsewhere), the WAL manager, and — when enabled —
the workload-aware migrator (§3.4) and application-hinted cache (§3.5).
Placement decisions are delegated to a ``PlacementPolicy`` (§3.3 / baselines).

SST sizing follows the paper: one SST fits a single SSD zone (93.9% of the
1077 MiB zone capacity) or spans four HDD zones.  All I/O paths are simulator
generators so queueing interference between foreground reads and background
flush/compaction/migration traffic is modelled faithfully.
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass
from typing import (Callable, Dict, FrozenSet, Generator, List, Optional,
                    Set, Tuple, TYPE_CHECKING, Union)

from ..zoned.device import MiB, Zone, ZonedDevice, ZoneState
from ..zoned.sim import Sim
from .hinted_cache import HintedCache
from .hints import CacheHint
from .migration import Migrator
from .placement import PlacementPolicy

if TYPE_CHECKING:
    from ..lsm.sstable import SST

SSD, HDD = "ssd", "hdd"
_CHUNK = int(1 * MiB)


class HybridZonedBackend:
    def __init__(self, sim: Sim, ssd: ZonedDevice, hdd: ZonedDevice,
                 placement: PlacementPolicy,
                 wal_cache_zones: int = 2,
                 block_size: int = 4096,
                 enable_migration: bool = False,
                 enable_cache: bool = False,
                 migration_rate: float = 4 * MiB,
                 io_chunk: int = int(1 * MiB),
                 basic_migration_low_levels: Optional[int] = None,
                 hdd_rate_window: float = 10.0):
        self.sim = sim
        self.ssd = ssd
        self.hdd = hdd
        self.placement = placement
        self.block_size = block_size
        self.io_chunk = io_chunk
        placement.attach(self)

        # ---- zone organization (§3.2) ---------------------------------
        self.reserve_zids: Set[int] = set()
        if placement.reserves_wal:
            carved = [ssd.alloc_zone("reserve-free")
                      for _ in range(wal_cache_zones)]
            for z in carved:
                # keep it EMPTY but remembered as reserved
                ssd.reset_zone(z)
                self.reserve_zids.add(z.zid)

        # ---- SST registry ----------------------------------------------
        self.ssts: Dict[int, "SST"] = {}
        self._ssd_level_counts: Dict[int, int] = defaultdict(int)

        # ---- WAL state --------------------------------------------------
        self._wal_records: List[dict] = []   # {zone, dev, gens:set}
        self._cur_wal: Optional[dict] = None
        # logical WAL payloads per MemTable generation — the replay source
        # for crash recovery (RocksDB: log records keyed by log number).
        # Dropped in wal_flushed() once the generation is durable as SSTs.
        self._wal_payloads: Dict[int, List[tuple]] = defaultdict(list)
        self._wal_waiters: List = []
        # WAL-full backpressure hook (the LSM-tree forces a memtable switch
        # + flush, as RocksDB does when max_total_wal_size is hit)
        self.wal_pressure_cb = None
        # group commit: concurrent writers batch into one WAL I/O
        self._wal_queue: "deque[tuple]" = deque()
        self._wal_writer_running = False

        # ---- optional components ---------------------------------------
        self.cache: Optional[HintedCache] = (
            HintedCache(self, block_size) if enable_cache else None)
        # dynamic cap on cache zones (repro.obs.control's reservation
        # knob): None = unlimited (default, behaviour unchanged); an int
        # makes HintedCache refuse/evict beyond that many zones, freeing
        # reserved zones for the WAL under write pressure
        self.cache_zone_budget: Optional[int] = None
        self.migrator: Optional[Migrator] = (
            Migrator(self, rate_limit=migration_rate, chunk_bytes=io_chunk,
                     basic_low_levels=basic_migration_low_levels)
            if enable_migration else None)

        # ---- read-rate window for popularity migration ------------------
        self._hdd_window = hdd_rate_window
        self._hdd_buckets: Dict[int, int] = defaultdict(int)

        # ---- stats -------------------------------------------------------
        self.stats = defaultdict(float)

    def start(self) -> None:
        self.placement.start()
        if self.migrator is not None:
            self.migrator.start()

    # ==================================================================
    # zone pool queries used by placement / migration
    # ==================================================================
    def device_of(self, tier: str) -> ZonedDevice:
        return self.ssd if tier == SSD else self.hdd

    def zone_bytes(self, tier: str) -> int:
        return self.device_of(tier).zone_capacity

    def c_ssd(self) -> int:
        """SSD zones available for SSTs (total minus reserved WAL/cache)."""
        return len(self.ssd.zones) - len(self.reserve_zids)

    def ssd_has_empty_sst_zone(self) -> bool:
        return any(z.state == ZoneState.EMPTY and z.zid not in self.reserve_zids
                   for z in self.ssd.zones)

    def ssd_empty_sst_zones(self) -> int:
        return sum(1 for z in self.ssd.zones
                   if z.state == ZoneState.EMPTY and z.zid not in self.reserve_zids)

    def ssd_sst_count_at_level(self, level: int) -> int:
        return self._ssd_level_counts.get(level, 0)

    def ssd_ssts(self) -> List["SST"]:
        return [s for s in self.ssts.values() if s.tier == SSD]

    def hdd_ssts(self) -> List["SST"]:
        return [s for s in self.ssts.values() if s.tier == HDD]

    # ==================================================================
    # hint entry point (LSM-tree -> middleware)
    # ==================================================================
    def on_hint(self, hint) -> None:
        self.placement.on_hint(hint)

    # ==================================================================
    # SST I/O
    # ==================================================================
    def alloc_sst_zones(self, tier: str, size_bytes: int,
                        owner: str) -> Optional[List[Zone]]:
        dev = self.device_of(tier)
        need = -(-size_bytes // dev.zone_capacity)
        free = [z for z in dev.zones
                if z.state == ZoneState.EMPTY
                and (tier == HDD or z.zid not in self.reserve_zids)]
        if len(free) < need:
            return None
        zones = free[:need]
        for z in zones:
            z.state = ZoneState.OPEN
            z.owner = owner
        return zones

    def write_sst(self, sst: "SST", source: str):
        """Generator: place (per policy) and sequentially write a new SST."""
        tier = self.placement.choose_tier(sst.level, source)
        zones = self.alloc_sst_zones(tier, sst.size_bytes, f"sst:{sst.sid}")
        if zones is None and tier == SSD:
            tier = HDD
            zones = self.alloc_sst_zones(HDD, sst.size_bytes, f"sst:{sst.sid}")
        if zones is None:
            raise RuntimeError("HDD out of zones — size the simulation larger")
        sst.tier = tier
        sst.zones = zones
        sst.birth = self.sim.now
        self._register(sst)
        # lock while the write streams: the SST is registered (placement
        # must see its zones as allocated) but the migrator must not move
        # a half-written SST
        sst.locked = True
        try:
            yield from self._stream_to_zones(
                self.device_of(tier), list(zones), sst.size_bytes,
                tag=f"L{sst.level}")
        finally:
            sst.locked = False

    def _stream_to_zones(self, dev: ZonedDevice, zones: List[Zone],
                         total: int, tag: str, background: bool = False):
        """Generator: sequentially append ``total`` bytes across ``zones``
        in ``io_chunk``-sized requests (shared by SST writes and repairs)."""
        done = 0
        zi = 0
        while done < total:
            n = min(self.io_chunk, total - done)
            rem = n
            while rem > 0:
                zone = zones[zi]
                take = min(rem, zone.remaining)
                if take == 0:
                    zi += 1
                    continue
                yield dev.append(zone, take, tag=tag, background=background)
                rem -= take
            done += n

    def delete_sst(self, sst: "SST") -> None:
        """SST removed by compaction: reset its zones (space reclaim)."""
        self._unregister(sst)
        dev = self.device_of(sst.tier)
        for z in sst.zones:
            dev.reset_zone(z)
        sst.zones = []
        if self.cache is not None:
            self.cache.drop_sst(sst.sid)
        self._wake_wal_waiters()

    def relocate(self, sst: "SST", new_tier: str, new_zones: List[Zone]) -> None:
        """Migration finished: flip tiers, reset source zones."""
        old_dev = self.device_of(sst.tier)
        for z in sst.zones:
            old_dev.reset_zone(z)
        if sst.tier == SSD:
            self._ssd_level_counts[sst.level] -= 1
        sst.tier = new_tier
        sst.zones = new_zones
        if new_tier == SSD:
            self._ssd_level_counts[sst.level] += 1
            # cached copies of now-SSD-resident blocks are redundant
            if self.cache is not None:
                self.cache.drop_sst(sst.sid)
        self._wake_wal_waiters()

    def note_level_change(self, sst: "SST", new_level: int) -> None:
        if sst.tier == SSD:
            self._ssd_level_counts[sst.level] -= 1
            self._ssd_level_counts[new_level] += 1
        sst.level = new_level

    def _register(self, sst: "SST") -> None:
        self.ssts[sst.sid] = sst
        if sst.tier == SSD:
            self._ssd_level_counts[sst.level] += 1

    def _unregister(self, sst: "SST") -> None:
        self.ssts.pop(sst.sid, None)
        if sst.tier == SSD:
            self._ssd_level_counts[sst.level] -= 1

    # ------------------------------------------------------------------
    def read_block(self, sst: "SST", block_idx: int):
        """Generator: read one data block; SSD cache zones checked first.

        Charges device I/O only — logical-read accounting (``num_reads``,
        the §3.4 popularity signal) lives in the tree's read path so that
        block-cache *hits* count too; counting only here made fully
        cache-resident hot SSTs look cold to the migrator."""
        if sst.tier == HDD and self.cache is not None \
                and self.cache.lookup(sst.sid, block_idx):
            self.cache.record_hit()
            self.stats["ssd_cache_hits"] += 1
            yield self.ssd.io(self.block_size, "rand_read", tag="cache")
            return "ssd-cache"
        dev = self.device_of(sst.tier)
        if sst.tier == HDD:
            self._hdd_buckets[int(self.sim.now)] += 1
            self.stats["hdd_block_reads"] += 1
        else:
            self.stats["ssd_block_reads"] += 1
        yield dev.io(self.block_size, "rand_read", tag=f"L{sst.level}")
        return sst.tier

    def on_block_evicted(self, sst: Optional[SST], block_idx: int) -> None:
        """Cache hint (§3.5): fire-and-forget admission into cache zones."""
        if self.cache is None or sst is None:
            return
        self.on_hint(CacheHint(sst_id=sst.sid, block_idx=block_idx))
        self.sim.process(self.cache.admit(sst.sid, block_idx, sst.tier))

    def hdd_read_rate(self) -> float:
        """HDD block reads per second over a sliding window (§3.4 trigger).

        Averages the ``w`` most recent *complete* one-second buckets
        [now-w, now); the current second's partial bucket is excluded —
        counting it while dividing by the full window dilutes the rate and
        delays popularity migration right after a read burst.  Buckets that
        fell out of the window are pruned on every call, so the dict stays
        at ~w entries regardless of run length."""
        now = int(self.sim.now)
        w = max(int(self._hdd_window), 1)
        total = sum(self._hdd_buckets.get(now - i, 0) for i in range(1, w + 1))
        stale = [k for k in self._hdd_buckets if k < now - w]
        for k in stale:
            del self._hdd_buckets[k]
        return total / float(w)

    # ==================================================================
    # device fault handling (repro.zoned.faults)
    # ==================================================================
    def on_zone_fault(self, tier: str, zone: Zone) -> None:
        """A zone was spontaneously reset by the device (torn zone).

        The host detects it (ZNS reports zone state) and repairs according
        to the owner: an SST zone keeps its allocation (so the allocator
        cannot hand it out while degraded) and the SST is re-replicated to
        fresh zones; a WAL zone's loss forces an immediate flush — the data
        still lives in the MemTables, flushing makes it durable again; a
        cache zone just drops its (clean-copy) mapping entries."""
        dev = self.device_of(tier)
        owner = zone.owner
        dev.reset_zone(zone)
        self.stats["zone_faults"] += 1
        if owner is None:
            return
        if owner == "wal":
            for rec in [r for r in self._wal_records if r["zone"] is zone]:
                self._wal_records.remove(rec)
                if rec is self._cur_wal:
                    self._cur_wal = None
            if self.wal_pressure_cb is not None:
                self.wal_pressure_cb()
            self._wake_wal_waiters()
        elif owner == "cache":
            if self.cache is not None:
                self.cache.on_zone_fault(zone)
            self._wake_wal_waiters()
        elif owner.startswith("sst:"):
            sst = self.ssts.get(int(owner.split(":", 1)[1]))
            if sst is None:
                return
            # keep the torn zone allocated to its SST while the repair runs
            # (a reset zone is EMPTY and the allocator would hand it out,
            # leaving two owners); the repair's relocate() resets it anyway
            zone.state = ZoneState.OPEN
            zone.owner = owner
            self.sim.process(self._repair_sst(sst))

    def _repair_sst(self, sst: "SST"):
        """Generator: re-create a full replacement copy of a degraded SST
        (as a production deployment would from a replica), then swap."""
        # wait out a compaction/migration holding the SST: compaction will
        # delete it, migration rewrites it — either resolves the torn zone
        while sst.locked or sst.migrating:
            if self.ssts.get(sst.sid) is not sst:
                return
            yield self.sim.timeout(0.25, daemon=True)
        if self.ssts.get(sst.sid) is not sst:
            return
        tier = sst.tier
        zones = self.alloc_sst_zones(tier, sst.size_bytes, f"sst:{sst.sid}")
        if zones is None:
            tier = HDD if tier == SSD else SSD
            zones = self.alloc_sst_zones(tier, sst.size_bytes,
                                         f"sst:{sst.sid}")
        if zones is None:
            self.stats["unrepaired_sst_faults"] += 1
            return
        sst.locked = True
        try:
            src = self.device_of(sst.tier)
            rem = sst.size_bytes
            while rem > 0:
                n = min(self.io_chunk, rem)
                yield src.read(n, random=False, tag="repair", background=True)
                rem -= n
            yield from self._stream_to_zones(self.device_of(tier), zones,
                                             sst.size_bytes, tag="repair",
                                             background=True)
        finally:
            sst.locked = False
        if self.ssts.get(sst.sid) is not sst:
            for z in zones:   # compacted away mid-repair: give zones back
                self.device_of(tier).reset_zone(z)
            return
        self.relocate(sst, tier, zones)
        self.stats["repaired_ssts"] += 1

    # ==================================================================
    # crash / recovery (DB.crash() / DB.reopen())
    # ==================================================================
    def crash_volatile(self) -> None:
        """Crash: the in-memory WAL machinery dies with the process; zones,
        records and per-generation payloads are durable and survive."""
        self._wal_waiters = []
        self._wal_queue = deque()
        self._wal_writer_running = False
        # recovery starts a fresh WAL zone (RocksDB starts a new log file)
        self._cur_wal = None

    def reopen_rebuild(self, ssts: List["SST"]) -> None:
        """Recovery: rebuild the SST registry, ``_ssd_level_counts`` and the
        zone map from durable state.

        ``ssts`` is the manifest — the SSTs that were durably installed at
        crash time.  Every non-empty zone not referenced by an installed
        SST or a live WAL record is garbage from in-flight work (partial
        SST writes, compaction outputs, migration/repair destinations,
        cache fills) and is reset; this single rule is the whole zone-map
        rebuild."""
        self.ssts = {}
        self._ssd_level_counts = defaultdict(int)
        for sst in ssts:
            sst.locked = False
            sst.migrating = False
            self._register(sst)
        # WAL records whose generations all flushed are dead weight
        self._wal_records = [r for r in self._wal_records if r["gens"]]
        live = {id(z) for s in ssts for z in s.zones}
        live |= {id(r["zone"]) for r in self._wal_records}
        for dev in (self.ssd, self.hdd):
            for z in dev.zones:
                if z.state != ZoneState.EMPTY and id(z) not in live:
                    dev.reset_zone(z)
        # the hinted cache's mapping table is in-memory: cold after restart
        if self.cache is not None:
            self.cache.clear_volatile()
        self.placement.on_reopen()

    # ==================================================================
    # WAL manager
    # ==================================================================
    def wal_zones_in_use(self) -> int:
        return len(self._wal_records)

    def wal_pressure(self) -> bool:
        """True while at least one writer is stalled waiting for a WAL zone.

        This is the overload signal the admission controller keys on: WAL
        stalls mean the flush pipeline cannot keep up with the offered write
        rate, so shedding (or delaying) new work is the only way to bound
        the queueing delay of tenants that must meet an SLO."""
        return bool(self._wal_waiters)

    def acquire_reserved_zone(self, kind: str) -> Optional[Zone]:
        for z in self.ssd.zones:
            if z.zid in self.reserve_zids and z.state == ZoneState.EMPTY:
                z.state = ZoneState.OPEN
                z.owner = kind
                return z
        return None

    def release_reserved_zone(self, zone: Zone) -> None:
        self.ssd.reset_zone(zone)
        self._wake_wal_waiters()

    def _wal_new_zone(self) -> Optional[dict]:
        if self.placement.reserves_wal:
            zone = self.acquire_reserved_zone("wal")
            if zone is None and self.cache is not None and self.cache.zones:
                # WAL pressure evicts cache zones (§3.5 cache eviction)
                self.cache.evict_oldest_zone()
                zone = self.acquire_reserved_zone("wal")
            if zone is None:
                return None
            dev = self.ssd
        else:
            # basic schemes: any empty SSD zone, else HDD (§2.3)
            zone = None
            for z in self.ssd.zones:
                if z.state == ZoneState.EMPTY:
                    zone, dev = z, self.ssd
                    break
            if zone is None:
                for z in self.hdd.zones:
                    if z.state == ZoneState.EMPTY:
                        zone, dev = z, self.hdd
                        break
            if zone is None:
                return None
            zone.state = ZoneState.OPEN
            zone.owner = "wal"
        rec = {"zone": zone, "dev": dev, "gens": set()}
        self._wal_records.append(rec)
        return rec

    def wal_append(self, nbytes: int):
        """Generator: append a log record (group-committed with concurrent
        writers, as RocksDB batches WAL writes from its write group).

        Returns the WAL zone records the batch landed in; the caller
        attributes its MemTable generation to them *after* inserting
        (attribution at enqueue time is wrong: the memtable can rotate —
        or even flush — while the write sits in the group-commit queue,
        leaving phantom generations that pin WAL zones forever)."""
        ev = self.sim.event()
        self._wal_queue.append((nbytes, ev))
        if not self._wal_writer_running:
            self._wal_writer_running = True
            self.sim.process(self._wal_writer())
        records = yield ev
        return records

    def wal_attribute(self, records, gen: int, key: Optional[int] = None,
                      tomb: bool = False, value: Optional[bytes] = None,
                      tenant: Optional[str] = None) -> None:
        """Attribute a group-committed batch's bytes to MemTable generation
        ``gen`` and log the logical record for crash replay.

        The payload is the durable mirror of the MemTable insert that just
        happened: on ``DB.reopen()`` the live generations' payloads are
        replayed back into fresh MemTables, in the original insert order.
        ``tenant`` rides along so replay rebuilds the per-tenant
        debt-attribution tallies (``MemTable.tenant_objs``) too."""
        for rec in records:
            rec["gens"].add(gen)
        if key is not None:
            self._wal_payloads[gen].append((key, tomb, value, tenant))

    def _wal_writer(self):
        try:
            while self._wal_queue:
                # bounded group commit: one batch never exceeds a WAL
                # zone's capacity.  An unbounded batch deadlocks under
                # bursts: writers are only acknowledged (and their data
                # only inserted into MemTables) once the WHOLE batch is on
                # stable storage, so a batch larger than the total WAL
                # space would wait forever for zones that can only be
                # freed by flushing data the batch itself still holds.
                # Basic schemes can spill the WAL to HDD zones (smaller),
                # so bound by the smallest device that may host it.
                if self.placement.reserves_wal:
                    cap = max(self.ssd.zone_capacity, 1)
                else:
                    cap = max(min(self.ssd.zone_capacity,
                                  self.hdd.zone_capacity), 1)
                batch: List[tuple] = []
                total = 0
                while self._wal_queue and \
                        (not batch or total + self._wal_queue[0][0] <= cap):
                    n, ev = self._wal_queue.popleft()
                    batch.append((n, ev))
                    total += n
                touched = []
                while total > 0:
                    rec = self._cur_wal
                    if rec is None or rec["zone"].remaining <= 0:
                        rec = self._wal_new_zone()
                        if rec is None:
                            # stall until a flush or zone reset frees WAL
                            # space; signal pressure so the tree force-flushes
                            if self.wal_pressure_cb is not None:
                                self.wal_pressure_cb()
                            ev = self.sim.event()
                            self._wal_waiters.append(ev)
                            self.stats["wal_stalls"] += 1
                            yield ev
                            continue
                        self._cur_wal = rec
                    take = min(total, rec["zone"].remaining)
                    if rec not in touched:
                        touched.append(rec)
                    yield rec["dev"].append(rec["zone"], take, tag="wal")
                    total -= take
                for _, ev in batch:
                    ev.succeed(touched)
        finally:
            self._wal_writer_running = False

    def wal_flushed(self, gens: Set[int]) -> None:
        """MemTable generations persisted as SSTs: their WAL data is dead."""
        for g in gens:
            self._wal_payloads.pop(g, None)
        kept = []
        for rec in self._wal_records:
            rec["gens"] -= gens
            full = rec["zone"].remaining <= 0
            # the current zone is also reclaimable once it is full + dead
            reclaim = not rec["gens"] and (rec is not self._cur_wal or full)
            if reclaim:
                if rec is self._cur_wal:
                    self._cur_wal = None
                if self.placement.reserves_wal:
                    self.release_reserved_zone(rec["zone"])
                else:
                    rec["dev"].reset_zone(rec["zone"])
            else:
                kept.append(rec)
        self._wal_records = kept
        self._wake_wal_waiters()

    def _wake_wal_waiters(self) -> None:
        waiters, self._wal_waiters = self._wal_waiters, []
        for ev in waiters:
            ev.succeed()

    # ==================================================================
    # telemetry (repro.obs) — pull gauges only: zero hot-path overhead
    # ==================================================================
    def install_metrics(self, reg, prefix: str = "") -> None:
        """Register the middleware's signals on a ``MetricsRegistry``.

        Every signal maps to a paper hint family (§3.1): WAL pressure and
        zone counts are the flush-side backpressure (§3.2 zone
        organization), migration traffic is the §3.4 migrator at work,
        cache hit rate is the §3.5 hinted cache paying off.  ``prefix``
        namespaces the series per shard (``s{i}.mw.*``) when the sharded
        cluster facade installs several backends on one registry.
        """
        p = prefix
        reg.gauge(f"{p}mw.wal_pressure", lambda: float(self.wal_pressure()))
        reg.gauge(f"{p}mw.wal_zones", lambda: float(self.wal_zones_in_use()))
        reg.gauge(f"{p}mw.wal_stalls", lambda: self.stats["wal_stalls"])
        reg.gauge(f"{p}mw.hdd_read_rate", self.hdd_read_rate)
        if self.cache is not None:
            reg.gauge(f"{p}mw.cache_hits", lambda: float(self.cache.hits))
            reg.gauge(f"{p}mw.cache_zones",
                      lambda: float(len(self.cache.zones)))
        if self.migrator is not None:
            reg.gauge(f"{p}mw.migrated_bytes",
                      lambda: float(self.migrator.bytes_moved))
            # migration traffic as a windowed rate (bytes/s between samples)
            reg.collector(lambda: {
                f"{p}mw.migration_rate": float(self.migrator.bytes_moved)},
                rate=True, name=f"{p}mw.migration_rate")


# ======================================================================
# admission control / load shedding (multi-tenant serving)
# ======================================================================
ADMIT, REJECT, DELAY = "admit", "reject", "delay"

ADMISSION_POLICIES = ("none", "reject", "delay", "token_bucket", "feedback")


@dataclass
class AdmissionConfig:
    """Configuration of the per-tenant admission controller.

    policy
        ``none``          admit everything (baseline).
        ``reject``        shed non-protected ops while the store is under
                          pressure (WAL stall or service backlog) — the op
                          is dropped before it ever queues.
        ``delay``         hold non-protected ops while under pressure and
                          admit them once the pressure clears (classic
                          delay-at-WAL-pressure: offered work is deferred,
                          not lost).
        ``token_bucket``  per-tenant token bucket: ops above a tenant's
                          sustained ``rate`` (with ``burst`` headroom) are
                          shed regardless of store pressure.
        ``feedback``      per-tenant token bucket whose rates are *driven*
                          by the SLO feedback controller
                          (``repro.obs.control.ControlPlane``): AIMD over
                          the non-protected tenants' rates, keyed on the
                          protected tenants' measured p99 vs their
                          ``TenantSpec.slo_p99`` targets and on compaction
                          debt vs ``debt_threshold``.
    protected
        Tenant names exempt from shedding/delaying under every policy —
        the SLO tenants the middleware exists to protect.
    queue_threshold
        Service-backlog gauge threshold: when a runner registers a queue
        gauge (see ``AdmissionController.queue_gauge``), a backlog above
        this count also counts as pressure.
    poll_interval
        Virtual seconds between pressure re-checks while a delayed op is
        held.
    bucket_rate / bucket_burst / bucket_rates
        Default token-bucket parameters (tokens/virtual-second, bucket
        size) and optional per-tenant ``{name: (rate, burst)}`` overrides.
        The default rate is infinite, i.e. tenants without an explicit
        budget are not rate-limited.  Bursts are normalized to >= 1.0
        token: admitting one op costs one full token, so a bucket smaller
        than one token could never admit anything — the tenant would be
        starved forever regardless of its configured rate.
    debt_threshold
        Compaction-debt pressure signal (bytes): when set and the
        controller has a ``debt_gauge`` (wired by ``DB`` / the runners to
        ``LSMTree.compaction_debt``), debt above this threshold counts as
        pressure for the ``reject``/``delay`` policies and as an
        over-target condition for the ``feedback`` controller — shedding
        starts while the debt is building, before it turns into write
        stalls.
    label
        Optional display name for result rows / cell names, so two cells
        sharing a policy kind but different parameters (e.g. ``reject``
        with and without ``debt_threshold``) stay distinguishable.
    feedback_interval / feedback_window / feedback_decrease /
    feedback_increase / feedback_headroom / feedback_floor
        Constants of the ``feedback`` policy's AIMD loop
        (``repro.obs.control.ControlPlane``): control period in virtual
        seconds, per-tenant latency samples for the p99 estimate,
        multiplicative decrease factor, additive increase step and rate
        floor (both as fractions of the tenant's base rate), and the
        p99/target ratio below which additive increase engages.
    feedback_controller
        Which control law drives the ``feedback`` policy's knobs:
        ``"aimd"`` (default, the PR-5 loop unchanged) or ``"pi"`` — a
        proportional-integral controller with anti-windup
        (``repro.obs.control.PIController``) on the worst protected
        p99/target ratio, emitting one smooth admission multiplier
        instead of AIMD's sawtooth.
    feedback_knobs
        Which actuators the control plane drives (any subset of
        ``repro.obs.control.KNOBS``): ``"admission"`` (per-tenant
        token-bucket rates — the only PR-5 knob), ``"compaction"``
        (SILK-style pacing of background compaction I/O via
        ``LSMTree.compaction_pace``), ``"migration"`` (scaling
        ``Migrator.rate_limit``), ``"cache"`` (the backend's
        ``cache_zone_budget``).  Defaults to admission-only, matching v1.
    feedback_kp / feedback_ki
        PI gains (per unit of p99/target ratio error); only read when
        ``feedback_controller == "pi"``.
    feedback_smooth
        EWMA smoothing factor in (0, 1] applied to the noisy per-tick
        p99/target measurement before the PI law sees it (1 = unsmoothed).
    feedback_rise
        Optional slew-rate limit on the PI actuation level's *recovery*
        (max increase of ``u`` per control period; ``None`` = unlimited).
        Throttling down stays unlimited — pressure must be cut within
        one period — but bounding the climb back keeps a high-gain PI
        from re-admitting a burst the moment one good p99 window lands
        (the overshoot half of the limit cycle).
    """

    policy: str = "none"
    protected: FrozenSet[str] = frozenset()
    queue_threshold: int = 128
    poll_interval: float = 0.5
    bucket_rate: float = float("inf")
    bucket_burst: float = 1.0
    bucket_rates: Optional[Dict[str, Tuple[float, float]]] = None
    debt_threshold: Optional[float] = None
    label: Optional[str] = None
    feedback_interval: float = 5.0
    feedback_window: int = 200
    feedback_decrease: float = 0.7
    feedback_increase: float = 0.08
    feedback_headroom: float = 0.8
    feedback_floor: float = 0.02
    feedback_controller: str = "aimd"
    feedback_knobs: Tuple[str, ...] = ("admission",)
    feedback_kp: float = 0.6
    feedback_ki: float = 0.15
    feedback_smooth: float = 0.5
    feedback_rise: Optional[float] = None

    def __post_init__(self):
        self.bucket_burst = max(float(self.bucket_burst), 1.0)
        if self.bucket_rates:
            self.bucket_rates = {
                t: (rate, max(float(burst), 1.0))
                for t, (rate, burst) in self.bucket_rates.items()}
        self.feedback_knobs = tuple(self.feedback_knobs)
        if self.feedback_controller not in ("aimd", "pi"):
            raise ValueError("feedback_controller must be 'aimd' or 'pi', "
                             f"got {self.feedback_controller!r}")


class AdmissionController:
    """Admission-control / load-shedding layer in front of the KV store.

    Sits between request arrival and the store's service queue (wired
    through ``DB.submit(gen, tenant=...)`` and the open-loop multi-tenant
    runner).  Each arriving op is attributed to a named tenant and gets one
    of three verdicts from :meth:`decide`:

    * ``ADMIT``  — enqueue for service now,
    * ``REJECT`` — shed (the op never executes; conserved in counters),
    * ``DELAY``  — hold via :meth:`hold` until pressure clears, then admit.

    Pressure (:meth:`under_pressure`) is WAL back-pressure from the
    middleware (``HybridZonedBackend.wal_pressure``) OR a service backlog
    reported by an attached ``queue_gauge`` (the open-loop runner registers
    its queue depth).  Protected tenants are always admitted.

    Per-tenant counters (``counters[name]``):
      ``arrived``   ops that reached the controller,
      ``admitted``  ops enqueued for service (including after a hold),
      ``rejected``  ops shed,
      ``delayed``   ops that entered a hold,
      ``holding``   ops currently held (0 after a drained run),
      ``delay_time`` total virtual seconds spent in holds.
    Conservation: ``arrived == admitted + rejected + holding`` at all times.
    """

    def __init__(self, sim: Sim, backend: Optional[HybridZonedBackend] = None,
                 cfg: Union[AdmissionConfig, str, None] = None):
        if cfg is None:
            cfg = AdmissionConfig()
        elif isinstance(cfg, str):
            cfg = AdmissionConfig(policy=cfg)
        if cfg.policy not in ADMISSION_POLICIES:
            raise ValueError(f"unknown admission policy {cfg.policy!r}; "
                             f"one of {ADMISSION_POLICIES}")
        self.sim = sim
        self.backend = backend
        self.cfg = cfg
        # pristine config as handed in: runners rebind self.cfg (e.g. to
        # widen `protected` for one run) but never touch base_cfg, so a
        # fresh per-run controller can always be rebuilt from it
        self.base_cfg = cfg
        # service-backlog gauge, registered by the open-loop runner:
        # () -> current queue depth
        self.queue_gauge: Optional[Callable[[], int]] = None
        # compaction-debt gauge (bytes), wired by DB / the runners to
        # LSMTree.compaction_debt; consulted only when cfg.debt_threshold
        # is set — the third pressure signal
        self.debt_gauge: Optional[Callable[[], float]] = None
        # shard-scoped pressure signals (repro.cluster): one () -> bool
        # callable per shard, typically that shard backend's wal_pressure.
        # Any shard under pressure puts the cluster controller under
        # pressure — a hot shard sheds/delays for the whole cluster, since
        # routed ops cannot know in advance which shard they will hit.
        self.shard_pressure: List[Callable[[], bool]] = []
        # live token-bucket rate overrides, driven by the SLO feedback
        # controller (repro.obs.control.ControlPlane) under policy
        # "feedback"; consulted before cfg.bucket_rates
        self.rate_overrides: Dict[str, float] = {}
        self.counters: Dict[str, Dict[str, float]] = {}
        self._buckets: Dict[str, List[float]] = {}   # name -> [tokens, t]

    # ------------------------------------------------------------------
    def tenant_counters(self, tenant: str) -> Dict[str, float]:
        c = self.counters.get(tenant)
        if c is None:
            c = self.counters[tenant] = {
                "arrived": 0, "admitted": 0, "rejected": 0,
                "delayed": 0, "holding": 0, "delay_time": 0.0}
        return c

    def under_pressure(self) -> bool:
        if self.backend is not None and self.backend.wal_pressure():
            return True
        if any(p() for p in self.shard_pressure):
            return True
        g = self.queue_gauge
        if g is not None and g() > self.cfg.queue_threshold:
            return True
        d = self.debt_gauge
        return (d is not None and self.cfg.debt_threshold is not None
                and d() > self.cfg.debt_threshold)

    def shard_under_pressure(self) -> List[bool]:
        """Per-shard pressure snapshot (empty for single-store
        controllers); exposed for telemetry and the cluster rebalancer."""
        return [bool(p()) for p in self.shard_pressure]

    # ------------------------------------------------------------------
    def decide(self, tenant: str) -> str:
        """Admission verdict for one arriving op of ``tenant``."""
        c = self.tenant_counters(tenant)
        c["arrived"] += 1
        pol = self.cfg.policy
        if pol == "none" or tenant in self.cfg.protected:
            c["admitted"] += 1
            return ADMIT
        if pol == "token_bucket" or pol == "feedback":
            if self._take_token(tenant):
                c["admitted"] += 1
                return ADMIT
            c["rejected"] += 1
            return REJECT
        if not self.under_pressure():
            c["admitted"] += 1
            return ADMIT
        if pol == "reject":
            c["rejected"] += 1
            return REJECT
        c["delayed"] += 1
        c["holding"] += 1
        return DELAY

    def hold(self, tenant: str) -> Generator:
        """Generator: park a DELAY-ed op until pressure clears (polling
        every ``poll_interval`` virtual seconds), then count it admitted."""
        c = self.tenant_counters(tenant)
        t0 = self.sim.now
        while self.under_pressure():
            yield self.cfg.poll_interval   # bare-delay sleep
        c["delay_time"] += self.sim.now - t0
        c["holding"] -= 1
        c["admitted"] += 1

    def _take_token(self, tenant: str) -> bool:
        rates = self.cfg.bucket_rates or {}
        rate, burst = rates.get(tenant,
                                (self.cfg.bucket_rate, self.cfg.bucket_burst))
        ov = self.rate_overrides.get(tenant)
        if ov is not None:
            rate = ov
        if rate == float("inf"):
            return True
        now = self.sim.now
        b = self._buckets.get(tenant)
        if b is None:
            b = self._buckets[tenant] = [float(burst), now]
        tokens = min(float(burst), b[0] + (now - b[1]) * rate)
        b[1] = now
        if tokens >= 1.0:
            b[0] = tokens - 1.0
            return True
        b[0] = tokens
        return False

    # ------------------------------------------------------------------
    def submit(self, gen: Generator, tenant: str):
        """``DB.submit`` facade: schedule ``gen`` subject to admission.

        Returns the scheduled Process, or ``None`` when the op was shed
        (the generator is closed without running)."""
        verdict = self.decide(tenant)
        if verdict == REJECT:
            gen.close()
            return None
        if verdict == DELAY:
            def held():
                yield from self.hold(tenant)
                result = yield from gen
                return result
            return self.sim.process(held())
        return self.sim.process(gen)

    def admission_summary(self, tenant: str) -> Dict[str, float]:
        """JSON-ready per-tenant admission counters (row schema field)."""
        c = dict(self.tenant_counters(tenant))
        c["mean_delay"] = (c["delay_time"] / c["delayed"]
                           if c["delayed"] else 0.0)
        return c

    @property
    def policy_label(self) -> str:
        """Display name for rows/cells: ``cfg.label`` or the policy kind."""
        return self.cfg.label or self.cfg.policy

    # ------------------------------------------------------------------
    def install_metrics(self, reg) -> None:
        """Per-tenant arrival/admit/reject *rates* (ops/s between samples)
        on a ``MetricsRegistry``.  Collector-based because tenants appear
        lazily (the key set grows as tenants send their first op)."""
        def _collect() -> Dict[str, float]:
            out: Dict[str, float] = {}
            for t, c in self.counters.items():
                out[f"adm.{t}.arrived"] = c["arrived"]
                out[f"adm.{t}.admitted"] = c["admitted"]
                out[f"adm.{t}.rejected"] = c["rejected"]
            return out

        reg.collector(_collect, rate=True, name="adm.tenants")
        reg.gauge("adm.pressure", lambda: float(self.under_pressure()))
        if self.shard_pressure:
            # per-shard pressure gauges: which shard is pushing back
            def _shards() -> Dict[str, float]:
                return {f"adm.s{i}.pressure": float(p())
                        for i, p in enumerate(self.shard_pressure)}
            reg.collector(_shards, name="adm.shard_pressure")
