"""Hint types passed from the LSM-tree KV store to the HHZS middleware (§3.1).

Each hint is tens of bytes in the real system; here they are small dataclasses
flowing synchronously alongside the corresponding operation.  The same hint
vocabulary is reused by the TPU-serving KV-cache tier manager
(``repro.serving.tiering``): prefill ≙ flush, sequence growth across length
buckets ≙ compaction, HBM block-pool eviction ≙ cache eviction.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class FlushHint:
    """Identifies an SST about to be written at L0 by a flush operation."""
    sst_id: int


@dataclass(frozen=True)
class CompactionTriggerHint:
    """Phase (i): compaction triggered; identifies selected SSTs + target level."""
    cid: int
    selected_sst_ids: Tuple[int, ...]
    target_level: int


@dataclass(frozen=True)
class CompactionOutputHint:
    """Phase (ii): compaction generates one output SST at ``level``."""
    cid: int
    sst_id: int
    level: int


@dataclass(frozen=True)
class CompactionDoneHint:
    """Phase (iii): compaction complete; generated SSTs identified."""
    cid: int
    target_level: int
    num_selected: int
    num_generated: int
    input_sst_ids: Tuple[int, ...] = ()
    output_sst_ids: Tuple[int, ...] = ()


@dataclass(frozen=True)
class CacheHint:
    """In-memory block cache evicted a data block (SST id + offset)."""
    sst_id: int
    block_idx: int
