from .pipeline import SyntheticLM, FileTokens, Prefetcher

__all__ = ["SyntheticLM", "FileTokens", "Prefetcher"]
