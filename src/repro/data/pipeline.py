"""Token data pipeline: deterministic, resumable, prefetched.

``SyntheticLM`` derives every batch from (seed, step) with a splitmix64
mix, so resuming at step N after a restart reproduces the byte-identical
stream with no state file (the property the resume tests assert).
``FileTokens`` samples fixed-length windows from a memory-mapped token
file, again purely (seed, step)-indexed.  ``Prefetcher`` runs the iterator
in a thread with a bounded queue so host batch assembly overlaps device
compute.
"""
from __future__ import annotations

import queue
import threading
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


def _mix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        return x ^ (x >> np.uint64(31))


class SyntheticLM:
    """Deterministic synthetic LM batches with learnable structure
    (a noisy repeat-previous-token pattern, so tiny models show a
    decreasing loss)."""

    def __init__(self, vocab_size: int, batch: int, seq_len: int,
                 seed: int = 0):
        self.vocab = vocab_size
        self.batch = batch
        self.seq = seq_len
        self.seed = np.uint64(seed)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        n = self.batch * (self.seq + 1)
        with np.errstate(over="ignore"):
            idx = (np.arange(n, dtype=np.uint64)
                   + np.uint64(step) * np.uint64(n + 1)
                   + self.seed * np.uint64(0x9E3779B97F4A7C15))
        h = _mix64(idx)
        # markov-ish stream: every other token repeats its predecessor
        raw = (h % np.uint64(self.vocab)).astype(np.int64)
        toks = raw.reshape(self.batch, self.seq + 1)
        toks[:, 1::2] = toks[:, 0:-1:2]      # predictable half
        return {"tokens": toks[:, :-1].astype(np.int32),
                "targets": toks[:, 1:].astype(np.int32)}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class FileTokens:
    """Windows from a memory-mapped token file, (seed, step)-indexed."""

    def __init__(self, path: str, batch: int, seq_len: int,
                 dtype=np.uint16, seed: int = 0):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.n_windows = max(1, (len(self.tokens) - 1) // seq_len)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = _mix64(np.arange(self.batch, dtype=np.uint64)
                     + np.uint64(step * self.batch)
                     + np.uint64(self.seed) * np.uint64(0x9E3779B9))
        starts = (idx % np.uint64(self.n_windows)).astype(np.int64) \
            * self.seq
        toks = np.stack([self.tokens[s:s + self.seq + 1].astype(np.int32)
                         for s in starts])
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Bounded-queue background prefetch around any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self.q.put(item)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
