from . import adamw
from .adamw import OptState, cosine_schedule, global_norm

__all__ = ["adamw", "OptState", "cosine_schedule", "global_norm"]
