"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Built directly in JAX (no optax dependency in this environment).  The
optimizer state is a pytree matching params:
  master: fp32 copy of params   (source of truth)
  mu, nu: fp32 Adam moments
Params stay bf16 for compute; updates apply to master and are re-cast.
This is the standard large-model recipe (and what the roofline memory
analysis should account: 2 + 4+4+4 = 14 bytes/param).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from ..config import TrainConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any
    mu: Any
    nu: Any


def init(params) -> OptState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    master=jax.tree.map(f32, params),
                    mu=jax.tree.map(zeros, params),
                    nu=jax.tree.map(zeros, params))


def cosine_schedule(tc: TrainConfig):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(tc.warmup_steps, 1), 1.0)
        prog = jnp.clip((step - tc.warmup_steps)
                        / jnp.maximum(tc.total_steps - tc.warmup_steps, 1),
                        0.0, 1.0)
        return tc.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return lr


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def update(grads, state: OptState, tc: TrainConfig):
    """One AdamW step. Returns (new_params_bf16, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = cosine_schedule(tc)(step)
    b1, b2, eps = tc.beta1, tc.beta2, 1e-8
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        p = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + tc.weight_decay * p)
        return m, v, p

    flat = jax.tree.map(upd, grads, state.mu, state.nu, state.master)
    mu = jax.tree.map(lambda t: t[0], flat,
                      is_leaf=lambda t: isinstance(t, tuple))
    nu = jax.tree.map(lambda t: t[1], flat,
                      is_leaf=lambda t: isinstance(t, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda t: isinstance(t, tuple))
    params = jax.tree.map(lambda p: p.astype(jnp.bfloat16), master)
    new_state = OptState(step=step, master=master, mu=mu, nu=nu)
    return params, new_state, {"grad_norm": gnorm, "lr": lr}
