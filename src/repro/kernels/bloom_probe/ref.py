"""Pure-jnp oracle for the Bloom probe + filter construction."""
from __future__ import annotations

import jax.numpy as jnp

_MUL1 = jnp.uint32(0x85EBCA6B)
_MUL2 = jnp.uint32(0xC2B2AE35)


def _mix(x, seed):
    x = x ^ seed
    x = (x ^ (x >> 16)) * _MUL1
    x = (x ^ (x >> 13)) * _MUL2
    return x ^ (x >> 16)


def build_filter(keys: jnp.ndarray, num_words: int,
                 k_hashes: int = 7) -> jnp.ndarray:
    """Insert keys into a packed uint32 bit array (jnp, for the oracle).

    Bits are set on a flat bool array (duplicate scatter indices all write
    True, so no read-modify-write races) and packed into uint32 words."""
    flat = jnp.zeros((num_words * 32,), bool)
    for i in range(k_hashes):
        h = _mix(keys.astype(jnp.uint32), jnp.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF))
        word = ((h >> 5) % jnp.uint32(num_words)).astype(jnp.int32)
        bit = (h & jnp.uint32(31)).astype(jnp.int32)
        flat = flat.at[word * 32 + bit].set(True)
    lanes = flat.reshape(num_words, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes * weights, axis=-1, dtype=jnp.uint32)


def bloom_probe_ref(keys: jnp.ndarray, bits: jnp.ndarray,
                    k_hashes: int = 7) -> jnp.ndarray:
    hit = jnp.ones(keys.shape, jnp.int32)
    for i in range(k_hashes):
        h = _mix(keys.astype(jnp.uint32), jnp.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF))
        word = ((h >> 5) % jnp.uint32(bits.shape[0])).astype(jnp.int32)
        bit = h & jnp.uint32(31)
        hit &= ((bits[word] >> bit) & jnp.uint32(1)).astype(jnp.int32)
    return hit
