"""Pure-jnp oracle for the Bloom probe + filter construction.

Hash family (shared bit-for-bit with the Pallas kernel and the numpy
fallback in ``repro.lsm.filters``): keys are splitmix64-hashed host-side
(``repro.lsm.sstable._mix64`` — jnp runs 32-bit by default, so the uint64
finaliser never crosses into jax), the hash is split into uint32 halves
``lo`` / ``hi`` (hi forced odd), and probe position ``i`` is
Kirsch-Mitzenmacher double hashing ``(lo + i*hi) mod (num_words*32)`` in
wrapping uint32 arithmetic.
"""
from __future__ import annotations

import jax.numpy as jnp


def build_filter(lo: jnp.ndarray, hi: jnp.ndarray, num_words: int,
                 k_hashes: int = 7) -> jnp.ndarray:
    """Insert pre-hashed keys into a packed uint32 bit array (jnp oracle).

    Bits are set on a flat bool array (duplicate scatter indices all write
    True, so no read-modify-write races) and packed into uint32 words."""
    nbits = jnp.uint32(num_words * 32)
    flat = jnp.zeros((num_words * 32,), bool)
    lo = lo.astype(jnp.uint32)
    hi = hi.astype(jnp.uint32)
    for i in range(k_hashes):
        pos = (lo + jnp.uint32(i) * hi) % nbits
        flat = flat.at[pos.astype(jnp.int32)].set(True)
    lanes = flat.reshape(num_words, 32).astype(jnp.uint32)
    weights = jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(lanes * weights, axis=-1, dtype=jnp.uint32)


def bloom_probe_ref(lo: jnp.ndarray, hi: jnp.ndarray, bits: jnp.ndarray,
                    k_hashes: int = 7) -> jnp.ndarray:
    """Probe one filter with pre-hashed keys -> int32[N] hit mask."""
    nbits = jnp.uint32(bits.shape[0] * 32)
    lo = lo.astype(jnp.uint32)
    hi = hi.astype(jnp.uint32)
    hit = jnp.ones(lo.shape, jnp.int32)
    for i in range(k_hashes):
        pos = (lo + jnp.uint32(i) * hi) % nbits
        word = (pos >> 5).astype(jnp.int32)
        bit = pos & jnp.uint32(31)
        hit &= ((bits[word] >> bit) & jnp.uint32(1)).astype(jnp.int32)
    return hit


def bloom_probe_pairs_ref(lo: jnp.ndarray, hi: jnp.ndarray,
                          word_off: jnp.ndarray, num_words: jnp.ndarray,
                          bits_concat: jnp.ndarray,
                          k_hashes: int = 7) -> jnp.ndarray:
    """Ragged (key x filter) pairs probe: pair ``p`` tests the filter of
    ``num_words[p]`` words starting at ``word_off[p]`` in the concatenated
    word array — the batched LSM read path's shape (one vectorized call
    over every candidate pair of a level)."""
    nbits = num_words.astype(jnp.uint32) * jnp.uint32(32)
    off = word_off.astype(jnp.int32)
    lo = lo.astype(jnp.uint32)
    hi = hi.astype(jnp.uint32)
    hit = jnp.ones(lo.shape, jnp.int32)
    for i in range(k_hashes):
        pos = (lo + jnp.uint32(i) * hi) % nbits
        word = off + (pos >> 5).astype(jnp.int32)
        bit = pos & jnp.uint32(31)
        hit &= ((bits_concat[word] >> bit) & jnp.uint32(1)).astype(jnp.int32)
    return hit
