"""Jit'd public wrapper for the Bloom probe kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .bloom_probe import bloom_probe


def _is_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("k_hashes", "interpret"))
def probe(lo, hi, bits, k_hashes: int = 7,
          interpret: Optional[bool] = None):
    """Probe a packed filter with pre-hashed keys (see ``bloom_probe``)."""
    interp = (not _is_tpu()) if interpret is None else interpret
    return bloom_probe(lo, hi, bits, k_hashes=k_hashes, interpret=interp)
