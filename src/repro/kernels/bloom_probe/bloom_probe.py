"""Vectorised Bloom-filter probe kernel (pl.pallas_call + BlockSpec).

The LSM read path (§2.2) probes one Bloom filter per candidate SST; a
serving node answering thousands of point reads per second probes in
batches.  Keys arrive pre-hashed: the host splitmix64-hashes each uint64
key (``repro.lsm.sstable._mix64`` — TPU lanes are 32-bit, so the 64-bit
finaliser stays host-side) and ships the two uint32 halves ``lo`` / ``hi``
(hi forced odd).  The kernel tests ``k`` Kirsch-Mitzenmacher positions
``(lo + i*hi) mod (num_words*32)`` against a packed bit array: grid over
key blocks, filter words resident in VMEM, probes vectorised on the VPU
(8x128 lanes).  Gather-heavy / zero-matmul by design — the memory-bound
complement to the attention kernels.  Bit-for-bit identical to the jnp
oracle (``ref.py``) and the numpy fallback (``repro.lsm.filters``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _probe_kernel(lo_ref, hi_ref, bits_ref, out_ref, *, k_hashes,
                  num_words):
    lo = lo_ref[...]                      # [block] uint32
    hi = hi_ref[...]                      # [block] uint32
    bits = bits_ref[...]                  # [num_words] uint32
    # numpy scalar: plain literal inside the kernel (jnp constants would
    # be captured tracers, which pallas_call rejects)
    nbits = np.uint32(num_words * 32)
    hit = jnp.ones(lo.shape, jnp.int32)
    for i in range(k_hashes):
        pos = (lo + np.uint32(i) * hi) % nbits
        word = (pos >> np.uint32(5)).astype(jnp.int32)
        bit = pos & np.uint32(31)
        w = jnp.take(bits, word)
        hit &= ((w >> bit) & np.uint32(1)).astype(jnp.int32)
    out_ref[...] = hit


def bloom_probe(lo: jnp.ndarray, hi: jnp.ndarray, bits: jnp.ndarray, *,
                k_hashes: int = 7, block: int = 1024,
                interpret: bool = False) -> jnp.ndarray:
    """lo, hi: [N] uint32 halves of the splitmix64 key hashes;
    bits: [W] uint32 packed filter. -> [N] int32 hit mask."""
    n = lo.shape[0]
    block = min(block, n)
    assert n % block == 0
    w = bits.shape[0]
    kernel = functools.partial(_probe_kernel, k_hashes=k_hashes,
                               num_words=w)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(lo, hi, bits)
