"""Vectorised Bloom-filter probe kernel (pl.pallas_call + BlockSpec).

The LSM read path (§2.2) probes one Bloom filter per candidate SST; a
serving node answering thousands of point reads per second probes in
batches.  This kernel tests `k` splitmix64-derived hash positions per key
against a packed bit array: grid over key blocks, filter words resident in
VMEM, probes vectorised on the VPU (8x128 lanes).  Gather-heavy / zero-
matmul by design — the memory-bound complement to the attention kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# numpy scalars: plain literals inside the kernel (jnp constants would be
# captured tracers, which pallas_call rejects)
_MUL1 = np.uint32(0x85EBCA6B)
_MUL2 = np.uint32(0xC2B2AE35)


def _mix(x: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    x = x ^ seed
    x = (x ^ (x >> np.uint32(16))) * _MUL1
    x = (x ^ (x >> np.uint32(13))) * _MUL2
    return x ^ (x >> np.uint32(16))


def _probe_kernel(keys_ref, bits_ref, out_ref, *, k_hashes, num_words):
    keys = keys_ref[...]                  # [block] uint32
    bits = bits_ref[...]                  # [num_words] uint32
    hit = jnp.ones(keys.shape, jnp.int32)
    for i in range(k_hashes):
        h = _mix(keys, np.uint32((0x9E3779B9 * (i + 1)) & 0xFFFFFFFF))
        word = (h >> np.uint32(5)) % np.uint32(num_words)
        bit = h & np.uint32(31)
        w = jnp.take(bits, word.astype(jnp.int32))
        hit &= ((w >> bit) & np.uint32(1)).astype(jnp.int32)
    out_ref[...] = hit


def bloom_probe(keys: jnp.ndarray, bits: jnp.ndarray, *, k_hashes: int = 7,
                block: int = 1024, interpret: bool = False) -> jnp.ndarray:
    """keys: [N] uint32; bits: [W] uint32 packed filter. -> [N] int32."""
    n = keys.shape[0]
    block = min(block, n)
    assert n % block == 0
    w = bits.shape[0]
    kernel = functools.partial(_probe_kernel, k_hashes=k_hashes,
                               num_words=w)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((w,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(keys, bits)
