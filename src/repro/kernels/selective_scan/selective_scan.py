"""Mamba-1 selective scan kernel for TPU (pl.pallas_call + BlockSpec).

The recurrence h_t = exp(dt_t * A) h_t-1 + dt_t B_t x_t is sequential in t
but parallel over (batch, d_inner, state).  The grid is
(batch, d_inner blocks, seq chunks) with the chunk dim innermost
("arbitrary"): the [block_d, N] state carries across chunk iterations in
VMEM scratch while each chunk's [chunk, block_d] inputs stream through VMEM
tiles — the HBM->VMEM->VREG blocking a GPU implementation gets from
registers + shared memory.

Inside a chunk the scan runs as an unrolled fori_loop over time steps on
the VPU (elementwise ops; there is no matmul here, the MXU idles — this
kernel is bandwidth-bound by design, see the roofline notes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(dt_ref, bx_ref, c_ref, a_ref, y_ref, h_scratch, *,
                 chunk, n_state):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[...]                         # [block_d, N]
    dt = dt_ref[0]                         # [chunk, block_d]
    bx = bx_ref[0]                         # [chunk, block_d, N]
    c = c_ref[0]                           # [chunk, N]

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * a)          # [block_d, N]
        h = h * decay + bx[t]                        # [block_d, N]
        y_t = jnp.sum(h * c[t][None, :], axis=-1)    # [block_d]
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h0 = h_scratch[...]
    ys0 = jnp.zeros((chunk, a.shape[0]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scratch[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan(dt: jnp.ndarray, bx: jnp.ndarray, c: jnp.ndarray,
                   a: jnp.ndarray, *, block_d: int = 256, chunk: int = 64,
                   interpret: bool = False) -> jnp.ndarray:
    """dt: [B, T, di] fp32; bx: [B, T, di, N] fp32; c: [B, T, N] fp32;
    a: [di, N] fp32 (negative). Returns y [B, T, di] fp32."""
    b, t, di = dt.shape
    n = a.shape[-1]
    block_d = min(block_d, di)
    chunk = min(chunk, t)
    assert di % block_d == 0 and t % chunk == 0
    nd, nc = di // block_d, t // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, n_state=n)
    return pl.pallas_call(
        kernel,
        grid=(b, nd, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d),
                         lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, block_d, n),
                         lambda bi, d, ci: (bi, ci, d, 0)),
            pl.BlockSpec((1, chunk, n),
                         lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d, n),
                         lambda bi, d, ci: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda bi, d, ci: (bi, ci, d)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, bx, c, a)
