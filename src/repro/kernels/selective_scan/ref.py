"""Pure-jnp oracle for the selective scan (sequential recurrence)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(dt, bx, c, a):
    """dt: [B,T,di]; bx: [B,T,di,N]; c: [B,T,N]; a: [di,N] -> y [B,T,di]."""
    b, t, di = dt.shape
    n = a.shape[-1]

    def step(h, xs):
        dt_t, bx_t, c_t = xs                       # [B,di], [B,di,N], [B,N]
        decay = jnp.exp(dt_t[..., None] * a)
        h = h * decay + bx_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((b, di, n), jnp.float32)
    xs = (dt.transpose(1, 0, 2), bx.transpose(1, 0, 2, 3),
          c.transpose(1, 0, 2))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2)
