"""Fused Mamba-1 selective scan: dt·B·x computed in VMEM (v2 kernel).

The v1 kernel (selective_scan.py) consumes a precomputed bx = dt*B*x of
shape [B, T, di, N] — an N-fold HBM blowup of the activations.  This
version takes the *raw* operands (dt, x: [B,T,di]; Bmat, C: [B,T,N]) and
forms dt_t*x_t (x) B_t per step inside VMEM, so HBM traffic per chunk is
just the [chunk, block_d] activations + [chunk, N] projections + output:
~N x less than v1, ~30x less than the XLA associative-scan lowering
(7 log-passes x read+write over the materialised [B,T,di,N]).

This is the §Perf optimization for the falcon-mamba train cell; the
dry-run models its traffic with a stub (see models/layers.py) because
Pallas->TPU cannot lower on the CPU container.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _fused_kernel(dt_ref, x_ref, b_ref, c_ref, a_ref, y_ref, h_scratch, *,
                  chunk):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    a = a_ref[...]                         # [block_d, N]
    dt = dt_ref[0]                         # [chunk, block_d]
    x = x_ref[0]                           # [chunk, block_d]
    bm = b_ref[0]                          # [chunk, N]
    c = c_ref[0]                           # [chunk, N]

    def step(t, carry):
        h, ys = carry
        decay = jnp.exp(dt[t][:, None] * a)              # [block_d, N]
        bx = (dt[t] * x[t])[:, None] * bm[t][None, :]    # formed in VMEM
        h = h * decay + bx
        y_t = jnp.sum(h * c[t][None, :], axis=-1)
        ys = jax.lax.dynamic_update_index_in_dim(ys, y_t, t, 0)
        return h, ys

    h0 = h_scratch[...]
    ys0 = jnp.zeros((chunk, a.shape[0]), jnp.float32)
    h, ys = jax.lax.fori_loop(0, chunk, step, (h0, ys0))
    h_scratch[...] = h
    y_ref[0] = ys.astype(y_ref.dtype)


def selective_scan_fused(dt, x, bm, c, a, *, block_d: int = 256,
                         chunk: int = 64,
                         interpret: bool = False) -> jnp.ndarray:
    """dt/x: [B,T,di]; bm/c: [B,T,N]; a: [di,N] -> y [B,T,di] fp32."""
    b, t, di = dt.shape
    n = a.shape[-1]
    block_d = min(block_d, di)
    chunk = min(chunk, t)
    assert di % block_d == 0 and t % chunk == 0
    kernel = functools.partial(_fused_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, di // block_d, t // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, d, ci: (bi, ci, d)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, d, ci: (bi, ci, 0)),
            pl.BlockSpec((block_d, n), lambda bi, d, ci: (d, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda bi, d, ci: (bi, ci, d)),
        out_shape=jax.ShapeDtypeStruct((b, t, di), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, x, bm, c, a)
