"""Jit'd public wrapper for the selective scan kernel."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .selective_scan import selective_scan
from .ref import selective_scan_ref


def _is_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan(dt, bx, c, a, interpret: Optional[bool] = None):
    interp = (not _is_tpu()) if interpret is None else interpret
    return selective_scan(dt, bx, c, a, interpret=interp)
