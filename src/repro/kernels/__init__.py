"""Pallas TPU kernels for the perf-critical compute hot spots.

Each kernel package ships <name>.py (pl.pallas_call + BlockSpec VMEM
tiling), ops.py (jit'd public wrapper), ref.py (pure-jnp oracle); all are
validated against their oracles in interpret mode (tests/test_kernels.py).
"""
