"""Pure-jnp oracle for paged decode attention: gather pages, dense attn."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens):
    """q: [B, H, D]; pages [P, ps, KV, D]; tables [B, MP]; lens [B]."""
    bsz, h, d = q.shape
    _, ps, kvh, _ = k_pages.shape
    mp = block_tables.shape[1]
    g = h // kvh
    # gather each sequence's pages -> [B, MP*ps, KV, D]
    k = k_pages[block_tables].reshape(bsz, mp * ps, kvh, d)
    v = v_pages[block_tables].reshape(bsz, mp * ps, kvh, d)
    qr = q.reshape(bsz, kvh, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    pos = jnp.arange(mp * ps)[None, None, None, :]
    s = jnp.where(pos <= context_lens[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(bsz, h, d).astype(q.dtype)
