"""Jit'd public wrapper for paged decode attention."""
from __future__ import annotations

import functools
from typing import Optional

import jax

from .paged_attention import paged_attention_decode
from .ref import paged_attention_ref


def _is_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, block_tables, context_lens,
                    interpret: Optional[bool] = None):
    interp = (not _is_tpu()) if interpret is None else interpret
    return paged_attention_decode(q, k_pages, v_pages, block_tables,
                                  context_lens, interpret=interp)
