"""Paged decode attention for TPU (block-table indirection in the kernel).

The KV cache lives in a paged pool [num_pages, page_size, KV, D] managed by
the HHZS-style tier manager (repro.serving); each sequence owns a list of
pages via a block table.  The kernel grid is (batch, kv_head, page_slot):
page indices arrive via PrefetchScalarGridSpec so the BlockSpec index_map
can gather the right page of K/V into VMEM while the previous page computes
(the classic TPU paged-attention structure; vLLM's GPU kernel uses shared
memory + warps, here the insight maps to scalar-prefetch + VMEM tiles).

Online softmax accumulates across page slots in VMEM scratch.  Pages past a
sequence's length contribute nothing (masked); because block tables pad
with page 0, the gather stays in-bounds.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scratch, l_scratch, acc_scratch, *,
                   page_size, num_slots, scale):
    b = pl.program_id(0)
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0]                      # [G, D]
    k = k_ref[0, 0]                      # [page_size, D]
    v = v_ref[0, 0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask positions beyond the sequence length
    ctx = lens_ref[b]
    pos = si * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(pos <= ctx, s, NEG_INF)

    m_prev = m_scratch[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scratch[...] = alpha * l_scratch[...] + jnp.sum(p, axis=-1,
                                                      keepdims=True)
    acc_scratch[...] = acc_scratch[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scratch[...] = m_new

    @pl.when(si == num_slots - 1)
    def _finish():
        o_ref[0, 0] = (acc_scratch[...]
                       / jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def paged_attention_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, block_tables: jnp.ndarray,
                           context_lens: jnp.ndarray, *,
                           interpret: bool = False) -> jnp.ndarray:
    """One-token decode with paged KV.

    q: [B, H, D]; k_pages/v_pages: [P, page_size, KV, D];
    block_tables: [B, max_pages] int32 (pad with 0);
    context_lens: [B] int32 (index of the newest valid token).
    Returns [B, H, D].
    """
    bsz, h, d = q.shape
    npages, page_size, kvh, _ = k_pages.shape
    max_pages = block_tables.shape[1]
    g = h // kvh
    scale = 1.0 / np.sqrt(d)

    # [B, KV, G, D] so each (batch, kv head) program sees its G queries
    qr = q.reshape(bsz, kvh, g, d)
    # flatten pages per kv head: [KV, P, page_size, D]
    kp = k_pages.transpose(2, 0, 1, 3)
    vp = v_pages.transpose(2, 0, 1, 3)

    kernel = functools.partial(
        _decode_kernel, page_size=page_size, num_slots=max_pages,
        scale=scale)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block_tables, context_lens
        grid=(bsz, kvh, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, d),
                         lambda b, hi, si, tables, lens: (b, hi, 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, hi, si, tables, lens:
                         (hi, tables[b, si], 0, 0)),
            pl.BlockSpec((1, 1, page_size, d),
                         lambda b, hi, si, tables, lens:
                         (hi, tables[b, si], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda b, hi, si, tables, lens:
                               (b, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, kvh, g, d), q.dtype),
        interpret=interpret,
    )(block_tables, context_lens, qr, kp, vp)
    return out.reshape(bsz, h, d)
