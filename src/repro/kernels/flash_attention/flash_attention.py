"""Flash attention forward kernel for TPU (pl.pallas_call + BlockSpec).

Online-softmax tiling: the grid is (batch, kv_head, q_blocks, kv_blocks)
with the kv dimension innermost ("arbitrary" semantics); running max /
denominator / accumulator live in VMEM scratch across kv iterations.
Q blocks are [block_q, head_dim] per (batch, kv-head, group) — GQA folds
the group dim into the q-block rows so the MXU sees [block_q*G, D] tiles.
Causal + sliding-window masks are applied from block-relative positions.

Block sizes default to (block_q=256, block_k=512): at head_dim 128 the
working set is q (256·G·128·4) + k/v (2·512·128·2) + acc ≈ well under the
~16 MiB v5e VMEM budget, and all matmul dims are multiples of the 128-wide
MXU.  Validated against ``ref.py`` in interpret mode on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref,
                m_scratch, l_scratch, acc_scratch, *,
                scale, block_q, block_k, seq_len, causal, window,
                num_kv_blocks):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0, 0]                       # [block_q*G, D]
    k = k_ref[0, 0]                       # [block_k, D]
    v = v_ref[0, 0]                       # [block_k, D]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # [bq*G, bk]

    g = q.shape[0] // block_q
    q_pos = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q * g, block_k), 0) // g
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q * g, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scratch[...]               # [bq*G, 1]
    l_prev = l_scratch[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                # [bq*G, bk]
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc = acc_scratch[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scratch[...] = m_new
    l_scratch[...] = l_new
    acc_scratch[...] = acc

    @pl.when(ki == num_kv_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_scratch[...]
                       / jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        block_q: int = 256, block_k: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, S, D]; k/v: [B, KV, S, D] -> out [B, H, S, D]."""
    b, h, sq, d = q.shape
    _, kvh, skv, _ = k.shape
    g = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0
    nq, nk = sq // block_q, skv // block_k
    scale = 1.0 / np.sqrt(d)

    # fold GQA groups into q rows: [B, KV, G*S, D] with G-major blocks
    qr = q.reshape(b, kvh, g, sq, d).transpose(0, 1, 3, 2, 4) \
          .reshape(b, kvh, sq * g, d)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_len=skv, causal=causal, window=window, num_kv_blocks=nk)

    out = pl.pallas_call(
        kernel,
        grid=(b, kvh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q * g, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bi, hi, qi, ki: (bi, hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q * g, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, sq * g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, 1), jnp.float32),
            pltpu.VMEM((block_q * g, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v)
    return out.reshape(b, kvh, sq, g, d).transpose(0, 1, 3, 2, 4) \
              .reshape(b, h, sq, d)
