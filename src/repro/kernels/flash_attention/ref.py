"""Pure-jnp oracle for flash attention (dense softmax attention)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  *, causal: bool = True,
                  window: Optional[int] = None) -> jnp.ndarray:
    """q: [B, H, S, D]; k/v: [B, KV, S, D] -> [B, H, S, D]. fp32 softmax."""
    b, h, sq, d = q.shape
    kvh, skv = k.shape[1], k.shape[2]
    g = h // kvh
    qr = q.reshape(b, kvh, g, sq, d)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, h, sq, d).astype(q.dtype)
