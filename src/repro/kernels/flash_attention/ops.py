"""Jit'd public wrapper for the flash attention kernel.

On TPU this runs the Pallas kernel; everywhere else (CPU CI) it runs in
interpret mode or falls back to the jnp reference.  The backward pass is a
custom VJP that recomputes attention with the reference implementation —
numerically exact, memory-light (flash-style recompute).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_fwd
from .ref import attention_ref


def _is_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:
        return False


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, causal: bool = True,
                    window: Optional[int] = None,
                    interpret: Optional[bool] = None):
    """q: [B, H, S, D]; k/v: [B, KV, S, D] -> [B, H, S, D]."""
    interp = (not _is_tpu()) if interpret is None else interpret
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               interpret=interp)


def _fwd(q, k, v, causal, window, interpret):
    out = flash_attention(q, k, v, causal, window, interpret)
    return out, (q, k, v)


def _bwd(causal, window, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
