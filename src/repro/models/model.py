"""Model assembly: init + forward for every architecture family.

All families share one scanned-decoder skeleton.  Per-layer parameters are
stacked on a leading layer axis and consumed by ``jax.lax.scan`` so HLO size
is depth-independent.  Layer heterogeneity (hybrid archs mixing full
attention and sliding-window layers) is expressed as *data*: a per-layer
window array is passed through the scan instead of specialising the body.

Forward entry points:
  forward(params, batch)               -> logits            (train / prefill)
  decode_step(params, batch, caches)   -> logits, caches    (one token)
  init_caches(cfg, batch, max_len)     -> decode caches (KV / SSM / ring)
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig
from . import layers as L

Params = Dict


# ======================================================================
# per-layer parameter construction (stacked over layers via vmap)
# ======================================================================
def _init_layer(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 8)
    p: Params = {"attn_norm": jnp.ones((cfg.d_model,), L.DTYPE),
                 "mlp_norm": jnp.ones((cfg.d_model,), L.DTYPE)}
    if cfg.family != "ssm":
        p["attn"] = L.init_attention(ks[0], cfg)
    if cross:
        p["cross_norm"] = jnp.ones((cfg.d_model,), L.DTYPE)
        p["cross_attn"] = L.init_attention(ks[1], cfg, cross=True)
    if cfg.is_moe:
        p["moe"] = L.init_moe(ks[2], cfg)
    elif cfg.d_ff > 0:
        p["mlp"] = L.init_mlp(ks[3], cfg)
    if cfg.has_ssm:
        p["ssm_norm"] = jnp.ones((cfg.d_model,), L.DTYPE)
        p["ssm"] = L.init_mamba(ks[4], cfg)
    return p


def _stacked_layers(key, cfg: ModelConfig, n: int, cross: bool) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_layer(k, cfg, cross))(keys)


def init_params(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "embed": L._dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                               scale_axis=1),
        "final_norm": jnp.ones((cfg.d_model,), L.DTYPE),
        "layers": _stacked_layers(ks[1], cfg, cfg.num_layers,
                                  cross=cfg.encoder_layers > 0),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(ks[2], (cfg.d_model, cfg.vocab_size))
    if cfg.encoder_layers:
        enc_cfg = cfg
        p["encoder"] = {
            "layers": _stacked_layers(ks[3], enc_cfg, cfg.encoder_layers,
                                      cross=False),
            "final_norm": jnp.ones((cfg.d_model,), L.DTYPE),
        }
    return p


def param_shapes(cfg: ModelConfig) -> Params:
    """Shape tree without allocation (for the dry-run)."""
    return jax.eval_shape(
        lambda k: init_params(k, cfg), jax.random.PRNGKey(0))


# ======================================================================
# per-layer window schedule (hybrid archs)
# ======================================================================
def layer_windows(cfg: ModelConfig) -> Optional[np.ndarray]:
    """Per-layer sliding-window size; 0 = full attention.  Returned as an
    array scanned alongside the stacked params."""
    if not cfg.has_attention:
        return None
    if cfg.full_attn_layers:
        w = np.full((cfg.num_layers,), cfg.sliding_window or 0, np.int32)
        for i in cfg.full_attn_layers:
            w[i % cfg.num_layers] = 0
        return w
    if cfg.sliding_window:
        return np.full((cfg.num_layers,), cfg.sliding_window, np.int32)
    return np.zeros((cfg.num_layers,), np.int32)


def _window_or_none(w: jnp.ndarray):
    """Traced per-layer window: 0 means unbounded; encode as huge window so
    the mask computation stays uniform across scanned layers."""
    return jnp.where(w > 0, w, jnp.int32(2**30))


# ======================================================================
# decoder block (one scanned layer)
# ======================================================================
def _block(cfg: ModelConfig, x, layer: Params, positions, window,
           enc_kv=None, constraint=None):
    if cfg.family == "ssm":
        x = x + L.mamba(layer["ssm"], cfg,
                        L.rms_norm(x, layer["ssm_norm"], cfg.norm_eps))
    elif cfg.family == "hybrid":
        # parallel attention + SSM heads over the same normed input (Hymba)
        h = L.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        attn_out = L.attention(layer["attn"], cfg, h, positions,
                               window=_window_or_none(window))
        ssm_out = L.mamba(layer["ssm"], cfg,
                          L.rms_norm(x, layer["ssm_norm"], cfg.norm_eps))
        x = x + attn_out + ssm_out
    else:
        h = L.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        x = x + L.attention(layer["attn"], cfg, h, positions,
                            window=_window_or_none(window))
    if enc_kv is not None:
        h = L.rms_norm(x, layer["cross_norm"], cfg.norm_eps)
        x = x + L.cross_attention(layer["cross_attn"], cfg, h, enc_kv)
    if cfg.is_moe:
        h = L.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + _moe_dispatch(cfg, layer["moe"], h, constraint)
    elif cfg.d_ff > 0:
        h = L.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(layer["mlp"], cfg, h)
    return x


def _moe_dispatch(cfg: ModelConfig, p, h, constraint):
    """Pick the shard_map expert-parallel MoE when running on a mesh with
    sequence-sharded activations (the GSPMD scatter path replicates)."""
    mesh = getattr(constraint, "mesh", None)
    if mesh is not None and getattr(constraint, "seq_shard", False):
        from .moe_sharded import moe_shard_map
        dp = constraint.dp
        ep = mesh.shape["model"]
        b, s, _ = h.shape
        dp_size = int(np.prod([mesh.shape[a] for a in
                               (dp if isinstance(dp, tuple) else (dp,))]))
        if s % ep == 0 and b % dp_size == 0 \
                and (cfg.num_experts % ep == 0 or cfg.d_ff % ep == 0):
            return moe_shard_map(p, cfg, h, mesh, dp)
    return L.moe(p, cfg, h, constraint=constraint)


def _scan_blocks(cfg: ModelConfig, params: Params, x, positions,
                 enc_kv=None, remat: bool = True,
                 constraint=None):
    windows = layer_windows(cfg)
    windows = jnp.zeros((cfg.num_layers,), jnp.int32) if windows is None \
        else jnp.asarray(windows)

    def body(carry, xs):
        layer, window = xs
        y = _block(cfg, carry, layer, positions, window, enc_kv,
                   constraint=constraint)
        if constraint is not None:
            y = constraint(y)
        return y, ()

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = jax.lax.scan(body_fn, x, (params["layers"], windows))
    return x


# ======================================================================
# forward passes
# ======================================================================
def embed_inputs(cfg: ModelConfig, params: Params, batch: Dict):
    x = params["embed"][batch["tokens"]]
    if cfg.vision_prefix:
        # VLM stub: the first `vision_prefix` positions carry precomputed
        # patch embeddings from the (stubbed) vision frontend
        x = jax.lax.dynamic_update_slice(
            x, batch["vision_embeds"].astype(x.dtype), (0, 0, 0))
    return x


def _encode(cfg: ModelConfig, params: Params, frames):
    """Whisper-style encoder over precomputed frame embeddings (conv stub).
    Bidirectional attention (no causal mask) via full-window trick."""
    enc = params["encoder"]
    b, s, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, layer):
        h = L.rms_norm(carry, layer["attn_norm"], cfg.norm_eps)
        q, k, v = L._project_qkv(layer["attn"], cfg, h, h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        out = L.sdpa(q, k, v, cfg.num_heads // cfg.num_kv_heads,
                     causal=False)
        y = carry + out @ layer["attn"]["wo"]
        h = L.rms_norm(y, layer["mlp_norm"], cfg.norm_eps)
        y = y + L.mlp(layer["mlp"], cfg, h)
        return y, ()

    x, _ = jax.lax.scan(jax.checkpoint(body), frames.astype(L.DTYPE),
                        enc["layers"])
    return L.rms_norm(x, enc["final_norm"], cfg.norm_eps)


def encoder_kv(cfg: ModelConfig, params: Params, enc_out):
    """Precompute cross-attention K/V from encoder output.

    Uses layer 0's cross projections for all layers would be wrong — instead
    K/V are computed inside the scan from the stacked cross_attn params; this
    helper exists for the decode path where enc K/V are cached per layer."""
    def per_layer(layer):
        b, s, _ = enc_out.shape
        kv, hd = cfg.num_kv_heads, cfg.head_dim_
        k = (enc_out @ layer["cross_attn"]["wk"]).reshape(b, s, kv, hd)
        v = (enc_out @ layer["cross_attn"]["wv"]).reshape(b, s, kv, hd)
        return k, v
    return jax.vmap(per_layer)(params["layers"])     # [L, B, S, KV, D]


def forward(cfg: ModelConfig, params: Params, batch: Dict,
            remat: bool = True, constraint=None,
            return_hidden: bool = False) -> jnp.ndarray:
    """Training / prefill forward -> logits [B, S, V] (or hidden [B, S, D]
    when return_hidden=True, so the loss can chunk the vocab projection)."""
    x = embed_inputs(cfg, params, batch)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    if cfg.encoder_layers:
        enc_out = _encode(cfg, params, batch["frames"])
        # cross K/V are computed per scanned layer from stacked params
        ekv = encoder_kv(cfg, params, enc_out)

        windows = jnp.zeros((cfg.num_layers,), jnp.int32)

        def body(carry, xs):
            layer, window, (ek, ev) = xs
            y = _block(cfg, carry, layer, positions, window, enc_kv=(ek, ev),
                       constraint=constraint)
            if constraint is not None:
                y = constraint(y)
            return y, ()

        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x,
                            (params["layers"], windows, ekv))
    else:
        x = _scan_blocks(cfg, params, x, positions, remat=remat,
                         constraint=constraint)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x
    return x @ lm_head(cfg, params)


def lm_head(cfg: ModelConfig, params: Params) -> jnp.ndarray:
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


# ======================================================================
# decode (serve_step)
# ======================================================================
def init_caches(cfg: ModelConfig, batch_size: int, max_len: int) -> Dict:
    """Decode caches, ShapeDtypeStruct-compatible (built with jnp.zeros).

    Sliding-window attention uses a ring buffer of the window size — this is
    what makes mixtral/hymba long_500k decode O(window) instead of O(seq).
    """
    caches: Dict = {}
    kvl = cfg.num_kv_heads * 0 or None
    if cfg.has_attention:
        s = max_len
        if cfg.sliding_window and not cfg.full_attn_layers:
            s = min(max_len, cfg.sliding_window)
        caches["k"] = jnp.zeros(
            (cfg.num_layers, batch_size, s, cfg.num_kv_heads, cfg.head_dim_),
            L.DTYPE)
        caches["v"] = jnp.zeros_like(caches["k"])
    if cfg.has_ssm:
        caches["conv"] = jnp.zeros(
            (cfg.num_layers, batch_size, cfg.ssm_conv - 1, cfg.d_inner_),
            L.DTYPE)
        caches["ssm"] = jnp.zeros(
            (cfg.num_layers, batch_size, cfg.d_inner_, cfg.ssm_state),
            jnp.float32)
    if cfg.encoder_layers:
        caches["cross_k"] = jnp.zeros(
            (cfg.num_layers, batch_size, cfg.encoder_seq, cfg.num_kv_heads,
             cfg.head_dim_), L.DTYPE)
        caches["cross_v"] = jnp.zeros_like(caches["cross_k"])
    return caches


def decode_step(cfg: ModelConfig, params: Params, token: jnp.ndarray,
                cache_len: jnp.ndarray, caches: Dict) -> Tuple:
    """One decode step: token [B,1] int32, cache_len [B] -> (logits, caches).

    Scans over layers carrying the per-layer cache slices.
    """
    x = params["embed"][token]
    windows = layer_windows(cfg)
    windows = jnp.zeros((cfg.num_layers,), jnp.int32) if windows is None \
        else jnp.asarray(windows)

    def body(carry, xs):
        layer, window, cache = xs
        y, new_cache = _decode_block(cfg, carry, layer, window, cache,
                                     cache_len)
        return y, new_cache

    per_layer_caches = {k: v for k, v in caches.items()}
    x, new_caches = jax.lax.scan(
        body, x, (params["layers"], windows, per_layer_caches))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head, new_caches


def _decode_block(cfg: ModelConfig, x, layer, window, cache, cache_len):
    new_cache = dict(cache)
    if cfg.family == "ssm":
        h = L.rms_norm(x, layer["ssm_norm"], cfg.norm_eps)
        y, conv, ssm = L.mamba_decode(layer["ssm"], cfg, h,
                                      cache["conv"], cache["ssm"])
        new_cache["conv"], new_cache["ssm"] = conv, ssm
        x = x + y
    elif cfg.family == "hybrid":
        h = L.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        attn_out, kc, vc = L.attention_decode(
            layer["attn"], cfg, h, cache["k"], cache["v"], cache_len,
            window=_window_or_none(window))
        h2 = L.rms_norm(x, layer["ssm_norm"], cfg.norm_eps)
        ssm_out, conv, ssm = L.mamba_decode(layer["ssm"], cfg, h2,
                                            cache["conv"], cache["ssm"])
        new_cache.update(k=kc, v=vc, conv=conv, ssm=ssm)
        x = x + attn_out + ssm_out
    else:
        h = L.rms_norm(x, layer["attn_norm"], cfg.norm_eps)
        out, kc, vc = L.attention_decode(
            layer["attn"], cfg, h, cache["k"], cache["v"], cache_len,
            window=_window_or_none(window))
        new_cache.update(k=kc, v=vc)
        x = x + out
    if "cross_k" in cache:
        h = L.rms_norm(x, layer["cross_norm"], cfg.norm_eps)
        x = x + L.cross_attention(layer["cross_attn"], cfg, h,
                                  (cache["cross_k"], cache["cross_v"]))
    if cfg.is_moe:
        h = L.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + L.moe(layer["moe"], cfg, h)
    elif cfg.d_ff > 0:
        h = L.rms_norm(x, layer["mlp_norm"], cfg.norm_eps)
        x = x + L.mlp(layer["mlp"], cfg, h)
    return x, new_cache
