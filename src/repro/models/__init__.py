from . import layers, model, steps
from .model import init_params, param_shapes, forward, decode_step, init_caches
from .steps import (make_train_step, make_prefill_step, make_serve_step,
                    make_loss_fn, init_state, state_shapes)

__all__ = [
    "layers", "model", "steps",
    "init_params", "param_shapes", "forward", "decode_step", "init_caches",
    "make_train_step", "make_prefill_step", "make_serve_step",
    "make_loss_fn", "init_state", "state_shapes",
]
