"""Production MoE: shard_map dispatch with explicit expert-parallel a2a.

GSPMD cannot partition the dispatch scatter into an expert-sharded buffer
(it falls back to full-shape masked ops — 4 GiB u32 index tensors per
layer).  Real MoE frameworks hand-write this exchange; so do we:

EP path (num_experts % model_axis == 0):
  1. per device: local top-k + scatter into [E, C_src, d]  (local, clean)
  2. all_to_all over "model": split E, concat source shards
     -> [E/ep, ep*C_src, d]
  3. grouped GEMM with the local expert shard (weights FSDP-gathered
     over "data" inside the shard_map)
  4. all_to_all back + local combine.

TP fallback (E not divisible, e.g. mixtral's 8 experts on a 16-wide axis):
  every device runs all experts on its (batch x seq)-shard with
  d_ff-sharded weights; the down-projection psums over "model".

Activations enter and leave sequence-sharded P(dp, "model", None) — each
device dispatches only its seq shard, so dispatch buffers stay
O(T_local * k * d).  Capacity is per (expert, source shard), the standard
deployment semantics.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..config import ModelConfig


def _topk_dispatch(x, router, k: int, e: int, cap: int):
    """x: [T, d] -> buf [E, cap, d], (pos, keep, top_w, top_e)."""
    t, d = x.shape
    logits = x.astype(jnp.float32) @ router
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    flat_e = top_e.reshape(-1)                       # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < cap
    x_rep = jnp.broadcast_to(x[:, None], (t, k, d)).reshape(t * k, d)
    buf = jnp.zeros((e, cap, d), x.dtype)
    safe_pos = jnp.where(keep, pos, cap)
    buf = buf.at[flat_e, safe_pos].set(x_rep, mode="drop")
    return buf, flat_e, pos, keep, top_w


def _combine(out_rows, flat_e, pos, keep, top_w, cap: int, t: int, k: int):
    """out_rows: [E*cap, d] flattened expert outputs -> [T, d]."""
    idx = flat_e * cap + jnp.minimum(pos, cap - 1)
    gathered = out_rows[idx]                         # [T*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    w = top_w.reshape(-1)[:, None].astype(gathered.dtype)
    # tok_idx is repeat(arange(t), k): combine is a reshape + sum, no scatter
    return jnp.sum((gathered * w).reshape(t, k, -1), axis=1)


def moe_shard_map(p, cfg: ModelConfig, x: jnp.ndarray, mesh: Mesh,
                  dp) -> jnp.ndarray:
    """x: [B, S, d] sharded P(dp, "model", None). Returns same sharding."""
    e, k = cfg.num_experts, cfg.top_k
    ep = mesh.shape["model"]
    b, s, d = x.shape
    dp_size = 1
    for a in (dp if isinstance(dp, tuple) else (dp,)):
        dp_size *= mesh.shape[a]
    t_loc = (b // dp_size) * (s // ep)
    cap = max(int(cfg.capacity_factor * t_loc * k / e), 1)
    expert_parallel = (e % ep == 0)

    wspecs = {
        "router": P("data", None),
        "we_gate": P("model", "data", None) if expert_parallel
        else P(None, "data", "model"),
        "we_up": P("model", "data", None) if expert_parallel
        else P(None, "data", "model"),
        "we_down": P("model", None, "data") if expert_parallel
        else P(None, "model", "data"),
    }
    x_spec = P(dp, "model", None)

    def ep_body(xl, router, wg, wu, wd):
        # xl: [B_loc, S_loc, d]; wg: [E/ep, d/dp, f]
        router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        bl, sl, _ = xl.shape
        xf = xl.reshape(bl * sl, d)
        buf, flat_e, pos, keep, top_w = _topk_dispatch(xf, router, k, e, cap)
        # exchange: rows to their expert's shard
        buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                                 tiled=True)          # [E/ep, ep*cap, d]
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wu)
        out = jnp.einsum("ecf,efd->ecd", h, wd)       # [E/ep, ep*cap, d]
        out = jax.lax.all_to_all(out, "model", split_axis=1, concat_axis=0,
                                 tiled=True)          # [E, cap, d]
        y = _combine(out.reshape(e * cap, d), flat_e, pos, keep, top_w,
                     cap, bl * sl, k)
        return y.reshape(bl, sl, d).astype(xl.dtype)

    def tp_body(xl, router, wg, wu, wd):
        # xl: [B_loc, S_loc, d] seq-sharded; wg: [E, d/dp, f/ep].
        # With f TP-sharded, every model shard must see the SAME tokens:
        # gather the sequence, run all experts on the full local batch with
        # the f-shard, and psum_scatter the partial outputs back onto the
        # sequence sharding (Megatron-style MoE tensor parallelism).
        router = jax.lax.all_gather(router, "data", axis=0, tiled=True)
        wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
        wu = jax.lax.all_gather(wu, "data", axis=1, tiled=True)
        wd = jax.lax.all_gather(wd, "data", axis=2, tiled=True)
        x_full = jax.lax.all_gather(xl, "model", axis=1, tiled=True)
        bl, s_full, _ = x_full.shape
        t_full = bl * s_full
        cap_tp = max(int(cfg.capacity_factor * t_full * k / e), 1)
        xf = x_full.reshape(t_full, d)
        buf, flat_e, pos, keep, top_w = _topk_dispatch(xf, router, k, e,
                                                       cap_tp)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg)) \
            * jnp.einsum("ecd,edf->ecf", buf, wu)     # [E, cap, f/ep]
        out = jnp.einsum("ecf,efd->ecd", h, wd)       # partial over f
        y = _combine(out.reshape(e * cap_tp, d), flat_e, pos, keep, top_w,
                     cap_tp, t_full, k)               # [T, d] partial
        y = y.reshape(bl, s_full, d)
        y = jax.lax.psum_scatter(y, "model", scatter_dimension=1,
                                 tiled=True)          # summed + seq-sharded
        return y.astype(xl.dtype)

    body = ep_body if expert_parallel else tp_body
    fn = shard_map(body, mesh=mesh,
                   in_specs=(x_spec, wspecs["router"], wspecs["we_gate"],
                             wspecs["we_up"], wspecs["we_down"]),
                   out_specs=x_spec,
                   check_rep=False)
    return fn(x, p["router"], p["we_gate"], p["we_up"], p["we_down"])
