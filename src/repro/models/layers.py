"""Model building blocks, written as pure functions over param pytrees.

Everything is initialised with explicit shapes so the whole model can be
``jax.eval_shape``-d for the multi-pod dry-run without allocating memory.
Layer parameters are *stacked* along a leading layer axis and consumed by
``jax.lax.scan`` (keeps HLO size O(1) in depth — essential for compiling
88-layer configs with 512 partitions).

Covers: RMSNorm/qk-norm, RoPE, GQA attention (bias / sliding window /
cross-attention), SwiGLU & GELU MLPs, top-k MoE with scatter-based dispatch
(EP-shardable grouped GEMM), and Mamba-1 with a chunked selective scan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..config import ModelConfig

Params = Dict[str, jnp.ndarray]
DTYPE = jnp.bfloat16

# §Perf kernel-substitution switches (set by benchmarks/perf_lab.py only).
# When a Pallas kernel replaces an XLA region on real TPUs, its HBM traffic
# is inputs+outputs once (intermediates live in VMEM).  The CPU container
# cannot lower Pallas, so the dry-run models kernel cells with
# traffic-equivalent elementwise stand-ins; the kernels' numerics are
# validated separately in interpret mode (tests/test_kernels.py) and the
# removed FLOPs are added back analytically in EXPERIMENTS.md §Perf.
STUB_KERNELS = {"attention": False, "ssm": False}


# ======================================================================
# initialisation helpers
# ======================================================================
def _dense_init(key, shape, scale_axis=0):
    fan_in = shape[scale_axis] if shape else 1
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(DTYPE)


def _zeros(shape):
    return jnp.zeros(shape, dtype=DTYPE)


# ======================================================================
# norms / rope
# ======================================================================
def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [B, S, H, D]; positions: [B, S] int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ======================================================================
# attention
# ======================================================================
def init_attention(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, hd = cfg.d_model, cfg.head_dim_
    h, kv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, h * hd)),
        "wk": _dense_init(ks[1], (d, kv * hd)),
        "wv": _dense_init(ks[2], (d, kv * hd)),
        "wo": _dense_init(ks[3], (h * hd, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = _zeros((h * hd,))
        p["bk"] = _zeros((kv * hd,))
        p["bv"] = _zeros((kv * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), DTYPE)
        p["k_norm"] = jnp.ones((hd,), DTYPE)
    return p


def _project_qkv(p: Params, cfg: ModelConfig, xq: jnp.ndarray,
                 xkv: jnp.ndarray):
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*xq.shape[:-1], h, hd)
    k = k.reshape(*xkv.shape[:-1], kv, hd)
    v = v.reshape(*xkv.shape[:-1], kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _pick_block(s: int, target: int = 1024) -> int:
    """Largest divisor of s that is <= target."""
    if s <= target:
        return s
    for b in range(target, 0, -1):
        if s % b == 0:
            return b
    return s


def sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
         num_kv_groups: int, *, causal: bool,
         window: Optional[jnp.ndarray] = None,
         q_offset: int = 0, q_block: int = 1024) -> jnp.ndarray:
    """Grouped-query attention, blocked over query chunks.

    q: [B, Sq, H, D]; k/v: [B, Skv, KV, D].  Scores are materialised one
    query block at a time (lax.scan) — O(Sq_block x Skv) live memory instead
    of O(Sq x Skv); the same blocking the Pallas flash kernel uses in VMEM.
    Softmax in fp32.  ``window`` may be a traced scalar (per-layer SWA).
    """
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = num_kv_groups
    if STUB_KERNELS["attention"]:
        # flash-kernel traffic model: read q,k,v once, write o once
        o = q + jnp.mean(k, axis=2, keepdims=True) \
            + jnp.mean(v, axis=2, keepdims=True)
        return o.reshape(b, sq, h * d)
    qb = _pick_block(sq)
    nb = sq // qb
    q = q.reshape(b, nb, qb, kvh, g, d).transpose(1, 0, 2, 3, 4, 5)
    kpos = jnp.arange(skv)[None, :]                  # [1, Skv]

    def block(carry, xs):
        qblk, blk_idx = xs                           # [B, qb, KV, G, D]
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, k,
                            preferred_element_type=jnp.float32)
        scores = scores / np.sqrt(d)
        if causal or window is not None:
            qpos = (blk_idx * qb + jnp.arange(qb))[:, None] + q_offset
            m = jnp.ones((qb, skv), bool)
            if causal:
                m &= kpos <= qpos
            if window is not None:
                m &= kpos > qpos - window
            scores = jnp.where(m[None, None, None], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
        return carry, out

    # recompute scores in the backward pass (flash-attention-style): without
    # this, scan stacks per-block fp32 score residuals = the full S x S matrix
    _, outs = jax.lax.scan(jax.checkpoint(block), None, (q, jnp.arange(nb)))
    outs = outs.transpose(1, 0, 2, 3, 4, 5)          # [B, nb, qb, KV, G, D]
    return outs.reshape(b, sq, h * d)


def attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
              positions: jnp.ndarray,
              window: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full self-attention over a training/prefill sequence (causal)."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = sdpa(q, k, v, cfg.num_heads // cfg.num_kv_heads,
               causal=True, window=window)
    return out @ p["wo"]


def cross_attention(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                    enc_kv: Tuple[jnp.ndarray, jnp.ndarray]) -> jnp.ndarray:
    """Decoder cross-attention against precomputed encoder K/V."""
    b, s, _ = x.shape
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    k, v = enc_kv
    out = sdpa(q, k, v, h // kv, causal=False)
    return out @ p["wo"]


def attention_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray,
                     window: Optional[jnp.ndarray] = None):
    """One-token decode against a dense KV cache.

    x: [B, 1, d]; k_cache/v_cache: [B, S, KV, D]; cache_len: [B] current
    lengths (new token goes to position cache_len).  Returns
    (out [B, 1, d], k_cache, v_cache) with the caches updated in place
    (functionally) — sliding-window archs pass ring-buffer-sized caches and
    position `cache_len % S`.
    """
    b, _, _ = x.shape
    s_max = k_cache.shape[1]
    q, k, v = _project_qkv(p, cfg, x, x)
    q = apply_rope(q, cache_len[:, None], cfg.rope_theta)
    k = apply_rope(k, cache_len[:, None], cfg.rope_theta)
    slot = (cache_len % s_max).astype(jnp.int32)
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, slot].set(k[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, slot].set(v[:, 0].astype(v_cache.dtype))
    kpos = jnp.arange(s_max)[None, :]
    valid = kpos <= jnp.minimum(cache_len[:, None], s_max - 1)
    if window is not None:
        # ring buffer: everything still resident is within the window
        valid = valid & (kpos > cache_len[:, None] - s_max)
    # single-query attention against the cache (no blocking needed)
    g = cfg.num_heads // cfg.num_kv_heads
    kvh, hd = cfg.num_kv_heads, cfg.head_dim_
    qr = q.reshape(b, kvh, g, hd)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qr, k_cache.astype(q.dtype),
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(q.dtype),
                     v_cache.astype(q.dtype))
    out = out.reshape(b, 1, cfg.num_heads * hd)
    return out @ p["wo"], k_cache, v_cache


# ======================================================================
# MLP / MoE
# ======================================================================
def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.act == "gelu":
        return {"wi": _dense_init(ks[0], (d, f)),
                "wo": _dense_init(ks[1], (f, d))}
    return {"w_gate": _dense_init(ks[0], (d, f)),
            "w_up": _dense_init(ks[1], (d, f)),
            "w_down": _dense_init(ks[2], (f, d))}


def mlp(p: Params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if "wi" in p:
        return jax.nn.gelu(x @ p["wi"]) @ p["wo"]
    return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]


def init_moe(key, cfg: ModelConfig) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    return {
        "router": _dense_init(ks[0], (d, e)).astype(jnp.float32),
        "we_gate": _dense_init(ks[1], (e, d, f), scale_axis=1),
        "we_up": _dense_init(ks[2], (e, d, f), scale_axis=1),
        "we_down": _dense_init(ks[3], (e, f, d), scale_axis=1),
    }


def moe(p: Params, cfg: ModelConfig, x: jnp.ndarray,
        constraint=None) -> jnp.ndarray:
    """Top-k MoE with scatter-based dispatch into per-expert buffers.

    Dispatch runs *per batch row* (vmap) so the global-batch dim stays
    data-parallel under GSPMD; expert buffers [B, E, C, d] run as one
    grouped GEMM einsum whose E axis shards for expert parallelism (weights
    carry the "model"-axis sharding).  Capacity C = S·k/E·factor per row,
    overflow drops (GShard-style).  Memory stays O(B·S·k·d) — no
    [T, E, C] one-hot dispatch tensors.
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    cap = max(int(cfg.capacity_factor * s * k / e), 1)

    logits = (x.astype(jnp.float32) @ p["router"])           # [B, S, E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)                   # [B, S, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    def dispatch_row(xr, er):
        """xr: [S, d], er: [S, k] -> buf [E, C, d], pos [S*k], keep [S*k]."""
        flat_e = er.reshape(-1)                              # [S*k]
        onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        keep = pos < cap
        buf = jnp.zeros((e, cap, d), dtype=xr.dtype)
        tok_idx = jnp.repeat(jnp.arange(s), k)
        safe_pos = jnp.where(keep, pos, cap)                 # OOB -> dropped
        buf = buf.at[flat_e, safe_pos].set(xr[tok_idx], mode="drop")
        return buf, pos, keep

    buf, pos, keep = jax.vmap(dispatch_row)(x, top_e)        # [B, E, C, d]
    if constraint is not None:
        buf = constraint(buf, "moe_buf")
    # grouped expert GEMMs (EP: shard over E; data-parallel over B)
    h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, p["we_gate"])) \
        * jnp.einsum("becd,edf->becf", buf, p["we_up"])
    if constraint is not None:
        h = constraint(h, "moe_h")
    out_buf = jnp.einsum("becf,efd->becd", h, p["we_down"])
    if constraint is not None:
        out_buf = constraint(out_buf, "moe_buf")

    def combine_row(ob, er, posr, keepr, wr):
        flat_e = er.reshape(-1)
        gathered = ob[flat_e, jnp.minimum(posr, cap - 1)]    # [S*k, d]
        gathered = jnp.where(keepr[:, None], gathered, 0.0)
        w = wr.reshape(-1)[:, None].astype(gathered.dtype)
        out = jnp.zeros((s, d), dtype=gathered.dtype)
        tok_idx = jnp.repeat(jnp.arange(s), k)
        return out.at[tok_idx].add(gathered * w)

    out = jax.vmap(combine_row)(out_buf, top_e, pos, keep, top_w)
    return out.reshape(b, s, d)


# ======================================================================
# Mamba-1 (selective state space)
# ======================================================================
def init_mamba(key, cfg: ModelConfig) -> Params:
    d, di = cfg.d_model, cfg.d_inner_
    n, rk, kc = cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": _dense_init(ks[0], (d, 2 * di)),
        "conv_w": _dense_init(ks[1], (kc, di)),
        "conv_b": _zeros((di,)),
        "x_proj": _dense_init(ks[2], (di, rk + 2 * n)),
        "dt_proj": _dense_init(ks[3], (rk, di)),
        "dt_bias": _zeros((di,)),
        "A_log": jnp.log(a),                        # fp32 [di, N]
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": _dense_init(ks[4], (di, d)),
    }


def _ssm_scan_chunked(dt, a, bx, c, chunk: int):
    """Selective scan via lax.scan over chunks + associative scan inside.

    dt: [B,T,di]  (softplus'd delta)      a: [di,N]  (negative, fp32)
    bx: [B,T,di,N] (dt * B * x)           c: [B,T,N]
    Returns y: [B,T,di].  Chunking keeps the [B,chunk,di,N] intermediate
    bounded — the same blocking strategy the Pallas kernel uses in VMEM.
    """
    bsz, t, di = dt.shape
    n = a.shape[-1]
    nchunk = t // chunk
    dt_c = dt.reshape(bsz, nchunk, chunk, di).transpose(1, 0, 2, 3)
    bx_c = bx.reshape(bsz, nchunk, chunk, di, n).transpose(1, 0, 2, 3, 4)
    c_c = c.reshape(bsz, nchunk, chunk, n).transpose(1, 0, 2, 3)

    def chunk_step(h0, xs):
        dtk, bxk, ck = xs                       # [B,chunk,di], [B,chunk,di,N]
        decay = jnp.exp(dtk[..., None] * a)     # [B,chunk,di,N]
        # associative scan: (decay, add) pairs compose left-to-right
        def combine(l, r):
            dl, xl = l
            dr, xr = r
            return dl * dr, xl * dr + xr
        dprod, hs = jax.lax.associative_scan(
            combine, (decay, bxk), axis=1)
        hs = hs + dprod * h0[:, None]           # fold in carry state
        y = jnp.einsum("bldn,bln->bld", hs, ck)
        return hs[:, -1], y

    h0 = jnp.zeros((bsz, di, n), jnp.float32)
    _, ys = jax.lax.scan(jax.checkpoint(chunk_step), h0, (dt_c, bx_c, c_c))
    return ys.transpose(1, 0, 2, 3).reshape(bsz, t, di)


def mamba(p: Params, cfg: ModelConfig, x: jnp.ndarray,
          chunk: int = 128) -> jnp.ndarray:
    """Mamba-1 block over a full sequence (training / prefill)."""
    bsz, t, _ = x.shape
    di, n = cfg.d_inner_, cfg.ssm_state
    rk = cfg.dt_rank
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)           # [B,T,di] each
    # causal depthwise conv, kernel ssm_conv
    kc = cfg.ssm_conv
    xpad = jnp.pad(xs, ((0, 0), (kc - 1, 0), (0, 0)))
    xs = sum(xpad[:, i:i + t] * p["conv_w"][i] for i in range(kc))
    xs = jax.nn.silu(xs + p["conv_b"])
    proj = xs @ p["x_proj"]                     # [B,T,rk+2N]
    dt_in, b_in, c_in = jnp.split(proj, [rk, rk + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                    # [di,N]
    if STUB_KERNELS["ssm"]:
        # fused-scan-kernel traffic model: read dt/x/B/C once, write y once
        # (kernels/selective_scan/fused.py forms dt*B*x in VMEM)
        y = dt * xs.astype(jnp.float32) \
            * (jnp.sum(c_in, -1, keepdims=True)
               + jnp.sum(b_in, -1, keepdims=True)).astype(jnp.float32) \
            + jnp.sum(a) * 0.0
    else:
        bx = dt[..., None] * b_in[:, :, None, :].astype(jnp.float32) \
            * xs[..., None].astype(jnp.float32)     # [B,T,di,N]
        chunk = min(chunk, t)
        while t % chunk:
            chunk -= 1
        y = _ssm_scan_chunked(dt, a, bx, c_in.astype(jnp.float32), chunk)
    y = y + xs.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_decode(p: Params, cfg: ModelConfig, x: jnp.ndarray,
                 conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token Mamba step.

    x: [B,1,d]; conv_state: [B, kc-1, di]; ssm_state: [B, di, N] (fp32).
    Returns (y [B,1,d], conv_state, ssm_state).
    """
    bsz = x.shape[0]
    di, n, rk, kc = cfg.d_inner_, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    xz = x[:, 0] @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)           # [B,di]
    window = jnp.concatenate([conv_state, xs[:, None]], axis=1)  # [B,kc,di]
    conv_state = window[:, 1:]
    xs = jnp.einsum("bkd,kd->bd", window, p["conv_w"])
    xs = jax.nn.silu(xs + p["conv_b"])
    proj = xs @ p["x_proj"]
    dt_in, b_in, c_in = jnp.split(proj, [rk, rk + n], axis=-1)
    dt = jax.nn.softplus(
        (dt_in @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])  # [B,di]
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt[..., None] * a)          # [B,di,N]
    bx = dt[..., None] * b_in[:, None, :].astype(jnp.float32) \
        * xs[..., None].astype(jnp.float32)
    ssm_state = ssm_state * decay + bx
    y = jnp.einsum("bdn,bn->bd", ssm_state, c_in.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return (y @ p["out_proj"])[:, None], conv_state, ssm_state
