"""Train / prefill / decode step functions (the units the dry-run lowers).

``make_train_step`` returns a pure function
    (state, batch) -> (state, metrics)
with remat'd scanned layers, global-norm clipping and AdamW.  Optional
gradient accumulation scans over microbatches.  ``make_serve_step`` returns
the single-token decode step against dense caches (ring-buffer caches for
pure-SWA archs).  ``make_prefill_step`` is the no-grad forward.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..config import ModelConfig, ParallelConfig, TrainConfig
from ..optim import adamw
from . import model as M


def _pick_chunks(s: int, target: int = 512) -> int:
    if s <= target:
        return s
    for c in range(target, 0, -1):
        if s % c == 0:
            return c
    return s


def make_loss_fn(cfg: ModelConfig, parallel: ParallelConfig,
                 constraint=None):
    """Next-token CE with the vocab projection chunked over the sequence —
    the full [B, S, V] fp32 logits tensor never materialises."""
    def loss_fn(params, batch):
        hidden = M.forward(cfg, params, batch, remat=parallel.remat,
                           constraint=constraint, return_hidden=True)
        head = M.lm_head(cfg, params)
        targets = batch["targets"]
        b, s, d = hidden.shape
        c = _pick_chunks(s)
        nb = s // c
        h_c = hidden.reshape(b, nb, c, d).transpose(1, 0, 2, 3)
        t_c = targets.reshape(b, nb, c).transpose(1, 0, 2)

        def chunk(acc, xs):
            h, t = xs
            logits = (h @ head).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
            return acc + jnp.sum(logz - gold), ()

        total, _ = jax.lax.scan(jax.checkpoint(chunk),
                                jnp.zeros((), jnp.float32), (h_c, t_c))
        return total / float(b * s)
    return loss_fn


def make_train_step(cfg: ModelConfig, tc: TrainConfig,
                    parallel: ParallelConfig, constraint=None):
    loss_fn = make_loss_fn(cfg, parallel, constraint)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params, opt = state["params"], state["opt"]
        if parallel.grad_accum > 1:
            n = parallel.grad_accum

            def micro(acc, mb):
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc,
                                   jax.tree.map(
                                       lambda g: g.astype(jnp.float32) / n,
                                       grads))
                return acc, loss

            micro_batches = jax.tree.map(
                lambda x: x.reshape(n, x.shape[0] // n, *x.shape[1:]), batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, losses = jax.lax.scan(micro, zeros, micro_batches)
            loss = jnp.mean(losses)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_params, new_opt, om = adamw.update(grads, opt, tc)
        metrics = {"loss": loss, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, parallel: ParallelConfig,
                      constraint=None):
    def prefill_step(params, batch):
        # inference forward — remat off (no backward pass to feed); only the
        # final position needs the vocab projection
        hidden = M.forward(cfg, params, batch, remat=False,
                           constraint=constraint, return_hidden=True)
        return hidden[:, -1, :] @ M.lm_head(cfg, params)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, cache_len, caches):
        logits, caches = M.decode_step(cfg, params, token, cache_len, caches)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, caches
    return serve_step


def init_state(key, cfg: ModelConfig) -> Dict:
    params = M.init_params(key, cfg)
    return {"params": params, "opt": adamw.init(params)}


def state_shapes(cfg: ModelConfig) -> Dict:
    return jax.eval_shape(lambda k: init_state(k, cfg),
                          jax.random.PRNGKey(0))
