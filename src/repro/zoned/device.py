"""Zoned storage devices with the paper's timing model (Table 1).

A ``ZonedDevice`` exposes the zoned interface of §2.1: fixed-capacity
append-only zones with a write pointer, explicit reset, sequential writes
only.  Service times come from a calibrated model:

  sequential I/O : per-request submission overhead + bytes / bandwidth
  random read    : 1/IOPS for the first 4 KiB (seek + transfer, calibrated
                   against the measured fio IOPS) + remaining bytes / bandwidth

Devices are FIFO resources: an I/O submitted while the device is busy queues
behind earlier I/O — this is what creates the foreground/background
interference the paper measures in Exp#6.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from .sim import Sim

MiB = float(1 << 20)
KiB = float(1 << 10)


@dataclass(frozen=True)
class DeviceTiming:
    """Calibrated against Table 1 of the paper."""

    seq_read_bw: float    # bytes/s
    seq_write_bw: float   # bytes/s
    rand_read_iops: float  # 4 KiB random read IOPS
    seq_overhead: float   # per-request submission overhead, seconds

    @property
    def rand_read_base(self) -> float:
        """Service time of a 4 KiB random read."""
        return 1.0 / self.rand_read_iops


# Table 1: WD Ultrastar DC ZN540 (ZNS SSD), Seagate ST14000NM0007 (HM-SMR HDD)
ZN540_SSD = DeviceTiming(
    seq_read_bw=1039.6 * MiB,
    seq_write_bw=1002.8 * MiB,
    rand_read_iops=16928.3,
    seq_overhead=10e-6,
)
ST14000_HDD = DeviceTiming(
    seq_read_bw=210.0 * MiB,
    seq_write_bw=210.0 * MiB,
    rand_read_iops=115.0,
    seq_overhead=100e-6,
)


class ZoneState(enum.Enum):
    EMPTY = "empty"
    OPEN = "open"
    FULL = "full"


@dataclass
class Zone:
    zid: int
    capacity: int                  # writable zone capacity, bytes
    write_ptr: int = 0
    state: ZoneState = ZoneState.EMPTY
    owner: Optional[str] = None    # free-form tag: "wal", "cache", "sst:<id>"

    @property
    def remaining(self) -> int:
        return self.capacity - self.write_ptr


@dataclass
class TrafficCounters:
    read_bytes: float = 0.0
    write_bytes: float = 0.0
    read_ops: int = 0
    write_ops: int = 0
    busy_time: float = 0.0
    by_tag_write: Dict[str, float] = field(default_factory=dict)
    by_tag_read: Dict[str, float] = field(default_factory=dict)


class ZonedDevice:
    """Append-only zoned device + FIFO service queue in virtual time."""

    def __init__(self, sim: Sim, name: str, timing: DeviceTiming,
                 num_zones: int, zone_capacity: int, batched: bool = True):
        self.sim = sim
        self.name = name
        self.timing = timing
        self.zone_capacity = zone_capacity
        self.zones: List[Zone] = [Zone(zid=i, capacity=zone_capacity)
                                  for i in range(num_zones)]
        self._busy_until = 0.0
        self._bg_busy_until = 0.0
        # batched completion path: each FIFO track completes I/O in
        # nondecreasing time, so completions ride a per-track
        # MonotoneQueue (O(1) schedule, one heap entry per track) instead
        # of one heap timeout per request.  ``batched=False`` keeps the
        # per-request heap path — bit-identical virtual times, used by the
        # differential test in tests/test_zoned.py.
        self._fg_q = sim.monotone_queue() if batched else None
        self._bg_q = sim.monotone_queue() if batched else None
        # fault-injection hooks (repro.zoned.faults): while sim.now is
        # before _slow_until, service times are scaled by _slow_factor
        self._slow_until = 0.0
        self._slow_factor = 1.0
        self.counters = TrafficCounters()
        self.resets = 0

    # ------------------------------------------------------------------
    # zone management (the zoned interface)
    # ------------------------------------------------------------------
    def empty_zones(self) -> List[Zone]:
        return [z for z in self.zones if z.state == ZoneState.EMPTY]

    def num_empty(self) -> int:
        return sum(1 for z in self.zones if z.state == ZoneState.EMPTY)

    def alloc_zone(self, owner: str) -> Zone:
        for z in self.zones:
            if z.state == ZoneState.EMPTY:
                z.state = ZoneState.OPEN
                z.owner = owner
                return z
        raise RuntimeError(f"{self.name}: no empty zone for {owner!r}")

    def reset_zone(self, zone: Zone) -> None:
        """Reset: write pointer back to start; all data in the zone is gone."""
        zone.write_ptr = 0
        zone.state = ZoneState.EMPTY
        zone.owner = None
        self.resets += 1

    def finish_zone(self, zone: Zone) -> None:
        zone.state = ZoneState.FULL

    # ------------------------------------------------------------------
    # timed I/O
    # ------------------------------------------------------------------
    def _service_time(self, nbytes: float, kind: str) -> float:
        t = self.timing
        if kind == "seq_read":
            return t.seq_overhead + nbytes / t.seq_read_bw
        if kind == "seq_write":
            return t.seq_overhead + nbytes / t.seq_write_bw
        if kind == "rand_read":
            extra = max(0.0, nbytes - 4 * KiB)
            return t.rand_read_base + extra / t.seq_read_bw
        raise ValueError(kind)

    def io(self, nbytes: float, kind: str, tag: str = "",
           background: bool = False):
        """Submit an I/O; returns a completion the caller ``yield``-s.

        On the batched path this is a :class:`~repro.zoned.sim.MonotoneQueue`
        completion ticket (no Event allocated); with ``batched=False`` (or
        after a mid-crash ``restart()`` broke the track's monotonicity) it
        is a real Event scheduled at the same absolute completion time.
        Either way a process just ``yield``-s it.

        Foreground I/O queues FIFO.  Background I/O (rate-limited migration,
        cache-zone fills) models the drive's internal scheduler merging it
        into the stream: it completes on its own background track but still
        consumes device capacity — foreground feels it as added busy time.
        """
        service = self._service_time(nbytes, kind)
        if self.sim.now < self._slow_until:
            service *= self._slow_factor
        if background:
            start = max(self.sim.now, self._bg_busy_until)
            end = start + service
            self._bg_busy_until = end
            # capacity interference: foreground queue grows by the same work
            self._busy_until = max(self._busy_until, self.sim.now) + service
            q = self._bg_q
        else:
            start = max(self.sim.now, self._busy_until)
            end = start + service
            self._busy_until = end
            q = self._fg_q
        c = self.counters
        c.busy_time += service
        if kind.endswith("read"):
            c.read_bytes += nbytes
            c.read_ops += 1
            if tag:
                c.by_tag_read[tag] = c.by_tag_read.get(tag, 0.0) + nbytes
        else:
            c.write_bytes += nbytes
            c.write_ops += 1
            if tag:
                c.by_tag_write[tag] = c.by_tag_write.get(tag, 0.0) + nbytes
        if q is not None:
            return q.complete_at(end)
        return self.sim.schedule_at(end)

    def append(self, zone: Zone, nbytes: int, tag: str = "",
               background: bool = False):
        """Sequential append at the zone's write pointer (§2.1)."""
        if zone.state == ZoneState.FULL:
            raise RuntimeError(f"{self.name}: append to FULL zone {zone.zid}")
        if zone.state == ZoneState.EMPTY:
            zone.state = ZoneState.OPEN
        if nbytes > zone.remaining:
            raise RuntimeError(
                f"{self.name}: append {nbytes}B > remaining {zone.remaining}B "
                f"in zone {zone.zid}")
        zone.write_ptr += nbytes
        if zone.remaining == 0:
            zone.state = ZoneState.FULL
        return self.io(nbytes, "seq_write", tag=tag, background=background)

    def read(self, nbytes: float, random: bool, tag: str = "",
             background: bool = False):
        return self.io(nbytes, "rand_read" if random else "seq_read",
                       tag=tag, background=background)

    # ------------------------------------------------------------------
    # fault hooks (repro.zoned.faults)
    # ------------------------------------------------------------------
    def stall(self, duration: float) -> None:
        """Freeze the device for new work: every I/O *submitted* from now
        until the window ends queues behind it (models internal GC /
        firmware hiccups).  I/O already submitted keeps its precomputed
        completion time — the FIFO model schedules completions at submit,
        so an in-flight request is treated as already past the point the
        stall can affect."""
        end = self.sim.now + duration
        self._busy_until = max(self._busy_until, end)
        self._bg_busy_until = max(self._bg_busy_until, end)

    def degrade(self, duration: float, factor: float) -> None:
        """Transient bandwidth degradation: service times are multiplied by
        ``factor`` for I/O submitted in the next ``duration`` seconds."""
        self._slow_until = max(self._slow_until, self.sim.now + duration)
        self._slow_factor = factor

    def restart(self) -> None:
        """Crash/power-cycle hook: the in-device queue drains with the power
        (queued service obligations are gone; zones keep their pointers)."""
        self._busy_until = self._bg_busy_until = self.sim.now
        self._slow_until = 0.0
        self._slow_factor = 1.0

    # ------------------------------------------------------------------
    def utilization(self) -> float:
        if self.sim.now <= 0:
            return 0.0
        return self.counters.busy_time / self.sim.now

    def queue_depth_s(self, background: bool = False) -> float:
        """Seconds of service backlog on the (fore/back)ground track: how
        long an I/O submitted now would wait before starting."""
        until = self._bg_busy_until if background else self._busy_until
        return max(0.0, until - self.sim.now)

    def zone_occupancy(self) -> Dict[str, int]:
        """Zone counts by state (single pass; EMPTY/OPEN/FULL)."""
        empty = opened = full = 0
        for z in self.zones:
            s = z.state
            if s is ZoneState.EMPTY:
                empty += 1
            elif s is ZoneState.OPEN:
                opened += 1
            else:
                full += 1
        return {"empty": empty, "open": opened, "full": full}

    # ------------------------------------------------------------------
    # telemetry (repro.obs) — pull gauges only: io() is untouched
    # ------------------------------------------------------------------
    def install_metrics(self, reg, prefix: Optional[str] = None) -> None:
        """Register this device's per-tier signals on a ``MetricsRegistry``:
        queue depth (fg/bg backlog seconds), utilization, zone occupancy by
        state, and windowed read/write byte rates."""
        p = prefix or self.name
        reg.gauge(f"{p}.qdepth_s", self.queue_depth_s)
        reg.gauge(f"{p}.bg_qdepth_s",
                  lambda: self.queue_depth_s(background=True))
        reg.gauge(f"{p}.util", self.utilization)
        reg.collector(lambda: {
            f"{p}.zones.{k}": float(v)
            for k, v in self.zone_occupancy().items()})
        reg.collector(lambda: {
            f"{p}.read_rate": self.counters.read_bytes,
            f"{p}.write_rate": self.counters.write_bytes,
        }, rate=True)
