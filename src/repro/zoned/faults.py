"""Fault injection for hybrid zoned storage (crash/recovery evaluation).

ZNS studies (Tehrany & Trivedi, "Understanding NVMe ZNS SSDs") show that
zone-state transitions, resets and device hiccups are exactly where real
deployments break; a reproduction that only models the happy path cannot
validate the paper's WAL-zone organization (§3.2) at all.  This module
declares fault *schedules* and arms them against a running ``DB``:

* ``StallWindow``  — the device freezes for a window: every I/O (foreground
  and background) *submitted* during the window completes only after it
  ends (I/O already in flight keeps its precomputed completion time).
  Models internal garbage collection / firmware stalls.
* ``SlowWindow``   — transient bandwidth degradation: service times are
  multiplied by ``factor`` for I/O submitted inside the window.
* ``ZoneReset``    — the device spontaneously resets one zone (torn zone
  after power loss, firmware bug).  The middleware is notified through
  ``HybridZonedBackend.on_zone_fault`` and must repair: SST zones are
  re-replicated, WAL zones force a flush of their (still memory-resident)
  generations, cache zones drop their mapping entries.
* ``FaultSpec.crash_at`` — full crash + recovery: ``DB.crash()`` discards
  everything volatile and ``DB.reopen()`` rebuilds from durable state with
  WAL replay.  The crash itself is orchestrated by the open-loop runner
  (``run_open_loop(faults=...)``), which must also account for the ops it
  kills; the injector only arms the window faults.

All times are in virtual seconds relative to ``FaultInjector.arm()``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

SSD, HDD, BOTH = "ssd", "hdd", "both"


@dataclass(frozen=True)
class StallWindow:
    """Device freeze: I/O submitted in [at, at + duration) waits it out.

    ``shard`` targets one shard store of a ``repro.cluster.ShardedDB``
    (None = every store; ignored on a bare ``DB``)."""

    at: float
    duration: float
    device: str = SSD            # "ssd" | "hdd" | "both"
    shard: Optional[int] = None


@dataclass(frozen=True)
class SlowWindow:
    """Bandwidth degradation: service times x ``factor`` during the window."""

    at: float
    duration: float
    factor: float = 4.0
    device: str = HDD
    shard: Optional[int] = None


@dataclass(frozen=True)
class ZoneReset:
    """Spontaneous zone reset at ``at``; ``zid=None`` picks the first zone
    currently owned by an SST (deterministic, so runs are reproducible)."""

    at: float
    device: str = SSD
    zid: Optional[int] = None
    shard: Optional[int] = None


@dataclass(frozen=True)
class FaultSpec:
    """Declarative fault schedule for one run (times relative to run start).

    ``recovery_slo_s`` is a recovery-time SLO budget for the crash point:
    runs report ``recovery_slo_s``/``recovery_slo_met`` columns comparing
    the measured downtime (crash → serving again, WAL replay included)
    against it.
    """

    name: str = "faults"
    crash_at: Optional[float] = None
    # crash only this shard of a ShardedDB at crash_at (None = whole
    # store); the other shards keep serving while it replays its WAL
    crash_shard: Optional[int] = None
    stalls: Tuple[StallWindow, ...] = ()
    slows: Tuple[SlowWindow, ...] = ()
    zone_resets: Tuple[ZoneReset, ...] = ()
    recovery_slo_s: Optional[float] = None

    @property
    def label(self) -> str:
        """Human-readable schedule, used in result rows and reports."""
        parts = []
        if self.crash_at is not None:
            who = (f"(s{self.crash_shard})"
                   if self.crash_shard is not None else "")
            parts.append(f"crash{who}@{self.crash_at:g}")
        for s in self.stalls:
            parts.append(f"stall[{_dev_label(s)}]@{s.at:g}+{s.duration:g}")
        for s in self.slows:
            parts.append(f"slow[{_dev_label(s)}]x{s.factor:g}"
                         f"@{s.at:g}+{s.duration:g}")
        for z in self.zone_resets:
            parts.append(f"zreset[{_dev_label(z)}]@{z.at:g}")
        return ",".join(parts) if parts else "none"


def _dev_label(w) -> str:
    if w.shard is None:
        return w.device
    return f"s{w.shard}.{w.device}"


class FaultInjector:
    """Arms a ``FaultSpec``'s stall/slow/zone-reset events on a ``DB``.

    Each fault is a daemon process on the DB's simulator: it does not keep
    the run alive, and a fault scheduled past the end of the run simply
    never fires.  ``crash_at`` is deliberately NOT armed here — the runner
    owns the crash because it must coordinate in-flight op accounting
    around ``DB.crash()``/``DB.reopen()``.
    """

    def __init__(self, db, spec: FaultSpec):
        self.db = db
        self.spec = spec
        self.t0 = 0.0
        self.fired = {"stalls": 0, "slows": 0, "zone_resets": 0}

    # ------------------------------------------------------------------
    def arm(self, t0: Optional[float] = None,
            after: float = float("-inf")) -> None:
        """Spawn the fault processes.  ``t0`` anchors the schedule (default:
        now); ``after`` skips windows at or before that relative time —
        used to re-arm the not-yet-fired remainder after a crash killed
        the injector's processes along with everything else."""
        sim = self.db.sim
        self.t0 = sim.now if t0 is None else t0
        for w in self.spec.stalls:
            if w.at > after:
                sim.process(self._stall(w))
        for w in self.spec.slows:
            if w.at > after:
                sim.process(self._slow(w))
        for w in self.spec.zone_resets:
            if w.at > after:
                sim.process(self._zone_reset(w))

    def _dbs(self, shard: Optional[int]):
        """Target stores of a window: the shard stores of a ShardedDB
        (one of them when ``shard`` is set) or the bare DB itself."""
        subs = getattr(self.db, "shards", None)
        if subs is None or isinstance(subs, int):
            return [self.db]
        if shard is None:
            return list(subs)
        return [subs[shard]]

    def _devices(self, which: str, shard: Optional[int] = None):
        devs = []
        for db in self._dbs(shard):
            if which == BOTH:
                devs.extend([db.ssd, db.hdd])
            else:
                devs.append(db.backend.device_of(which))
        return devs

    def _wait(self, at: float):
        delay = self.t0 + at - self.db.sim.now
        if delay > 0:
            yield self.db.sim.timeout(delay, daemon=True)

    # ------------------------------------------------------------------
    def _stall(self, w: StallWindow):
        yield from self._wait(w.at)
        for dev in self._devices(w.device, w.shard):
            dev.stall(w.duration)
        self.fired["stalls"] += 1

    def _slow(self, w: SlowWindow):
        yield from self._wait(w.at)
        for dev in self._devices(w.device, w.shard):
            dev.degrade(w.duration, w.factor)
        self.fired["slows"] += 1

    def _zone_reset(self, w: ZoneReset):
        yield from self._wait(w.at)
        for db in self._dbs(w.shard):
            dev = db.backend.device_of(w.device)
            zone = self._pick(dev, w.zid)
            if zone is not None:
                db.backend.on_zone_fault(w.device, zone)
                self.fired["zone_resets"] += 1

    @staticmethod
    def _pick(dev, zid: Optional[int]):
        if zid is not None:
            return dev.zones[zid]
        for z in dev.zones:   # deterministic victim: first SST-owned zone
            if z.owner is not None and z.owner.startswith("sst:"):
                return z
        return None
