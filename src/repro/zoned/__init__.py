from .sim import Sim, Event, MonotoneQueue, Process, Semaphore
from .device import (
    DeviceTiming, Zone, ZoneState, ZonedDevice, ZN540_SSD, ST14000_HDD,
    MiB, KiB,
)
from .faults import (FaultInjector, FaultSpec, SlowWindow, StallWindow,
                     ZoneReset)

__all__ = [
    "Sim", "Event", "MonotoneQueue", "Process", "Semaphore",
    "DeviceTiming", "Zone", "ZoneState", "ZonedDevice",
    "ZN540_SSD", "ST14000_HDD", "MiB", "KiB",
    "FaultInjector", "FaultSpec", "StallWindow", "SlowWindow", "ZoneReset",
]
