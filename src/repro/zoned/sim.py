"""Minimal discrete-event simulation kernel (SimPy-flavoured, generator processes).

The storage substrate of the HHZS reproduction runs on virtual time: devices
are FIFO resources, foreground clients and background jobs (flush, compaction,
migration) are generator processes that ``yield`` events.  This keeps the
LSM-tree / HHZS logic an exact, inspectable reproduction of the paper's
control flow while producing throughput / latency numbers from the device
timing model (Table 1 of the paper).

Daemon events: periodic background pollers (migration ticks, AUTO's
throughput monitor) schedule *daemon* timeouts that do not keep ``run()``
alive — ``run()`` returns once only daemon events remain, i.e. when all real
work (client ops, flush/compaction/migration I/O) has settled.

Hot-path design (benchmarked by ``benchmarks/sim_speed.py``):

* **Slim entries.**  A scheduled entry is a plain tuple ending in
  ``(event, value)``: dispatch fires ``event.succeed(value)`` inline, so
  ``timeout()`` allocates no per-entry closure (the seed kernel built a
  lambda per scheduled event).
* **Single-waiter fast path.**  Almost every event has exactly one waiter
  (the process step that yielded it).  ``Event`` keeps that one callback in
  a dedicated ``_cb`` slot and only allocates a waiter list on the second
  subscriber.
* **Monotone run queue.**  DES schedules are overwhelmingly time-ordered:
  the kernel keeps a global deque of entries whose fire times never
  decrease (O(1) append / O(1) pop) and only out-of-order entries touch
  the binary heap.  Dispatch merges the heap head with every queue head by
  ``(time, seq)``, reproducing exactly the order per-entry heap scheduling
  would have produced.
* **Per-device completion batches.**  A FIFO busy-until resource completes
  I/O in nondecreasing time order, so ``ZonedDevice`` gives each service
  track its own :class:`MonotoneQueue` (the ``fifo_device`` bench shape):
  completions never contend with the global schedule for heap space.
* **Bare-delay yields.**  A process may yield a plain ``float``/``int``
  delay instead of ``timeout()``: the kernel schedules its resume callback
  directly — no Event is allocated at all (the ``process_chain`` /
  ``sem_pool`` / ``daemon_mix`` bench shapes; used by production sleeps).
* **Bulk insert.**  ``schedule_many()`` schedules a whole batch of timeouts
  as a one-shot monotone queue in O(n) when the batch is nondecreasing
  (the ``timer_churn`` bench shape), and via one O(n + h) ``heapify``
  otherwise — vs O(n log n) for n individual ``timeout()`` calls.
"""
from __future__ import annotations

import numbers

from heapq import heapify, heappop, heappush
from typing import Any, Callable, Generator, Iterable, List, Optional

from collections import deque

# written into a completion ticket's waiter slot when it fires unawaited:
# a process that yields the ticket afterwards resumes immediately (the
# moral equivalent of yielding an already-triggered Event)
_FIRED = object()

_INF = float("inf")


class Event:
    """One-shot event; processes wait on it by ``yield``-ing it.

    ``_cb`` is the single-waiter fast path; ``_waiters`` is lazily created
    only when a second callback subscribes before the event triggers.
    """

    __slots__ = ("sim", "triggered", "value", "_cb", "_waiters")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self._cb: Optional[Callable[[Any], None]] = None
        self._waiters: Optional[List[Callable[[Any], None]]] = None

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        cb = self._cb
        if cb is not None:
            self._cb = None
            cb(value)
        waiters = self._waiters
        if waiters is not None:
            self._waiters = None
            for w in waiters:
                w(value)
        return self

    def add_callback(self, cb: Callable[[Any], None]) -> None:
        if self.triggered:
            cb(self.value)
        elif self._cb is None:
            self._cb = cb
        elif self._waiters is None:
            self._waiters = [cb]
        else:
            self._waiters.append(cb)


class Process(Event):
    """Drives a generator; the Process itself is an Event that fires on return.

    A process yields either an :class:`Event` to wait on, or a bare
    real-number delay — sugar for ``timeout(delay)`` that skips the Event
    allocation entirely (the kernel resumes the generator directly).
    ``float``/``int`` take the fast path; any other ``numbers.Real``
    (numpy scalars like ``np.float64(0.25)``) is accepted via a
    conversion fallback.
    """

    __slots__ = ("gen", "_send", "_bound_step")

    def __init__(self, sim: "Sim", gen: Generator):
        # inlined Event.__init__ + immediate-start scheduling (process
        # creation is a hot allocation site for job-per-op pools)
        self.sim = sim
        self.triggered = False
        self.value = None
        self._cb = None
        self._waiters = None
        self.gen = gen
        self._send = gen.send
        # bind once: `self._step` attribute access builds a fresh bound
        # method per yield, which shows up in the hot loop
        step = self._bound_step = self._step
        now = sim.now
        sim._seq += 1
        sim._live += 1
        entry = (now, sim._seq, step, None)
        rq = sim._rq
        if rq._q and now < rq._last:
            heappush(sim._heap, (now, sim._seq, False, step, None))
        else:
            rq._q.append(entry)
            rq._last = now

    def _step(self, send_value: Any) -> None:
        try:
            ev = self._send(send_value)
        except StopIteration as stop:
            self.succeed(stop.value)
            return
        cls = ev.__class__
        if cls is Event:
            # inlined Event.add_callback (single-waiter fast path)
            if ev.triggered:
                self._bound_step(ev.value)
            elif ev._cb is None:
                ev._cb = self._bound_step
            elif ev._waiters is None:
                ev._waiters = [self._bound_step]
            else:
                ev._waiters.append(self._bound_step)
            return
        if cls is list:
            # completion ticket (MonotoneQueue.complete_at): write the
            # resume callback straight into the pending entry
            w = ev[2]
            if w is None:
                ev[2] = self._bound_step
            elif w is _FIRED:
                # already completed (the caller yielded other events
                # first): resume immediately, like a triggered Event
                self._bound_step(ev[3])
            else:
                raise RuntimeError("completion ticket already awaited")
            return
        if cls is float or cls is int:
            # bare delay: schedule the resume directly, no Event allocated
            if ev < 0:
                raise ValueError(f"negative delay {ev}")
            sim = self.sim
            at = sim.now + ev
            sim._seq += 1
            sim._live += 1
            rq = sim._rq
            if rq._q and at < rq._last:
                heappush(sim._heap,
                         (at, sim._seq, False, self._bound_step, None))
            else:
                rq._q.append((at, sim._seq, self._bound_step, None))
                rq._last = at
            return
        if isinstance(ev, Event):   # Event subclass (e.g. joining a Process)
            ev.add_callback(self._bound_step)
            return
        if isinstance(ev, numbers.Real):
            # any real number is a bare delay: numpy scalars
            # (np.float64(0.25), np.int64(1)) and other Real duck-types
            # are not `float`/`int` exactly, so they miss the fast path
            # above — convert once and take the same no-Event schedule
            delay = float(ev)
            if delay < 0:
                raise ValueError(f"negative delay {delay}")
            sim = self.sim
            at = sim.now + delay
            sim._seq += 1
            sim._live += 1
            rq = sim._rq
            if rq._q and at < rq._last:
                heappush(sim._heap,
                         (at, sim._seq, False, self._bound_step, None))
            else:
                rq._q.append((at, sim._seq, self._bound_step, None))
                rq._last = at
            return
        raise TypeError(
            f"process yielded non-event: {ev!r} — yield an Event, a device "
            f"completion ticket, or a real-number delay (float/int/numpy "
            f"scalar)")


class Sim:
    """Event loop over virtual seconds.

    Dispatch state lives in three places, merged by ``(time, seq)``:

    * ``_heap``   — out-of-order and daemon entries:
      ``(at, seq, daemon, target, value)``
    * ``_rq``     — the global monotone run queue (in-order entries)
    * ``_mono``   — attached device queues and one-shot batches;
      entries in all queues are ``(at, seq, target, value)``

    A ``target`` is either an :class:`Event` (fired inline) or a bare
    callback (a suspended process's resume; called directly).
    """

    # processes may `yield <float>` instead of `yield timeout(<float>)`
    # (feature-detected by benchmarks/sim_speed.py against the seed kernel)
    BARE_DELAY_YIELDS = True

    def __init__(self) -> None:
        self.now = 0.0
        self._heap: List[tuple] = []
        self._seq = 0
        self._live = 0  # non-daemon entries across heap + queues
        self._mono: List["MonotoneQueue"] = []  # run queue + device queues
        self._mono_ver = 0     # bumped on attach/prune; dispatch re-hoists
        self._n_transient = 0  # one-shot schedule_many batches in _mono
        self._rq = MonotoneQueue(self)          # global monotone run queue
        # crash support (DB.crash): events/processes killed by a simulated
        # power loss are pinned here so CPython never finalizes their
        # suspended generators — GeneratorExit would run their `finally`
        # blocks (semaphore releases, waiter wake-ups), resurrecting other
        # dead processes after the crash
        self.graveyard: List = []

    # -- scheduling -------------------------------------------------------
    def timeout(self, delay: float, value: Any = None,
                daemon: bool = False) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        # inlined Event() + scheduling: timeout is the kernel's hottest
        # allocation site (one per I/O, per yield, per poller tick)
        ev = Event.__new__(Event)
        ev.sim = self
        ev.triggered = False
        ev.value = None
        ev._cb = None
        ev._waiters = None
        at = self.now + delay
        self._seq += 1
        if daemon:
            heappush(self._heap, (at, self._seq, True, ev, value))
            return ev
        self._live += 1
        rq = self._rq
        if rq._q and at < rq._last:
            heappush(self._heap, (at, self._seq, False, ev, value))
        else:
            rq._q.append((at, self._seq, ev, value))
            rq._last = at
        return ev

    def schedule_at(self, at: float, value: Any = None,
                    daemon: bool = False) -> Event:
        """Schedule an event at *absolute* virtual time ``at`` (>= now).

        Unlike ``timeout(at - now)`` this fires at exactly ``at`` — no
        float round-trip through a delay — which is what lets the batched
        and unbatched device paths produce bit-identical completion times.
        """
        if at < self.now:
            raise ValueError(f"schedule_at({at}) is in the past ({self.now})")
        ev = Event.__new__(Event)
        ev.sim = self
        ev.triggered = False
        ev.value = None
        ev._cb = None
        ev._waiters = None
        self._seq += 1
        if not daemon:
            self._live += 1
        heappush(self._heap, (at, self._seq, daemon, ev, value))
        return ev

    def schedule_many(self, delays: Iterable[float], value: Any = None,
                      daemon: bool = False) -> List[Event]:
        """Bulk-insert a batch of timeouts; returns their Events in order.

        A nondecreasing non-daemon batch is stored as a one-shot
        :class:`MonotoneQueue` (O(n) to build, O(1) per dispatch, zero
        heap traffic — the pre-scheduled sweep shape); any other batch
        lands on the heap via one O(n + h) ``heapify`` — vs
        O(n log(n + h)) for n individual ``timeout()`` calls.  Semantics
        (ordering, daemon flag, returned Events) are identical to calling
        ``timeout`` once per delay.
        """
        now = self.now
        seq = self._seq
        new = Event.__new__
        entries: List[tuple] = []
        append = entries.append
        prev = float("-inf")
        in_order = True
        for d in delays:
            if d < 0:
                raise ValueError(f"negative delay {d}")
            at = now + d
            seq += 1
            ev = new(Event)
            ev.sim = self
            ev.triggered = False
            ev.value = None
            ev._cb = None
            ev._waiters = None
            append((at, seq, ev, value))
            if at < prev:
                in_order = False
            prev = at
        self._seq = seq
        if not daemon:
            self._live += len(entries)
        if entries and in_order and not daemon:
            # one-shot completion batch: dispatched straight off a deque,
            # merged with the heap by (time, seq); pruned once drained
            q = MonotoneQueue(self, transient=True)
            q._q.extend(entries)
            q._last = entries[-1][0]
            self._n_transient += 1
        else:
            heap = self._heap
            heap.extend((at, sq, daemon, ev, v)
                        for at, sq, ev, v in entries)
            heapify(heap)
        return [e[2] for e in entries]

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    def monotone_queue(self) -> "MonotoneQueue":
        """Attach a new per-device completion batch (see MonotoneQueue)."""
        return MonotoneQueue(self)

    def _prune_transient(self) -> None:
        """Drop drained one-shot schedule_many batches from the merge scan."""
        kept = [q for q in self._mono if not (q.transient and not q._q)]
        self._n_transient -= len(self._mono) - len(kept)
        self._mono = kept
        self._mono_ver += 1

    # -- running ----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        """Run until no *non-daemon* work remains (or virtual ``until``).

        ``until`` never moves time backwards: a target already in the past
        is a no-op (virtual time is monotonic; rewinding it would corrupt
        every timestamp captured afterwards)."""
        if self._n_transient:
            self._prune_transient()
        heap = self._heap
        # deque identities are stable, so hoist them out of the merge scan:
        # the run queue is scanned unrolled (it is always present), device
        # queues / transient batches land in `others` (usually empty or
        # tiny); the version guard re-hoists if a queue attaches mid-run
        ver = self._mono_ver
        rdq = self._rq._q
        others = [q._q for q in self._mono if q is not self._rq]
        while self._live > 0:
            if self._mono_ver != ver:
                ver = self._mono_ver
                others = [q._q for q in self._mono if q is not self._rq]
            # pick the earliest source by (time, seq)
            src: Optional[deque] = None    # None -> heap
            if heap:
                head = heap[0]
                at = head[0]
                sq = head[1]
            else:
                at = _INF
                sq = 0
            if rdq:
                e = rdq[0]
                eat = e[0]
                if eat < at or (eat == at and e[1] < sq):
                    at = eat
                    sq = e[1]
                    src = rdq
            if others:
                for dq in others:
                    if dq:
                        e = dq[0]
                        eat = e[0]
                        if eat < at or (eat == at and e[1] < sq):
                            at = eat
                            sq = e[1]
                            src = dq
            if at == _INF:
                break
            if until is not None and at > until:
                if until > self.now:
                    self.now = until
                return
            self.now = at
            if src is None:
                _, _, daemon, ev, value = heappop(heap)
                if not daemon:
                    self._live -= 1
            else:
                entry = src.popleft()
                self._live -= 1
                if entry.__class__ is list:
                    ev = entry[2]
                    value = entry[3]
                    entry[2] = _FIRED   # late yields resume immediately
                else:
                    _, _, ev, value = entry
            # fire: an Event succeeds inline; a bare callback (process
            # resume from a bare-delay yield or a completion ticket) is
            # called directly; None is an un-awaited ticket (no waiter)
            if ev.__class__ is Event:
                if ev.triggered:
                    raise RuntimeError("event already triggered")
                ev.triggered = True
                ev.value = value
                cb = ev._cb
                if cb is not None:
                    ev._cb = None
                    cb(value)
                ws = ev._waiters
                if ws is not None:
                    ev._waiters = None
                    for w in ws:
                        w(value)
            elif ev is not None:
                ev(value)
        if until is not None and until > self.now:
            self.now = until

    def run_until(self, ev: Event) -> Any:
        """Run until ``ev`` triggers (used by the synchronous KV facade)."""
        if self._n_transient:
            self._prune_transient()
        heap = self._heap
        ver = self._mono_ver
        rdq = self._rq._q
        others = [q._q for q in self._mono if q is not self._rq]
        daemon_only = 0
        while not ev.triggered:
            if self._mono_ver != ver:
                ver = self._mono_ver
                others = [q._q for q in self._mono if q is not self._rq]
            src: Optional[deque] = None    # None -> heap
            if heap:
                head = heap[0]
                at = head[0]
                sq = head[1]
            else:
                at = _INF
                sq = 0
            if rdq:
                e = rdq[0]
                eat = e[0]
                if eat < at or (eat == at and e[1] < sq):
                    at = eat
                    sq = e[1]
                    src = rdq
            if others:
                for dq in others:
                    if dq:
                        e = dq[0]
                        eat = e[0]
                        if eat < at or (eat == at and e[1] < sq):
                            at = eat
                            sq = e[1]
                            src = dq
            if at == _INF:
                raise RuntimeError("deadlock: event never triggers")
            if self._live == 0:
                daemon_only += 1
                if daemon_only > 1_000_000:
                    raise RuntimeError(
                        "livelock: only daemon events remain but the "
                        "awaited event never triggers")
            else:
                daemon_only = 0
            if src is None:
                _, _, daemon, e, value = heappop(heap)
                if not daemon:
                    self._live -= 1
            else:
                entry = src.popleft()
                self._live -= 1
                if entry.__class__ is list:
                    e = entry[2]
                    value = entry[3]
                    entry[2] = _FIRED   # late yields resume immediately
                else:
                    _, _, e, value = entry
            self.now = at
            # fire (hot: one per client op yield) — see run()
            if e.__class__ is Event:
                if e.triggered:
                    raise RuntimeError("event already triggered")
                e.triggered = True
                e.value = value
                cb = e._cb
                if cb is not None:
                    e._cb = None
                    cb(value)
                ws = e._waiters
                if ws is not None:
                    e._waiters = None
                    for w in ws:
                        w(value)
            elif e is not None:
                e(value)
        return ev.value


class MonotoneQueue:
    """A batch of scheduled entries whose fire times never decrease.

    Three users share this shape:

    * the Sim's built-in global run queue (``Sim._rq``): ``timeout()`` and
      bare-delay yields land here whenever their fire time is >= the tail;
    * per-device completion batches (``ZonedDevice`` service tracks): a
      FIFO busy-until resource completes I/O in nondecreasing time, so its
      completions always ride the O(1) deque;
    * one-shot ``schedule_many`` batches (``transient=True``), pruned from
      the merge scan once drained.

    Entries are ``(at, seq, target, value)`` and are never daemon; the
    dispatch loops merge every queue head against the heap head by
    ``(time, seq)``, so global order is exactly what per-entry heap
    scheduling would have produced.  ``schedule_at`` falls back to a plain
    heap entry whenever the monotonicity invariant would break (e.g. after
    ``ZonedDevice.restart()`` mid-crash) — correctness never depends on
    the invariant, only the O(1) fast path does.
    """

    __slots__ = ("sim", "_q", "_last", "transient")

    def __init__(self, sim: Sim, transient: bool = False):
        self.sim = sim
        self._q: deque = deque()   # (at, seq, target, value), nondecreasing
        self._last = 0.0           # newest pending time (valid while busy)
        self.transient = transient
        sim._mono.append(self)
        sim._mono_ver += 1

    def schedule_at(self, at: float, value: Any = None) -> Event:
        """Schedule a completion at absolute time ``at`` (>= sim.now)."""
        sim = self.sim
        if at < sim.now:
            raise ValueError(f"schedule_at({at}) is in the past ({sim.now})")
        if self._q and at < self._last:
            # non-monotone (device restarted under pending completions):
            # take the exact-same-time heap path
            return sim.schedule_at(at, value)
        ev = Event.__new__(Event)
        ev.sim = sim
        ev.triggered = False
        ev.value = None
        ev._cb = None
        ev._waiters = None
        sim._seq += 1
        sim._live += 1
        self._q.append((at, sim._seq, ev, value))
        self._last = at
        return ev

    def complete_at(self, at: float, value: Any = None) -> Any:
        """Schedule a completion *ticket* at absolute time ``at``.

        The ticket is the pending entry itself (a mutable
        ``[at, seq, waiter, value]`` list): a process that ``yield``-s it
        gets its resume callback written straight into slot 2 — no Event
        is allocated and dispatch calls the waiter directly.  A ticket
        nobody awaits completes silently; one first yielded *after* its
        completion fired resumes the process immediately (like yielding
        an already-triggered Event).  Use :meth:`schedule_at` when the
        caller needs a real Event (``add_callback``, multiple waiters).
        """
        sim = self.sim
        if at < sim.now:
            raise ValueError(f"complete_at({at}) is in the past ({sim.now})")
        if self._q and at < self._last:
            # non-monotone (device restarted under pending completions):
            # same absolute fire time through the heap, as a real Event
            return sim.schedule_at(at, value)
        sim._seq += 1
        sim._live += 1
        entry = [at, sim._seq, None, value]
        self._q.append(entry)
        self._last = at
        return entry

    def crash_clear(self) -> List[tuple]:
        """Drop every pending completion (power loss); returns the dropped
        entries so ``DB.crash`` can pin them in the graveyard."""
        dead = list(self._q)
        self._q.clear()
        self.sim._live -= len(dead)
        return dead


class Semaphore:
    """Counting semaphore for background job thread pools."""

    def __init__(self, sim: Sim, capacity: int):
        self.sim = sim
        self.capacity = capacity
        self.in_use = 0
        self._queue: deque = deque()

    def acquire(self) -> Event:
        # inlined Event(): one acquire per background job makes this hot
        ev = Event.__new__(Event)
        ev.sim = self.sim
        ev.value = None
        ev._cb = None
        ev._waiters = None
        if self.in_use < self.capacity:
            self.in_use += 1
            ev.triggered = True    # immediate grant: nobody subscribed yet
        else:
            ev.triggered = False
            self._queue.append(ev)
        return ev

    def release(self) -> None:
        q = self._queue
        if q:
            # inlined Event.succeed (one grant per queued background job)
            ev = q.popleft()
            ev.triggered = True
            cb = ev._cb
            if cb is not None:
                ev._cb = None
                cb(None)
            ws = ev._waiters
            if ws is not None:
                ev._waiters = None
                for w in ws:
                    w(None)
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("semaphore released below zero")
