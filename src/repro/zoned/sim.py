"""Minimal discrete-event simulation kernel (SimPy-flavoured, generator processes).

The storage substrate of the HHZS reproduction runs on virtual time: devices
are FIFO resources, foreground clients and background jobs (flush, compaction,
migration) are generator processes that ``yield`` events.  This keeps the
LSM-tree / HHZS logic an exact, inspectable reproduction of the paper's
control flow while producing throughput / latency numbers from the device
timing model (Table 1 of the paper).

Daemon events: periodic background pollers (migration ticks, AUTO's
throughput monitor) schedule *daemon* timeouts that do not keep ``run()``
alive — ``run()`` returns once only daemon events remain, i.e. when all real
work (client ops, flush/compaction/migration I/O) has settled.

Hot-path design (benchmarked by ``benchmarks/sim_speed.py``):

* **Slim heap entries.**  An entry is ``(at, seq, daemon, event, value)``:
  popping calls ``event.succeed(value)`` directly, so ``timeout()`` allocates
  no per-entry closure (the seed kernel built a lambda per scheduled event).
* **Single-waiter fast path.**  Almost every event has exactly one waiter
  (the process step that yielded it).  ``Event`` keeps that one callback in
  a dedicated ``_cb`` slot and only allocates a waiter list on the second
  subscriber.
* **Batched same-timestamp dispatch.**  ``run()`` / ``run_until()`` hoist
  heap/attribute lookups into locals and drain ready entries in a tight
  loop instead of re-entering a method call per event.
"""
from __future__ import annotations

from heapq import heappop, heappush
from typing import Any, Callable, Generator, List, Optional

from collections import deque


class Event:
    """One-shot event; processes wait on it by ``yield``-ing it.

    ``_cb`` is the single-waiter fast path; ``_waiters`` is lazily created
    only when a second callback subscribes before the event triggers.
    """

    __slots__ = ("sim", "triggered", "value", "_cb", "_waiters")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.triggered = False
        self.value: Any = None
        self._cb: Optional[Callable[[Any], None]] = None
        self._waiters: Optional[List[Callable[[Any], None]]] = None

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            raise RuntimeError("event already triggered")
        self.triggered = True
        self.value = value
        cb = self._cb
        if cb is not None:
            self._cb = None
            cb(value)
        waiters = self._waiters
        if waiters is not None:
            self._waiters = None
            for w in waiters:
                w(value)
        return self

    def add_callback(self, cb: Callable[[Any], None]) -> None:
        if self.triggered:
            cb(self.value)
        elif self._cb is None:
            self._cb = cb
        elif self._waiters is None:
            self._waiters = [cb]
        else:
            self._waiters.append(cb)


class Process(Event):
    """Drives a generator; the Process itself is an Event that fires on return."""

    __slots__ = ("gen", "_send", "_bound_step")

    def __init__(self, sim: "Sim", gen: Generator):
        super().__init__(sim)
        self.gen = gen
        self._send = gen.send
        # bind once: `self._step` attribute access builds a fresh bound
        # method per yield, which shows up in the hot loop
        self._bound_step = self._step
        sim._immediate(self._bound_step, None)

    def _step(self, send_value: Any) -> None:
        try:
            ev = self._send(send_value)
        except StopIteration as stop:
            self.succeed(stop.value)
            return
        if ev.__class__ is not Event and not isinstance(ev, Event):
            raise TypeError(f"process yielded non-event: {ev!r}")
        # inlined Event.add_callback (single-waiter fast path)
        if ev.triggered:
            self._bound_step(ev.value)
        elif ev._cb is None:
            ev._cb = self._bound_step
        elif ev._waiters is None:
            ev._waiters = [self._bound_step]
        else:
            ev._waiters.append(self._bound_step)


class Sim:
    """Event loop over virtual seconds."""

    def __init__(self) -> None:
        self.now = 0.0
        # heap entries: (at, seq, daemon, event, value) — popping an entry
        # fires event.succeed(value); no per-entry callable is allocated
        self._heap: List[tuple] = []
        self._seq = 0
        self._live = 0  # non-daemon entries in the heap
        # crash support (DB.crash): events/processes killed by a simulated
        # power loss are pinned here so CPython never finalizes their
        # suspended generators — GeneratorExit would run their `finally`
        # blocks (semaphore releases, waiter wake-ups), resurrecting other
        # dead processes after the crash
        self.graveyard: List = []

    # -- scheduling -------------------------------------------------------
    def _schedule(self, at: float, ev: Event, value: Any,
                  daemon: bool) -> None:
        self._seq += 1
        if not daemon:
            self._live += 1
        heappush(self._heap, (at, self._seq, daemon, ev, value))

    def _immediate(self, fn: Callable[[Any], None], value: Any) -> None:
        ev = Event(self)
        ev._cb = fn
        self._schedule(self.now, ev, value, False)

    def timeout(self, delay: float, value: Any = None,
                daemon: bool = False) -> Event:
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        # inlined Event() + _schedule(): timeout is the kernel's hottest
        # allocation site (one per I/O, per yield, per poller tick)
        ev = Event.__new__(Event)
        ev.sim = self
        ev.triggered = False
        ev.value = None
        ev._cb = None
        ev._waiters = None
        self._seq += 1
        if not daemon:
            self._live += 1
        heappush(self._heap, (self.now + delay, self._seq, daemon, ev, value))
        return ev

    def event(self) -> Event:
        return Event(self)

    def process(self, gen: Generator) -> Process:
        return Process(self, gen)

    # -- running ----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        """Run until no *non-daemon* work remains (or virtual ``until``).

        ``until`` never moves time backwards: a target already in the past
        is a no-op (virtual time is monotonic; rewinding it would corrupt
        every timestamp captured afterwards)."""
        heap = self._heap
        while heap and self._live > 0:
            at = heap[0][0]
            if until is not None and at > until:
                if until > self.now:
                    self.now = until
                return
            # drain everything ready at this timestamp in one tight loop,
            # firing events inline (saves a method call per entry)
            self.now = at
            while heap and heap[0][0] == at and self._live > 0:
                _, _, daemon, ev, value = heappop(heap)
                if not daemon:
                    self._live -= 1
                if ev.triggered:
                    raise RuntimeError("event already triggered")
                ev.triggered = True
                ev.value = value
                cb = ev._cb
                if cb is not None:
                    ev._cb = None
                    cb(value)
                ws = ev._waiters
                if ws is not None:
                    ev._waiters = None
                    for w in ws:
                        w(value)
        if until is not None and until > self.now:
            self.now = until

    def run_until(self, ev: Event) -> Any:
        """Run until ``ev`` triggers (used by the synchronous KV facade)."""
        heap = self._heap
        daemon_only = 0
        while not ev.triggered:
            if not heap:
                raise RuntimeError("deadlock: event never triggers")
            if self._live == 0:
                daemon_only += 1
                if daemon_only > 1_000_000:
                    raise RuntimeError(
                        "livelock: only daemon events remain but the awaited "
                        "event never triggers")
            else:
                daemon_only = 0
            at, _, daemon, e, value = heappop(heap)
            if not daemon:
                self._live -= 1
            self.now = at
            # inlined Event.succeed (hot: one fire per client op yield)
            if e.triggered:
                raise RuntimeError("event already triggered")
            e.triggered = True
            e.value = value
            cb = e._cb
            if cb is not None:
                e._cb = None
                cb(value)
            ws = e._waiters
            if ws is not None:
                e._waiters = None
                for w in ws:
                    w(value)
        return ev.value


class Semaphore:
    """Counting semaphore for background job thread pools."""

    def __init__(self, sim: Sim, capacity: int):
        self.sim = sim
        self.capacity = capacity
        self.in_use = 0
        self._queue: deque = deque()

    def acquire(self) -> Event:
        ev = self.sim.event()
        if self.in_use < self.capacity:
            self.in_use += 1
            ev.succeed()
        else:
            self._queue.append(ev)
        return ev

    def release(self) -> None:
        if self._queue:
            self._queue.popleft().succeed()
        else:
            self.in_use -= 1
            if self.in_use < 0:
                raise RuntimeError("semaphore released below zero")
