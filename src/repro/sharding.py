"""Sharding rules: logical-axes -> mesh axes with divisibility fallback.

Train mode: 2D sharding — tensor-parallel dims (heads / d_ff / experts /
vocab / d_inner) on "model", FSDP on "data" over the other large dim
(params are all-gathered per layer on use, reduce-scattered on grad, i.e.
ZeRO-3).  Optimizer state mirrors param shardings.  Batch is data-parallel
over ("pod", "data") on the multi-pod mesh — params are sharded *within*
a pod and replicated across pods (gradients all-reduce over "pod"), which
keeps the slow inter-pod links off the per-layer all-gather path.

Serve mode: TP only (no FSDP) — weights must be resident, decode is
latency-bound.  KV caches shard batch over "data" and kv-heads over
"model" when divisible, else the sequence dim takes "model".

Every rule degrades gracefully: if a dim is not divisible by the mesh axis
it would take, the dim is left unsharded (GSPMD correctness > perfect
balance; the fallbacks are listed in DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .config import ModelConfig, ShapeSpec

# §Perf knob (benchmarks/perf_lab.py): "2d" = TP over "model" + FSDP over
# "data" (default); "dp_only" = no tensor parallelism — the model axis joins
# data parallelism and params shard over all 256 chips (right-sizes TP for
# small models whose TP collectives dominate).
MODE = "2d"


# ----------------------------------------------------------------------
def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def maybe(mesh: Mesh, axis, dim: int):
    """Use `axis` for a dim only when it divides evenly."""
    return axis if axis is not None and dim % _axis_size(mesh, axis) == 0 \
        else None


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch data-parallel axes: ("pod","data") on multi-pod meshes;
    in dp_only mode the "model" axis joins data parallelism."""
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    if MODE == "dp_only":
        dp = dp + ("model",)
    return dp


# ----------------------------------------------------------------------
# parameter shardings by name
# ----------------------------------------------------------------------
def _param_spec(mesh: Mesh, cfg: ModelConfig, path: Tuple[str, ...],
                shape: Tuple[int, ...], fsdp: bool) -> P:
    name = path[-1]
    stacked = "layers" in path          # leading L axis
    if MODE == "dp_only":
        dp = data_axes(mesh) if fsdp else None
        mdl = None
    else:
        dp = "data" if fsdp else None
        mdl = "model"

    def spec(*axes):
        lead = (None,) if stacked else ()
        axes = lead + axes
        return P(*axes)

    dims = shape[1:] if stacked else shape

    if name in ("embed",):
        return P(maybe(mesh, mdl, shape[0]),
                 maybe(mesh, dp, shape[1]))
    if name == "lm_head":
        return P(maybe(mesh, dp, shape[0]), maybe(mesh, mdl, shape[1]))
    if name in ("final_norm", "attn_norm", "mlp_norm", "ssm_norm",
                "cross_norm", "q_norm", "k_norm", "dt_bias_"):
        return spec(*([None] * len(dims)))
    if name in ("wq", "wk", "wv"):
        return spec(maybe(mesh, dp, dims[0]), maybe(mesh, mdl, dims[1]))
    if name == "wo":
        return spec(maybe(mesh, mdl, dims[0]), maybe(mesh, dp, dims[1]))
    if name in ("bq", "bk", "bv"):
        return spec(maybe(mesh, mdl, dims[0]))
    if name in ("w_gate", "w_up", "wi"):
        return spec(maybe(mesh, dp, dims[0]), maybe(mesh, mdl, dims[1]))
    if name in ("w_down",):
        return spec(maybe(mesh, mdl, dims[0]), maybe(mesh, dp, dims[1]))
    if name == "router":
        return spec(maybe(mesh, dp, dims[0]), None)
    if name in ("we_gate", "we_up"):            # [E, D, F]
        if dims[0] % _axis_size(mesh, mdl) == 0:   # expert parallel
            return spec(mdl, maybe(mesh, dp, dims[1]), None)
        return spec(None, maybe(mesh, dp, dims[1]),
                    maybe(mesh, mdl, dims[2]))
    if name == "we_down":                        # [E, F, D]
        if dims[0] % _axis_size(mesh, mdl) == 0:
            return spec(mdl, None, maybe(mesh, dp, dims[2]))
        return spec(None, maybe(mesh, mdl, dims[1]),
                    maybe(mesh, dp, dims[2]))
    if name == "in_proj":                        # [D, 2*di]
        return spec(maybe(mesh, dp, dims[0]), maybe(mesh, mdl, dims[1]))
    if name == "conv_w":                         # [kc, di]
        return spec(None, maybe(mesh, mdl, dims[1]))
    if name in ("conv_b", "D", "dt_bias"):       # [di]
        return spec(maybe(mesh, mdl, dims[0]))
    if name == "x_proj":                         # [di, rk+2N]
        return spec(maybe(mesh, mdl, dims[0]), None)
    if name == "dt_proj":                        # [rk, di]
        return spec(None, maybe(mesh, mdl, dims[1]))
    if name == "A_log":                          # [di, N]
        return spec(maybe(mesh, mdl, dims[0]), None)
    if name == "out_proj":                       # [di, D]
        return spec(maybe(mesh, mdl, dims[0]), maybe(mesh, dp, dims[1]))
    # default: replicate
    return spec(*([None] * len(dims)))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "name"):
            names.append(str(k.name))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(mesh: Mesh, cfg: ModelConfig, params_shape,
                fsdp: bool = True):
    """PartitionSpec tree matching a params (or shapes) pytree."""
    def f(path, leaf):
        return _param_spec(mesh, cfg, _path_names(path),
                           tuple(leaf.shape), fsdp)
    return jax.tree_util.tree_map_with_path(f, params_shape)


def state_specs(mesh: Mesh, cfg: ModelConfig, state_shape,
                fsdp: bool = True):
    """Shardings for {"params": ..., "opt": OptState} training state.
    master/mu/nu mirror the param shardings; step is replicated."""
    pspec = param_specs(mesh, cfg, state_shape["params"], fsdp)
    opt = state_shape["opt"]
    return {
        "params": pspec,
        "opt": type(opt)(
            step=P(),
            master=param_specs(mesh, cfg, opt.master, fsdp),
            mu=param_specs(mesh, cfg, opt.mu, fsdp),
            nu=param_specs(mesh, cfg, opt.nu, fsdp),
        ),
    }


# ----------------------------------------------------------------------
# batch / cache shardings
# ----------------------------------------------------------------------
def batch_specs(mesh: Mesh, cfg: ModelConfig, shape: ShapeSpec):
    dp = data_axes(mesh)
    specs: Dict[str, P] = {"tokens": P(dp, None)}
    if shape.kind == "train":
        specs["targets"] = P(dp, None)
    if cfg.encoder_layers:
        specs["frames"] = P(dp, None, None)
    if cfg.vision_prefix:
        specs["vision_embeds"] = P(dp, None, None)
    return specs


def cache_specs(mesh: Mesh, cfg: ModelConfig, caches_shape):
    """Decode cache shardings: [L, B, S, KV, D] (or SSM state trees)."""
    dp = data_axes(mesh)

    def f(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shp = leaf.shape
        if name in ("k", "v", "cross_k", "cross_v"):
            _, b, s, kv, hd = shp
            if kv % _axis_size(mesh, "model") == 0:
                return P(None, maybe(mesh, dp, b), None, "model", None)
            return P(None, maybe(mesh, dp, b),
                     maybe(mesh, "model", s), None, None)
        if name == "conv":                       # [L, B, kc-1, di]
            return P(None, maybe(mesh, dp, shp[1]), None,
                     maybe(mesh, "model", shp[3]))
        if name == "ssm":                        # [L, B, di, N]
            return P(None, maybe(mesh, dp, shp[1]),
                     maybe(mesh, "model", shp[2]), None)
        return P(*([None] * len(shp)))

    return jax.tree_util.tree_map_with_path(f, caches_shape)


def logits_spec(mesh: Mesh, cfg: ModelConfig):
    return P(data_axes(mesh), None, maybe(mesh, "model", cfg.vocab_size))


def named(mesh: Mesh, tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))


def activation_constraint(mesh: Mesh, seq_shard: bool = False):
    """Activation sharding constraints, applied at key program points.

    kind="act":      between-layer residuals [B,S,D] — batch over data, and
                     optionally seq over "model" (sequence parallelism).
    kind="moe_buf":  expert dispatch buffers [B,E,C,D] — batch over data,
                     E over "model" when divisible (expert parallelism).
                     GSPMD loses the batch sharding through the dispatch
                     scatter without this (it replicates the global batch).
    kind="moe_h":    expert FFN hidden [B,E,C,F] — as moe_buf, plus F over
                     "model" in the TP fallback.
    """
    dp = data_axes(mesh)
    seq = "model" if seq_shard else None

    def f(x, kind: str = "act"):
        if kind == "act":
            spec = P(dp, seq, None)
        else:
            e = x.shape[1]
            ep = maybe(mesh, "model", e)
            if kind == "moe_h" and ep is None:
                spec = P(dp, None, None, maybe(mesh, "model", x.shape[-1]))
            else:
                spec = P(dp, ep, None, None)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec))

    # metadata the model uses to pick mesh-aware paths (shard_map MoE)
    f.mesh = mesh
    f.dp = dp
    f.seq_shard = seq_shard
    return f
