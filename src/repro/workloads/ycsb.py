"""YCSB-style workload generation + closed-loop client runner.

The six core workloads (§4 Exp#1) and the W1-W4 mixes of Exp#2 are expressed
as ``WorkloadSpec``s.  Key popularity follows a Zipf distribution with
parameter alpha over *scrambled* key ranks (YCSB hashes keys, so hot keys are
scattered across the key space and therefore across SSTs).  Workload D reads
the most recently inserted keys ("latest" distribution).

The runner drives N closed-loop client processes against the simulated DB
and records per-operation latency in virtual time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Union

import numpy as np

# op codes
READ, UPDATE, INSERT, SCAN, RMW = 0, 1, 2, 3, 4
OP_NAMES = {READ: "read", UPDATE: "update", INSERT: "insert",
            SCAN: "scan", RMW: "rmw"}


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    read: float = 0.0
    update: float = 0.0
    insert: float = 0.0
    scan: float = 0.0
    rmw: float = 0.0
    dist: str = "zipf"        # "zipf" | "latest" | "hotspot"
    alpha: float = 0.9
    scan_max: int = 100
    # "hotspot" distribution: zipf-popular ranks map to a *contiguous*
    # key range (no scramble) whose base drifts by ``hotspot_step`` keys
    # on a schedule — a moving hot spot in keyspace, the adversarial load
    # for range sharding (the hot range concentrates on one shard, then
    # walks off it).  ``hotspot_step`` semantics:
    #   "auto" -> n_keys // 8, resolved when the stream is built
    #   0      -> stationary hotspot (no drift)
    #   k > 0  -> walk by k keys per period
    # The walk schedule is ``hotspot_period_s`` *virtual seconds* when
    # set (schemes at different service rates see the same hot range at
    # the same virtual time — the drift-trace mode), else every
    # ``hotspot_period`` *ops* (legacy op-index mode, kept for backward
    # compat: it advances at the stream's own service rate).
    hotspot_period: int = 2000
    hotspot_step: Union[int, str] = "auto"
    hotspot_period_s: Optional[float] = None

    def mix(self):
        return np.array([self.read, self.update, self.insert,
                         self.scan, self.rmw], dtype=np.float64)


# The six YCSB core workloads (Exp#1), alpha=0.9 per the paper ([28] default)
YCSB = {
    "A": WorkloadSpec("A", read=0.5, update=0.5),
    "B": WorkloadSpec("B", read=0.95, update=0.05),
    "C": WorkloadSpec("C", read=1.0),
    "D": WorkloadSpec("D", read=0.95, insert=0.05, dist="latest"),
    "E": WorkloadSpec("E", scan=0.95, insert=0.05),
    "F": WorkloadSpec("F", read=0.5, rmw=0.5),
}


def mixed(name: str, read_frac: float, alpha: float) -> WorkloadSpec:
    """Exp#2-4 style workloads: read/update mixes at a given skewness."""
    return WorkloadSpec(name, read=read_frac, update=1.0 - read_frac,
                        alpha=alpha)


def zipf_probs(n: int, alpha: float) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return p / p.sum()


@dataclass
class Ops:
    codes: np.ndarray       # int8 op codes
    args: np.ndarray        # int64: zipf rank / recency offset / scan len<<32|rank
    scan_lens: np.ndarray   # int32


def generate_ops(spec: WorkloadSpec, n_ops: int, n_keys: int,
                 seed: int = 0) -> Ops:
    rng = np.random.default_rng(seed)
    codes = rng.choice(5, size=n_ops, p=spec.mix() / spec.mix().sum())
    p = zipf_probs(n_keys, spec.alpha)
    ranks = rng.choice(n_keys, size=n_ops, p=p)
    scan_lens = rng.integers(1, spec.scan_max + 1, size=n_ops,
                             dtype=np.int32)
    return Ops(codes=codes.astype(np.int8), args=ranks.astype(np.int64),
               scan_lens=scan_lens)


@dataclass
class WorkloadResult:
    name: str
    scheme: str
    n_ops: int
    duration: float
    throughput: float                     # OPS in virtual time
    latency_p: Dict[str, float]           # percentiles over all ops
    read_latency_p: Dict[str, float]      # percentiles over reads only
    op_counts: Dict[str, int]
    extras: Dict[str, float]

    def row(self) -> str:
        return (f"{self.scheme:7s} {self.name:6s} ops={self.n_ops} "
                f"dur={self.duration:9.3f}s thpt={self.throughput:10.1f} OPS "
                f"p99={self.latency_p.get('p99', 0)*1e3:8.3f}ms")


_PCTS = {"p50": 50, "p90": 90, "p99": 99, "p999": 99.9, "p9999": 99.99}


def _pct(lat: np.ndarray) -> Dict[str, float]:
    if len(lat) == 0:
        return {k: 0.0 for k in _PCTS}
    return {k: float(np.percentile(lat, q)) for k, q in _PCTS.items()}


class OpStream:
    """Pre-generated op stream + key resolution, shared by the closed-loop
    runner below and the open-loop engine (``repro.workloads.runner``).

    Key resolution semantics (scrambled Zipf popularity, "latest" reads
    against the insert frontier, frontier-advancing inserts) live here so
    every runner drives the tree identically.
    """

    def __init__(self, db, spec: WorkloadSpec, n_ops: int, n_keys: int,
                 seed: int = 1):
        self.spec = spec
        self.ops = generate_ops(spec, n_ops, n_keys, seed=seed)
        self.n_ops = n_ops
        self.n_keys = n_keys
        # scrambled popularity: zipf rank -> key id
        self.scramble = np.random.default_rng(seed + 1) \
            .permutation(n_keys).astype(np.int64)
        self.load_order = getattr(db, "load_order",
                                  np.arange(n_keys, dtype=np.int64))
        # the insert frontier starts at the number of keys actually
        # loaded, not at n_keys: a stream may declare a keyspace larger
        # than the loaded prefix (drift "grow" phases) and the gap is
        # filled by frontier-advancing inserts, never by load_order
        self._loaded = min(n_keys, len(self.load_order))
        self.frontier = self._loaded      # total inserted keys (D/E inserts)
        self.db = db
        self.counts = {name: 0 for name in OP_NAMES.values()}
        step = spec.hotspot_step
        self._hot_step = max(1, n_keys // 8) if step == "auto" else int(step)
        # virtual-time origin for the hotspot_period_s walk: drift is
        # measured from stream creation, not absolute sim time (load
        # phases of different lengths must not offset the schedule)
        self._t0 = float(db.sim.now)
        # originating tenant for write attribution (set by the
        # multi-tenant runner): rides every put() into the tree, tagging
        # flushed bytes for per-tenant compaction-debt attribution
        self.tenant: Optional[str] = None

    @property
    def tree(self):
        # resolved per-op, not cached: DB.reopen() swaps in a fresh tree
        # (or the sharded facade re-routes) and queued ops must not write
        # into discarded state
        return self.db.kv

    def resolve(self, code: int, rank: int, i: int = 0) -> int:
        if self.spec.dist == "latest" and code == READ:
            # most-recent first: offset `rank` back from the insert frontier
            off = self.frontier - 1 - rank
            if off < 0:
                off = 0
            return int(self.load_order[off]) if off < self._loaded else off
        if self.spec.dist == "hotspot":
            # contiguous drifting hot range: popular ranks land next to
            # each other in keyspace (deliberately unscrambled) and the
            # base walks every hotspot_period_s virtual seconds (or, in
            # the legacy mode, every hotspot_period ops)
            if self.spec.hotspot_period_s:
                epoch = int((self.db.sim.now - self._t0)
                            // self.spec.hotspot_period_s)
            else:
                epoch = i // max(1, self.spec.hotspot_period)
            return int((rank + epoch * self._hot_step) % self.n_keys)
        return int(self.scramble[rank % self.n_keys])

    def is_point_read(self, i: int) -> bool:
        """Whether op ``i`` is a point READ (batchable by the open-loop
        runner's vectorized-probe read path)."""
        return int(self.ops.codes[i]) == READ

    def execute_read_batch(self, idxs):
        """Generator servicing several point READs in one
        ``LSMTree.get_batch`` call (vectorized Bloom probing).  Result-
        identical to executing them one by one; only service timing and
        python overhead differ."""
        keys = [self.resolve(READ, int(self.ops.args[i]), int(i))
                for i in idxs]
        res = yield from self.tree.get_batch(keys)
        self.counts["read"] += len(idxs)
        return res

    def execute(self, i: int):
        """Generator running op ``i`` against the tree (virtual-timed)."""
        code = int(self.ops.codes[i])
        rank = int(self.ops.args[i])
        # tenant tag only when set: untagged streams call put(key) exactly
        # as before, keeping single-stream runs event-for-event unchanged
        kw = {"tenant": self.tenant} if self.tenant is not None else {}
        if code == READ:
            yield from self.tree.get(self.resolve(code, rank, i))
        elif code == UPDATE:
            yield from self.tree.put(self.resolve(code, rank, i), **kw)
        elif code == INSERT:
            key = self.frontier
            self.frontier += 1
            yield from self.tree.put(key, **kw)
        elif code == SCAN:
            yield from self.tree.scan(self.resolve(code, rank, i),
                                      int(self.ops.scan_lens[i]))
        elif code == RMW:
            key = self.resolve(code, rank, i)
            yield from self.tree.get(key)
            yield from self.tree.put(key, **kw)
        self.counts[OP_NAMES[code]] += 1


def collect_extras(db) -> Dict[str, float]:
    """Device/cache/migration counters attached to every result row —
    delegated to the store (``DB.extras`` / ``ShardedDB.extras``, which
    aggregates across shards)."""
    return db.extras()


def run_load(db, n_keys: int, num_clients: int = 16, seed: int = 42,
             sampler=None) -> WorkloadResult:
    """Load phase: insert all keys in scrambled order."""
    rng = np.random.default_rng(seed)
    load_order = rng.permutation(n_keys).astype(np.int64)
    db.load_order = load_order          # recency mapping for workload D
    tree, sim = db.kv, db.sim
    t0 = sim.now
    lat: List[float] = []
    cursor = {"i": 0}

    def client():
        while True:
            i = cursor["i"]
            if i >= n_keys:
                return
            cursor["i"] += 1
            s = sim.now
            yield from tree.put(int(load_order[i]))
            lat.append(sim.now - s)

    procs = [sim.process(client()) for _ in range(num_clients)]
    for p in procs:
        sim.run_until(p)
    dur = sim.now - t0
    lat_arr = np.asarray(lat)
    return WorkloadResult(
        name="load", scheme=db.scheme, n_ops=n_keys, duration=dur,
        throughput=n_keys / max(dur, 1e-12), latency_p=_pct(lat_arr),
        read_latency_p={}, op_counts={"insert": n_keys},
        extras={})


def run_workload(db, spec: WorkloadSpec, n_ops: int, n_keys: int,
                 num_clients: int = 16, seed: int = 1) -> WorkloadResult:
    """Run phase: closed-loop clients over a pre-generated op stream."""
    stream = OpStream(db, spec, n_ops, n_keys, seed=seed)
    sim = db.sim
    t0 = sim.now
    lat = np.zeros(n_ops, np.float64)
    cursor = {"i": 0}

    def client():
        while True:
            i = cursor["i"]
            if i >= n_ops:
                return
            cursor["i"] += 1
            s = sim.now
            yield from stream.execute(i)
            lat[i] = sim.now - s

    procs = [sim.process(client()) for _ in range(num_clients)]
    for p in procs:
        sim.run_until(p)
    dur = sim.now - t0
    reads_mask = stream.ops.codes == READ
    return WorkloadResult(
        name=spec.name, scheme=db.scheme, n_ops=n_ops, duration=dur,
        throughput=n_ops / max(dur, 1e-12),
        latency_p=_pct(lat), read_latency_p=_pct(lat[reads_mask]),
        op_counts=stream.counts, extras=collect_extras(db))


class LevelSampler:
    """Samples actual level sizes every ``period`` (O1, Fig. 2a)."""

    def __init__(self, db, period: float = 60.0):
        self.db = db
        self.period = period
        self.samples: List[List[int]] = []
        self.wal_samples: List[int] = []
        db.sim.process(self._run())

    def _run(self):
        while True:
            yield self.db.sim.timeout(self.period, daemon=True)
            self.samples.append(self.db.tree.level_sizes())
            self.wal_samples.append(self.db.backend.wal_zones_in_use())

    def stats(self):
        if not self.samples:
            return None
        arr = np.asarray(self.samples, dtype=np.float64)
        return {
            "min": arr.min(axis=0), "max": arr.max(axis=0),
            "median": np.median(arr, axis=0),
            "q1": np.percentile(arr, 25, axis=0),
            "q3": np.percentile(arr, 75, axis=0),
        }
