"""Open-loop LLM KV-cache serving scenarios on the DES clock.

The serving analogue of ``run_open_loop``: sequences (requests) arrive by
an ``ArrivalProcess``, each with a sampled prompt and output length, and a
single continuous-batching engine prefills/decodes them against the
two-tier paged KV stack (``repro.serving``) under a selectable placement
policy — ``static`` (HBM-only with rejection), ``lru`` (hint-blind
demotion) or ``hhzs`` (the paper's §3.3–3.5 hint-driven manager).  See
``repro.serving.policies``.

Engine time is charged from a deterministic cost model
(:class:`ServingCosts`): prefill per prompt token, a per-step floor, an
attention read per resident token priced by tier (host-resident KV is the
slow path; the §3.5 prefix cache serves its span at HBM price), and
migration bytes at DMA bandwidth.  Everything — arrivals, lengths,
preempt/resume churn — is seeded, so a cell's rows are byte-identical for
any worker count or telemetry setting, which is what lets serving cells
ride the existing parallel sweep driver (``repro.workloads.sweep``) and
its CI determinism gates.

Preempt/resume churn: each decoded token may pause its sequence (seeded
per-sequence draws, identical across policies), modelling user think time
/ scheduler preemption.  Paused sequences go cold; the tier managers
demote them and pay promotion on resume — the churn that differentiates
placement policies (cf. Keigo's concurrency argument).

Tenants are ``TenantSpec``s whose ``workload`` is a
:class:`ServingWorkload` and whose ``slo_p99`` is a time-to-first-token
target; admission verdicts and the SLO feedback plane
(``repro.obs.control``) come from the same control stack the storage
runners use.

Verification mode (``materialize=True, verify=True``): KV payloads are a
deterministic function of (sequence id, position) and every decode step
re-reads the full resident KV of every active sequence — any tier
migration or cache admit that corrupts, drops or aliases a page fails
loudly.  This is the differential the correctness suite runs under every
policy.

CLI (the serving grid; same sweep semantics as ``repro.workloads.sweep``)::

  PYTHONPATH=src python -m repro.workloads.serving \\
      --policies static,lru,hhzs --arrivals poisson,bursty \\
      --hbm 10,16 --rate 3 --out results/storage/serving.json
"""
from __future__ import annotations

import argparse
import json
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.middleware import (DELAY, REJECT, AdmissionConfig,
                               AdmissionController)
from ..serving.paged_kv import PagedPool
from ..serving.policies import POLICIES, make_manager
from ..zoned.sim import Sim
from .runner import (ArrivalProcess, BurstyArrivals, FlashCrowdArrivals,
                     PoissonArrivals, TenantSpec)
from .ycsb import _pct


# ======================================================================
# specs
# ======================================================================
@dataclass(frozen=True)
class ServingWorkload:
    """Prompt/output shape of one serving tenant's traffic.

    Lengths are lognormal around the medians (the shape observed in chat
    traces), clipped to the caps.  ``pause_prob`` is the per-decoded-token
    probability the sequence pauses (user think time / preemption) for an
    Exp(``pause_mean``) interval.  ``slo_ttft`` is the tenant's
    time-to-first-token p99 target in virtual seconds (the serving
    ``TenantSpec.slo_p99``)."""

    name: str = "chat"
    prompt_med: int = 96
    prompt_sigma: float = 0.6
    prompt_max: int = 384
    out_med: int = 48
    out_sigma: float = 0.5
    out_max: int = 192
    pause_prob: float = 0.005
    pause_mean: float = 8.0
    slo_ttft: Optional[float] = None

    def _lengths(self, rng: np.random.Generator, n: int, med: int,
                 sigma: float, cap: int) -> np.ndarray:
        ln = rng.lognormal(np.log(max(med, 1)), sigma, n)
        return np.clip(np.rint(ln), 1, cap).astype(np.int64)

    def sample(self, rng: np.random.Generator,
               n: int) -> Tuple[np.ndarray, np.ndarray]:
        """(prompt_lens, out_lens) for n requests — one vectorized draw
        per run so the streams are policy-independent."""
        return (self._lengths(rng, n, self.prompt_med, self.prompt_sigma,
                              self.prompt_max),
                self._lengths(rng, n, self.out_med, self.out_sigma,
                              self.out_max))


@dataclass(frozen=True)
class ServingPool:
    """Sizing of the two-tier paged KV stack for one cell."""

    hbm_zones: int = 12
    host_zones: int = 96
    pages_per_zone: int = 4
    page_size: int = 16
    num_layers: int = 2
    kv_heads: int = 2
    head_dim: int = 16
    cache_zones: int = 2               # §3.5 reserved prefix-cache zones
    max_batch: int = 8                 # live (running+paused) sequences
    migration_budget: int = 1          # zones per tick (§3.4 rate limit)

    @property
    def zone_tokens(self) -> int:
        return self.pages_per_zone * self.page_size

    def build(self, materialize: bool = False) -> Tuple[PagedPool, PagedPool]:
        mk = lambda name, zones, host: PagedPool(
            name, self.num_layers, zones, self.pages_per_zone,
            self.page_size, self.kv_heads, self.head_dim, host=host,
            materialize=materialize)
        return (mk("hbm", self.hbm_zones, False),
                mk("host", self.host_zones, True))


@dataclass(frozen=True)
class ServingCosts:
    """Virtual-seconds cost model of one engine step (all deterministic).

    ``decode_base`` is the per-step floor (kernel launch + sampling);
    each active sequence adds its resident-KV read priced per token by
    tier; prompt prefill charges per token; migration bytes issued this
    step are charged at ``dma_bandwidth``."""

    prefill_token: float = 1e-4
    decode_base: float = 2e-3
    hbm_token: float = 1e-6
    host_token: float = 2e-5
    dma_bandwidth: float = 8 * 2**20   # bytes / virtual second


# ======================================================================
# the engine run
# ======================================================================
@dataclass
class _Live:
    """One admitted sequence inside the engine."""
    ti: int
    i: int
    sid: int
    out_target: int
    rng: np.random.Generator
    state: str = "running"             # running | paused
    produced: int = 0
    resume_at: float = 0.0
    last_tok: float = 0.0
    skip_gap: bool = False             # first token after a pause: the
    # think-time is not engine latency (the promotion stall after it is)


def _payload(sid: int, pos: int, shape) -> np.ndarray:
    """Deterministic token KV payload for the verification differential."""
    return np.full(shape, ((sid * 100003 + pos) % 65521) / 7.0, np.float32)


def _verify_resident(mgr, seq, shape) -> None:
    """Re-read a sequence's full resident KV; raise if any page was
    corrupted, dropped or aliased by migration."""
    pos = 0
    pool = mgr.pool_of(seq)
    for z in seq.zones:
        for idx in range(z.write_ptr):
            k, _ = pool.read_token(z, idx)
            want = _payload(seq.sid, pos, shape)
            if not np.array_equal(k, want):
                raise AssertionError(
                    f"KV mismatch: sid={seq.sid} pos={pos} tier={seq.tier} "
                    f"zone={z.zid} got {k.flat[0]} want {want.flat[0]}")
            pos += 1


def _verify_cache(mgr, sid: int, shape) -> None:
    """The cached prefix copy must read back as the sequence's first
    tokens — the §3.5 consistency invariant after demotion."""
    cz = mgr.prefix_cache.get(sid)
    if cz is None:
        return
    for idx in range(cz.write_ptr):
        k, _ = mgr.hbm.read_token(cz, idx)
        want = _payload(sid, idx, shape)
        if not np.array_equal(k, want):
            raise AssertionError(
                f"prefix-cache mismatch: sid={sid} pos={idx} "
                f"got {k.flat[0]} want {want.flat[0]}")


@dataclass
class ServingResult:
    """One serving run: per-tenant rows + run-level manager stats."""

    rows: List[Dict]
    stats: Dict[str, float]
    duration: float

    def row(self) -> str:
        r = self.rows[0]
        return (f"serving {r['tiering']:<6s} {r['workload']:<6s} "
                f"{r['arrival']:<28s} ttft_p99={r['ttft_p']['p99']:7.3f}s "
                f"decode_p99={r['decode_p']['p99'] * 1e3:7.2f}ms "
                f"hbm_hit={r['hbm_hit_rate']:.3f} "
                f"adm={r['admitted']}/{r['n_arrived']}")


def run_serving(tenants: Sequence[TenantSpec],
                policy: str = "hhzs", *,
                pool: Optional[ServingPool] = None,
                costs: Optional[ServingCosts] = None,
                duration: float = 300.0,
                warmup: float = 30.0,
                seed: int = 1,
                admission: Union[AdmissionConfig, str, None] = None,
                materialize: bool = False,
                verify: Union[bool, str] = False,
                sim: Optional[Sim] = None,
                registry=None) -> ServingResult:
    """Open-loop serving run: arrivals -> admission -> prefill -> decode.

    Each ``TenantSpec``'s workload must be a :class:`ServingWorkload`;
    its ``slo_p99`` is a TTFT target.  Deterministic given (tenants,
    policy, pool, costs, duration, seed) — telemetry (``registry``) is
    pull-only and never changes the rows.

    ``verify=True`` (needs ``materialize=True``) re-reads every
    sequence's full KV at completion and its cached prefix every decode
    step; ``verify="step"`` re-reads the full resident KV of every
    active sequence every step — O(steps x batch x length), for
    test-scale runs only."""
    if policy not in POLICIES:
        raise ValueError(f"unknown policy {policy!r} (known {POLICIES})")
    pool = pool or ServingPool()
    costs = costs or ServingCosts()
    if verify and not materialize:
        raise ValueError("verify=True needs materialize=True")
    sim = sim or Sim()
    hbm, host = pool.build(materialize=materialize)
    mgr = make_manager(policy, hbm, host, cache_zones=pool.cache_zones,
                       migration_zone_budget_per_step=pool.migration_budget)
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    wls: List[ServingWorkload] = []
    for t in tenants:
        if not isinstance(t.workload, ServingWorkload):
            raise TypeError(f"tenant {t.name!r}: serving tenants take a "
                            f"ServingWorkload, got {type(t.workload)}")
        wls.append(t.workload)
    kv_shape = (pool.num_layers, pool.kv_heads, pool.head_dim)

    ctrl = AdmissionController(
        sim, None, admission if admission is not None else "none")
    prot = frozenset(t.name for t in tenants if t.protected)
    if prot:
        ctrl.cfg = replace(ctrl.cfg,
                           protected=frozenset(ctrl.cfg.protected) | prot)
    control = None
    if ctrl.cfg.policy == "feedback":
        from ..obs.control import ControlPlane
        control = ControlPlane(
            sim, ctrl,
            targets={t.name: t.slo_p99 for t in tenants
                     if t.protected and t.slo_p99},
            registry=registry)
        control.start()

    # seeded streams, mirroring run_multi_tenant's per-tenant strides
    rels, prompts, outs = [], [], []
    for ti, t in enumerate(tenants):
        arr_rng = np.random.default_rng(seed + 2 + 9973 * ti)
        rels.append(t.arrival.times(arr_rng, duration))
        len_rng = np.random.default_rng(seed + 5 + 9973 * ti)
        p, o = wls[ti].sample(len_rng, len(rels[ti]))
        prompts.append(p)
        outs.append(o)
    m_at = np.concatenate(rels) if rels else np.empty(0, np.float64)
    m_ti = np.concatenate([np.full(len(r), ti, np.int64)
                           for ti, r in enumerate(rels)]) \
        if rels else np.empty(0, np.int64)
    m_i = np.concatenate([np.arange(len(r), dtype=np.int64)
                          for r in rels]) if rels else np.empty(0, np.int64)
    order = np.argsort(m_at, kind="stable")
    m_at, m_ti, m_i = m_at[order], m_ti[order], m_i[order]
    # sid = merged arrival rank: deterministic and policy-independent
    sids = [np.full(len(r), -1, np.int64) for r in rels]
    for j in range(len(m_at)):
        sids[int(m_ti[j])][int(m_i[j])] = j

    t0 = sim.now
    arrive = [np.full(len(r), np.nan) for r in rels]
    first = [np.full(len(r), np.nan) for r in rels]       # TTFT stamp
    done = [np.full(len(r), np.nan) for r in rels]
    shed = [np.zeros(len(r), bool) for r in rels]
    cap_rej = [0] * len(tenants)          # policy capacity rejections
    tok_hbm = [0] * len(tenants)          # resident-KV reads by tier
    tok_host = [0] * len(tenants)
    tok_out = [0] * len(tenants)
    gaps: List[List[float]] = [[] for _ in tenants]        # decode gaps
    pauses = [0] * len(tenants)
    queue: List[Tuple[int, int]] = []
    live: Dict[int, _Live] = {}
    idle: List = []
    state = {"dispatched": False, "holding": 0, "max_queue": 0,
             "max_live": 0}
    eng = {"steps": 0, "tokens_out": 0}   # registry-visible counters
    ctrl.queue_gauge = lambda: len(queue)

    def _enqueue(ti: int, i: int) -> None:
        queue.append((ti, i))
        state["max_queue"] = max(state["max_queue"], len(queue))
        if idle:
            idle.pop().succeed()

    def _maybe_close() -> None:
        if state["dispatched"] and state["holding"] == 0:
            while idle:
                idle.pop().succeed()

    def held(ti: int, i: int):
        yield from ctrl.hold(names[ti])
        state["holding"] -= 1
        _enqueue(ti, i)
        _maybe_close()

    def dispatcher():
        for j in range(len(m_at)):
            at = t0 + float(m_at[j])
            if at > sim.now:
                yield at - sim.now
            ti, i = int(m_ti[j]), int(m_i[j])
            arrive[ti][i] = sim.now
            verdict = ctrl.decide(names[ti])
            if verdict == REJECT:
                shed[ti][i] = True
                continue
            if verdict == DELAY:
                state["holding"] += 1
                sim.process(held(ti, i))
                continue
            _enqueue(ti, i)
        state["dispatched"] = True
        _maybe_close()

    def _write_tok(seq, sid: int) -> None:
        zone = mgr.writable_zone(seq)
        if materialize:
            pl = _payload(sid, seq.length, kv_shape)
            mgr.pool_of(seq).write_token(zone, pl, pl)
        else:
            mgr.pool_of(seq).write_token(zone)
        seq.length += 1

    def engine():
        while True:
            if not queue and not live:
                if state["dispatched"] and state["holding"] == 0:
                    return
                ev = sim.event()
                idle.append(ev)
                yield ev
                continue
            now = sim.now
            for r in live.values():
                if r.state == "paused" and r.resume_at <= now:
                    r.state = "running"
            running = [r for r in live.values() if r.state == "running"]
            if not running and not (queue and len(live) < pool.max_batch):
                # everyone is paused and no admission is possible: sleep
                # to the earliest resume (arrivals in between just queue)
                nxt = min(r.resume_at for r in live.values()
                          if r.state == "paused")
                yield max(nxt - now, 1e-9)
                continue
            cost = costs.decode_base
            admitted_now: List[_Live] = []
            while queue and len(live) < pool.max_batch:
                ti, i = queue.pop(0)
                sid = int(sids[ti][i])
                total = int(prompts[ti][i] + outs[ti][i])
                if not mgr.admit(sid, total):
                    shed[ti][i] = True
                    cap_rej[ti] += 1
                    continue
                seq = mgr.on_prefill(sid, int(prompts[ti][i]))
                for _ in range(int(prompts[ti][i])):
                    _write_tok(seq, sid)
                cost += int(prompts[ti][i]) * costs.prefill_token
                r = _Live(ti=ti, i=i, sid=sid,
                          out_target=int(outs[ti][i]),
                          rng=np.random.default_rng(
                              (seed + 11) * 1_000_003 + sid))
                live[sid] = r
                admitted_now.append(r)
                state["max_live"] = max(state["max_live"], len(live))
            running = [r for r in live.values() if r.state == "running"]
            mig0 = mgr.stats["bytes_migrated"]
            mgr.tick([r.sid for r in running])
            for r in running:
                seq = mgr.seqs[r.sid]
                h, c = mgr.residency(seq)
                tok_hbm[r.ti] += h
                tok_host[r.ti] += c
                cost += h * costs.hbm_token + c * costs.host_token
                if verify == "step":
                    _verify_resident(mgr, seq, kv_shape)
                if verify:
                    _verify_cache(mgr, r.sid, kv_shape)
                _write_tok(seq, r.sid)
                r.produced += 1
            cost += (mgr.stats["bytes_migrated"] - mig0) \
                / costs.dma_bandwidth
            eng["steps"] += 1
            yield cost
            now = sim.now
            for r in admitted_now:
                first[r.ti][r.i] = now
            for r in running:
                tok_out[r.ti] += 1
                eng["tokens_out"] += 1
                if r.produced > 1 and not r.skip_gap:
                    gaps[r.ti].append(now - r.last_tok)
                r.skip_gap = False
                r.last_tok = now
                if r.produced >= r.out_target:
                    done[r.ti][r.i] = now
                    if verify:
                        _verify_resident(mgr, mgr.seqs[r.sid], kv_shape)
                    mgr.release(r.sid)
                    del live[r.sid]
                    if control is not None:
                        control.observe(names[r.ti],
                                        now - arrive[r.ti][r.i])
                elif r.rng.random() < wls[r.ti].pause_prob:
                    r.state = "paused"
                    r.skip_gap = True
                    r.resume_at = now + r.rng.exponential(
                        wls[r.ti].pause_mean)
                    pauses[r.ti] += 1

    if registry is not None:
        registry.gauge("serving.hbm_free_zones",
                       lambda: float(hbm.num_free()))
        registry.gauge("serving.host_free_zones",
                       lambda: float(host.num_free()))
        registry.gauge("serving.queue_depth", lambda: float(len(queue)))
        registry.gauge("serving.live_seqs", lambda: float(len(live)))
        registry.attach_dict(mgr.stats, prefix="serving.", rate=True,
                             name="serving.mgr")
        registry.attach_dict(eng, prefix="serving.", rate=True,
                             name="serving.engine")
        registry.start()

    sim.process(dispatcher())
    eng_proc = sim.process(engine())
    sim.run_until(eng_proc)
    busy = max(sim.now - t0, 1e-12)
    ctrl.queue_gauge = None
    if control is not None:
        control.stop()

    rows: List[Dict] = []
    for ti, t in enumerate(tenants):
        arr, fs, dn = arrive[ti], first[ti], done[ti]
        completed = ~np.isnan(dn)
        measured = completed & (arr - t0 >= warmup)
        ttft = (fs - arr)[measured & ~np.isnan(fs)]
        n_arrived = len(arr)
        admitted = int(n_arrived - shed[ti].sum())
        reads = tok_hbm[ti] + tok_host[ti]
        row = {
            "workload": wls[ti].name,
            "arrival": t.arrival.name,
            "tiering": policy,
            "serving_tenant": t.name,
            "admission_policy": ctrl.cfg.label or ctrl.cfg.policy,
            "admission": dict(ctrl.tenant_counters(names[ti])),
            "n_arrived": n_arrived,
            "admitted": admitted,
            "rejected": int(shed[ti].sum()),
            "capacity_rejected": cap_rej[ti],
            "n_completed": int(completed.sum()),
            "n_measured": int(measured.sum()),
            "duration": float(duration),
            "offered_rate": n_arrived / max(duration, 1e-12),
            "throughput": float(completed.sum()) / busy,
            "token_throughput": tok_out[ti] / busy,
            "tokens_out": tok_out[ti],
            "ttft_p": _pct(ttft),
            "decode_p": _pct(np.asarray(gaps[ti])),
            "mean_ttft": float(ttft.mean()) if len(ttft) else 0.0,
            "hbm_hit_rate": (tok_hbm[ti] / reads) if reads else 1.0,
            "cache_hits": int(mgr.stats["cache_hits"]),
            "cache_admits": int(mgr.stats["cache_admits"]),
            "promote_pages": int(mgr.stats["promote_pages"]),
            "demote_pages": int(mgr.stats["demote_pages"]),
            "migrated_bytes": float(mgr.stats["bytes_migrated"]),
            "preempt_stalls": int(mgr.stats["preempt_stalls"]),
            "pauses": pauses[ti],
            "hbm_placements": int(mgr.stats["hbm_placements"]),
            "host_placements": int(mgr.stats["host_placements"]),
            "hbm_zones": pool.hbm_zones,
            "host_zones": pool.host_zones,
            "max_batch": pool.max_batch,
            "max_live": state["max_live"],
            "queue_depth_max": state["max_queue"],
        }
        if t.slo_p99:
            row["slo_p99"] = float(t.slo_p99)
            row["slo_met"] = bool(row["ttft_p"]["p99"] <= t.slo_p99)
            ok = measured & ~np.isnan(fs) & (fs - arr <= t.slo_p99)
            row["goodput"] = float(ok.sum()) / busy
        rows.append(row)
    stats = dict(mgr.stats)
    stats.update(steps=eng["steps"], tokens_out=eng["tokens_out"],
                 hbm_free_zones=hbm.num_free(),
                 host_free_zones=host.num_free())
    return ServingResult(rows=rows, stats=stats, duration=busy)


# ======================================================================
# matrix integration
# ======================================================================
@dataclass(frozen=True)
class ServingCell:
    """One serving cell of a ``ScenarioMatrix``: policy x workload x
    arrival x pool sizing — self-contained, like ``ScenarioCell``."""

    policy: str
    workload: ServingWorkload
    arrival: ArrivalProcess
    spool: ServingPool

    @property
    def name(self) -> str:
        return (f"serving/{self.policy}/{self.workload.name}"
                f"/{self.arrival.name}/h{self.spool.hbm_zones}")


def run_matrix_cell(matrix, cell: ServingCell
                    ) -> Tuple[List[ServingResult], List[Dict]]:
    """Run one serving cell for ``ScenarioMatrix.run_cell`` (same
    contract: fresh state, rows tagged with the cell name)."""
    sim = Sim()
    reg = None
    if matrix.telemetry or matrix.timeline_dir is not None:
        period = (float(matrix.telemetry)
                  if not isinstance(matrix.telemetry, bool)
                  and matrix.telemetry else 5.0)
        from ..obs.metrics import MetricsRegistry
        reg = MetricsRegistry(sim, period)
    tenants = [TenantSpec(
        name="default", workload=cell.workload, arrival=cell.arrival,
        protected=cell.workload.slo_ttft is not None,
        slo_p99=cell.workload.slo_ttft)]
    res = run_serving(
        tenants, cell.policy, pool=cell.spool,
        costs=matrix.serving_costs or ServingCosts(),
        duration=matrix.duration, warmup=matrix.warmup, seed=matrix.seed,
        admission=matrix.serving_admission, sim=sim, registry=reg)
    if reg is not None:
        reg.sample_now()
        if matrix.timeline_dir is not None:
            from ..obs.metrics import timeline_path
            reg.dump_timeline(
                timeline_path(matrix.timeline_dir, cell.name),
                meta={"cell": cell.name, "policy": cell.policy,
                      "hbm_zones": cell.spool.hbm_zones})
    for row in res.rows:
        row["cell"] = cell.name
    return [res], res.rows


def serving_arrivals(kinds: Sequence[str],
                     rate: float) -> List[ArrivalProcess]:
    """Serving arrival shapes anchored to one sequence rate (seqs/s)."""
    table = {
        "poisson": PoissonArrivals(round(rate, 4)),
        "bursty": BurstyArrivals(round(0.3 * rate, 4),
                                 round(2.5 * rate, 4), on=30.0, off=90.0),
        "flash": FlashCrowdArrivals(round(0.6 * rate, 4),
                                    round(4.0 * rate, 4),
                                    at=60.0, decay=30.0),
    }
    unknown = [k for k in kinds if k not in table]
    if unknown:
        raise ValueError(f"unknown arrival kinds {unknown}; "
                         f"one of {sorted(table)}")
    return [table[k] for k in kinds]


def build_serving_grid(policies: Sequence[str],
                       arrival_kinds: Sequence[str],
                       hbm_zones: Sequence[int], *,
                       rate: float = 2.5,
                       duration: float = 400.0,
                       warmup: float = 40.0,
                       seed: int = 1,
                       workload: Optional[ServingWorkload] = None,
                       admission: Union[AdmissionConfig, str, None] = None,
                       telemetry: Union[bool, float] = False,
                       timeline_dir=None):
    """A serving-only ``ScenarioMatrix``: policy x arrival x HBM sizing."""
    from .runner import ScenarioMatrix
    wl = workload or ServingWorkload(slo_ttft=2.0)
    return ScenarioMatrix(
        schemes=(), workloads=(),
        arrivals=serving_arrivals(arrival_kinds, rate),
        duration=duration, warmup=warmup, seed=seed,
        serving_policies=tuple(policies),
        serving_workloads=(wl,),
        serving_pools=tuple(ServingPool(hbm_zones=h) for h in hbm_zones),
        serving_admission=admission,
        telemetry=telemetry, timeline_dir=timeline_dir)


# ======================================================================
# CLI
# ======================================================================
def main(argv: Optional[Sequence[str]] = None) -> int:
    from .sweep import run_sweep
    ap = argparse.ArgumentParser(
        description="LLM KV-cache serving grid (policy x arrival x pool)")
    ap.add_argument("--policies", default="static,lru,hhzs")
    ap.add_argument("--arrivals", default="poisson,bursty")
    ap.add_argument("--hbm", default="10,16",
                    help="comma list of HBM zone counts")
    ap.add_argument("--rate", type=float, default=2.5,
                    help="sequence arrival rate anchor (seqs/s)")
    ap.add_argument("--duration", type=float, default=400.0)
    ap.add_argument("--warmup", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--workers", type=int, default=0)
    ap.add_argument("--out", default=None)
    ap.add_argument("--timelines", default=None,
                    help="directory for per-cell timeline artifacts")
    ap.add_argument("--fresh", action="store_true",
                    help="re-run cells already present in --out")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sizing for CI smoke")
    args = ap.parse_args(argv)
    if args.quick:
        args.duration, args.warmup = 150.0, 20.0
    matrix = build_serving_grid(
        [p for p in args.policies.split(",") if p],
        [a for a in args.arrivals.split(",") if a],
        [int(h) for h in args.hbm.split(",") if h],
        rate=args.rate, duration=args.duration, warmup=args.warmup,
        seed=args.seed,
        telemetry=args.timelines is not None,
        timeline_dir=args.timelines)
    try:
        from benchmarks.validate_results import validate_rows
        validate = lambda rs: validate_rows(rs, strict=True)  # noqa: E731
    except ImportError:            # benchmarks/ not on the path: skip lint
        validate = None
    rows = run_sweep(matrix, args.out, workers=args.workers,
                     resume=not args.fresh, validate=validate)
    if args.out is None:
        print(json.dumps(rows, indent=1))
    return 0


if __name__ == "__main__":
    import sys

    # delegate to the canonical module object (already imported via the
    # package), not this __main__ copy: cells built here would pickle as
    # __main__.* and fail isinstance checks in sweep worker processes
    from repro.workloads.serving import main as _main
    sys.exit(_main())
