"""Parallel, resumable, deterministic sweep driver over ScenarioMatrix cells.

``ScenarioMatrix`` declares a grid of (scheme x workload x arrival x
SSD-budget) cells; this module actually *runs* the grid at scale:

* **Sharding.**  Cells are distributed across worker processes.  Every cell
  is self-contained — a freshly loaded store, seeded arrival/op streams —
  so the rows are **identical for any worker count** (asserted by
  ``tests/test_sweep.py``): workers only change wall-clock time, never
  results.  The output file lists rows in canonical cell order (the order
  ``ScenarioMatrix.cells()`` enumerates), not completion order.
* **Resume.**  Rows already present in the output file are kept and their
  cells skipped (``resume=True``), so an interrupted sweep continues where
  it stopped; the file is rewritten atomically after every completed cell.
  Rows whose cell is *not* part of the running matrix (multi-tenant rows,
  fault rows, other sweeps) are always preserved untouched — the
  merge-never-overwrite invariant of ``results/storage/scenarios.json``.
* **Selection.**  ``cells=`` takes either index ranges (``"0,3,7-9"``) or
  an ``fnmatch`` pattern against cell names (``"HHZS/*/z20"``);
  ``budget_s=`` stops dispatching new cells once the wall-clock budget is
  spent (completed cells are kept — rerun to continue).

CLI (the full-grid reproduction sweep)::

  PYTHONPATH=src python -m repro.workloads.sweep \
      --workers 2 --out results/storage/scenarios.json
  PYTHONPATH=src python -m repro.workloads.sweep \
      --schemes B3,HHZS --workloads A,B --arrivals poisson \
      --key-div 16 --duration 300 --cells 'HHZS/*' --budget-s 600

The default grid is all 10 schemes x YCSB A-F x {poisson, bursty, ramp}
x 2 SSD budgets; offered rates are calibrated once from a seeded
closed-loop probe (deterministic, so resumed runs regenerate identical
cell names).
"""
from __future__ import annotations

import argparse
import fnmatch
import json
import multiprocessing
import os
import sys
import time
from concurrent.futures import FIRST_COMPLETED, ProcessPoolExecutor, wait
from dataclasses import dataclass
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Union

from .runner import (BurstyArrivals, PoissonArrivals, RampArrivals,
                     ScenarioMatrix)
from .ycsb import YCSB, WorkloadSpec, run_load, run_workload


@dataclass(frozen=True)
class GridDBFactory:
    """Picklable store factory for sweep cells (workers must rebuild it).

    Mirrors the methodology of ``benchmarks/storage_exps.py``: fresh store,
    load ``paper_keys // (load_div * key_div)`` objects, drain the WAL, run
    while the compaction backlog is live.
    """

    key_div: int = 1
    load_div: int = 4
    rebalance_period: float = 30.0

    def __call__(self, scheme: str, ssd_zones: int,
                 filter_bits: Optional[int] = None, shards: int = 1,
                 routing: str = "hash", rebalance: bool = False):
        from dataclasses import replace
        from ..lsm import DB, ScenarioConfig
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        if filter_bits is not None:     # the matrix's filter-bits axis
            sc = replace(sc, lsm=replace(
                sc.lsm, filter_bits_per_key=int(filter_bits)))
        n = sc.paper_keys // (self.load_div * self.key_div)
        if shards > 1:                  # the matrix's sharding axis
            from ..cluster import ShardedDB
            db = ShardedDB(scheme, sc, shards=shards, routing=routing,
                           key_space=n, rebalance=rebalance,
                           rebalance_period=self.rebalance_period)
        else:
            db = DB(scheme, sc)
        run_load(db, n_keys=n)
        db.flush_all()
        db.n_keys = n
        return db


def _run_cell(matrix: ScenarioMatrix, idx: int):
    """Worker entry: run cell ``idx`` of the (pickled) matrix."""
    cell = matrix.cells()[idx]
    _, rows = matrix.run_cell(cell)
    return idx, rows


def parse_cell_selector(spec: Optional[str]) -> Callable[[int, str], bool]:
    """Build a (index, cell-name) predicate from a ``--cells`` argument.

    ``None``/empty selects everything; a string of digits, commas and
    dashes selects index ranges (``"0,3,7-9"``); anything else is an
    ``fnmatch`` pattern against the cell name (``"HHZS/*/z20"``).
    """
    if not spec:
        return lambda i, name: True
    if all(c.isdigit() or c in ",- " for c in spec):
        picked = set()
        for part in spec.replace(" ", "").split(","):
            if not part:
                continue
            if "-" in part:
                lo, hi = part.split("-", 1)
                picked.update(range(int(lo), int(hi) + 1))
            else:
                picked.add(int(part))
        return lambda i, name: i in picked
    return lambda i, name: fnmatch.fnmatch(name, spec)


def _atomic_write(path: Path, rows: List[Dict]) -> None:
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(json.dumps(rows, indent=1))
    os.replace(tmp, path)


def run_sweep(matrix: ScenarioMatrix,
              out: Optional[Union[str, Path]] = None,
              *,
              workers: int = 0,
              cells: Optional[str] = None,
              budget_s: Optional[float] = None,
              resume: bool = True,
              verbose: bool = True,
              validate: Optional[Callable[[List[Dict]], None]] = None
              ) -> List[Dict]:
    """Run (the selected part of) a ScenarioMatrix, sharded over workers.

    Returns the matrix's rows in canonical cell order (resumed rows
    included).  With ``out``, the file is updated atomically after every
    completed cell: foreign rows first (file order), then matrix rows in
    canonical order.  ``workers=0`` runs inline (no process pool) —
    row-identical to any ``workers>=1`` run by construction, since cells
    share no state.  ``validate`` (if given) is called on the merged row
    list before every write and must raise on schema violations.
    """
    all_cells = matrix.cells()
    names = [c.name for c in all_cells]
    if len(set(names)) != len(names):
        dupes = sorted({n for n in names if names.count(n) > 1})
        raise ValueError(f"matrix has duplicate cell names: {dupes[:3]}")
    name_set = set(names)

    existing: List[Dict] = []
    out_path = Path(out) if out is not None else None
    if out_path is not None and out_path.exists():
        existing = json.loads(out_path.read_text())
    foreign = [r for r in existing if r.get("cell") not in name_set]
    # previously published rows for this matrix's cells: with resume they
    # make the cell skippable; without (--fresh) the cell re-runs but its
    # old rows are kept until the replacement lands — selecting a subset
    # or interrupting a fresh run must never drop published results
    done: Dict[str, List[Dict]] = {}
    for r in existing:
        cell = r.get("cell")
        if cell in name_set:
            done.setdefault(cell, []).append(r)

    selected = parse_cell_selector(cells)
    pending = [i for i, c in enumerate(all_cells)
               if selected(i, c.name)
               and (not resume or c.name not in done)]
    if verbose and resume and done:
        print(f"[sweep] resume: {len(done)} cells already in {out_path}, "
              f"{len(pending)} to run", flush=True)

    fresh: Dict[int, List[Dict]] = {}
    deadline = None if budget_s is None else time.monotonic() + budget_s

    def merged() -> List[Dict]:
        rows: List[Dict] = []
        for i, c in enumerate(all_cells):
            if i in fresh:                    # this run's result wins
                rows.extend(fresh[i])
            elif c.name in done:              # kept (resumed or not rerun)
                rows.extend(done[c.name])
        return rows

    def checkpoint() -> None:
        if out_path is None:
            return
        rows = foreign + merged()
        if validate is not None:
            validate(rows)
        out_path.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write(out_path, rows)

    def note(idx: int, rows: List[Dict]) -> None:
        fresh[idx] = rows
        checkpoint()
        if verbose:
            for r in rows:
                if "shard" in r:        # per-shard sub-rows: no latency
                    continue
                # serving rows carry decode_p where storage rows carry
                # latency_p — the note line is kind-agnostic
                lat = r.get("latency_p") or r.get("decode_p") or {}
                print(f"[sweep {idx + 1}/{len(all_cells)}] {r['cell']:<48s} "
                      f"thpt={r['throughput']:8.1f}/s "
                      f"p99={lat.get('p99', 0) * 1e3:9.2f}ms",
                      flush=True)

    skipped_budget = 0
    if workers <= 0:
        for idx in pending:
            if deadline is not None and time.monotonic() > deadline:
                skipped_budget = len(pending) - len(fresh)
                break
            note(*_run_cell(matrix, idx))
    else:
        # fork is fast (workers inherit loaded modules), but forking a
        # process that already imported JAX (multithreaded) can deadlock —
        # under pytest or notebook sessions fall back to spawn
        method = "spawn" if "jax" in sys.modules else "fork"
        ctx = multiprocessing.get_context(method)
        with ProcessPoolExecutor(max_workers=workers, mp_context=ctx) as pool:
            in_flight = {}
            it = iter(pending)
            stop = False

            def submit_next() -> bool:
                nonlocal stop
                if stop:
                    return False
                if deadline is not None and time.monotonic() > deadline:
                    stop = True
                    return False
                idx = next(it, None)
                if idx is None:
                    return False
                in_flight[pool.submit(_run_cell, matrix, idx)] = idx
                return True

            for _ in range(2 * workers):
                if not submit_next():
                    break
            while in_flight:
                ready, _ = wait(list(in_flight), return_when=FIRST_COMPLETED)
                for fut in ready:
                    del in_flight[fut]
                    note(*fut.result())
                    submit_next()
        skipped_budget = len(pending) - len(fresh)

    if skipped_budget and verbose:
        print(f"[sweep] wall-clock budget spent: {skipped_budget} selected "
              f"cells not run (resume with the same command)", flush=True)
    checkpoint()
    return merged()


# ======================================================================
# the default full-grid sweep (CLI)
# ======================================================================
ARRIVAL_KINDS = ("poisson", "bursty", "ramp")


def arrivals_for_rate(kinds: Sequence[str], svc: float) -> List:
    """The sweep's arrival shapes, anchored to one service rate ``svc``:
    base Poisson at 0.5x (stable), bursty 0.2x->3x (overloads during
    bursts, drains in the off phase), ramp 0.1x->1.5x (crosses saturation
    mid-run)."""
    table = {
        "poisson": PoissonArrivals(round(0.5 * svc, 4)),
        "bursty": BurstyArrivals(round(0.2 * svc, 4), round(3.0 * svc, 4),
                                 on=60.0, off=240.0),
        "ramp": RampArrivals(round(0.1 * svc, 4), round(1.5 * svc, 4)),
    }
    unknown = [k for k in kinds if k not in table]
    if unknown:
        raise ValueError(f"unknown arrival kinds {unknown}; "
                         f"one of {sorted(table)}")
    return [table[k] for k in kinds]


def calibrated_arrivals(kinds: Sequence[str], workloads: Sequence[str],
                        *, key_div: int, load_div: int = 4,
                        ssd_zones: int = 20, seed: int = 1,
                        verbose: bool = False) -> Dict[str, List]:
    """Per-workload offered rates from seeded closed-loop probes of the
    weakest baseline (B3), as in ``benchmarks/storage_exps.py`` — but per
    YCSB workload, because service rates differ by an order of magnitude
    across the mix (scan-heavy E serves ~15x slower than read-heavy C;
    one global rate would leave half the grid permanently overloaded).
    Probes are deterministic, so resumed sweeps regenerate identical rates
    — and therefore identical cell names."""
    factory = GridDBFactory(key_div=key_div, load_div=load_div)
    out: Dict[str, List] = {}
    for w in workloads:
        probe = factory("B3", ssd_zones)
        spec = YCSB[w] if isinstance(w, str) else w
        pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys,
                          seed=seed)
        svc = max(pr.throughput, 1e-6)
        out[spec.name] = arrivals_for_rate(kinds, svc)
        if verbose:
            print(f"[sweep] probe {spec.name}: service ~{svc:.1f} ops/s",
                  flush=True)
    return out


def build_grid(schemes: Sequence[str], workloads: Sequence[str],
               arrival_kinds: Sequence[str], budgets: Sequence[int],
               *, duration: float, warmup: float, key_div: int,
               seed: int = 1, verbose: bool = False,
               timelines: Optional[str] = None,
               shards: Sequence[int] = (1,), routing: str = "hash",
               rebalance: Sequence[bool] = (False,)) -> ScenarioMatrix:
    """The full-grid ScenarioMatrix the CLI (and CI smoke/nightly) runs.

    ``timelines`` enables the per-cell telemetry bus (``repro.obs``) and
    dumps one timeline artifact per cell into that directory — telemetry
    is pull-only, so the published rows stay byte-identical with it on
    (asserted by the CI grid-smoke telemetry leg).
    """
    arrivals = calibrated_arrivals(arrival_kinds, workloads,
                                   key_div=key_div, ssd_zones=min(budgets),
                                   seed=seed, verbose=verbose)
    return ScenarioMatrix(
        schemes=list(schemes), workloads=list(workloads),
        arrivals=arrivals, ssd_zone_budgets=list(budgets),
        duration=duration, warmup=warmup, key_div=key_div, seed=seed,
        db_factory=GridDBFactory(key_div=key_div),
        telemetry=timelines is not None, timeline_dir=timelines,
        shards=list(shards), routing=routing, rebalance=list(rebalance))


def build_control_grid(schemes: Sequence[str], *, duration: float,
                       warmup: float, key_div: int, seed: int = 1,
                       verbose: bool = False,
                       timelines: Optional[str] = None) -> ScenarioMatrix:
    """A small multi-tenant control-plane matrix (CLI ``--control``).

    One protected + one bulk tenant under the full-knob feedback policy
    (PI controller driving admission, compaction pacing, migration
    aggressiveness and the hinted-cache reservation) — the same
    construction as ``benchmarks/storage_exps.py::bench_control`` at
    smoke sizing.  The CI grid-smoke job runs this grid twice (2 workers
    vs inline, telemetry on) and requires byte-identical rows: the
    control plane is a sim process, so its ticks — and every knob write
    they make — are part of the deterministic event schedule.
    """
    from repro.core.middleware import AdmissionConfig
    from repro.lsm import SCALE
    from repro.zoned.device import MiB

    from .runner import TenantSpec
    from .ycsb import WorkloadSpec

    factory = GridDBFactory(key_div=key_div)
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    bspec = WorkloadSpec("bulkmix", read=0.5, update=0.5, alpha=0.9)
    # anchor rates/SLOs to a seeded closed-loop probe of the weakest
    # baseline, exactly as calibrated_arrivals() does for the YCSB grid
    probe = factory("B3", 20)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys,
                      seed=seed)
    svc = max(pr.throughput, 1e-6)
    slo_prot = round(1.5 * pr.latency_p["p99"], 4)
    debt_th = round(1.5 * float(probe.tree.compaction_debt())
                    + 256 * MiB / SCALE, 1)
    bulk_rate = round(1.2 * svc, 4)
    if verbose:
        print(f"[sweep] control probe: service ~{svc:.1f} ops/s, "
              f"prot slo {slo_prot * 1e3:.1f}ms", flush=True)
    mix = [
        TenantSpec("prot", spec, PoissonArrivals(round(0.25 * svc, 4)),
                   protected=True, slo_p99=slo_prot),
        TenantSpec("bulk", bspec, PoissonArrivals(bulk_rate),
                   slo_p99=round(1.5 * slo_prot, 4)),
    ]
    policy = AdmissionConfig(
        policy="feedback", bucket_rates={"bulk": (bulk_rate, 20.0)},
        debt_threshold=debt_th, label="pi+knobs", queue_threshold=8,
        feedback_interval=2.5, feedback_window=60,
        feedback_controller="pi", feedback_kp=2.0, feedback_ki=0.5,
        feedback_smooth=1.0, feedback_rise=0.08,
        feedback_knobs=("admission", "compaction", "migration", "cache"))
    return ScenarioMatrix(
        schemes=list(schemes), workloads=[], arrivals=[], tenants=[mix],
        policies=[policy], ssd_zone_budgets=[20],
        duration=duration, warmup=warmup, max_concurrency=16,
        key_div=key_div, seed=seed, db_factory=factory,
        telemetry=timelines is not None, timeline_dir=timelines)


def build_drift_grid(schemes: Sequence[str], programs: Sequence[str],
                     arrival_kinds: Sequence[str], *, phase_s: float,
                     warmup: float, key_div: int, seed: int = 1,
                     verbose: bool = False,
                     timelines: Optional[str] = None,
                     budgets: Sequence[int] = (20,)) -> ScenarioMatrix:
    """The drift scenario grid (CLI ``--drift``): named
    ``TraceProgram``\\ s (``repro.workloads.drift``) x schemes x arrival
    kinds x SSD budgets.  Offered rates are anchored to one seeded
    closed-loop probe of the weakest baseline (B3) on a 50/50 mix, as in
    ``build_control_grid`` — deterministic, so resumed sweeps regenerate
    identical programs and cell names.  Each cell runs the program's own
    virtual-time schedule and emits per-tenant rows with
    ``drift``/``phases`` columns; with ``timelines`` the telemetry bus
    additionally records phase-boundary marks (pull-only: rows are
    byte-identical either way, asserted by the CI grid-smoke drift leg).
    """
    from .drift import build_program

    factory = GridDBFactory(key_div=key_div)
    probe = factory("B3", min(budgets))
    spec = WorkloadSpec("mix", read=0.5, update=0.5, alpha=0.9)
    pr = run_workload(probe, spec, n_ops=2000, n_keys=probe.n_keys,
                      seed=seed)
    svc = max(pr.throughput, 1e-6)
    if verbose:
        print(f"[sweep] drift probe: service ~{svc:.1f} ops/s", flush=True)
    progs = [build_program(name, svc=round(svc, 4), n_keys=probe.n_keys,
                           arrival_kind=kind, phase_s=phase_s)
             for name in programs for kind in arrival_kinds]
    return ScenarioMatrix(
        schemes=list(schemes), workloads=[], arrivals=[],
        ssd_zone_budgets=list(budgets), warmup=warmup,
        key_div=key_div, seed=seed, db_factory=factory,
        telemetry=timelines is not None, timeline_dir=timelines,
        drift_programs=progs)


def main(argv: Optional[List[str]] = None) -> int:
    from repro.lsm.db import SCHEMES
    ap = argparse.ArgumentParser(
        description="full-grid scenario sweep (parallel, resumable)")
    ap.add_argument("--schemes", default=",".join(SCHEMES),
                    help="comma-separated placement schemes")
    ap.add_argument("--workloads", default="A,B,C,D,E,F",
                    help="comma-separated YCSB workload letters")
    ap.add_argument("--arrivals", default="poisson,bursty,ramp",
                    help="comma-separated arrival kinds "
                         "(poisson, bursty, ramp)")
    ap.add_argument("--budgets", default="20,40",
                    help="comma-separated SSD zone budgets")
    ap.add_argument("--duration", type=float, default=600.0,
                    help="virtual seconds of arrivals per cell")
    ap.add_argument("--warmup", type=float, default=60.0)
    ap.add_argument("--key-div", type=int, default=16,
                    help="dataset divisor (1 = paper-scale dataset)")
    ap.add_argument("--seed", type=int, default=1)
    ap.add_argument("--workers", type=int, default=0,
                    help="worker processes (0 = run inline)")
    ap.add_argument("--cells", default=None,
                    help="cell selector: index ranges '0,3,7-9' or an "
                         "fnmatch pattern like 'HHZS/*/z20'")
    ap.add_argument("--budget-s", type=float, default=None,
                    help="wall-clock budget; stop dispatching new cells "
                         "after this many seconds")
    ap.add_argument("--shards", default="1",
                    help="comma-separated shard counts; entries > 1 run "
                         "the cell on a ShardedDB (repro.cluster)")
    ap.add_argument("--routing", default="hash",
                    choices=("hash", "range"),
                    help="router for sharded cells")
    ap.add_argument("--rebalance", action="store_true",
                    help="also sweep the online rebalancer on sharded "
                         "cells (adds the -rb variant; range routing)")
    ap.add_argument("--out", default="results/storage/scenarios.json")
    ap.add_argument("--fresh", action="store_true",
                    help="re-run cells even if already present in --out")
    ap.add_argument("--timelines", default=None, metavar="DIR",
                    help="enable per-cell telemetry (repro.obs) and write "
                         "one timeline artifact per cell into DIR; rows "
                         "are unchanged")
    ap.add_argument("--control", action="store_true",
                    help="run the small multi-tenant control-plane grid "
                         "(prot+bulk tenants, full-knob PI feedback "
                         "policy) instead of the YCSB grid; honours "
                         "--schemes/--duration/--warmup/--key-div")
    ap.add_argument("--drift", default=None, metavar="PROGRAMS",
                    help="run the drift grid instead of the YCSB grid: "
                         "comma-separated TraceProgram names "
                         "(repro.workloads.drift, e.g. 'rotate,churn'); "
                         "honours --schemes/--arrivals (poisson, bursty)/"
                         "--budgets/--warmup/--key-div/--phase-s")
    ap.add_argument("--phase-s", type=float, default=150.0,
                    help="virtual seconds per drift-program phase")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    if args.drift:
        matrix = build_drift_grid(
            [s for s in args.schemes.split(",") if s],
            [p for p in args.drift.split(",") if p],
            [a for a in args.arrivals.split(",") if a
             and a in ("poisson", "bursty")] or ["poisson"],
            phase_s=args.phase_s, warmup=args.warmup,
            key_div=args.key_div, seed=args.seed,
            verbose=not args.quiet, timelines=args.timelines,
            budgets=[int(b) for b in args.budgets.split(",") if b])
    elif args.control:
        matrix = build_control_grid(
            [s for s in args.schemes.split(",") if s],
            duration=args.duration, warmup=args.warmup,
            key_div=args.key_div, seed=args.seed,
            verbose=not args.quiet, timelines=args.timelines)
    else:
        matrix = build_grid(
            [s for s in args.schemes.split(",") if s],
            [w for w in args.workloads.split(",") if w],
            [a for a in args.arrivals.split(",") if a],
            [int(b) for b in args.budgets.split(",") if b],
            duration=args.duration, warmup=args.warmup,
            key_div=args.key_div, seed=args.seed,
            timelines=args.timelines,
            shards=[int(s) for s in args.shards.split(",") if s],
            routing=args.routing,
            rebalance=[False, True] if args.rebalance else [False])

    validate = None
    try:  # optional: schema linting before every write (CI installs it)
        from benchmarks.validate_results import validate_rows as _vr
        validate = lambda rows: _vr(rows, strict=True)  # noqa: E731
    except ImportError:
        pass

    t0 = time.time()
    rows = run_sweep(matrix, out=args.out, workers=args.workers,
                     cells=args.cells, budget_s=args.budget_s,
                     resume=not args.fresh, verbose=not args.quiet,
                     validate=validate)
    n_cells = len({r["cell"] for r in rows})
    print(f"[sweep] {n_cells}/{len(matrix.cells())} cells "
          f"({len(rows)} rows) in {args.out} "
          f"[{time.time() - t0:.0f}s wall]", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
