from .ycsb import (YCSB, WorkloadSpec, WorkloadResult, Ops, generate_ops,
                   run_load, run_workload, mixed, zipf_probs, LevelSampler,
                   READ, UPDATE, INSERT, SCAN, RMW)

__all__ = [
    "YCSB", "WorkloadSpec", "WorkloadResult", "Ops", "generate_ops",
    "run_load", "run_workload", "mixed", "zipf_probs", "LevelSampler",
    "READ", "UPDATE", "INSERT", "SCAN", "RMW",
]
