from .ycsb import (YCSB, WorkloadSpec, WorkloadResult, Ops, OpStream,
                   collect_extras, generate_ops, run_load, run_workload,
                   mixed, zipf_probs, LevelSampler,
                   READ, UPDATE, INSERT, SCAN, RMW)
from .runner import (ArrivalProcess, PoissonArrivals, BurstyArrivals,
                     RampArrivals, DiurnalArrivals, FlashCrowdArrivals,
                     OpenLoopResult, run_open_loop,
                     TenantSpec, MultiTenantResult, run_multi_tenant,
                     ScenarioCell, MultiTenantCell, ScenarioMatrix)

__all__ = [
    "YCSB", "WorkloadSpec", "WorkloadResult", "Ops", "OpStream",
    "collect_extras", "generate_ops", "run_load", "run_workload",
    "mixed", "zipf_probs", "LevelSampler",
    "READ", "UPDATE", "INSERT", "SCAN", "RMW",
    "ArrivalProcess", "PoissonArrivals", "BurstyArrivals", "RampArrivals",
    "DiurnalArrivals", "FlashCrowdArrivals",
    "OpenLoopResult", "run_open_loop",
    "TenantSpec", "MultiTenantResult", "run_multi_tenant",
    "ScenarioCell", "MultiTenantCell", "ScenarioMatrix",
]
