from .ycsb import (YCSB, WorkloadSpec, WorkloadResult, Ops, OpStream,
                   collect_extras, generate_ops, run_load, run_workload,
                   mixed, zipf_probs, LevelSampler,
                   READ, UPDATE, INSERT, SCAN, RMW)
from .runner import (ArrivalProcess, PoissonArrivals, BurstyArrivals,
                     RampArrivals, OpenLoopResult, run_open_loop,
                     ScenarioCell, ScenarioMatrix)

__all__ = [
    "YCSB", "WorkloadSpec", "WorkloadResult", "Ops", "OpStream",
    "collect_extras", "generate_ops", "run_load", "run_workload",
    "mixed", "zipf_probs", "LevelSampler",
    "READ", "UPDATE", "INSERT", "SCAN", "RMW",
    "ArrivalProcess", "PoissonArrivals", "BurstyArrivals", "RampArrivals",
    "OpenLoopResult", "run_open_loop", "ScenarioCell", "ScenarioMatrix",
]
