from .ycsb import (YCSB, WorkloadSpec, WorkloadResult, Ops, OpStream,
                   collect_extras, generate_ops, run_load, run_workload,
                   mixed, zipf_probs, LevelSampler,
                   READ, UPDATE, INSERT, SCAN, RMW)
from .runner import (ArrivalProcess, PoissonArrivals, BurstyArrivals,
                     RampArrivals, DiurnalArrivals, FlashCrowdArrivals,
                     OpenLoopResult, run_open_loop,
                     TenantSpec, MultiTenantResult, run_multi_tenant,
                     ScenarioCell, MultiTenantCell, ScenarioMatrix)
from .serving import (ServingWorkload, ServingPool, ServingCosts,
                      ServingCell, ServingResult, run_serving,
                      serving_arrivals, build_serving_grid)
from .drift import (Phase, DriftTenant, TraceProgram, DriftCell,
                    run_drift, build_program, PROGRAM_BUILDERS,
                    inject_scan_burst, phase_rankings, rank_flips)
# NOTE: the sweep driver (repro.workloads.sweep) is imported explicitly,
# not re-exported here — it doubles as `python -m repro.workloads.sweep`
# and importing it at package load would shadow that entry point.
# repro.workloads.serving is ALSO a `-m` entry point, but its module body
# only defines the grid (main() runs under __main__), so re-exporting the
# specs here is safe.

__all__ = [
    "YCSB", "WorkloadSpec", "WorkloadResult", "Ops", "OpStream",
    "collect_extras", "generate_ops", "run_load", "run_workload",
    "mixed", "zipf_probs", "LevelSampler",
    "READ", "UPDATE", "INSERT", "SCAN", "RMW",
    "ArrivalProcess", "PoissonArrivals", "BurstyArrivals", "RampArrivals",
    "DiurnalArrivals", "FlashCrowdArrivals",
    "OpenLoopResult", "run_open_loop",
    "TenantSpec", "MultiTenantResult", "run_multi_tenant",
    "ScenarioCell", "MultiTenantCell", "ScenarioMatrix",
    "ServingWorkload", "ServingPool", "ServingCosts", "ServingCell",
    "ServingResult", "run_serving", "serving_arrivals",
    "build_serving_grid",
    "Phase", "DriftTenant", "TraceProgram", "DriftCell", "run_drift",
    "build_program", "PROGRAM_BUILDERS", "inject_scan_burst",
    "phase_rankings", "rank_flips",
]
