from .ycsb import (YCSB, WorkloadSpec, WorkloadResult, Ops, OpStream,
                   collect_extras, generate_ops, run_load, run_workload,
                   mixed, zipf_probs, LevelSampler,
                   READ, UPDATE, INSERT, SCAN, RMW)
from .runner import (ArrivalProcess, PoissonArrivals, BurstyArrivals,
                     RampArrivals, DiurnalArrivals, FlashCrowdArrivals,
                     OpenLoopResult, run_open_loop,
                     TenantSpec, MultiTenantResult, run_multi_tenant,
                     ScenarioCell, MultiTenantCell, ScenarioMatrix)
# NOTE: the sweep driver (repro.workloads.sweep) is imported explicitly,
# not re-exported here — it doubles as `python -m repro.workloads.sweep`
# and importing it at package load would shadow that entry point.

__all__ = [
    "YCSB", "WorkloadSpec", "WorkloadResult", "Ops", "OpStream",
    "collect_extras", "generate_ops", "run_load", "run_workload",
    "mixed", "zipf_probs", "LevelSampler",
    "READ", "UPDATE", "INSERT", "SCAN", "RMW",
    "ArrivalProcess", "PoissonArrivals", "BurstyArrivals", "RampArrivals",
    "DiurnalArrivals", "FlashCrowdArrivals",
    "OpenLoopResult", "run_open_loop",
    "TenantSpec", "MultiTenantResult", "run_multi_tenant",
    "ScenarioCell", "MultiTenantCell", "ScenarioMatrix",
]
