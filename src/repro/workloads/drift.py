"""Phase-programmed drift traces: deterministic non-stationary workloads.

Every other scenario family drives the store with a *stationary*
key-popularity process, so the paper scheme's hinted caching and §3.4-3.5
popularity/capacity migration are never stress-tested in the regimes where
they could lose: drifting hotspots, growing working sets, and tenants that
arrive and depart mid-run.  This module adds the missing axis:

* A :class:`TraceProgram` is an ordered list of :class:`Phase`\\ s pinned to
  virtual-time boundaries.  Each phase overrides the op mix and key chooser
  (via a full ``WorkloadSpec`` — Zipf with a per-phase reseeded rank
  rotation, the contiguous ``hotspot`` walk on a *virtual-time* schedule,
  or ``latest``), the working-set size (keyspace growth between phases),
  scan-burst injection (a fraction of the phase's ops become long scans —
  an analytical phase), and the live tenant set (departing tenants drain
  in-flight ops against a deadline; arriving tenants get fresh seeded
  ``OpStream``\\ s).
* :func:`run_drift` executes a program against one store with the same
  bounded server pool / queueing-vs-service decomposition as
  ``run_open_loop``, and reports **per-phase metric windows**: each
  per-tenant row carries a ``phases`` column with per-phase throughput and
  queueing/service p99 (an op straddling a boundary is counted in exactly
  one window — the phase it *arrived* in).
* :func:`phase_rankings` / :func:`rank_flips` compare schemes' per-phase
  throughput across rows of a sweep and count the phase transitions where
  the scheme ordering changes — the run-level ``rank_flips`` summary the
  published drift family carries.

Determinism contract: all arrival timestamps and op streams are generated
up front from seeds derived only from ``(seed, tenant index, phase
index)`` — never from execution state — so the same program yields
byte-identical op sequences across schemes, sweep worker counts, and
telemetry settings (asserted by ``tests/test_drift.py`` and the CI
grid-smoke drift leg).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .runner import (ArrivalProcess, BurstyArrivals, OpenLoopResult,
                     PoissonArrivals)
from .ycsb import (READ, SCAN, OpStream, Ops, WorkloadSpec, YCSB, _pct,
                   collect_extras)


# ======================================================================
# program schema
# ======================================================================
@dataclass(frozen=True)
class Phase:
    """One virtual-time window of a :class:`TraceProgram`.

    ``workload`` (a YCSB letter or full ``WorkloadSpec``) is the phase's
    default op mix + key chooser; ``per_tenant`` overrides it for named
    tenants.  ``n_keys`` overrides the working-set size for this phase
    (keyspace growth: choosers span the larger range, reads beyond the
    loaded set miss — cheap under Bloom filters, exactly like a freshly
    grown keyspace).  ``scan_burst`` rewrites that fraction of the
    phase's ops into ``scan_len``-long scans (seeded, pre-generated).
    ``tenants`` restricts the live tenant set (``None`` = all program
    tenants live).
    """

    name: str
    duration: float                       # virtual seconds
    workload: Union[str, WorkloadSpec]
    per_tenant: Tuple[Tuple[str, Union[str, WorkloadSpec]], ...] = ()
    n_keys: int = 0                       # 0 = the program/runner default
    scan_burst: float = 0.0               # fraction of ops becoming scans
    scan_len: int = 200
    tenants: Optional[Tuple[str, ...]] = None


@dataclass(frozen=True)
class DriftTenant:
    """A named tenant of a program with its own arrival process.  The
    tenant's *index in the program* (not the live set) seeds its streams,
    so adding/removing other tenants never reshuffles its ops."""

    name: str
    arrival: ArrivalProcess


@dataclass(frozen=True)
class TraceProgram:
    """An ordered, virtual-time-pinned sequence of phases over a fixed
    tenant table.  Frozen + built from frozen parts, so ``DriftCell``\\ s
    pickle into sweep workers unchanged."""

    name: str
    phases: Tuple[Phase, ...]
    tenants: Tuple[DriftTenant, ...]
    n_keys: int = 0                       # 0 = the runner's n_keys
    # departing tenants: ops queued at the departure boundary are dropped
    # there; ops already in service must complete within this deadline
    # (violations are counted on the row and asserted zero by tests)
    drain_s: float = 30.0

    @property
    def duration(self) -> float:
        return float(sum(p.duration for p in self.phases))

    def bounds(self) -> List[Tuple[float, float]]:
        """Relative [t0, t1) virtual-time window of every phase."""
        out, t = [], 0.0
        for p in self.phases:
            out.append((t, t + p.duration))
            t += p.duration
        return out

    def live_in(self, phase: Phase, tenant: str) -> bool:
        return phase.tenants is None or tenant in phase.tenants

    def spec_for(self, phase: Phase, tenant: str) -> WorkloadSpec:
        w = dict(phase.per_tenant).get(tenant, phase.workload)
        return YCSB[w] if isinstance(w, str) else w


def inject_scan_burst(ops: Ops, frac: float, scan_len: int,
                      rng: np.random.Generator) -> Ops:
    """Rewrite a seeded ``frac`` of pre-generated ops into ``scan_len``-long
    scans, in place — the analytical-phase knob.  Pre-generation keeps the
    rewrite part of the deterministic op sequence."""
    if frac <= 0.0:
        return ops
    mask = rng.random(len(ops.codes)) < frac
    ops.codes[mask] = SCAN
    ops.scan_lens[mask] = scan_len
    return ops


# ======================================================================
# the engine
# ======================================================================
@dataclass
class _Slice:
    """One (tenant x live phase) pre-generated arrival/op slice."""

    ti: int
    k: int
    rel: np.ndarray                       # absolute-relative arrival times
    stream: OpStream


def run_drift(db, program: TraceProgram, *, n_keys: int = 0,
              warmup: float = 0.0, max_concurrency: int = 64,
              seed: int = 1) -> List[OpenLoopResult]:
    """Run a phase-programmed drift trace; one ``OpenLoopResult`` per
    program tenant, each carrying ``drift``/``phases`` columns.

    Every (tenant x live-phase) pair gets its own arrival-time array and
    fresh seeded ``OpStream`` (seeds stride by tenant *and* phase index),
    generated before the first event fires — the op sequence is a pure
    function of ``(program, n_keys, seed)``.  The merged arrival stream
    feeds one bounded pool of ``max_concurrency`` servers, exactly like
    ``run_multi_tenant`` without admission control.

    Phase-window accounting assigns each op to the phase it *arrived* in
    (a boundary straddler counts in exactly one window); per tenant,
    ``sum(phase n_arrived) == n_arrived`` and
    ``n_arrived == n_completed + dropped`` (``drain=True`` semantics:
    everything still live at end-of-program completes).

    Tenant departure (live in phase k-1, absent from phase k): arrivals
    stop at the boundary by construction; ops still *queued* there are
    dropped at the boundary (counted in ``dropped``, never executed); ops
    already in service drain, and any that complete after
    ``boundary + program.drain_s`` count as ``drain_violations``.
    """
    sim = db.sim
    tenants = program.tenants
    if not tenants:
        raise ValueError(f"program {program.name!r} has no tenants")
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    if not program.phases:
        raise ValueError(f"program {program.name!r} has no phases")
    phases = program.phases
    bounds = program.bounds()
    total = bounds[-1][1]

    # ---- pre-generate every (tenant x live phase) slice -------------
    slices: List[_Slice] = []
    for ti, ten in enumerate(tenants):
        for k, ph in enumerate(phases):
            if not program.live_in(ph, ten.name):
                continue
            spec = program.spec_for(ph, ten.name)
            pk = ph.n_keys or program.n_keys or n_keys
            if pk <= 0:
                raise ValueError("run_drift needs n_keys (argument, "
                                 "program, or phase override)")
            rng = np.random.default_rng(seed + 2 + 9973 * ti + 101 * k)
            rel = bounds[k][0] + ten.arrival.times(rng, ph.duration)
            stream = OpStream(db, spec, n_ops=len(rel), n_keys=pk,
                              seed=seed + 9973 * ti + 101 * k)
            # write attribution: flushed bytes tag back to the tenant
            stream.tenant = ten.name
            inject_scan_burst(
                stream.ops, ph.scan_burst, ph.scan_len,
                np.random.default_rng(seed + 5 + 9973 * ti + 101 * k))
            slices.append(_Slice(ti=ti, k=k, rel=rel, stream=stream))

    m_at = (np.concatenate([s.rel for s in slices])
            if slices else np.empty(0, np.float64))
    m_si = np.concatenate([np.full(len(s.rel), si, np.int64)
                           for si, s in enumerate(slices)]) \
        if slices else np.empty(0, np.int64)
    m_i = np.concatenate([np.arange(len(s.rel), dtype=np.int64)
                          for s in slices]) \
        if slices else np.empty(0, np.int64)
    order = np.argsort(m_at, kind="stable")   # ties: tenant/phase order
    m_at, m_si, m_i = m_at[order], m_si[order], m_i[order]
    m = len(m_at)

    t0 = sim.now
    arrive = [np.full(len(s.rel), np.nan) for s in slices]
    start = [np.full(len(s.rel), np.nan) for s in slices]
    done = [np.full(len(s.rel), np.nan) for s in slices]
    dropped = [np.zeros(len(s.rel), bool) for s in slices]
    queue: deque = deque()
    idle: List = []                       # events of parked servers
    depth = [0] * len(tenants)            # per-tenant ops in queue
    tmax_depth = [0] * len(tenants)
    state = {"closed": False, "max_depth": 0, "next": 0}

    # departure boundaries: tenants live in phase k-1 but not in phase k
    departures: List[Tuple[float, int, frozenset]] = []
    for k in range(1, len(phases)):
        prev_live = {t.name for t in tenants
                     if program.live_in(phases[k - 1], t.name)}
        now_live = {t.name for t in tenants
                    if program.live_in(phases[k], t.name)}
        gone = prev_live - now_live
        if gone:
            departures.append((bounds[k][0], k, frozenset(gone)))

    # phase-boundary markers on the telemetry bus (pull-only: marks are
    # recorded via daemon timeouts and never perturb the event schedule)
    reg = getattr(db, "metrics", None)
    if reg is not None and hasattr(reg, "mark"):
        def marker():
            for k, (b0, _b1) in enumerate(bounds):
                at = t0 + b0
                if at > sim.now:
                    yield sim.timeout(at - sim.now, daemon=True)
                reg.mark(f"phase:{phases[k].name}")
        sim.process(marker())

    def dispatcher():
        while state["next"] < m:
            j = state["next"]
            at = t0 + float(m_at[j])
            if at > sim.now:
                yield at - sim.now   # bare-delay: no Event
            si, i = int(m_si[j]), int(m_i[j])
            arrive[si][i] = sim.now
            state["next"] = j + 1
            ti = slices[si].ti
            queue.append((si, i))
            depth[ti] += 1
            if depth[ti] > tmax_depth[ti]:
                tmax_depth[ti] = depth[ti]
            if len(queue) > state["max_depth"]:
                state["max_depth"] = len(queue)
            if idle:
                idle.pop().succeed()
        state["closed"] = True
        while idle:
            idle.pop().succeed()

    def server():
        while True:
            while not queue:
                if state["closed"]:
                    return
                ev = sim.event()
                idle.append(ev)
                yield ev
            si, i = queue.popleft()
            depth[slices[si].ti] -= 1
            start[si][i] = sim.now
            yield from slices[si].stream.execute(i)
            done[si][i] = sim.now

    def reaper(at_rel: float, k: int, gone: frozenset):
        # departure boundary: cancel the departed tenants' queued (not
        # yet started) ops; in-service ops drain toward the deadline
        at = t0 + at_rel
        if at > sim.now:
            yield at - sim.now   # bare-delay: no Event
        kept = deque()
        while queue:
            si, i = queue.popleft()
            if names[slices[si].ti] in gone and slices[si].k < k:
                dropped[si][i] = True
                depth[slices[si].ti] -= 1
            else:
                kept.append((si, i))
        queue.extend(kept)

    procs = [db.submit(server()) for _ in range(max_concurrency)]
    procs.append(db.submit(dispatcher()))
    for at_rel, k, gone in departures:
        procs.append(sim.process(reaper(at_rel, k, gone)))
    for p in procs:
        sim.run_until(p)
    busy_span = max(sim.now - t0, 1e-12)

    # ---- per-tenant, per-phase accounting ---------------------------
    extras = collect_extras(db)
    results: List[OpenLoopResult] = []
    for ti, ten in enumerate(tenants):
        mine = [si for si, s in enumerate(slices) if s.ti == ti]
        arr = np.concatenate([arrive[si] for si in mine])
        st = np.concatenate([start[si] for si in mine])
        dn = np.concatenate([done[si] for si in mine])
        drp = np.concatenate([dropped[si] for si in mine])
        completed = ~np.isnan(dn)
        measured = completed & (arr - t0 >= warmup)
        lat = dn - arr
        qdel = st - arr
        serv = dn - st
        codes = np.concatenate([slices[si].stream.ops.codes for si in mine]) \
            if mine else np.empty(0, np.int8)
        reads = (codes == READ) & measured

        phase_rows: List[Dict] = []
        for si in mine:
            s = slices[si]
            b0, b1 = bounds[s.k]
            c = ~np.isnan(done[si])
            mz = c & (arrive[si] - t0 >= warmup)
            tt = done[si] - arrive[si]
            qq = start[si] - arrive[si]
            vv = done[si] - start[si]
            phase_rows.append({
                "phase": s.k, "name": phases[s.k].name,
                "t0": b0, "t1": b1,
                "workload": s.stream.spec.name,
                "n_arrived": int(len(arrive[si])),
                "n_completed": int(c.sum()),
                "n_dropped": int(dropped[si].sum()),
                "n_measured": int(mz.sum()),
                "throughput": float(c.sum()) / max(b1 - b0, 1e-12),
                "latency_p99": _pct(tt[mz])["p99"],
                "queue_p99": _pct(qq[mz])["p99"],
                "service_p99": _pct(vv[mz])["p99"],
            })

        violations = 0
        for at_rel, k, gone in departures:
            if ten.name not in gone:
                continue
            deadline = t0 + at_rel + program.drain_s
            for si in mine:
                if slices[si].k < k:
                    d = done[si]
                    violations += int((d[~np.isnan(d)] > deadline).sum())

        counts: Dict[str, int] = {}
        for si in mine:
            for op, c in slices[si].stream.counts.items():
                counts[op] = counts.get(op, 0) + c
        results.append(OpenLoopResult(
            name=program.name, scheme=db.scheme, arrival=ten.arrival.name,
            n_arrived=int(len(arr)), n_measured=int(measured.sum()),
            duration=total,
            offered_rate=len(arr) / max(total, 1e-12),
            throughput=float(completed.sum()) / busy_span,
            latency_p=_pct(lat[measured]), queue_p=_pct(qdel[measured]),
            service_p=_pct(serv[measured]),
            read_latency_p=_pct(lat[reads]),
            mean_latency=float(lat[measured].mean()) if measured.any() else 0.0,
            mean_queue=float(qdel[measured].mean()) if measured.any() else 0.0,
            mean_service=float(serv[measured].mean()) if measured.any() else 0.0,
            max_queue_depth=tmax_depth[ti],
            op_counts=counts, extras=extras,
            tenant=ten.name, drift=program.name, phases=phase_rows,
            n_completed=int(completed.sum()), dropped=int(drp.sum()),
            drain_violations=violations))
    return results


# ======================================================================
# cross-scheme per-phase rankings
# ======================================================================
def phase_rankings(rows: Sequence[Dict], metric: str = "latency_p99"
                   ) -> Dict[Tuple, Dict]:
    """Rank schemes by per-phase ``metric`` across drift rows.

    The default metric is the in-window sojourn tail (``latency_p99``,
    lower is better): because every op is scored in the phase it
    *arrived* in and the run drains to completion, per-phase
    *throughput* is arrival-bound by construction — identical across
    schemes except for drops — so tails are the quantity that actually
    discriminates.  ``metric="throughput"`` is still accepted (higher is
    better) for drop-heavy programs.

    Rows are grouped by ``(drift program, arrival, tenant, ssd_zones)`` —
    everything but the scheme — and within each group every phase gets a
    scheme ordering (best first; ties broken by scheme name for
    determinism; schemes with no measured op in the window are excluded
    rather than ranked on an empty percentile).  Returns ``{group:
    {"phases": [{"phase", "name", "ranking", <metric>}...], "flips": n}}``
    where ``flips`` counts the phase transitions whose ordering differs
    from the previous phase — the run-level non-stationarity summary.
    """
    lower_is_better = metric != "throughput"
    groups: Dict[Tuple, List[Dict]] = {}
    for r in rows:
        if "drift" not in r or "phases" not in r:
            continue
        key = (r["drift"], r.get("arrival"), r.get("tenant"),
               r.get("ssd_zones"))
        groups.setdefault(key, []).append(r)
    out: Dict[Tuple, Dict] = {}
    for key in sorted(groups, key=str):
        per_phase: Dict[int, List[Tuple[str, float]]] = {}
        pnames: Dict[int, str] = {}
        for r in groups[key]:
            for p in r["phases"]:
                if lower_is_better and not p.get("n_measured", 1):
                    continue
                per_phase.setdefault(p["phase"], []).append(
                    (r["scheme"], float(p[metric])))
                pnames[p["phase"]] = p["name"]
        phases_out: List[Dict] = []
        prev = None
        flips = 0
        for k in sorted(per_phase):
            vals = per_phase[k]
            sign = 1.0 if lower_is_better else -1.0
            ranking = [s for s, _v in
                       sorted(vals, key=lambda sv: (sign * sv[1], sv[0]))]
            if prev is not None and ranking != prev:
                flips += 1
            prev = ranking
            phases_out.append({"phase": k, "name": pnames[k],
                               "ranking": ranking,
                               metric: dict(sorted(vals))})
        out[key] = {"phases": phases_out, "flips": flips}
    return out


def rank_flips(rows: Sequence[Dict], metric: str = "latency_p99"
               ) -> Dict[Tuple, int]:
    """Per group (see :func:`phase_rankings`), the number of phase
    boundaries where the scheme ordering by ``metric`` changed."""
    return {k: v["flips"] for k, v in phase_rankings(rows, metric).items()}


# ======================================================================
# named programs
# ======================================================================
def _arrival(kind: str, rate: float, phase_s: float) -> ArrivalProcess:
    """Arrival shapes for drift tenants, anchored to a calibrated rate —
    the burst period scales with the phase length so every phase sees
    full on/off cycles."""
    if kind == "poisson":
        return PoissonArrivals(round(rate, 4))
    if kind == "bursty":
        return BurstyArrivals(round(0.4 * rate, 4), round(2.5 * rate, 4),
                              on=round(0.12 * phase_s, 4),
                              off=round(0.28 * phase_s, 4))
    raise ValueError(f"unknown drift arrival kind {kind!r}; "
                     f"one of ('poisson', 'bursty')")


def _rotate(*, svc: float, n_keys: int, arrival_kind: str,
            phase_s: float) -> TraceProgram:
    """Single-tenant chooser rotation: skewed reads -> virtual-time
    hotspot walk -> scan-burst analytics -> working-set growth.  Each
    phase reseeds the Zipf rank scramble, so the hot *keys* rotate at
    every boundary even where the mix does not change."""
    tenants = (DriftTenant("t0", _arrival(arrival_kind, 0.45 * svc,
                                          phase_s)),)
    readmix = WorkloadSpec("readmix", read=0.9, update=0.1, alpha=0.99)
    shift = WorkloadSpec("shift", read=0.8, update=0.2, dist="hotspot",
                         alpha=0.99, hotspot_step="auto",
                         hotspot_period_s=round(phase_s / 5.0, 4))
    grow = WorkloadSpec("grow", read=0.6, insert=0.4, dist="latest",
                        alpha=0.9)
    phases = (
        Phase("warm", phase_s, readmix),
        Phase("shift", phase_s, shift),
        Phase("analytics", phase_s, readmix, scan_burst=0.25, scan_len=200),
        Phase("grow", phase_s, grow, n_keys=int(1.5 * n_keys)),
    )
    return TraceProgram(f"rotate~{arrival_kind}", phases, tenants,
                        n_keys=n_keys)


def _churn(*, svc: float, n_keys: int, arrival_kind: str,
           phase_s: float) -> TraceProgram:
    """Tenant churn: a persistent read-heavy tenant, plus a write/scan
    batch tenant that arrives for the middle phase and departs (its
    queued ops are dropped at the boundary, in-service ops drain)."""
    tenants = (
        DriftTenant("base", _arrival(arrival_kind, 0.35 * svc, phase_s)),
        DriftTenant("batch", _arrival("poisson", 0.5 * svc, phase_s)),
    )
    readmix = WorkloadSpec("readmix", read=0.9, update=0.1, alpha=0.99)
    batchmix = WorkloadSpec("batchmix", update=0.6, scan=0.2, insert=0.2,
                            alpha=0.9, scan_max=60)
    phases = (
        Phase("solo", phase_s, readmix, tenants=("base",)),
        Phase("contend", phase_s, readmix,
              per_tenant=(("batch", batchmix),),
              tenants=("base", "batch")),
        Phase("after", phase_s, readmix, tenants=("base",)),
    )
    return TraceProgram(f"churn~{arrival_kind}", phases, tenants,
                        n_keys=n_keys)


PROGRAM_BUILDERS = {"rotate": _rotate, "churn": _churn}


def build_program(name: str, *, svc: float, n_keys: int,
                  arrival_kind: str = "poisson",
                  phase_s: float = 150.0) -> TraceProgram:
    """Instantiate a named program against a calibrated service rate.
    The program name encodes the arrival kind (``rotate~poisson``), so
    one sweep can carry both arrival variants as distinct cells."""
    try:
        builder = PROGRAM_BUILDERS[name]
    except KeyError:
        raise ValueError(f"unknown drift program {name!r}; "
                         f"one of {sorted(PROGRAM_BUILDERS)}") from None
    return builder(svc=svc, n_keys=n_keys, arrival_kind=arrival_kind,
                   phase_s=phase_s)


# ======================================================================
# sweep integration
# ======================================================================
@dataclass(frozen=True)
class DriftCell:
    """One fully-resolved drift cell: a program on one scheme/SSD budget.
    The run's duration is the program's own (``TraceProgram.duration``),
    not the matrix default."""

    scheme: str
    program: TraceProgram
    ssd_zones: int

    @property
    def name(self) -> str:
        return f"{self.scheme}/drift:{self.program.name}/z{self.ssd_zones}"
