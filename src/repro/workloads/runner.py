"""Open-loop workload engine + declarative scenario matrix.

The paper evaluates HHZS only with closed-loop YCSB clients (ycsb.py):
offered load self-throttles to the store's service rate, so queueing never
builds up and the flush/compaction/migration interference shows only in
service time.  Production KV stores face *open-loop* arrivals — requests
keep coming whether or not the store keeps up — where the same interference
surfaces as queueing delay and tail-latency blowup.

This module adds:

* Arrival processes: ``PoissonArrivals`` (memoryless), ``BurstyArrivals``
  (on-off modulated Poisson: bursts over a base rate), ``RampArrivals``
  (linearly ramping rate — a single diurnal load edge), ``DiurnalArrivals``
  (piecewise-linear multi-ramp through a list of rate knots — a full
  day-shaped profile), ``FlashCrowdArrivals`` (steady base rate with a
  sudden spike that decays exponentially — news-event traffic), all
  generating arrival timestamps in virtual seconds from a seeded RNG.
* ``run_open_loop``: arrivals enqueue ops; a bounded server pool (modelling
  the store's request threads) services the queue.  Per-op accounting
  splits total latency into *queueing delay* (arrival -> service start)
  and *service time* (start -> completion), with a warm-up window excluded
  from statistics and a virtual-time limit on the arrival stream.
* ``run_multi_tenant``: N named tenants (``TenantSpec``), each with its own
  workload, arrival process, and seeded op stream, share one ``DB`` and one
  bounded server pool.  The same queueing/service decomposition is reported
  *per tenant*, and each arrival passes through the store's admission
  controller (``repro.core.middleware.AdmissionController``) so shedding /
  delaying policies can protect an SLO tenant from a misbehaving neighbour.
* ``ScenarioMatrix``: sweeps (scheme x workload x arrival x SSD-zone
  budget) — or, in multi-tenant mode, (scheme x tenant-mix x admission
  policy x SSD-zone budget) — from a declarative spec, loads a fresh store
  per cell, and emits JSON rows consumed by ``benchmarks/report.py``.

Op semantics are shared with the closed-loop runner via ``OpStream`` —
placement/migration/caching schemes see byte-identical request streams,
and a single-tenant run under policy ``none`` is event-for-event identical
to ``run_open_loop`` (asserted by ``tests/test_multitenant.py``).
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Dict, List, Mapping, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.middleware import (DELAY, REJECT, AdmissionConfig,
                               AdmissionController)
from ..zoned.faults import FaultInjector, FaultSpec
from .ycsb import (OP_NAMES, READ, OpStream, WorkloadSpec, YCSB, _pct,
                   collect_extras, run_load)


# ======================================================================
# arrival processes
# ======================================================================
class ArrivalProcess:
    """Generates arrival timestamps in [0, duration) virtual seconds."""

    name: str = "arrivals"

    def times(self, rng: np.random.Generator,
              duration: float) -> np.ndarray:
        raise NotImplementedError

    @staticmethod
    def _poisson_times(rng, rate: float, start: float,
                       end: float) -> np.ndarray:
        """Homogeneous Poisson arrivals on [start, end)."""
        span = end - start
        if rate <= 0 or span <= 0:
            return np.empty(0, np.float64)
        out: List[np.ndarray] = []
        t = start
        # draw in chunks; extend until we pass `end`
        chunk = max(16, int(rate * span * 1.2))
        while t < end:
            gaps = rng.exponential(1.0 / rate, size=chunk)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = ts[-1]
        times = np.concatenate(out)
        return times[times < end]


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at a constant offered rate (ops/virtual-s)."""

    rate: float

    @property
    def name(self) -> str:
        return f"poisson({self.rate:g})"

    def times(self, rng, duration):
        return self._poisson_times(rng, self.rate, 0.0, duration)


@dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """On-off modulated Poisson: ``burst_rate`` for ``on`` seconds, then
    ``base_rate`` for ``off`` seconds, repeating — the classic open-loop
    burst pattern where queues built during the burst drain (or don't)
    during the off phase."""

    base_rate: float
    burst_rate: float
    on: float
    off: float

    @property
    def name(self) -> str:
        return (f"bursty({self.base_rate:g}->{self.burst_rate:g},"
                f"on={self.on:g},off={self.off:g})")

    def times(self, rng, duration):
        out: List[np.ndarray] = []
        t = 0.0
        while t < duration:
            hi = min(t + self.on, duration)
            out.append(self._poisson_times(rng, self.burst_rate, t, hi))
            t = hi
            if t >= duration:
                break
            hi = min(t + self.off, duration)
            out.append(self._poisson_times(rng, self.base_rate, t, hi))
            t = hi
        return np.concatenate(out) if out else np.empty(0, np.float64)


@dataclass(frozen=True)
class RampArrivals(ArrivalProcess):
    """Linearly ramping rate from ``start_rate`` to ``end_rate`` over the
    run (diurnal load edge), via thinning of a max-rate Poisson stream."""

    start_rate: float
    end_rate: float

    @property
    def name(self) -> str:
        return f"ramp({self.start_rate:g}->{self.end_rate:g})"

    def times(self, rng, duration):
        rmax = max(self.start_rate, self.end_rate)
        cand = self._poisson_times(rng, rmax, 0.0, duration)
        if not len(cand):
            return cand
        rate_t = self.start_rate + (self.end_rate - self.start_rate) \
            * (cand / duration)
        keep = rng.random(len(cand)) < rate_t / rmax
        return cand[keep]


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Piecewise-linear multi-ramp rate through ``rates`` knots spread
    evenly over one ``period`` (default: the whole run), closing the loop
    back to the first knot — e.g. ``rates=(low, high, mid, high, low)`` is
    a two-peak day.  Runs longer than ``period`` repeat the profile.
    Implemented by thinning a max-rate Poisson stream."""

    rates: Tuple[float, ...]
    period: Optional[float] = None

    @property
    def name(self) -> str:
        knots = "->".join(f"{r:g}" for r in self.rates)
        if self.period is not None:
            return f"diurnal({knots},T={self.period:g})"
        return f"diurnal({knots})"

    def times(self, rng, duration):
        rates = tuple(self.rates)
        if not rates:
            return np.empty(0, np.float64)
        period = self.period if self.period is not None else duration
        rmax = max(rates)
        cand = self._poisson_times(rng, rmax, 0.0, duration)
        if not len(cand):
            return cand
        xp = np.linspace(0.0, period, len(rates) + 1)
        fp = np.asarray(rates + (rates[0],), np.float64)
        rate_t = np.interp(np.mod(cand, period), xp, fp)
        keep = rng.random(len(cand)) < rate_t / rmax
        return cand[keep]


@dataclass(frozen=True)
class FlashCrowdArrivals(ArrivalProcess):
    """Steady Poisson at ``base_rate`` until ``at``, then an instantaneous
    spike to ``peak_rate`` that decays exponentially back toward the base
    with time constant ``decay`` — the canonical flash-crowd / news-event
    shape.  Expected extra arrivals beyond the base load:
    ``(peak_rate - base_rate) * decay`` (for runs much longer than
    ``at + decay``).  Implemented by thinning a max-rate Poisson stream."""

    base_rate: float
    peak_rate: float
    at: float
    decay: float

    @property
    def name(self) -> str:
        return (f"flash({self.base_rate:g}->{self.peak_rate:g}"
                f"@{self.at:g},tau={self.decay:g})")

    def times(self, rng, duration):
        rmax = max(self.base_rate, self.peak_rate)
        cand = self._poisson_times(rng, rmax, 0.0, duration)
        if not len(cand):
            return cand
        rate_t = np.full(len(cand), float(self.base_rate))
        post = cand >= self.at
        rate_t[post] += (self.peak_rate - self.base_rate) \
            * np.exp(-(cand[post] - self.at) / max(self.decay, 1e-12))
        keep = rng.random(len(cand)) < rate_t / rmax
        return cand[keep]


# ======================================================================
# open-loop runner
# ======================================================================
@dataclass
class OpenLoopResult:
    """Result of one open-loop (sub-)run with queueing/service decomposition.

    One instance describes either a whole single-stream run
    (``run_open_loop``) or one tenant's slice of a multi-tenant run
    (``run_multi_tenant``); serialized by :meth:`to_json` it is exactly one
    row of ``results/storage/scenarios.json``.  Row schema:

    ``workload``        workload (``WorkloadSpec``) name, e.g. ``"A"``.
    ``scheme``          placement scheme (``repro.lsm.db.SCHEMES``).
    ``arrival``         arrival-process descriptor, e.g. ``"poisson(50)"``.
    ``n_arrived``       ops generated by the arrival process (including
                        shed/uncompleted ones).
    ``n_measured``      completed ops that arrived after the warm-up window
                        (the statistics population).
    ``duration``        virtual seconds of the arrival window.
    ``offered_rate``    ``n_arrived / duration`` (ops/virtual-second).
    ``throughput``      completed ops / busy span (arrival start -> last
                        completion).
    ``latency_p``       percentiles (p50/p90/p99/p999/p9999, virtual
                        seconds) of total sojourn time: arrival -> done.
    ``queue_p``         percentiles of queueing delay: arrival -> service
                        start (the wait for a free server, plus any
                        admission-control hold under policy ``delay``).
    ``service_p``       percentiles of service time: start -> done (device
                        time incl. background-job interference).
    ``read_latency_p``  sojourn percentiles over READ ops only.
    ``mean_latency`` / ``mean_queue`` / ``mean_service``
                        means over the measured population; by construction
                        ``mean_latency == mean_queue + mean_service``.
    ``max_queue_depth`` peak number of queued ops (this tenant's ops only
                        in multi-tenant runs; the whole queue otherwise).
    ``op_counts``       executed ops by type (read/update/insert/scan/rmw).
    ``extras``          device/cache/migration counters
                        (``repro.workloads.ycsb.collect_extras``).

    Multi-tenant rows additionally carry (absent on single-stream rows):

    ``tenant``          tenant name from ``TenantSpec``.
    ``policy``          admission policy the run used
                        (``repro.core.middleware.ADMISSION_POLICIES``).
    ``protected``       whether this tenant was exempt from shedding.
    ``admission``       per-tenant admission counters: ``arrived``,
                        ``admitted``, ``rejected``, ``delayed``,
                        ``holding`` (0 after a drained run), ``delay_time``
                        and ``mean_delay`` (virtual seconds); conservation:
                        ``arrived == admitted + rejected + holding``.

    Multi-tenant rows with an SLO target (``TenantSpec.slo_p99``) also
    carry:

    ``slo_p99``         the tenant's sojourn-p99 target (virtual seconds).
    ``slo_met``         whether the measured p99 met the target.
    ``goodput``         ops/s completing *within* the target over the busy
                        span (== ``throughput`` for tenants without a
                        target) — the SLO-attainment quantity
                        ``bench_control`` compares policies on.

    Multi-tenant rows under policy ``feedback`` also carry:

    ``control``         end-of-run control-plane knob summary
                        (``ControlPlane.knob_summary``): ``controller``
                        (``"aimd"``/``"pi"``), ``knobs`` (enabled actuator
                        names), final actuation level ``u`` and the
                        resulting ``pace`` / ``migration`` /
                        ``cache_budget`` knob values (-1.0 = unlimited).

    Fault-injection rows (``run_open_loop(faults=...)`` or
    ``run_multi_tenant(faults=...)``) additionally carry:

    ``fault``           the ``FaultSpec.label`` schedule description.
    ``availability``    completed ops / offered ops — below 1.0 when a
                        crash killed in-flight ops or refused arrivals
                        during the outage.  On per-tenant rows the
                        denominator excludes admission-shed ops (shedding
                        is policy, not unavailability).
    ``stall_p``         sojourn percentiles over ops that *arrived inside a
                        stall window* (the during-stall tail), when the
                        spec has stall windows.
    ``crash``           crash/recovery accounting, when the spec has a
                        crash point: ``downtime`` (crash -> serving again,
                        virtual s), ``lost_in_flight`` (ops killed by the
                        crash), ``refused`` (arrivals during the outage),
                        plus ``DB.recovery``'s ``live_wal_zones`` /
                        ``replayed_gens`` / ``replayed_records``; on
                        per-tenant rows ``lost_in_flight``/``refused`` are
                        this tenant's share.
    ``recovery_slo_s`` / ``recovery_slo_met``
                        recovery-time SLO accounting on crash rows, when
                        the spec sets ``FaultSpec.recovery_slo_s``:
                        the downtime budget and whether the measured
                        downtime stayed within it.

    Drift rows (``repro.workloads.drift.run_drift``) carry instead of the
    multi-tenant block (``tenant`` names the drift tenant; no admission
    columns):

    ``drift``           the ``TraceProgram`` name, e.g. ``"rotate~poisson"``.
    ``phases``          per-phase metric windows, one dict per phase the
                        tenant was live in: ``phase`` (index), ``name``,
                        ``t0``/``t1`` (window, virtual s relative to run
                        start), ``workload``, ``n_arrived``,
                        ``n_completed``, ``n_dropped``, ``n_measured``,
                        ``throughput`` (completions / window length) and
                        ``latency_p99``/``queue_p99``/``service_p99``.
                        Ops are assigned to the phase they *arrived* in,
                        so a boundary straddler counts in exactly one
                        window and ``sum(phase n_arrived) == n_arrived``.
    ``n_completed``     completed ops over the whole program
                        (``n_arrived == n_completed + dropped``).
    ``dropped``         departed-tenant ops cancelled while still queued
                        at their departure boundary.
    ``drain_violations``
                        departed-tenant ops completing after the
                        ``boundary + TraceProgram.drain_s`` deadline
                        (kept at 0 by the engine's drop-at-boundary
                        semantics unless a single op's service time
                        exceeds the grace window).
    ``rank_flips``      run-level summary attached by ``bench_drift``
                        (absent on raw sweep rows): how many phase
                        boundaries changed the cross-scheme throughput
                        ordering of this row's (program x arrival x
                        tenant x budget) group.
    """

    name: str                      # workload name
    scheme: str
    arrival: str
    n_arrived: int
    n_measured: int                # completed ops past warm-up
    duration: float                # virtual seconds of arrivals
    offered_rate: float            # arrivals / duration
    throughput: float              # completed ops / busy span
    latency_p: Dict[str, float]    # total sojourn (arrival -> done)
    queue_p: Dict[str, float]      # queueing delay (arrival -> start)
    service_p: Dict[str, float]    # service time   (start -> done)
    read_latency_p: Dict[str, float]
    max_queue_depth: int
    op_counts: Dict[str, int]
    extras: Dict[str, float]
    mean_latency: float = 0.0
    mean_queue: float = 0.0
    mean_service: float = 0.0
    # set only on per-tenant rows from run_multi_tenant
    tenant: Optional[str] = None
    policy: Optional[str] = None
    protected: Optional[bool] = None
    admission: Optional[Dict[str, float]] = None
    goodput: Optional[float] = None
    slo_p99: Optional[float] = None
    slo_met: Optional[bool] = None
    # set only on feedback-policy tenant rows (ControlPlane.knob_summary)
    control: Optional[Dict] = None
    # set only on fault-injection rows (run_open_loop(faults=...) and
    # run_multi_tenant(faults=...))
    fault: Optional[str] = None
    availability: Optional[float] = None
    stall_p: Optional[Dict[str, float]] = None
    crash: Optional[Dict[str, float]] = None
    recovery_slo_s: Optional[float] = None
    recovery_slo_met: Optional[bool] = None
    # set only on drift rows (repro.workloads.drift.run_drift)
    drift: Optional[str] = None
    phases: Optional[List[Dict]] = None
    n_completed: Optional[int] = None
    dropped: Optional[int] = None
    drain_violations: Optional[int] = None
    rank_flips: Optional[int] = None

    def row(self) -> str:
        tag = ""
        if self.drift is not None:
            tag = f"[{self.tenant}@{self.drift}] "
        elif self.tenant is not None:
            star = "*" if self.protected else ""
            tag = f"[{self.tenant}{star}/{self.policy}] "
        shed = ""
        if self.admission and self.admission.get("rejected"):
            shed = f" shed={int(self.admission['rejected'])}"
        extra = ""
        if self.fault is not None:
            extra = f" fault={self.fault} avail={self.availability:.4f}"
        return (f"{tag}{self.scheme:7s} {self.name:4s} {self.arrival:28s} "
                f"offered={self.offered_rate:8.1f}/s "
                f"thpt={self.throughput:8.1f}/s "
                f"p99={self.latency_p.get('p99', 0)*1e3:9.2f}ms "
                f"(queue {self.queue_p.get('p99', 0)*1e3:9.2f}ms / "
                f"service {self.service_p.get('p99', 0)*1e3:8.2f}ms)"
                f"{shed}{extra}")

    def to_json(self) -> Dict:
        d = {
            "workload": self.name, "scheme": self.scheme,
            "arrival": self.arrival, "n_arrived": self.n_arrived,
            "n_measured": self.n_measured, "duration": self.duration,
            "offered_rate": self.offered_rate, "throughput": self.throughput,
            "latency_p": self.latency_p, "queue_p": self.queue_p,
            "service_p": self.service_p,
            "read_latency_p": self.read_latency_p,
            "mean_latency": self.mean_latency, "mean_queue": self.mean_queue,
            "mean_service": self.mean_service,
            "max_queue_depth": self.max_queue_depth,
            "op_counts": self.op_counts, "extras": self.extras,
        }
        if self.drift is not None:
            d.update(tenant=self.tenant, drift=self.drift,
                     phases=self.phases, n_completed=self.n_completed,
                     dropped=self.dropped,
                     drain_violations=self.drain_violations)
            if self.rank_flips is not None:
                d["rank_flips"] = self.rank_flips
        elif self.tenant is not None:
            d.update(tenant=self.tenant, policy=self.policy,
                     protected=self.protected, admission=self.admission,
                     goodput=self.goodput)
            if self.slo_p99 is not None:
                d.update(slo_p99=self.slo_p99, slo_met=self.slo_met)
            if self.control is not None:
                d["control"] = self.control
        if self.fault is not None:
            d.update(fault=self.fault, availability=self.availability)
            if self.stall_p is not None:
                d["stall_p"] = self.stall_p
            if self.crash is not None:
                d["crash"] = self.crash
            if self.recovery_slo_s is not None:
                d.update(recovery_slo_s=self.recovery_slo_s,
                         recovery_slo_met=self.recovery_slo_met)
        return d


def _mean(arr: np.ndarray) -> float:
    return float(arr.mean()) if len(arr) else 0.0


def run_open_loop(db, spec: WorkloadSpec, arrival: ArrivalProcess,
                  duration: float, n_keys: int, *, warmup: float = 0.0,
                  max_concurrency: int = 64, seed: int = 1,
                  drain: bool = True, read_batch: int = 1,
                  faults: Optional[FaultSpec] = None) -> OpenLoopResult:
    """Open-loop run: ops arrive per ``arrival`` regardless of completion.

    A bounded pool of ``max_concurrency`` server processes (the store's
    request threads) pulls from the arrival queue; queueing delay is the
    wait for a server, service time is the op's execution (which itself
    includes device-queue interference from background jobs).  Ops arriving
    before ``warmup`` complete normally but are excluded from statistics.
    The arrival stream stops at ``duration``; with ``drain`` the queue is
    serviced to empty afterwards (ops past the limit still complete).
    With ``drain=False`` the run hard-stops at the time limit; ops still
    queued or in flight are excluded from statistics but remain pending
    work in the store — a later ``db.drain()`` or follow-up run on the
    same DB executes them, exactly as real queued requests would.

    ``read_batch`` > 1 turns on the batched read path: a server pulling a
    point READ from the queue also takes up to ``read_batch - 1`` further
    *consecutively queued* point reads (concurrently-arrived gets) and
    services them in one ``LSMTree.get_batch`` call — one vectorized Bloom
    probe over every (key x candidate-SST) pair instead of per-key python
    probing.  Results are identical to ``read_batch=1``; batched ops share
    a service start and completion time.  The default (1) keeps the
    per-key path, preserving event-for-event equivalence with
    ``run_multi_tenant`` (which does not batch).

    ``faults`` arms a :class:`repro.zoned.faults.FaultSpec` against the
    run: stall/slow/zone-reset windows perturb the devices underneath the
    unchanged engine, while ``crash_at`` kills the store mid-run
    (``DB.crash()``) — every queued or in-flight op is lost, arrivals
    during the outage are refused, and after ``DB.reopen()`` + WAL replay
    a fresh server fleet resumes the remaining arrival stream.  The result
    row then carries ``fault`` / ``availability`` / ``stall_p`` / ``crash``
    (see :class:`OpenLoopResult`).
    """
    sim = db.sim
    rng = np.random.default_rng(seed + 2)
    rel = arrival.times(rng, duration)
    n = len(rel)
    stream = OpStream(db, spec, n_ops=n, n_keys=n_keys, seed=seed)
    t0 = sim.now
    arrive = np.full(n, np.nan)
    start = np.full(n, np.nan)
    done = np.full(n, np.nan)
    queue: deque = deque()
    idle: List = []                       # events of parked servers
    state = {"closed": False, "max_depth": 0, "next": 0}
    crash_info: Dict[str, float] = {}

    def dispatcher():
        while state["next"] < n:
            i = state["next"]
            at = t0 + float(rel[i])
            if at > sim.now:
                yield at - sim.now   # bare-delay: no Event
            arrive[i] = sim.now
            state["next"] = i + 1
            queue.append(i)
            if len(queue) > state["max_depth"]:
                state["max_depth"] = len(queue)
            if idle:
                idle.pop().succeed()
        state["closed"] = True
        while idle:
            idle.pop().succeed()

    def server():
        while True:
            while not queue:
                if state["closed"]:
                    return
                ev = sim.event()
                idle.append(ev)
                yield ev
            i = queue.popleft()
            if read_batch > 1 and stream.is_point_read(i):
                batch = [i]
                while (queue and len(batch) < read_batch
                       and stream.is_point_read(queue[0])):
                    batch.append(queue.popleft())
                now = sim.now
                for j in batch:
                    start[j] = now
                yield from stream.execute_read_batch(batch)
                now = sim.now
                for j in batch:
                    done[j] = now
                continue
            start[i] = sim.now
            yield from stream.execute(i)
            done[i] = sim.now

    def crash_ctl():
        at = t0 + faults.crash_at
        if at > sim.now:
            yield at - sim.now   # bare-delay: no Event
        down0 = sim.now
        if faults.crash_shard is not None:
            # per-shard power loss (sharded stores): the dispatcher, the
            # queue and every server not caught mid-op on the crashed
            # shard keep serving; ops routed to the down shard park at
            # the router and complete after recovery — only the shard's
            # own in-flight ops are lost
            info = db.crash_shard(faults.crash_shard)
            crash_info["lost_in_flight"] = int(info["lost_in_flight"])
            killed = {id(p) for p in info["killed_processes"]}
            rec = yield from db.reopen_shard_gen(faults.crash_shard)
            crash_info.update(rec)
            crash_info["downtime"] = sim.now - down0
            crash_info["refused"] = 0
            # replace exactly the servers that died with the shard
            for _ in range(sum(1 for p in procs if id(p) in killed)):
                procs.append(db.submit(server()))
            return
        crash_info["lost_in_flight"] = \
            int((~np.isnan(arrive) & np.isnan(done)).sum())
        db.crash()                 # kills the dispatcher and every server
        queue.clear()
        idle.clear()
        rec = yield from db.reopen_gen()
        crash_info.update(rec)
        crash_info["downtime"] = sim.now - down0
        # clients that knocked during the outage were refused: account
        # their arrival, skip their execution
        refused = 0
        while state["next"] < n and t0 + float(rel[state["next"]]) <= sim.now:
            i = state["next"]
            arrive[i] = t0 + float(rel[i])
            state["next"] = i + 1
            refused += 1
        crash_info["refused"] = refused
        # the injector's processes died with the crash: re-arm the fault
        # windows that have not fired yet on the original schedule
        FaultInjector(db, faults).arm(t0=t0, after=sim.now - t0)
        # fresh serving fleet resumes the remaining arrival stream
        for _ in range(max_concurrency):
            db.submit(server())
        db.submit(dispatcher())

    procs = [db.submit(server()) for _ in range(max_concurrency)]
    procs.append(db.submit(dispatcher()))
    crashing = faults is not None and faults.crash_at is not None
    if faults is not None:
        FaultInjector(db, faults).arm()
        if crashing:
            sim.process(crash_ctl())
    if drain:
        if crashing:
            # the phase-1 processes die at the crash, so their completion
            # events never fire: drive the run to global quiescence instead
            sim.run()
        else:
            for p in procs:
                sim.run_until(p)
    else:
        # hard time limit: stop at the end of the arrival window; ops still
        # queued or in flight are excluded from statistics below
        db.run_for(t0 + duration - sim.now)
    busy_span = max(sim.now - t0, 1e-12)

    completed = ~np.isnan(done)
    if crashing and completed.any():
        # the crash path ran to global quiescence (sim.run()), which
        # includes background compaction settling after the last op; clamp
        # the busy span to the last completion so throughput stays
        # comparable with non-crash cells (run_until stops there)
        busy_span = max(float(done[completed].max()) - t0, 1e-12)
    measured = completed & (arrive - t0 >= warmup)
    total = done - arrive
    qdel = start - arrive
    serv = done - start
    reads = (stream.ops.codes == READ) & measured
    fault_fields: Dict = {}
    if faults is not None:
        fault_fields["fault"] = faults.label
        fault_fields["availability"] = float(completed.sum()) / max(n, 1)
        if faults.stalls:
            smask = np.zeros(n, bool)
            for w in faults.stalls:
                smask |= ((arrive >= t0 + w.at)
                          & (arrive < t0 + w.at + w.duration))
            fault_fields["stall_p"] = _pct(total[smask & measured])
        if crashing:
            fault_fields["crash"] = dict(crash_info)
            if faults.recovery_slo_s is not None:
                fault_fields["recovery_slo_s"] = faults.recovery_slo_s
                fault_fields["recovery_slo_met"] = bool(
                    crash_info.get("downtime", float("inf"))
                    <= faults.recovery_slo_s)
    return OpenLoopResult(
        name=spec.name, scheme=db.scheme, arrival=arrival.name,
        n_arrived=n, n_measured=int(measured.sum()), duration=duration,
        offered_rate=n / max(duration, 1e-12),
        throughput=float(completed.sum()) / busy_span,
        latency_p=_pct(total[measured]), queue_p=_pct(qdel[measured]),
        service_p=_pct(serv[measured]),
        read_latency_p=_pct(total[reads]),
        mean_latency=_mean(total[measured]), mean_queue=_mean(qdel[measured]),
        mean_service=_mean(serv[measured]),
        max_queue_depth=state["max_depth"],
        # snapshot: with drain=False the stream keeps mutating its counts
        # if leftover queued ops execute on a later drain
        op_counts=dict(stream.counts), extras=collect_extras(db),
        **fault_fields)


# ======================================================================
# multi-tenant open-loop serving
# ======================================================================
@dataclass(frozen=True)
class TenantSpec:
    """One tenant of a multi-tenant open-loop run.

    ``workload`` may be a YCSB letter key ("A".."F") or a full
    ``WorkloadSpec``; ``arrival`` is this tenant's own arrival process.
    ``protected`` marks the tenant exempt from admission-control
    shedding/delaying — the SLO tenant the policies exist to protect.
    ``slo_p99`` is the tenant's sojourn-p99 target in virtual seconds: it
    defines the row's ``goodput``/``slo_met`` columns and, on protected
    tenants under policy ``feedback``, drives the SLO feedback controller
    (``repro.obs.control.ControlPlane``).
    """

    name: str
    workload: Union[str, WorkloadSpec]
    arrival: ArrivalProcess
    protected: bool = False
    slo_p99: Optional[float] = None


@dataclass
class MultiTenantResult:
    """Result of one multi-tenant run: per-tenant ``OpenLoopResult`` slices
    (each carrying tenant/policy/admission fields) plus shared aggregates."""

    scheme: str
    policy: str
    duration: float
    n_arrived: int                  # all tenants
    n_completed: int                # all tenants
    max_queue_depth: int            # shared service queue
    tenants: List[OpenLoopResult]
    extras: Dict[str, float]

    def by_tenant(self, name: str) -> OpenLoopResult:
        for t in self.tenants:
            if t.tenant == name:
                return t
        raise KeyError(name)

    def rows(self) -> List[Dict]:
        return [t.to_json() for t in self.tenants]

    def row(self) -> str:
        return "\n".join(t.row() for t in self.tenants)


def run_multi_tenant(db, tenants: Sequence[TenantSpec], duration: float,
                     n_keys: int, *, warmup: float = 0.0,
                     max_concurrency: int = 64, seed: int = 1,
                     drain: bool = True,
                     policy: Union[AdmissionConfig, str, None] = None,
                     faults: Optional[FaultSpec] = None
                     ) -> MultiTenantResult:
    """N tenants with independent arrival processes share one store.

    Each tenant gets its own seeded ``OpStream`` (distinct key-popularity
    scramble and op mix) and its own arrival timestamps; the merged arrival
    sequence feeds one bounded pool of ``max_concurrency`` servers, so
    tenants contend for service exactly as co-located workloads contend for
    a store's request threads.  Every arrival passes through
    ``db.admission`` (``AdmissionController``): shed ops count in the
    tenant's ``admission`` row but never execute; delayed ops are held
    until store pressure clears, the hold time showing up as queueing
    delay.  ``policy`` (a policy name or full ``AdmissionConfig``)
    reconfigures ``db.admission`` for this run; tenants flagged
    ``protected`` are added to the controller's protected set.

    Under policy ``"feedback"`` the run additionally spins up an SLO
    feedback controller (``repro.obs.control.ControlPlane``): every
    completion's sojourn is observed per tenant, and an AIMD daemon loop
    drives the non-protected tenants' token-bucket rates toward the
    protected tenants' ``TenantSpec.slo_p99`` targets (and away from
    compaction debt above ``AdmissionConfig.debt_threshold``).

    ``faults`` arms a :class:`repro.zoned.faults.FaultSpec` against the
    run exactly as in ``run_open_loop``: stall/slow/zone-reset windows
    perturb the devices under the unchanged engine, ``crash_at`` kills the
    store mid-run (queued, in-flight and admission-held ops are lost,
    arrivals during the outage are refused per tenant) and recovery
    resumes the remaining merged arrival stream with a fresh server fleet.
    Per-tenant rows then carry ``fault``/``availability``/``stall_p``/
    ``crash`` columns (see :class:`OpenLoopResult`).

    Accounting mirrors ``run_open_loop`` per tenant (queueing vs service
    decomposition, warm-up exclusion, ``drain`` semantics); with one
    tenant and policy ``none`` the run is event-for-event identical to
    ``run_open_loop``.
    """
    sim = db.sim
    names = [t.name for t in tenants]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate tenant names: {names}")
    if faults is not None and faults.crash_shard is not None:
        raise ValueError("per-shard crashes (FaultSpec.crash_shard) are a "
                         "single-stream feature; use run_open_loop")
    # fresh controller per run: counters, per-run protected-set widening
    # and the queue gauge must not leak into later runs on the same store.
    # The store wires its own pressure signals — backend WAL pressure and
    # the compaction-debt gauge on a DB, per-shard pressure callbacks on a
    # ShardedDB — and re-installs metrics; policy None keeps the store's
    # configured policy via its pristine base_cfg.
    ctrl = db.fresh_admission(policy)
    prot = frozenset(t.name for t in tenants if t.protected)
    if prot:
        # rebind (never mutate) the config: callers may share one
        # AdmissionConfig across runs/cells with different tenant mixes
        ctrl.cfg = replace(ctrl.cfg,
                           protected=frozenset(ctrl.cfg.protected) | prot)
    control = None
    if ctrl.cfg.policy == "feedback":
        from ..obs.control import ControlPlane
        control = ControlPlane(
            sim, ctrl,
            targets={t.name: t.slo_p99 for t in tenants
                     if t.protected and t.slo_p99},
            debt_gauge=ctrl.debt_gauge,
            registry=getattr(db, "metrics", None),
            db=db)
        control.start()

    specs = [YCSB[t.workload] if isinstance(t.workload, str) else t.workload
             for t in tenants]
    # per-tenant seeds: tenant 0 matches run_open_loop's (seed + 2 arrival
    # rng, seed op stream) so the single-tenant differential holds; the
    # 9973 stride keeps tenants' streams decorrelated
    rels, streams = [], []
    for ti, t in enumerate(tenants):
        rng = np.random.default_rng(seed + 2 + 9973 * ti)
        rels.append(t.arrival.times(rng, duration))
        streams.append(OpStream(db, specs[ti], n_ops=len(rels[ti]),
                                n_keys=n_keys, seed=seed + 9973 * ti))
        # tag writes with the originating tenant so flushed bytes (and
        # hence compaction debt) attribute back to them
        streams[-1].tenant = t.name
    m_at = (np.concatenate(rels) if rels else np.empty(0, np.float64))
    m_ti = np.concatenate([np.full(len(r), ti, np.int64)
                           for ti, r in enumerate(rels)]) \
        if rels else np.empty(0, np.int64)
    m_i = np.concatenate([np.arange(len(r), dtype=np.int64) for r in rels]) \
        if rels else np.empty(0, np.int64)
    order = np.argsort(m_at, kind="stable")   # ties: tenant order
    m_at, m_ti, m_i = m_at[order], m_ti[order], m_i[order]
    m = len(m_at)

    t0 = sim.now
    arrive = [np.full(len(r), np.nan) for r in rels]
    start = [np.full(len(r), np.nan) for r in rels]
    done = [np.full(len(r), np.nan) for r in rels]
    shed = [np.zeros(len(r), bool) for r in rels]   # admission-rejected
    queue: deque = deque()
    idle: List = []                       # events of parked servers
    depth = [0] * len(tenants)            # per-tenant ops in queue
    tmax_depth = [0] * len(tenants)
    state = {"closed": False, "max_depth": 0, "dispatched": False,
             "holding": 0, "next": 0}
    crash_info: Dict[str, float] = {}
    lost_t = [0] * len(tenants)           # per-tenant crash accounting
    refused_t = [0] * len(tenants)
    ctrl.queue_gauge = lambda: len(queue)

    def _enqueue(ti: int, i: int) -> None:
        queue.append((ti, i))
        depth[ti] += 1
        if depth[ti] > tmax_depth[ti]:
            tmax_depth[ti] = depth[ti]
        if len(queue) > state["max_depth"]:
            state["max_depth"] = len(queue)
        if idle:
            idle.pop().succeed()

    def _maybe_close() -> None:
        # servers may only exit once arrivals AND held ops are exhausted
        if state["dispatched"] and state["holding"] == 0 \
                and not state["closed"]:
            state["closed"] = True
            while idle:
                idle.pop().succeed()

    def held(ti: int, i: int):
        yield from ctrl.hold(names[ti])
        state["holding"] -= 1
        _enqueue(ti, i)
        _maybe_close()

    def dispatcher():
        # cursor-based (not `for j in range(m)`) so the post-crash
        # respawn resumes the merged stream where the outage left it
        while state["next"] < m:
            j = state["next"]
            at = t0 + float(m_at[j])
            if at > sim.now:
                yield at - sim.now   # bare-delay: no Event
            ti, i = int(m_ti[j]), int(m_i[j])
            arrive[ti][i] = sim.now
            state["next"] = j + 1
            verdict = ctrl.decide(names[ti])
            if verdict == REJECT:
                shed[ti][i] = True
                continue
            if verdict == DELAY:
                state["holding"] += 1
                sim.process(held(ti, i))
                continue
            _enqueue(ti, i)
        state["dispatched"] = True
        _maybe_close()

    def server():
        while True:
            while not queue:
                if state["closed"]:
                    return
                ev = sim.event()
                idle.append(ev)
                yield ev
            ti, i = queue.popleft()
            depth[ti] -= 1
            start[ti][i] = sim.now
            yield from streams[ti].execute(i)
            done[ti][i] = sim.now
            if control is not None:
                control.observe(names[ti], sim.now - arrive[ti][i])

    def crash_ctl():
        # mirrors run_open_loop's crash controller, with per-tenant
        # accounting: everything queued, in flight, or admission-held dies
        # with the store; arrivals during the outage are refused
        at = t0 + faults.crash_at
        if at > sim.now:
            yield at - sim.now   # bare-delay: no Event
        for ti in range(len(tenants)):
            lost_t[ti] = int((~np.isnan(arrive[ti]) & ~shed[ti]
                              & np.isnan(done[ti])).sum())
        down0 = sim.now
        db.crash()                 # kills dispatcher, servers, held ops
        queue.clear()
        idle.clear()
        for ti in range(len(tenants)):
            depth[ti] = 0
        state["holding"] = 0       # held ops died with their processes
        rec = yield from db.reopen_gen()
        crash_info.update(rec)
        crash_info["downtime"] = sim.now - down0
        while state["next"] < m and \
                t0 + float(m_at[state["next"]]) <= sim.now:
            j = state["next"]
            ti, i = int(m_ti[j]), int(m_i[j])
            arrive[ti][i] = t0 + float(m_at[j])
            state["next"] = j + 1
            refused_t[ti] += 1
        crash_info["lost_in_flight"] = sum(lost_t)
        crash_info["refused"] = sum(refused_t)
        # re-arm the not-yet-fired fault windows on the original schedule
        FaultInjector(db, faults).arm(t0=t0, after=sim.now - t0)
        if control is not None:
            control.start()    # the AIMD loop died with the crash
        for _ in range(max_concurrency):
            db.submit(server())
        db.submit(dispatcher())

    procs = [db.submit(server()) for _ in range(max_concurrency)]
    procs.append(db.submit(dispatcher()))
    crashing = faults is not None and faults.crash_at is not None
    if faults is not None:
        FaultInjector(db, faults).arm()
        if crashing:
            sim.process(crash_ctl())
    if drain:
        if crashing:
            # phase-1 processes die at the crash and their completion
            # events never fire: drive to global quiescence instead
            sim.run()
        else:
            for p in procs:
                sim.run_until(p)
    else:
        # hard time limit (see run_open_loop): shed/held/queued ops that
        # did not complete are excluded from statistics below
        db.run_for(t0 + duration - sim.now)
    busy_span = max(sim.now - t0, 1e-12)
    if crashing:
        last = max((float(d[~np.isnan(d)].max())
                    for d in done if (~np.isnan(d)).any()),
                   default=sim.now)
        # clamp to the last completion (see run_open_loop's crash path)
        busy_span = max(last - t0, 1e-12)
    ctrl.queue_gauge = None   # this run's queue is dead; don't let later
    # DB.submit calls read pressure off it
    control_summary = None
    if control is not None:
        # snapshot before stop(): stop restores every knob to neutral
        control_summary = control.knob_summary()
        control.stop()        # retire the control daemon loop with the run

    extras = collect_extras(db)
    results: List[OpenLoopResult] = []
    for ti, t in enumerate(tenants):
        arr, st, dn = arrive[ti], start[ti], done[ti]
        completed = ~np.isnan(dn)
        measured = completed & (arr - t0 >= warmup)
        total = dn - arr
        qdel = st - arr
        serv = dn - st
        reads = (streams[ti].ops.codes == READ) & measured
        throughput = float(completed.sum()) / busy_span
        latency_p = _pct(total[measured])
        # SLO-attainment columns: goodput counts only completions within
        # the tenant's sojourn target (== throughput without a target)
        slo_fields: Dict = {"goodput": throughput}
        if t.slo_p99 is not None:
            within = int((total[completed] <= t.slo_p99).sum())
            slo_fields["goodput"] = within / busy_span
            slo_fields["slo_p99"] = t.slo_p99
            slo_fields["slo_met"] = bool(latency_p["p99"] <= t.slo_p99)
        fault_fields: Dict = {}
        if faults is not None:
            fault_fields["fault"] = faults.label
            served = len(arr) - int(shed[ti].sum())
            fault_fields["availability"] = \
                float(completed.sum()) / max(served, 1)
            if faults.stalls:
                smask = np.zeros(len(arr), bool)
                for w in faults.stalls:
                    smask |= ((arr >= t0 + w.at)
                              & (arr < t0 + w.at + w.duration))
                fault_fields["stall_p"] = _pct(total[smask & measured])
            if crashing:
                cd = dict(crash_info)
                cd["lost_in_flight"] = lost_t[ti]
                cd["refused"] = refused_t[ti]
                fault_fields["crash"] = cd
                if faults.recovery_slo_s is not None:
                    fault_fields["recovery_slo_s"] = faults.recovery_slo_s
                    fault_fields["recovery_slo_met"] = bool(
                        crash_info.get("downtime", float("inf"))
                        <= faults.recovery_slo_s)
        results.append(OpenLoopResult(
            name=specs[ti].name, scheme=db.scheme, arrival=t.arrival.name,
            n_arrived=len(arr), n_measured=int(measured.sum()),
            duration=duration,
            offered_rate=len(arr) / max(duration, 1e-12),
            throughput=throughput,
            latency_p=latency_p, queue_p=_pct(qdel[measured]),
            service_p=_pct(serv[measured]),
            read_latency_p=_pct(total[reads]),
            mean_latency=_mean(total[measured]),
            mean_queue=_mean(qdel[measured]),
            mean_service=_mean(serv[measured]),
            max_queue_depth=tmax_depth[ti],
            op_counts=dict(streams[ti].counts), extras=extras,
            tenant=t.name, policy=ctrl.policy_label, protected=t.protected,
            admission=ctrl.admission_summary(t.name),
            control=control_summary,
            **slo_fields, **fault_fields))
    return MultiTenantResult(
        scheme=db.scheme, policy=ctrl.policy_label, duration=duration,
        n_arrived=m,
        n_completed=sum(int((~np.isnan(d)).sum()) for d in done),
        max_queue_depth=state["max_depth"], tenants=results, extras=extras)


# ======================================================================
# scenario matrix
# ======================================================================
@dataclass(frozen=True)
class ScenarioCell:
    """One fully-resolved cell of the matrix."""

    scheme: str
    workload: WorkloadSpec
    arrival: ArrivalProcess
    ssd_zones: int
    fault: Optional[FaultSpec] = None
    # Bloom bits-per-key override for this cell's store (None = the
    # scenario default) — the filter-sweep axis
    filter_bits: Optional[int] = None
    # sharding axis: shards > 1 runs the cell on a ShardedDB
    # (repro.cluster) with the given routing policy; rebalance arms the
    # telemetry-driven online splitter (range routing only)
    shards: int = 1
    routing: str = "hash"
    rebalance: bool = False

    @property
    def name(self) -> str:
        base = (f"{self.scheme}/{self.workload.name}/"
                f"{self.arrival.name}/z{self.ssd_zones}")
        if self.filter_bits is not None:
            base += f"/fb{self.filter_bits}"
        if self.shards > 1:
            base += f"/sh{self.shards}-{self.routing}"
            if self.rebalance:
                base += "-rb"
        if self.fault is not None:
            base += f"/f:{self.fault.name}"
        return base


@dataclass(frozen=True)
class MultiTenantCell:
    """One fully-resolved multi-tenant cell: a tenant mix under one
    admission policy on one scheme/SSD budget (optionally with a fault
    schedule armed against the run)."""

    scheme: str
    tenants: Tuple[TenantSpec, ...]
    policy: Union[str, AdmissionConfig]
    ssd_zones: int
    fault: Optional[FaultSpec] = None

    @property
    def policy_name(self) -> str:
        if isinstance(self.policy, str):
            return self.policy
        return self.policy.label or self.policy.policy

    @property
    def name(self) -> str:
        mix = "+".join(t.name for t in self.tenants)
        base = (f"{self.scheme}/mt[{mix}]/{self.policy_name}"
                f"/z{self.ssd_zones}")
        if self.fault is not None:
            base += f"/f:{self.fault.name}"
        return base


@dataclass
class ScenarioMatrix:
    """Declarative sweep of (scheme x workload x arrival x SSD budget) —
    or, when ``tenants`` is set, (scheme x tenant-mix x admission policy x
    SSD budget).

    ``workloads`` entries may be YCSB letter keys ("A".."F") or full
    ``WorkloadSpec``s.  Each cell gets a freshly loaded store (same
    methodology as benchmarks/storage_exps.py: load, drain WAL, run while
    the compaction backlog is live), then an open-loop run.  Rows land in
    a JSON artifact (``results/storage/scenarios.json``) consumed by
    ``benchmarks/report.py``; the row schema is documented on
    :class:`OpenLoopResult` (``run`` adds ``cell`` — the cell name — and
    ``ssd_zones`` to every row).

    Multi-tenant mode: ``tenants`` is a list of tenant *mixes* (each a
    sequence of ``TenantSpec``); ``workloads``/``arrivals`` are ignored and
    every cell runs ``run_multi_tenant`` under each entry of ``policies``
    (policy names or ``AdmissionConfig``s), emitting one row *per tenant*
    per cell.

    Fault mode: ``faults`` sweeps cells across ``FaultSpec``s (device
    stalls, bandwidth degradation, zone resets, mid-run crash +
    recovery) — in single-stream *and* multi-tenant mode; ``None``
    entries keep the undisturbed baseline cell.  Fault rows carry
    ``fault``/``availability``/``stall_p``/``crash`` fields (per tenant
    in multi-tenant mode) and are rendered by
    ``benchmarks.report.fault_recovery_table``.

    Telemetry: ``telemetry=True`` (or a sample period) attaches the
    ``repro.obs`` metrics bus to every cell's store; with
    ``timeline_dir`` each cell dumps a timeline artifact
    (``results/storage/timelines/*.json`` schema).  Telemetry is
    pull-only and never changes a cell's rows.
    """

    schemes: Sequence[str]
    workloads: Sequence[Union[str, WorkloadSpec]]
    # either one list for every workload, or {workload name: list} to give
    # each workload its own (e.g. per-workload-calibrated) arrival rates
    arrivals: Union[Sequence[ArrivalProcess],
                    Mapping[str, Sequence[ArrivalProcess]]]
    ssd_zone_budgets: Sequence[int] = (20,)
    duration: float = 600.0            # virtual seconds of arrivals
    warmup: float = 60.0
    max_concurrency: int = 64
    key_div: int = 1                   # dataset divisor (quick sweeps)
    seed: int = 1
    db_factory: Optional[object] = None   # (scheme, ssd_zones) -> loaded db
    tenants: Sequence[Sequence[TenantSpec]] = ()
    policies: Sequence[Union[str, AdmissionConfig]] = ("none",)
    # fault-injection sweep dimension (single-stream AND multi-tenant
    # cells); None = the undisturbed baseline cell
    faults: Sequence[Optional[FaultSpec]] = (None,)
    # Bloom filter-bits sweep dimension (single-stream cells only): each
    # non-None entry loads the cell's store with that
    # ``filter_bits_per_key``; rows then carry a ``filter_bits`` column
    # (FP rate x throughput pivot: ``benchmarks.report.filter_sweep_table``)
    filter_bits: Sequence[Optional[int]] = (None,)
    # batched read path: >1 services consecutively queued point reads via
    # ``LSMTree.get_batch`` (see ``run_open_loop``)
    read_batch: int = 1
    # sharding sweep (single-stream cells only): each entry > 1 runs the
    # cell on a ``repro.cluster.ShardedDB`` with that many shard stores;
    # ``routing`` picks the router ("hash" | "range") and ``rebalance``
    # sweeps the online splitter on/off (ignored at shards == 1, where
    # the sharded facade is event-identical to a bare DB)
    shards: Sequence[int] = (1,)
    routing: str = "hash"
    rebalance: Sequence[bool] = (False,)
    # telemetry (repro.obs): True (or a sample period in virtual seconds)
    # attaches a MetricsRegistry to every cell's store — pull-only, so
    # rows stay byte-identical (asserted by CI grid-smoke); with
    # timeline_dir each cell also dumps its timeline artifact there
    telemetry: Union[bool, float] = False
    timeline_dir: Optional[Union[str, Path]] = None
    # serving scenario family (repro.workloads.serving): non-empty
    # serving_policies adds one ServingCell per policy x serving_workload
    # x arrival x serving_pool — KV-cache tiering policies selectable the
    # way storage schemes are
    serving_policies: Sequence[str] = ()
    serving_workloads: Sequence[object] = ()      # ServingWorkload
    serving_pools: Sequence[object] = ()          # ServingPool
    serving_admission: Union[str, AdmissionConfig, None] = None
    serving_costs: Optional[object] = None        # ServingCosts
    # drift scenario family (repro.workloads.drift): each TraceProgram
    # adds one DriftCell per scheme x SSD budget; the cell runs the
    # program's own virtual-time schedule (``duration`` is ignored) and
    # emits one per-tenant row with ``drift``/``phases`` columns
    drift_programs: Sequence[object] = ()         # TraceProgram
    results: List[OpenLoopResult] = field(default_factory=list)

    def _workload_spec(self, w) -> WorkloadSpec:
        return YCSB[w] if isinstance(w, str) else w

    def _arrivals_of(self, spec: WorkloadSpec) -> Sequence[ArrivalProcess]:
        if isinstance(self.arrivals, Mapping):
            return self.arrivals[spec.name]
        return self.arrivals

    def _serving_cells(self) -> List:
        if not self.serving_policies:
            return []
        from .serving import ServingCell, ServingPool, ServingWorkload
        wls = self.serving_workloads or (ServingWorkload(),)
        pools = self.serving_pools or (ServingPool(),)
        if isinstance(self.arrivals, Mapping):
            raise ValueError("serving cells need a flat arrival list, "
                             "not a per-workload mapping")
        return [ServingCell(p, w, a, sp)
                for p in self.serving_policies
                for w in wls
                for a in self.arrivals
                for sp in pools]

    def _drift_cells(self) -> List:
        if not self.drift_programs:
            return []
        from .drift import DriftCell
        return [DriftCell(s, p, z)
                for s in self.schemes
                for p in self.drift_programs
                for z in self.ssd_zone_budgets]

    def cells(self) -> List[Union[ScenarioCell, MultiTenantCell]]:
        if self.tenants:
            return [MultiTenantCell(s, tuple(mix), pol, z, f)
                    for s in self.schemes
                    for mix in self.tenants
                    for pol in self.policies
                    for z in self.ssd_zone_budgets
                    for f in self.faults] \
                + self._serving_cells() + self._drift_cells()
        return [ScenarioCell(s, w, a, z, f, fb, nsh, self.routing, rb)
                for s in self.schemes
                for w in map(self._workload_spec, self.workloads)
                for a in self._arrivals_of(w)
                for z in self.ssd_zone_budgets
                for f in self.faults
                for fb in self.filter_bits
                for nsh in self.shards
                for rb in (self.rebalance if nsh > 1 else (False,))
                ] + self._serving_cells() + self._drift_cells()

    def _fresh_db(self, scheme: str, ssd_zones: int,
                  filter_bits: Optional[int] = None, shards: int = 1,
                  routing: str = "hash", rebalance: bool = False):
        if self.db_factory is not None:
            # factories only need to understand the sweep kwargs the
            # matrix actually exercises (GridDBFactory takes them all) —
            # defaults are omitted so plain (scheme, zones) factories
            # keep working
            kw = {}
            if filter_bits is not None:
                kw["filter_bits"] = filter_bits
            if shards > 1:
                kw.update(shards=shards, routing=routing,
                          rebalance=rebalance)
            return self.db_factory(scheme, ssd_zones, **kw)
        from dataclasses import replace as _replace
        from ..lsm import DB, ScenarioConfig
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        if filter_bits is not None:
            sc = _replace(sc, lsm=_replace(
                sc.lsm, filter_bits_per_key=int(filter_bits)))
        n_keys = sc.paper_keys // self.key_div
        if shards > 1:
            from ..cluster import ShardedDB
            db = ShardedDB(scheme, sc, shards=shards, routing=routing,
                           key_space=n_keys, rebalance=rebalance)
        else:
            db = DB(scheme, sc)
        run_load(db, n_keys=n_keys)
        db.flush_all()
        db.n_keys = n_keys
        return db

    def run_cell(self, cell: Union[ScenarioCell, MultiTenantCell]
                 ) -> Tuple[List[OpenLoopResult], List[Dict]]:
        """Run one fully-resolved cell on a freshly loaded store.

        A cell's outcome depends only on the cell spec and the matrix's
        sizing/seed fields — never on other cells — which is what lets the
        sweep driver (``repro.workloads.sweep``) shard cells across worker
        processes and still produce rows identical to a sequential run.
        Returns the per-(sub)run results plus their JSON rows (one per
        tenant for multi-tenant cells, else exactly one).
        """
        from .drift import DriftCell, run_drift
        from .serving import ServingCell, run_matrix_cell
        if isinstance(cell, ServingCell):
            return run_matrix_cell(self, cell)
        n_shards = getattr(cell, "shards", 1)
        db = self._fresh_db(cell.scheme, cell.ssd_zones,
                            getattr(cell, "filter_bits", None),
                            shards=n_shards,
                            routing=getattr(cell, "routing", "hash"),
                            rebalance=getattr(cell, "rebalance", False))
        n_keys = getattr(db, "n_keys",
                         db.scenario.paper_keys // self.key_div)
        # sharded cells: baseline the router counters after the load phase
        # so per-shard rows report the measured run only
        kv_snap = db.kv.snapshot() if n_shards > 1 else None
        reg = None
        if self.telemetry or self.timeline_dir is not None:
            period = (float(self.telemetry)
                      if not isinstance(self.telemetry, bool)
                      and self.telemetry else 5.0)
            reg = db.enable_telemetry(period)
        if isinstance(cell, MultiTenantCell):
            res = run_multi_tenant(
                db, list(cell.tenants), self.duration, n_keys=n_keys,
                warmup=self.warmup,
                max_concurrency=self.max_concurrency,
                seed=self.seed, policy=cell.policy, faults=cell.fault)
            per_cell = res.tenants
        elif isinstance(cell, DriftCell):
            per_cell = run_drift(
                db, cell.program, n_keys=n_keys, warmup=self.warmup,
                max_concurrency=self.max_concurrency, seed=self.seed)
        else:
            per_cell = [run_open_loop(
                db, cell.workload, cell.arrival, self.duration,
                n_keys=n_keys, warmup=self.warmup,
                max_concurrency=self.max_concurrency, seed=self.seed,
                read_batch=self.read_batch, faults=cell.fault)]
        if reg is not None:
            reg.sample_now()        # close the series at end-of-run state
            if self.timeline_dir is not None:
                from ..obs.metrics import timeline_path
                meta = {"cell": cell.name, "scheme": cell.scheme,
                        "ssd_zones": cell.ssd_zones}
                if isinstance(cell, DriftCell):
                    # phase windows (relative virtual s) so timeline
                    # plots can segment by phase alongside the marks
                    meta["drift"] = cell.program.name
                    meta["phases"] = [
                        {"name": p.name, "t0": b[0], "t1": b[1]}
                        for p, b in zip(cell.program.phases,
                                        cell.program.bounds())]
                reg.dump_timeline(
                    timeline_path(self.timeline_dir, cell.name), meta=meta)
        rows = []
        for r in per_cell:
            row = r.to_json()
            row["ssd_zones"] = cell.ssd_zones
            row["cell"] = cell.name
            fb = getattr(cell, "filter_bits", None)
            if fb is not None:
                row["filter_bits"] = fb
            if n_shards > 1:
                calls0, routed0, _ = kv_snap
                calls1, routed1, _ = db.kv.snapshot()
                row["shards"] = n_shards
                row["routing"] = cell.routing
                row["rebalance"] = cell.rebalance
                row["kv_calls"] = calls1 - calls0
                row["shard_ops"] = {
                    str(i): routed1[i] - routed0[i]
                    for i in range(n_shards)}
                row["splits"] = [dict(s) for s in db.splits]
            rows.append(row)
        if n_shards > 1:
            # per-shard sub-rows share the cell name (aggregate row is
            # the one WITHOUT a "shard" column)
            for srow in db.shard_stats(kv_snap):
                srow.update(cell=cell.name, scheme=cell.scheme,
                            ssd_zones=cell.ssd_zones, shards=n_shards,
                            routing=cell.routing,
                            rebalance=cell.rebalance)
                rows.append(srow)
        return per_cell, rows

    def run(self, out: Optional[Union[str, Path]] = None,
            verbose: bool = True) -> List[Dict]:
        rows: List[Dict] = []
        for cell in self.cells():
            per_cell, cell_rows = self.run_cell(cell)
            self.results.extend(per_cell)
            rows.extend(cell_rows)
            if verbose:
                for r in per_cell:
                    print(r.row(), flush=True)
        if out is not None:
            out = Path(out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(rows, indent=1))
        return rows
