"""Open-loop workload engine + declarative scenario matrix.

The paper evaluates HHZS only with closed-loop YCSB clients (ycsb.py):
offered load self-throttles to the store's service rate, so queueing never
builds up and the flush/compaction/migration interference shows only in
service time.  Production KV stores face *open-loop* arrivals — requests
keep coming whether or not the store keeps up — where the same interference
surfaces as queueing delay and tail-latency blowup.

This module adds:

* Arrival processes: ``PoissonArrivals`` (memoryless), ``BurstyArrivals``
  (on-off modulated Poisson: bursts over a base rate), ``RampArrivals``
  (linearly ramping rate — diurnal load edges), all generating arrival
  timestamps in virtual seconds from a seeded RNG.
* ``run_open_loop``: arrivals enqueue ops; a bounded server pool (modelling
  the store's request threads) services the queue.  Per-op accounting
  splits total latency into *queueing delay* (arrival -> service start)
  and *service time* (start -> completion), with a warm-up window excluded
  from statistics and a virtual-time limit on the arrival stream.
* ``ScenarioMatrix``: sweeps (scheme x workload x arrival x SSD-zone
  budget) from a declarative spec, loads a fresh store per cell, and emits
  JSON rows consumed by ``benchmarks/report.py``.

Op semantics are shared with the closed-loop runner via ``OpStream`` —
placement/migration/caching schemes see byte-identical request streams.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .ycsb import (OP_NAMES, READ, OpStream, WorkloadSpec, YCSB, _pct,
                   collect_extras, run_load)


# ======================================================================
# arrival processes
# ======================================================================
class ArrivalProcess:
    """Generates arrival timestamps in [0, duration) virtual seconds."""

    name: str = "arrivals"

    def times(self, rng: np.random.Generator,
              duration: float) -> np.ndarray:
        raise NotImplementedError

    @staticmethod
    def _poisson_times(rng, rate: float, start: float,
                       end: float) -> np.ndarray:
        """Homogeneous Poisson arrivals on [start, end)."""
        span = end - start
        if rate <= 0 or span <= 0:
            return np.empty(0, np.float64)
        out: List[np.ndarray] = []
        t = start
        # draw in chunks; extend until we pass `end`
        chunk = max(16, int(rate * span * 1.2))
        while t < end:
            gaps = rng.exponential(1.0 / rate, size=chunk)
            ts = t + np.cumsum(gaps)
            out.append(ts)
            t = ts[-1]
        times = np.concatenate(out)
        return times[times < end]


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Memoryless arrivals at a constant offered rate (ops/virtual-s)."""

    rate: float

    @property
    def name(self) -> str:
        return f"poisson({self.rate:g})"

    def times(self, rng, duration):
        return self._poisson_times(rng, self.rate, 0.0, duration)


@dataclass(frozen=True)
class BurstyArrivals(ArrivalProcess):
    """On-off modulated Poisson: ``burst_rate`` for ``on`` seconds, then
    ``base_rate`` for ``off`` seconds, repeating — the classic open-loop
    burst pattern where queues built during the burst drain (or don't)
    during the off phase."""

    base_rate: float
    burst_rate: float
    on: float
    off: float

    @property
    def name(self) -> str:
        return (f"bursty({self.base_rate:g}->{self.burst_rate:g},"
                f"on={self.on:g},off={self.off:g})")

    def times(self, rng, duration):
        out: List[np.ndarray] = []
        t = 0.0
        while t < duration:
            hi = min(t + self.on, duration)
            out.append(self._poisson_times(rng, self.burst_rate, t, hi))
            t = hi
            if t >= duration:
                break
            hi = min(t + self.off, duration)
            out.append(self._poisson_times(rng, self.base_rate, t, hi))
            t = hi
        return np.concatenate(out) if out else np.empty(0, np.float64)


@dataclass(frozen=True)
class RampArrivals(ArrivalProcess):
    """Linearly ramping rate from ``start_rate`` to ``end_rate`` over the
    run (diurnal load edge), via thinning of a max-rate Poisson stream."""

    start_rate: float
    end_rate: float

    @property
    def name(self) -> str:
        return f"ramp({self.start_rate:g}->{self.end_rate:g})"

    def times(self, rng, duration):
        rmax = max(self.start_rate, self.end_rate)
        cand = self._poisson_times(rng, rmax, 0.0, duration)
        if not len(cand):
            return cand
        rate_t = self.start_rate + (self.end_rate - self.start_rate) \
            * (cand / duration)
        keep = rng.random(len(cand)) < rate_t / rmax
        return cand[keep]


# ======================================================================
# open-loop runner
# ======================================================================
@dataclass
class OpenLoopResult:
    """Result of one open-loop run, with queueing/service decomposition."""

    name: str                      # workload name
    scheme: str
    arrival: str
    n_arrived: int
    n_measured: int                # completed ops past warm-up
    duration: float                # virtual seconds of arrivals
    offered_rate: float            # arrivals / duration
    throughput: float              # completed ops / busy span
    latency_p: Dict[str, float]    # total sojourn (arrival -> done)
    queue_p: Dict[str, float]      # queueing delay (arrival -> start)
    service_p: Dict[str, float]    # service time   (start -> done)
    read_latency_p: Dict[str, float]
    max_queue_depth: int
    op_counts: Dict[str, int]
    extras: Dict[str, float]

    def row(self) -> str:
        return (f"{self.scheme:7s} {self.name:4s} {self.arrival:28s} "
                f"offered={self.offered_rate:8.1f}/s "
                f"thpt={self.throughput:8.1f}/s "
                f"p99={self.latency_p.get('p99', 0)*1e3:9.2f}ms "
                f"(queue {self.queue_p.get('p99', 0)*1e3:9.2f}ms / "
                f"service {self.service_p.get('p99', 0)*1e3:8.2f}ms)")

    def to_json(self) -> Dict:
        return {
            "workload": self.name, "scheme": self.scheme,
            "arrival": self.arrival, "n_arrived": self.n_arrived,
            "n_measured": self.n_measured, "duration": self.duration,
            "offered_rate": self.offered_rate, "throughput": self.throughput,
            "latency_p": self.latency_p, "queue_p": self.queue_p,
            "service_p": self.service_p,
            "read_latency_p": self.read_latency_p,
            "max_queue_depth": self.max_queue_depth,
            "op_counts": self.op_counts, "extras": self.extras,
        }


def run_open_loop(db, spec: WorkloadSpec, arrival: ArrivalProcess,
                  duration: float, n_keys: int, *, warmup: float = 0.0,
                  max_concurrency: int = 64, seed: int = 1,
                  drain: bool = True) -> OpenLoopResult:
    """Open-loop run: ops arrive per ``arrival`` regardless of completion.

    A bounded pool of ``max_concurrency`` server processes (the store's
    request threads) pulls from the arrival queue; queueing delay is the
    wait for a server, service time is the op's execution (which itself
    includes device-queue interference from background jobs).  Ops arriving
    before ``warmup`` complete normally but are excluded from statistics.
    The arrival stream stops at ``duration``; with ``drain`` the queue is
    serviced to empty afterwards (ops past the limit still complete).
    With ``drain=False`` the run hard-stops at the time limit; ops still
    queued or in flight are excluded from statistics but remain pending
    work in the store — a later ``db.drain()`` or follow-up run on the
    same DB executes them, exactly as real queued requests would.
    """
    sim = db.sim
    rng = np.random.default_rng(seed + 2)
    rel = arrival.times(rng, duration)
    n = len(rel)
    stream = OpStream(db, spec, n_ops=n, n_keys=n_keys, seed=seed)
    t0 = sim.now
    arrive = np.full(n, np.nan)
    start = np.full(n, np.nan)
    done = np.full(n, np.nan)
    queue: deque = deque()
    idle: List = []                       # events of parked servers
    state = {"closed": False, "max_depth": 0}

    def dispatcher():
        for i in range(n):
            at = t0 + float(rel[i])
            if at > sim.now:
                yield sim.timeout(at - sim.now)
            arrive[i] = sim.now
            queue.append(i)
            if len(queue) > state["max_depth"]:
                state["max_depth"] = len(queue)
            if idle:
                idle.pop().succeed()
        state["closed"] = True
        while idle:
            idle.pop().succeed()

    def server():
        while True:
            while not queue:
                if state["closed"]:
                    return
                ev = sim.event()
                idle.append(ev)
                yield ev
            i = queue.popleft()
            start[i] = sim.now
            yield from stream.execute(i)
            done[i] = sim.now

    procs = [db.submit(server()) for _ in range(max_concurrency)]
    procs.append(db.submit(dispatcher()))
    if drain:
        for p in procs:
            sim.run_until(p)
    else:
        # hard time limit: stop at the end of the arrival window; ops still
        # queued or in flight are excluded from statistics below
        db.run_for(t0 + duration - sim.now)
    busy_span = max(sim.now - t0, 1e-12)

    completed = ~np.isnan(done)
    measured = completed & (arrive - t0 >= warmup)
    total = done - arrive
    qdel = start - arrive
    serv = done - start
    reads = (stream.ops.codes == READ) & measured
    return OpenLoopResult(
        name=spec.name, scheme=db.scheme, arrival=arrival.name,
        n_arrived=n, n_measured=int(measured.sum()), duration=duration,
        offered_rate=n / max(duration, 1e-12),
        throughput=float(completed.sum()) / busy_span,
        latency_p=_pct(total[measured]), queue_p=_pct(qdel[measured]),
        service_p=_pct(serv[measured]),
        read_latency_p=_pct(total[reads]),
        max_queue_depth=state["max_depth"],
        # snapshot: with drain=False the stream keeps mutating its counts
        # if leftover queued ops execute on a later drain
        op_counts=dict(stream.counts), extras=collect_extras(db))


# ======================================================================
# scenario matrix
# ======================================================================
@dataclass(frozen=True)
class ScenarioCell:
    """One fully-resolved cell of the matrix."""

    scheme: str
    workload: WorkloadSpec
    arrival: ArrivalProcess
    ssd_zones: int

    @property
    def name(self) -> str:
        return (f"{self.scheme}/{self.workload.name}/"
                f"{self.arrival.name}/z{self.ssd_zones}")


@dataclass
class ScenarioMatrix:
    """Declarative sweep of (scheme x workload x arrival x SSD budget).

    ``workloads`` entries may be YCSB letter keys ("A".."F") or full
    ``WorkloadSpec``s.  Each cell gets a freshly loaded store (same
    methodology as benchmarks/storage_exps.py: load, drain WAL, run while
    the compaction backlog is live), then an open-loop run.  Rows land in
    a JSON artifact consumed by ``benchmarks/report.py``.
    """

    schemes: Sequence[str]
    workloads: Sequence[Union[str, WorkloadSpec]]
    arrivals: Sequence[ArrivalProcess]
    ssd_zone_budgets: Sequence[int] = (20,)
    duration: float = 600.0            # virtual seconds of arrivals
    warmup: float = 60.0
    max_concurrency: int = 64
    key_div: int = 1                   # dataset divisor (quick sweeps)
    seed: int = 1
    db_factory: Optional[object] = None   # (scheme, ssd_zones) -> loaded db
    results: List[OpenLoopResult] = field(default_factory=list)

    def _workload_spec(self, w) -> WorkloadSpec:
        return YCSB[w] if isinstance(w, str) else w

    def cells(self) -> List[ScenarioCell]:
        return [ScenarioCell(s, self._workload_spec(w), a, z)
                for s in self.schemes
                for w in self.workloads
                for a in self.arrivals
                for z in self.ssd_zone_budgets]

    def _fresh_db(self, scheme: str, ssd_zones: int):
        if self.db_factory is not None:
            return self.db_factory(scheme, ssd_zones)
        from ..lsm import DB, ScenarioConfig
        sc = ScenarioConfig(ssd_zones=ssd_zones)
        db = DB(scheme, sc)
        n_keys = sc.paper_keys // self.key_div
        run_load(db, n_keys=n_keys)
        db.flush_all()
        db.n_keys = n_keys
        return db

    def run(self, out: Optional[Union[str, Path]] = None,
            verbose: bool = True) -> List[Dict]:
        rows: List[Dict] = []
        for cell in self.cells():
            db = self._fresh_db(cell.scheme, cell.ssd_zones)
            res = run_open_loop(
                db, cell.workload, cell.arrival, self.duration,
                n_keys=getattr(db, "n_keys", db.scenario.paper_keys
                               // self.key_div),
                warmup=self.warmup, max_concurrency=self.max_concurrency,
                seed=self.seed)
            self.results.append(res)
            row = res.to_json()
            row["ssd_zones"] = cell.ssd_zones
            row["cell"] = cell.name
            rows.append(row)
            if verbose:
                print(res.row(), flush=True)
        if out is not None:
            out = Path(out)
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(rows, indent=1))
        return rows
