from .watchdog import (HeartbeatRegistry, plan_elastic_mesh,
                       TrainSupervisor)

__all__ = ["HeartbeatRegistry", "plan_elastic_mesh", "TrainSupervisor"]
