"""Fault tolerance: heartbeats, straggler detection, elastic re-meshing.

At thousand-node scale the failure model is: slow chips (stragglers),
dead hosts, and whole-pod losses.  The control-plane pieces here are
host-framework-agnostic and unit-tested in simulation:

  HeartbeatRegistry   workers report (step, wall time); the coordinator
                      flags stale heartbeats (dead) and step-laggards
                      (stragglers — candidates for hot-sparing).
  plan_elastic_mesh   given surviving chip count, pick the largest
                      (data, model) mesh the survivors can form while
                      keeping the model axis intact (TP groups must stay
                      whole; DP shrinks), and report the batch adjustment.
  TrainSupervisor     restart loop: run -> on failure restore the latest
                      checkpoint onto the new mesh (checkpoints are
                      mesh-shape agnostic, see repro.checkpoint) -> resume
                      the data stream at the restored step (deterministic
                      (seed, step) indexing makes this exact).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


@dataclass
class Heartbeat:
    step: int
    t: float


class HeartbeatRegistry:
    def __init__(self, timeout_s: float = 60.0, straggle_steps: int = 5):
        self.timeout = timeout_s
        self.straggle_steps = straggle_steps
        self.beats: Dict[str, Heartbeat] = {}

    def report(self, worker: str, step: int,
               t: Optional[float] = None) -> None:
        self.beats[worker] = Heartbeat(step=step, t=t if t is not None
                                       else time.monotonic())

    def dead(self, now: Optional[float] = None) -> List[str]:
        now = now if now is not None else time.monotonic()
        return [w for w, hb in self.beats.items()
                if now - hb.t > self.timeout]

    def stragglers(self) -> List[str]:
        if not self.beats:
            return []
        lead = max(hb.step for hb in self.beats.values())
        return [w for w, hb in self.beats.items()
                if lead - hb.step >= self.straggle_steps]


def plan_elastic_mesh(surviving_chips: int, model_parallel: int,
                      pods: int = 1) -> Tuple[Tuple[int, ...], float]:
    """Largest (pods?, data, model) mesh from survivors.

    The model axis is kept intact (a TP group is useless partially), the
    data axis shrinks to the largest whole multiple.  Returns (mesh shape,
    batch scale factor relative to full strength)."""
    if surviving_chips < model_parallel:
        raise RuntimeError("fewer chips than one model-parallel group")
    per_pod = surviving_chips // pods
    data = per_pod // model_parallel
    if data < 1:
        raise RuntimeError("cannot form a single data-parallel group")
    shape = (pods, data, model_parallel) if pods > 1 \
        else (data, model_parallel)
    full = pods * data * model_parallel
    return shape, full / surviving_chips if surviving_chips else 0.0


@dataclass
class TrainSupervisor:
    """Restart loop around a step function; used by launch/train.py and
    exercised in tests with injected failures."""
    save_every: int = 50
    max_restarts: int = 3
    restarts: int = 0
    events: List[str] = field(default_factory=list)

    def run(self, *, total_steps: int, start_step: int,
            run_steps: Callable[[int, int], int],
            save: Callable[[int], None],
            restore: Callable[[], int]) -> int:
        """run_steps(from, to) executes and returns the last completed step
        (raising on simulated/actual failure)."""
        step = start_step
        while step < total_steps:
            target = min(step + self.save_every, total_steps)
            try:
                step = run_steps(step, target)
                save(step)
            except Exception as e:      # noqa: BLE001 - restart on anything
                self.restarts += 1
                self.events.append(f"failure at ~{step}: {e}")
                if self.restarts > self.max_restarts:
                    raise
                step = restore()
                self.events.append(f"restored at {step}")
        return step
