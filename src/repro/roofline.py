"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:

  compute    = per-device matmul FLOPs / peak FLOP/s      (197 TFLOP/s bf16)
  memory     = per-device HBM bytes    / HBM bandwidth    (819 GB/s)
  collective = per-device collective bytes / ICI link bw  (~50 GB/s/link)

XLA's ``cost_analysis()`` counts while-loop bodies ONCE (verified: flops
are identical for 4- and 16-iteration scans), so scanned-layer models would
be undercounted ~num_layers-fold.  We therefore parse the compiled HLO text
ourselves and propagate multipliers through the call graph:

  entry -> while bodies (x trip count from the loop-condition constant)
        -> fusion / call / to_apply computations (+1 per call site)

FLOPs come from `dot` instructions (2 x prod(result) x prod(contracting)),
counted in every computation with its multiplier.  HBM bytes are counted on
*control* computations only (entry, while bodies, conditional branches):
each top-level instruction contributes operands + result — fusion-internal
intermediates live in VMEM/registers and are correctly excluded.
Collective bytes use ring-algorithm traffic from result shapes and replica
group sizes.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# TPU v5e-flavoured constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (we budget one link)

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_ZERO_COST = ("parameter", "constant", "get-tuple-element", "tuple",
              "bitcast", "after-all", "custom-call")

_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s*"
    r"([a-z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")
_DOT_DIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _shape_info(shape_str: str) -> Tuple[float, List[int]]:
    """(total bytes, dims of the first array shape)."""
    total = 0.0
    first_dims: List[int] = []
    for i, (dtype, dims) in enumerate(_SHAPE_RE.findall(shape_str)):
        if dtype not in _DTYPE_BYTES:
            continue
        ds = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in ds:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        if not first_dims:
            first_dims = ds
    return total, first_dims


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_hbm: float = 0.0
    collective_bytes: float = 0.0
    coll_by_op: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, int] = field(default_factory=dict)


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return default


def _ring_bytes(op: str, result_bytes: float, s: int) -> float:
    if s <= 1:
        return 0.0
    frac = (s - 1) / s
    if op == "all-gather":
        return result_bytes * frac
    if op == "all-reduce":
        return 2.0 * result_bytes * frac
    if op == "reduce-scatter":
        return result_bytes * (s - 1)
    if op == "all-to-all":
        return result_bytes * frac
    if op == "collective-permute":
        return result_bytes
    return 0.0


def analyze_hlo(hlo_text: str, default_group: int,
                default_trip: int = 1) -> HloStats:
    # ---- 1. split into computations -----------------------------------
    # computation headers sit at column 0 and end with "{"; instruction
    # lines are indented.  (Header param lists may contain nested tuple
    # parens, so we key on indentation rather than balanced parens.)
    comps: Dict[str, List[str]] = {}
    order: List[str] = []
    entry = None
    cur = "<none>"
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        is_header = (line and not line[0].isspace()
                     and stripped.endswith("{") and "->" in line)
        if is_header:
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)", line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                order.append(cur)
                if m.group(1):
                    entry = cur
                continue
        comps.setdefault(cur, []).append(line)
    if entry is None and order:
        entry = order[-1]

    # ---- 2. per-computation symbol tables + instruction records --------
    @dataclass
    class Instr:
        name: str
        op: str
        result_bytes: float
        result_dims: List[int]
        line: str

    tables: Dict[str, Dict[str, Instr]] = {}
    for comp, lines in comps.items():
        tbl: Dict[str, Instr] = {}
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape_str, op = m.groups()
            nbytes, dims = _shape_info(shape_str)
            tbl[name] = Instr(name, op, nbytes, dims, line)
        tables[comp] = tbl

    # ---- 3. call-graph multipliers --------------------------------------
    mult: Dict[str, float] = {c: 0.0 for c in comps}
    kind: Dict[str, str] = {c: "internal" for c in comps}
    if entry:
        mult[entry] = 1.0
        kind[entry] = "control"

    def trip_of(cond: str) -> float:
        consts = [int(x) for line in comps.get(cond, [])
                  for x in _CONST_RE.findall(line)]
        return float(max(consts)) if consts else float(default_trip)

    for _ in range(6):        # propagate through nesting levels
        new = {c: 0.0 for c in comps}
        if entry:
            new[entry] = 1.0
        for comp, lines in comps.items():
            src = mult.get(comp, 0.0)
            if src <= 0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.groups()
                    t = trip_of(cond)
                    new[body] = new.get(body, 0.0) + src * t
                    new[cond] = new.get(cond, 0.0) + src * (t + 1)
                    kind[body] = "control"
                    continue
                bm = _BRANCH_RE.search(line)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        new[b] = new.get(b, 0.0) + src
                        kind[b] = "control"
                    continue
                cm = _CALLS_RE.search(line)
                if cm:
                    callee = cm.group(1)
                    new[callee] = new.get(callee, 0.0) + src
        if all(abs(new[c] - mult[c]) < 1e-9 for c in comps):
            mult = new
            break
        mult = new

    # ---- 4. walk instructions -------------------------------------------
    stats = HloStats()
    for comp, lines in comps.items():
        k = mult.get(comp, 0.0)
        if k <= 0:
            continue
        tbl = tables[comp]
        is_control = kind.get(comp) == "control"
        for line in lines:
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, shape_str, op = m.groups()
            instr = tbl[name]
            # ---- flops: dot instructions everywhere ----
            if op == "dot":
                dm = _DOT_DIMS_RE.search(line)
                paren = line.split("(", 1)[1]
                ops_names = _OPERANDS_RE.findall(paren.split(")", 1)[0])
                lhs = tbl.get(ops_names[0]) if ops_names else None
                contract = 1
                if dm and lhs:
                    for idx in dm.group(1).split(","):
                        if idx:
                            contract *= lhs.result_dims[int(idx)]
                n_out = 1
                for d in instr.result_dims:
                    n_out *= d
                stats.flops += k * 2.0 * n_out * contract
            # ---- collectives ----
            for cop in _COLL_OPS:
                if op.startswith(cop):
                    s = _group_size(line, default_group)
                    b = _ring_bytes(cop, instr.result_bytes, s) * k
                    stats.collective_bytes += b
                    stats.coll_by_op[cop] = stats.coll_by_op.get(cop, 0) + b
                    stats.coll_counts[cop] = \
                        stats.coll_counts.get(cop, 0) + int(max(k, 1))
                    break
            # ---- HBM bytes: control computations, top-level ops ----
            if is_control and op not in _ZERO_COST:
                paren = line.split("(", 1)[1]
                ops_names = _OPERANDS_RE.findall(paren.split(")", 1)[0])
                read = sum(tbl[o].result_bytes for o in ops_names
                           if o in tbl)
                stats.bytes_hbm += k * (read + instr.result_bytes)
    return stats


# backwards-compatible helper used by dryrun
@dataclass
class CollectiveStats:
    total_bytes: float = 0.0
    by_op: Dict[str, float] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)


def parse_collectives(hlo_text: str, default_group: int,
                      default_trip: int = 1) -> CollectiveStats:
    st = analyze_hlo(hlo_text, default_group, default_trip)
    return CollectiveStats(total_bytes=st.collective_bytes,
                           by_op=st.coll_by_op, counts=st.coll_counts)


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    model_flops_total: float
    memory_per_device: Optional[float] = None   # persistent bytes

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs (remat & redundancy waste)."""
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops_total / hlo_total if hlo_total else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs / (chips x peak x bound-time)."""
        denom = self.chips * PEAK_FLOPS * self.bound_s
        return self.model_flops_total / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes,
            "model_flops_total": self.model_flops_total,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu": self.mfu,
            "memory_per_device": self.memory_per_device,
        }


def model_flops(cfg, shape) -> float:
    """6·N·D (train) / 2·N·D (prefill/decode), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch           # one token per sequence
