"""Checkpointing: atomic, async-capable, elastic (mesh-shape agnostic).

Layout per step:
  <dir>/step_<N>.tmp/   -> written, fsync'd, then atomically renamed to
  <dir>/step_<N>/
      manifest.json     tree structure + shapes + dtypes + step
      arrays.npz        flattened leaves (key = "/"-joined tree path)

Leaves are gathered to host before writing, so a checkpoint taken on a
(16,16) mesh restores onto a (2,16,16) or (4,) mesh unchanged — restore
simply ``jax.device_put``s each leaf with the *new* mesh's sharding
(elastic scaling / failure-shrink path).  ``save_async`` snapshots to host
synchronously (consistency) and writes in a background thread so the train
loop overlaps checkpoint I/O with compute.  ``keep_last`` prunes old steps.

At real multi-pod scale each host would write only its addressable shards
(per-shard files keyed by shard index); the single-process layout here is
the degenerate case of that design — see DESIGN.md §Fault-tolerance.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def save(state, step: int, ckpt_dir: str, keep_last: int = 3) -> Path:
    """Synchronous atomic checkpoint."""
    host_state = jax.tree.map(np.asarray, state)
    return _write(host_state, state, step, ckpt_dir, keep_last)


def save_async(state, step: int, ckpt_dir: str,
               keep_last: int = 3) -> threading.Thread:
    """Snapshot to host now; write in the background."""
    host_state = jax.tree.map(np.asarray, state)   # consistent snapshot
    t = threading.Thread(target=_write,
                         args=(host_state, state, step, ckpt_dir, keep_last),
                         daemon=True)
    t.start()
    return t


def _write(host_state, state, step, ckpt_dir, keep_last) -> Path:
    base = Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    tmp = base / f"step_{step}.tmp"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    flat = _flatten(host_state)
    arrays = {}
    dtypes = {}
    for k, v in flat:
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype.kind not in "fiub" or str(a.dtype) == "bfloat16":
            a = a.astype(np.float32)      # npz-safe widening (bf16 etc.)
        arrays[k] = a
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(v.shape), "dtype": dtypes[k]}
                   for k, v in arrays.items()},
    }
    np.savez(tmp / "arrays.npz", **{k.replace("/", "__"): v
                                    for k, v in arrays.items()})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)          # atomic publish
    _prune(base, keep_last)
    return final


def _prune(base: Path, keep_last: int) -> None:
    steps = sorted((int(p.name.split("_")[1]), p)
                   for p in base.glob("step_*") if p.is_dir()
                   and not p.name.endswith(".tmp"))
    for _, p in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in base.glob("step_*")
             if p.is_dir() and not p.name.endswith(".tmp")]
    return max(steps) if steps else None


def restore(like, ckpt_dir: str, step: Optional[int] = None,
            shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the *current* mesh — this is the elastic path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step}"
    data = np.load(d / "arrays.npz")
    flat_like = _flatten(like)
    leaves = []
    for key, leaf in flat_like:
        arr = data[key.replace("/", "__")]
        expect = tuple(leaf.shape)
        if tuple(arr.shape) != expect:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {expect}")
        leaves.append(jnp.asarray(arr).astype(leaf.dtype))
    treedef = jax.tree_util.tree_structure(like)
    state = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, step
