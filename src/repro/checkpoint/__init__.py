from .ckpt import save, save_async, restore, latest_step

__all__ = ["save", "save_async", "restore", "latest_step"]
