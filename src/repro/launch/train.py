"""End-to-end training driver (local mesh; production mesh via dry-run).

Wires: config -> synthetic/file data (deterministic resume) -> jitted
train_step on a local mesh -> periodic async checkpoints -> supervisor
restart loop.  Used by examples/train_lm.py and the e2e tests.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import sharding as SH
from ..checkpoint import ckpt
from ..config import ParallelConfig, TrainConfig
from ..configs import get_config
from ..data import Prefetcher, SyntheticLM
from ..ft import TrainSupervisor
from ..models import steps as S
from .mesh import make_local_mesh


def train_loop(cfg, *, steps: int, batch: int, seq: int,
               tc: Optional[TrainConfig] = None,
               parallel: Optional[ParallelConfig] = None,
               ckpt_dir: Optional[str] = None, save_every: int = 50,
               model_parallel: int = 1, log_every: int = 10,
               resume: bool = True, fail_at: Optional[int] = None,
               seed: int = 0, log=print) -> Dict:
    tc = tc or TrainConfig(total_steps=steps)
    parallel = parallel or ParallelConfig(seq_shard_activations=False)
    mesh = make_local_mesh(model_parallel)
    data = SyntheticLM(cfg.vocab_size, batch, seq, seed=seed)

    state_shapes = S.state_shapes(cfg)
    st_spec = SH.state_specs(mesh, cfg, state_shapes, fsdp=parallel.fsdp)
    st_shard = SH.named(mesh, st_spec)
    b_shard = SH.named(mesh, {"tokens": P(SH.data_axes(mesh), None),
                              "targets": P(SH.data_axes(mesh), None)})
    step_fn = jax.jit(S.make_train_step(cfg, tc, parallel),
                      in_shardings=(st_shard, b_shard),
                      out_shardings=(st_shard, NamedSharding(mesh, P())),
                      donate_argnums=(0,))

    start_step = 0
    if ckpt_dir and resume and ckpt.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt.restore(state_shapes, ckpt_dir,
                                         shardings=st_shard)
        log(f"[train] resumed from step {start_step}")
    else:
        with mesh:
            state = jax.jit(
                lambda k: S.init_state(k, cfg),
                out_shardings=st_shard)(jax.random.PRNGKey(tc.seed))

    losses: list = []
    holder = {"state": state, "fail_at": fail_at}

    def run_steps(frm: int, to: int) -> int:
        it = Prefetcher(data.iter_from(frm))
        try:
            for step in range(frm, to):
                if holder["fail_at"] is not None \
                        and step == holder["fail_at"]:
                    holder["fail_at"] = None     # inject exactly once
                    raise RuntimeError("injected failure")
                b = next(it)
                hb = {k: jnp.asarray(v) for k, v in b.items()}
                holder["state"], metrics = step_fn(holder["state"], hb)
                if (step + 1) % log_every == 0 or step + 1 == to:
                    loss = float(metrics["loss"])
                    losses.append((step + 1, loss))
                    log(f"[train] step {step+1:5d} loss {loss:.4f} "
                        f"lr {float(metrics['lr']):.2e} "
                        f"gnorm {float(metrics['grad_norm']):.2f}")
        finally:
            it.close()
        return to

    def save(step: int) -> None:
        if ckpt_dir:
            ckpt.save(holder["state"], step, ckpt_dir)

    def restore() -> int:
        st, step = ckpt.restore(state_shapes, ckpt_dir, shardings=st_shard)
        holder["state"] = st
        return step

    sup = TrainSupervisor(save_every=save_every)
    t0 = time.time()
    final = sup.run(total_steps=steps, start_step=start_step,
                    run_steps=run_steps, save=save,
                    restore=restore if ckpt_dir else (lambda: start_step))
    wall = time.time() - t0
    return {"final_step": final, "losses": losses, "wall_s": wall,
            "restarts": sup.restarts, "events": sup.events,
            "state": holder["state"]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    out = train_loop(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                     ckpt_dir=args.ckpt_dir,
                     model_parallel=args.model_parallel)
    first = out["losses"][0][1] if out["losses"] else float("nan")
    last = out["losses"][-1][1] if out["losses"] else float("nan")
    print(f"[train] done: {out['final_step']} steps in {out['wall_s']:.1f}s"
          f"  loss {first:.3f} -> {last:.3f}")


if __name__ == "__main__":
    main()
