"""ShapeDtypeStruct stand-ins for every model input (dry-run currency).

``input_specs(cfg, shape)`` returns the kwargs for lowering the step
function of that shape kind:
  train   -> {"state", "batch"}                          for train_step
  prefill -> {"params", "batch"}                         for prefill_step
  decode  -> {"params", "token", "cache_len", "caches"}  for serve_step

Modality frontends are stubs per the assignment: whisper gets precomputed
frame embeddings, internvl gets patch embeddings.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..config import ModelConfig, ShapeSpec
from ..models import model as M
from ..models import steps as S


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs_shapes(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["targets"] = sds((b, s), jnp.int32)
    if cfg.encoder_layers:
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model),
                              jnp.bfloat16)
    if cfg.vision_prefix:
        batch["vision_embeds"] = sds((b, cfg.vision_prefix, cfg.d_model),
                                     jnp.bfloat16)
    return batch


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch = batch_specs_shapes(cfg, shape)
        if shape.kind == "train":
            return {"state": S.state_shapes(cfg), "batch": batch}
        return {"params": M.param_shapes(cfg), "batch": batch}
    # decode: one new token against caches of length seq_len
    caches = jax.eval_shape(lambda: M.init_caches(cfg, b, s))
    return {
        "params": M.param_shapes(cfg),
        "token": sds((b, 1), jnp.int32),
        "cache_len": sds((b,), jnp.int32),
        "caches": caches,
    }
