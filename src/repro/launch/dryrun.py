import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), which is why the docstring sits below them.
_DOC = """Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the appropriate step function (train_step for
train shapes, prefill/serve steps for inference shapes) with ShapeDtypeStruct
inputs and NamedShardings on the production mesh, compiles it, and records
memory_analysis / cost_analysis / parsed collective bytes for §Dry-run and
§Roofline of EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch olmoe-1b-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .. import roofline as R
from .. import sharding as SH
from ..config import SHAPES, ParallelConfig, TrainConfig
from ..configs import get_config, list_configs
from ..models import steps as S
from . import specs as SP
from .mesh import make_production_mesh


def skip_reason(cfg, shape) -> str:
    """Cells that are skipped by assignment rules (documented in DESIGN.md)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("full-attention arch: 500k-token dense KV decode is "
                "intentionally unsupported (sub-quadratic archs only)")
    return ""


def lower_cell(arch: str, shape_name: str, mesh, mesh_name: str,
               fsdp: bool = True, extra_tag: str = "",
               parallel: ParallelConfig = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "chips": int(np.prod(list(mesh.shape.values()))),
           "tag": extra_tag}
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    if parallel is None:
        # production default: microbatch the giant models' train step so
        # per-microbatch activations fit 16 GB HBM alongside params+opt
        accum = 2 if (cfg.d_model >= 6144 and shape.kind == "train") else 1
        parallel = ParallelConfig(grad_accum=accum)
    constraint = SH.activation_constraint(
        mesh, seq_shard=parallel.seq_shard_activations)
    t0 = time.time()
    specs = SP.input_specs(cfg, shape)
    if shape.kind == "train":
        step = S.make_train_step(cfg, TrainConfig(), parallel,
                                 constraint=constraint)
        state_spec = SH.state_specs(mesh, cfg, specs["state"], fsdp=fsdp)
        batch_spec = SH.batch_specs(mesh, cfg, shape)
        in_shardings = (SH.named(mesh, state_spec),
                        SH.named(mesh, batch_spec))
        out_shardings = (SH.named(mesh, state_spec),
                         NamedSharding(mesh, P()))
        args = (specs["state"],
                {k: v for k, v in specs["batch"].items()})
    elif shape.kind == "prefill":
        step = S.make_prefill_step(cfg, parallel, constraint=constraint)
        # ZeRO-style inference sharding: weights 2D-sharded, gathered per
        # layer — required to fit >=34B params on 16 GB chips
        pspec = SH.param_specs(mesh, cfg, specs["params"], fsdp=fsdp)
        batch_spec = SH.batch_specs(mesh, cfg, shape)
        in_shardings = (SH.named(mesh, pspec), SH.named(mesh, batch_spec))
        out_shardings = NamedSharding(
            mesh, P(SH.data_axes(mesh),
                    SH.maybe(mesh, "model", cfg.vocab_size)))
        args = (specs["params"], specs["batch"])
    else:  # decode
        step = S.make_serve_step(cfg)
        pspec = SH.param_specs(mesh, cfg, specs["params"], fsdp=fsdp)
        cspec = SH.cache_specs(mesh, cfg, specs["caches"])
        dp = SH.data_axes(mesh)
        tok_s = NamedSharding(mesh, P(SH.maybe(mesh, dp,
                                               shape.global_batch), None))
        len_s = NamedSharding(mesh, P(SH.maybe(mesh, dp,
                                               shape.global_batch)))
        in_shardings = (SH.named(mesh, pspec), tok_s, len_s,
                        SH.named(mesh, cspec))
        out_shardings = (tok_s,
                         NamedSharding(
                             mesh, P(SH.maybe(mesh, dp, shape.global_batch),
                                     None,
                                     SH.maybe(mesh, "model",
                                              cfg.vocab_size))),
                         SH.named(mesh, cspec))
        args = (specs["params"], specs["token"], specs["cache_len"],
                specs["caches"])

    with mesh:
        jitted = jax.jit(step, in_shardings=in_shardings,
                         out_shardings=out_shardings)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    default_group = mesh.shape.get("model", 1)
    # own HLO walk: XLA's cost_analysis counts while-loop bodies once
    st = R.analyze_hlo(hlo, default_group, default_trip=cfg.num_layers)
    colls = R.CollectiveStats(total_bytes=st.collective_bytes,
                              by_op=st.coll_by_op, counts=st.coll_counts)
    rl = R.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=rec["chips"],
        flops_per_device=st.flops,
        bytes_per_device=st.bytes_hbm,
        collective_bytes=colls.total_bytes,
        model_flops_total=R.model_flops(cfg, shape),
        memory_per_device=float(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)),
    )
    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "xla_cost_analysis": {
            "flops_loop_body_once": float(ca.get("flops", 0.0)),
            "bytes_loop_body_once": float(ca.get("bytes accessed", 0.0))},
        "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
        "alias_bytes": int(getattr(ma, "alias_size_in_bytes", 0)),
        "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
        "collectives_by_op": {k: round(v) for k, v in colls.by_op.items()},
        "collective_counts": colls.counts,
        "roofline": rl.to_dict(),
    })
    return rec


def run_cells(archs, shapes, meshes, out_dir: Path, fsdp: bool = True,
              resume: bool = True) -> list:
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = out_dir / f"{tag}.json"
                if resume and path.exists():
                    results.append(json.loads(path.read_text()))
                    print(f"[cached] {tag}")
                    continue
                print(f"[lower+compile] {tag} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape_name, mesh, mesh_name,
                                     fsdp=fsdp)
                except Exception as e:        # record, keep going
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                path.write_text(json.dumps(rec, indent=1))
                results.append(rec)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    rl = rec["roofline"]
                    extra = (f" dominant={rl['dominant']} "
                             f"mfu={rl['mfu']:.3f} "
                             f"mem/dev={rec['argument_bytes']/2**30:.2f}GiB"
                             f"+tmp{rec['temp_bytes']/2**30:.2f}GiB "
                             f"compile={rec['compile_s']}s")
                elif status == "error":
                    extra = " " + rec["error"][:160]
                print(f"  -> {status}{extra}", flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = list_configs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    results = run_cells(archs, shapes, meshes, Path(args.out),
                        fsdp=not args.no_fsdp, resume=not args.no_resume)
    ok = sum(1 for r in results if r["status"] == "ok")
    sk = sum(1 for r in results if r["status"] == "skipped")
    err = [r for r in results if r["status"] == "error"]
    print(f"\n=== dry-run: {ok} ok, {sk} skipped, {len(err)} errors "
          f"of {len(results)} cells ===")
    for r in err:
        print(f"  ERROR {r['arch']} {r['shape']} {r['mesh']}: {r['error']}")
    return 1 if err else 0


if __name__ == "__main__":
    raise SystemExit(main())
