from .sstable import SST, merge_runs
from .block_cache import BlockCache
from .tree import LSMConfig, LSMTree, MemTable
from .db import DB, ScenarioConfig, SCHEMES, SCALE

__all__ = [
    "SST", "merge_runs", "BlockCache", "LSMConfig", "LSMTree", "MemTable",
    "DB", "ScenarioConfig", "SCHEMES", "SCALE",
]
