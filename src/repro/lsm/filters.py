"""Real packed Bloom filters with one unified hash family (splitmix64).

Every SST carries a packed uint32 bit array built from its key set
(``filter_bits_per_key`` bits per key, ``k = round(bits_per_key * ln 2)``
probe positions).  The hash family is shared across every implementation:

* keys are pre-hashed **host-side** with the same splitmix64 finaliser the
  injected-FP oracle already uses (``sstable._mix64``) — uint64 hashing
  never happens on the accelerator, where 64-bit lanes are unavailable;
* the 64-bit hash is split into two uint32 halves ``lo = h & 0xffffffff``
  and ``hi = (h >> 32) | 1`` (forced odd so the probe stride cycles);
* probe position ``i`` is Kirsch-Mitzenmacher double hashing,
  ``pos_i = (lo + i * hi) mod (num_words * 32)``, computed in wrapping
  uint32 arithmetic — bit-for-bit identical in the pure-numpy fallback
  here, the jnp oracle (``repro.kernels.bloom_probe.ref``), and the Pallas
  kernel (``repro.kernels.bloom_probe``).

The numpy fallback is the simulator default (no jax import required);
``impl="jax"`` routes probes through the kernel package, and the
cross-implementation agreement is asserted by ``tests/test_filters.py``.
"""
from __future__ import annotations

import math
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .sstable import SST, _mix64

_LN2 = math.log(2.0)
_M64 = (1 << 64) - 1
_M32 = (1 << 32) - 1


# ----------------------------------------------------------------------
# hashing
# ----------------------------------------------------------------------
def split_hash(keys) -> Tuple[np.ndarray, np.ndarray]:
    """splitmix64 the uint64 keys, split into (lo, hi) uint32 halves.

    ``hi`` is forced odd so the double-hashing stride is coprime with any
    power-of-two and never collapses the k probe positions onto one bit.
    """
    keys = np.atleast_1d(np.asarray(keys, dtype=np.uint64))
    h = _mix64(keys)
    lo = (h & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (h >> np.uint64(32)).astype(np.uint32) | np.uint32(1)
    return lo, hi


def _split_hash_int(key: int) -> Tuple[int, int]:
    """Python-int twin of :func:`split_hash` for the per-key read path."""
    x = key & _M64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _M64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _M64
    x = x ^ (x >> 31)
    return x & _M32, (x >> 32) | 1


def filter_params(num_keys: int, bits_per_key: int) -> Tuple[int, int]:
    """(num_words, k_hashes) for a key count at a bits-per-key budget."""
    nbits = max(1, int(num_keys)) * max(1, int(bits_per_key))
    num_words = max(1, -(-nbits // 32))
    k = max(1, min(16, int(round(bits_per_key * _LN2))))
    return num_words, k


# ----------------------------------------------------------------------
# pure-numpy build + probe (the simulator default; no jax required)
# ----------------------------------------------------------------------
def build_filter_np(lo: np.ndarray, hi: np.ndarray, num_words: int,
                    k_hashes: int) -> np.ndarray:
    """Set k bits per key on a packed uint32 array (same packing as the
    jnp oracle: word ``w`` bit ``b`` lives at flat index ``w*32 + b``)."""
    nbits = np.uint32(num_words * 32)
    flat = np.zeros(num_words * 32, dtype=bool)
    with np.errstate(over="ignore"):
        for i in range(k_hashes):
            pos = (lo + np.uint32(i) * hi) % nbits
            flat[pos.astype(np.int64)] = True
    lanes = flat.reshape(num_words, 32).astype(np.uint32)
    weights = np.uint32(1) << np.arange(32, dtype=np.uint32)
    return np.sum(lanes * weights, axis=-1, dtype=np.uint32)


def probe_np(lo: np.ndarray, hi: np.ndarray, bits: np.ndarray,
             k_hashes: int) -> np.ndarray:
    """Probe one filter with a batch of pre-hashed keys -> bool[N]."""
    nbits = np.uint32(bits.shape[0] * 32)
    hit = np.ones(lo.shape, dtype=bool)
    with np.errstate(over="ignore"):
        for i in range(k_hashes):
            pos = (lo + np.uint32(i) * hi) % nbits
            w = bits[(pos >> np.uint32(5)).astype(np.int64)]
            hit &= ((w >> (pos & np.uint32(31))) & np.uint32(1)).astype(bool)
    return hit


def probe_pairs_np(lo: np.ndarray, hi: np.ndarray, word_off: np.ndarray,
                   num_words: np.ndarray, bits_concat: np.ndarray,
                   k_hashes: int) -> np.ndarray:
    """Probe P (key x filter) pairs in one vectorized call.

    ``bits_concat`` is the concatenation of every candidate SST's filter
    words; pair ``p`` probes the ``num_words[p]`` words starting at
    ``word_off[p]``.  This is the ragged form the batched read path needs:
    each key may probe a different filter per level.
    """
    nbits = (num_words.astype(np.uint32) * np.uint32(32))
    off = word_off.astype(np.int64)
    hit = np.ones(lo.shape, dtype=bool)
    with np.errstate(over="ignore"):
        for i in range(k_hashes):
            pos = (lo + np.uint32(i) * hi) % nbits
            w = bits_concat[off + (pos >> np.uint32(5)).astype(np.int64)]
            hit &= ((w >> (pos & np.uint32(31))) & np.uint32(1)).astype(bool)
    return hit


def probe_one_np(key: int, bits: np.ndarray, k_hashes: int) -> bool:
    """Scalar probe in plain python ints — the per-key `get` fast path.

    Bitwise-identical to :func:`probe_np` on a length-1 batch (asserted by
    ``tests/test_filters.py``); avoids numpy array overhead per get.
    """
    lo, hi = _split_hash_int(key)
    nbits = bits.shape[0] * 32
    for i in range(k_hashes):
        pos = ((lo + i * hi) & _M32) % nbits
        if not (int(bits[pos >> 5]) >> (pos & 31)) & 1:
            return False
    return True


# ----------------------------------------------------------------------
# jax route (kernel package) — optional, bit-identical
# ----------------------------------------------------------------------
_HAVE_JAX: Optional[bool] = None


def have_jax() -> bool:
    global _HAVE_JAX
    if _HAVE_JAX is None:
        try:
            import jax  # noqa: F401
            _HAVE_JAX = True
        except Exception:
            _HAVE_JAX = False
    return _HAVE_JAX


def resolve_impl(impl: str) -> str:
    """"auto" -> the kernel/ref route when jax imports, else numpy."""
    if impl == "auto":
        return "jax" if have_jax() else "numpy"
    if impl not in ("numpy", "jax"):
        raise ValueError(f"unknown filter impl {impl!r}")
    return impl


def probe_pairs(lo, hi, word_off, num_words, bits_concat, k_hashes,
                impl: str = "numpy") -> np.ndarray:
    """Dispatch the ragged pairs probe to the selected implementation."""
    if resolve_impl(impl) == "jax":
        from ..kernels.bloom_probe.ref import bloom_probe_pairs_ref
        out = bloom_probe_pairs_ref(lo, hi, word_off.astype(np.int32),
                                    num_words.astype(np.uint32),
                                    bits_concat, k_hashes=k_hashes)
        return np.asarray(out).astype(bool)
    return probe_pairs_np(lo, hi, word_off, num_words, bits_concat, k_hashes)


# ----------------------------------------------------------------------
# SST attachment
# ----------------------------------------------------------------------
def attach_filter(sst: SST, bits_per_key: int) -> None:
    """Build and attach the packed filter for an SST's key set."""
    num_words, k = filter_params(sst.num_objs, bits_per_key)
    lo, hi = split_hash(sst.keys)
    sst.filter_words = build_filter_np(lo, hi, num_words, k)
    sst.filter_k = k


def concat_filters(ssts: Sequence[SST]) -> Tuple[np.ndarray, dict]:
    """Concatenate distinct SSTs' filter words for the pairs probe.

    Returns (bits_concat, {sid: (word_off, num_words)}).
    """
    offsets: dict = {}
    chunks: List[np.ndarray] = []
    off = 0
    for sst in ssts:
        if sst.sid in offsets or sst.filter_words is None:
            continue
        w = sst.filter_words
        offsets[sst.sid] = (off, len(w))
        chunks.append(w)
        off += len(w)
    bits = (np.concatenate(chunks) if chunks
            else np.zeros(0, dtype=np.uint32))
    return bits, offsets
