"""SSTable representation for the simulated LSM-tree.

Keys are uint64 ranks held in sorted numpy arrays (compact and fast to merge
with vectorised numpy); per-key tombstone bits support deletes.  Values are
optionally materialised (correctness tests / the quickstart example run with
``store_values=True``; large benchmark runs track sizes only).

Each SST carries a Bloom filter in one of two modes (``LSMConfig.filters``):

* ``"real"`` (default): a packed uint32 bit array built from the key set by
  ``repro.lsm.filters`` (splitmix64-derived double hashing, shared
  bit-for-bit with the ``repro.kernels.bloom_probe`` Pallas kernel and its
  jnp oracle), stored in ``filter_words``/``filter_k``.
* ``"injected"``: the original differential oracle — membership is exact
  via binary search (we *have* the key set) and false positives are
  injected deterministically from a hash of (key, sst uid) at the
  configured FP rate, reproducing the paper's ~1% Bloom FP read
  amplification without storing bit arrays.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

_MIX = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray | int) -> np.ndarray | int:
    """splitmix64 finaliser — deterministic hash for bloom FP injection."""
    x = np.uint64(x) if np.isscalar(x) else x.astype(np.uint64)
    with np.errstate(over="ignore"):
        x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
        x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
        x = x ^ (x >> np.uint64(31))
    return x


def merge_runs(runs_newest_first: List[np.ndarray],
               tombs_newest_first: List[np.ndarray]):
    """Merge sorted key runs, newest first; newest version of each key wins.

    Returns (keys, tombstones) sorted ascending, deduplicated.
    """
    if not runs_newest_first:
        return (np.empty(0, np.uint64), np.empty(0, np.bool_))
    keys = np.concatenate(runs_newest_first)
    tombs = np.concatenate(tombs_newest_first)
    # stable sort keeps newest-first order among equal keys
    order = np.argsort(keys, kind="stable")
    keys = keys[order]
    tombs = tombs[order]
    first = np.ones(len(keys), dtype=bool)
    first[1:] = keys[1:] != keys[:-1]
    return keys[first], tombs[first]


@dataclass
class SST:
    sid: int
    level: int
    keys: np.ndarray                      # sorted uint64
    tombs: np.ndarray                     # bool per key
    obj_size: int                         # bytes per KV object (key+value)
    block_size: int                       # data block bytes
    birth: float = 0.0
    tier: str = ""                        # "ssd" | "hdd" — set by the middleware
    zones: list = field(default_factory=list)
    num_reads: int = 0
    locked: bool = False                  # selected by a running compaction
    migrating: bool = False               # being moved between tiers
    values: Optional[Dict[int, bytes]] = None
    # real Bloom filter (filters="real"): packed uint32 bit array + probe
    # count, built by repro.lsm.filters.attach_filter; None under the
    # injected-FP oracle mode
    filter_words: Optional[np.ndarray] = None
    filter_k: int = 0

    # ------------------------------------------------------------------
    @property
    def num_objs(self) -> int:
        return len(self.keys)

    @property
    def objs_per_block(self) -> int:
        return max(1, self.block_size // self.obj_size)

    @property
    def num_blocks(self) -> int:
        return -(-self.num_objs // self.objs_per_block)

    @property
    def size_bytes(self) -> int:
        return self.num_objs * self.obj_size

    @property
    def min_key(self) -> int:
        return int(self.keys[0])

    @property
    def max_key(self) -> int:
        return int(self.keys[-1])

    def read_rate(self, now: float) -> float:
        """Reads/s since birth — the priority signal of §3.4."""
        age = max(now - self.birth, 1e-9)
        return self.num_reads / age

    # ------------------------------------------------------------------
    def find(self, key: int):
        """Exact membership. Returns (found, idx)."""
        idx = int(np.searchsorted(self.keys, np.uint64(key)))
        found = idx < self.num_objs and int(self.keys[idx]) == key
        return found, idx

    def block_of(self, idx: int) -> int:
        return idx // self.objs_per_block

    def bloom_maybe_contains(self, key: int, fp_rate: float) -> bool:
        """Bloom probe: exact positives + deterministic false positives."""
        found, _ = self.find(key)
        if found:
            return True
        if fp_rate <= 0.0:
            return False
        h = int(_mix64(np.uint64(key) ^ _mix64(np.uint64(self.sid))))
        return (h % 1_000_000) < int(fp_rate * 1_000_000)

    def count_in_range(self, lo: int, hi: int) -> int:
        """Number of keys in [lo, hi)."""
        a = int(np.searchsorted(self.keys, np.uint64(lo), side="left"))
        b = int(np.searchsorted(self.keys, np.uint64(hi), side="left"))
        return b - a

    def overlaps(self, lo: int, hi: int) -> bool:
        """Key-range overlap with [lo, hi] inclusive."""
        return not (self.max_key < lo or self.min_key > hi)
