"""KV store facade: sim + devices + middleware + LSM-tree, per scheme.

Scheme names follow the paper:
  B1..B4    basic placement (§2.3), level threshold h
  B3+M      basic + workload-aware migration (Exp#2)
  AUTO      SpanDB automated placement (§4.1)
  P         HHZS write-guided placement only
  P+M       + workload-aware migration
  P+M+C     + application-hinted caching  (== HHZS, the full system)
  HHZS      alias of P+M+C

Scaling: the paper's setup is reproduced at 1/SCALE.  Every *size* (object
dataset, SSTs, zones, MemTables, level targets, caches) and every
*bandwidth* (sequential device rates, migration rate limit, delayed-write
rate) is divided by SCALE, while random-read IOPS and per-request overheads
are kept — this preserves all the paper's time ratios exactly (an SST
migration still takes ~4.2 virtual minutes at the default rate; loading
still takes ~8 virtual hours), with 1/SCALE the number of simulated
operations.  Reported OPS are therefore paper-OPS / SCALE.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional

from ..core.middleware import (AdmissionConfig, AdmissionController,
                               HybridZonedBackend)
from ..core.placement import (AutoPlacement, BasicScheme, HHZSPlacement,
                              PlacementPolicy)
from ..zoned.device import (MiB, ST14000_HDD, ZN540_SSD, DeviceTiming,
                            ZonedDevice)
from ..zoned.sim import Sim
from .tree import LSMConfig, LSMTree, MemTable

SCALE = 100  # paper sizes & bandwidths / SCALE


def _scaled_timing(t: DeviceTiming, s: int) -> DeviceTiming:
    """Scale every *rate* by 1/s (sizes are scaled elsewhere): the simulated
    system is then exactly the paper's system slowed down by s — every
    dimensionless ratio (cache lifetime / run length, migration time / SST
    churn, interference fractions) is preserved.  Virtual durations match
    the paper 1:1; simulated OPS = paper OPS / s; latencies = paper × s."""
    return DeviceTiming(seq_read_bw=t.seq_read_bw / s,
                        seq_write_bw=t.seq_write_bw / s,
                        rand_read_iops=t.rand_read_iops / s,
                        seq_overhead=t.seq_overhead)


@dataclass
class ScenarioConfig:
    ssd_zones: int = 20
    ssd_zone_cap: int = int(1077 * MiB) // SCALE
    hdd_zones: int = 12000
    hdd_zone_cap: int = int(256 * MiB) // SCALE
    wal_cache_zones: int = 2
    migration_rate: float = 4 * MiB / SCALE
    io_chunk: int = max(4096, int(1 * MiB) // SCALE)
    ssd_timing: DeviceTiming = _scaled_timing(ZN540_SSD, SCALE)
    hdd_timing: DeviceTiming = _scaled_timing(ST14000_HDD, SCALE)
    lsm: LSMConfig = field(default_factory=lambda: LSMConfig(
        sst_size=int(1011.2 * MiB) // SCALE,
        memtable_size=int(512 * MiB) // SCALE,
        level_targets=(int(1024 * MiB) // SCALE, int(1024 * MiB) // SCALE,
                       int(10 * 1024 * MiB) // SCALE,
                       int(100 * 1024 * MiB) // SCALE,
                       int(1000 * 1024 * MiB) // SCALE),
        block_cache_blocks=int(8 * MiB) // SCALE // 4096,
        soft_pending_bytes=int(64 * 1024 * MiB) // SCALE,
        delayed_write_rate=16 * MiB / SCALE,
    ))

    @property
    def paper_keys(self) -> int:
        """200 GiB of 1 KiB objects, scaled."""
        return int(200 * 1024 * MiB / SCALE / self.lsm.obj_size)


SCHEMES = ("B1", "B2", "B3", "B4", "B3+M", "AUTO", "P", "P+M", "P+M+C", "HHZS")


def _build_placement(scheme: str) -> PlacementPolicy:
    if scheme.startswith("B"):
        h = int(scheme[1])
        return BasicScheme(h)
    if scheme == "AUTO":
        return AutoPlacement()
    return HHZSPlacement()


class DB:
    """One KV store instance on one hybrid zoned storage scenario."""

    def __init__(self, scheme: str = "HHZS",
                 scenario: Optional[ScenarioConfig] = None,
                 store_values: bool = False,
                 admission: "AdmissionConfig | str" = "none",
                 telemetry: "bool | float" = False,
                 sim: Optional[Sim] = None):
        base = scheme.split("+")[0]
        if scheme not in SCHEMES:
            raise ValueError(f"unknown scheme {scheme!r}; one of {SCHEMES}")
        self.scheme = scheme
        sc = scenario or ScenarioConfig()
        if store_values:
            sc = replace(sc, lsm=replace(sc.lsm, store_values=True))
        self.scenario = sc
        # ``sim`` lets several stores share one DES clock — the sharded
        # cluster facade (repro.cluster) runs N shard DBs on one simulator
        self.sim = sim if sim is not None else Sim()
        self.ssd = ZonedDevice(self.sim, "ssd", sc.ssd_timing,
                               sc.ssd_zones, sc.ssd_zone_cap)
        self.hdd = ZonedDevice(self.sim, "hdd", sc.hdd_timing,
                               sc.hdd_zones, sc.hdd_zone_cap)
        placement = _build_placement(base)
        enable_m = scheme in ("B3+M", "P+M", "P+M+C", "HHZS")
        enable_c = scheme in ("P+M+C", "HHZS")
        self.backend = HybridZonedBackend(
            self.sim, self.ssd, self.hdd, placement,
            wal_cache_zones=sc.wal_cache_zones,
            block_size=sc.lsm.block_size,
            enable_migration=enable_m,
            enable_cache=enable_c,
            migration_rate=sc.migration_rate,
            io_chunk=sc.io_chunk,
            basic_migration_low_levels=(3 if scheme == "B3+M" else None),
        )
        self.tree = LSMTree(self.sim, sc.lsm, self.backend)
        # multi-tenant admission control (policy "none" admits everything);
        # consulted by submit(..., tenant=...) and the open-loop runners
        self.admission = AdmissionController(self.sim, self.backend,
                                             admission)
        # compaction debt is the third admission pressure signal (consulted
        # only when the policy sets a debt_threshold); the lambda reads
        # through self.tree so it survives crash/reopen tree swaps
        self.admission.debt_gauge = lambda: float(self.tree.compaction_debt())
        self._crashed = False
        self.recovery: Optional[dict] = None   # stats of the last reopen()
        # telemetry bus (repro.obs): off by default; telemetry=True attaches
        # a MetricsRegistry at the default sample period, a float sets the
        # period in virtual seconds
        self.metrics = None
        if telemetry:
            self.enable_telemetry(
                5.0 if telemetry is True else float(telemetry))
        self.backend.start()

    # ---- telemetry (repro.obs) ----------------------------------------
    def enable_telemetry(self, sample_period: float = 5.0,
                         capacity: int = 720):
        """Attach a ``MetricsRegistry`` sampling every layer's signals on
        the DES clock; idempotent.  Returns the registry.

        All built-in signals are pull gauges over state the layers already
        maintain, so enabling telemetry never changes the virtual-time
        history of a run (asserted by ``tests/test_obs.py`` and the CI
        grid-smoke telemetry leg)."""
        if self.metrics is not None:
            return self.metrics
        from ..obs import MetricsRegistry
        reg = MetricsRegistry(self.sim, sample_period, capacity)
        self.ssd.install_metrics(reg, "ssd")
        self.hdd.install_metrics(reg, "hdd")
        self.backend.install_metrics(reg)
        self.tree.install_metrics(reg)
        self.admission.install_metrics(reg)
        reg.start()
        self.metrics = reg
        return reg

    # ---- store interface (repro.workloads.* target this, not DB) ------
    # The open-loop runners, OpStream and the scenario matrix talk to any
    # object exposing: sim/now, kv (op generators: put/get/get_batch/
    # delete/scan), submit, run_for, drain, flush_all, extras(),
    # compaction_debt(), fresh_admission(), scheme/scenario.  DB and
    # repro.cluster.ShardedDB both satisfy it.
    @property
    def kv(self):
        """Op-generator surface (put/get/get_batch/delete/scan).  For a
        single store this is the LSM tree itself; the sharded facade
        returns its routing layer instead."""
        return self.tree

    def compaction_debt(self) -> float:
        """Bytes of compaction backlog (admission's third pressure signal).
        Reads through ``self.tree`` so it survives crash/reopen swaps."""
        return float(self.tree.compaction_debt())

    def extras(self) -> dict:
        """Device/cache/migration counters attached to every result row."""
        tree = self.tree
        extras = {
            "ssd_read_bytes": self.ssd.counters.read_bytes,
            "hdd_read_bytes": self.hdd.counters.read_bytes,
            "ssd_write_bytes": self.ssd.counters.write_bytes,
            "hdd_write_bytes": self.hdd.counters.write_bytes,
            "block_cache_hit_rate": tree.block_cache.hit_rate(),
            # Bloom accounting: probes of candidate SSTs and survivors that
            # turned out absent; fp-per-probe = bloom_fp / filter_probes
            "filter_probes": tree.stats["filter_probes"],
            "bloom_fp": tree.stats["bloom_fp"],
        }
        if self.backend.cache is not None:
            extras["ssd_cache_hits"] = self.backend.cache.hits
            extras["ssd_cache_admitted"] = self.backend.cache.admitted
        if self.backend.migrator is not None:
            extras["migrated_bytes"] = self.backend.migrator.bytes_moved
        return extras

    def fresh_admission(self, policy=None) -> AdmissionController:
        """Install and return a fresh per-run admission controller.

        Counters, the per-run protected-set widening and the queue gauge
        must not leak between runs on the same store; ``policy`` (a name
        or ``AdmissionConfig``) overrides the constructor's config for
        this run only — the pristine ``base_cfg`` is preserved so a later
        ``policy=None`` run still sees the constructor's policy."""
        orig_base = self.admission.base_cfg
        self.admission = AdmissionController(
            self.sim, self.backend,
            policy if policy is not None else orig_base)
        self.admission.base_cfg = orig_base
        self.admission.debt_gauge = lambda: float(self.compaction_debt())
        if self.metrics is not None:
            self.admission.install_metrics(self.metrics)
        return self.admission

    # ---- synchronous helpers (tests / examples) -----------------------
    def _run(self, gen):
        return self.sim.run_until(self.sim.process(gen))

    def put(self, key: int, value: Optional[bytes] = None):
        return self._run(self.tree.put(key, value))

    def get(self, key: int):
        return self._run(self.tree.get(key))

    def get_batch(self, keys):
        """Service concurrently-arriving point reads in one batched call
        (vectorized Bloom probing; see ``LSMTree.get_batch``)."""
        return self._run(self.tree.get_batch(list(keys)))

    def delete(self, key: int):
        return self._run(self.tree.delete(key))

    def scan(self, start_key: int, count: int):
        return self._run(self.tree.scan(start_key, count))

    def flush_all(self):
        """Flush all MemTables + WAL (clean reopen between load and run)."""
        return self._run(self.tree.flush_all())

    def drain(self) -> None:
        """Run the simulator until all background work settles."""
        self.sim.run()

    # ---- crash / recovery ---------------------------------------------
    def crash(self) -> None:
        """Power loss at the current virtual instant.

        Everything volatile dies: the MemTables (active, immutable and
        flushing), every in-flight op and background job (the whole event
        heap), the device service queues and the WAL group-commit queue.
        Durable state survives: zones and their write pointers, installed
        SSTs (the manifest), and live WAL records with their logical
        payloads.  Call :meth:`reopen` to recover; until then the store
        must not be used.
        """
        sim = self.sim
        # pin everything we are about to kill: dropping the last reference
        # to a suspended generator raises GeneratorExit inside it, running
        # its `finally` blocks (semaphore releases, waiter wake-ups) and
        # thereby resurrecting other dead processes — but a power loss
        # must not execute ANY further code.  The graveyard keeps the dead
        # suspended forever instead.
        g = sim.graveyard
        g.append(list(sim._heap))
        g.append(self.backend._wal_waiters)
        g.append(self.backend._wal_queue)
        g.append(self.tree._stall_waiters)
        g.append(self.tree._flush_watchers)
        g.append(self.tree.jobs._queue)
        g.append(self.tree)
        # every pending event — in-flight ops, flush/compaction/migration
        # jobs, daemon pollers — dies with the process, including the
        # batched per-device completion queues (their heads are heap
        # entries and die with the heap clear below)
        for q in sim._mono:
            g.append(q.crash_clear())
        sim._heap.clear()
        sim._live = 0
        for dev in (self.ssd, self.hdd):
            dev.restart()
        self.backend.crash_volatile()
        self._crashed = True

    def reopen_gen(self):
        """Generator: recovery in virtual time (replay I/O is charged).

        Mirrors RocksDB recovery on zoned storage: rebuild the SST registry
        and level counts from the manifest, reset every zone not referenced
        by durable state (partial SST writes, compaction outputs, migration
        destinations, cache fills), then read the live WAL zones and replay
        their logical records into fresh MemTables, oldest generation
        first.  Returns (and stores in ``self.recovery``) replay stats.
        """
        if not self._crashed:
            raise RuntimeError("reopen() requires a preceding crash()")
        be, sim = self.backend, self.sim
        old = self.tree
        ssts = sorted(old.manifest.values(), key=lambda s: s.sid)
        be.reopen_rebuild(ssts)
        # fresh LSM tree over the recovered registry (rebinds the WAL
        # pressure callback and starts a new delayed-write controller)
        tree = LSMTree(sim, self.scenario.lsm, be)
        tree._next_sst = max([old._next_sst] + [s.sid for s in ssts])
        for sst in ssts:
            tree._install_sst(sst, sst.level)
        for lvl in range(1, len(tree.levels)):
            tree.levels[lvl].sort(key=lambda s: s.min_key)
        # WAL replay: read every live WAL zone (recovery I/O is real I/O),
        # then rebuild the MemTables from the per-generation payloads —
        # ascending generations reproduce the original insert order, so
        # newest-version-wins semantics are preserved exactly
        for rec in be._wal_records:
            if rec["zone"].write_ptr > 0:
                yield rec["dev"].read(rec["zone"].write_ptr, random=False,
                                      tag="recover")
        gens = sorted({g for rec in be._wal_records for g in rec["gens"]})
        replayed = 0
        for g in gens:
            mt = MemTable(gen=g)
            for key, tomb, value, tenant in be._wal_payloads.get(g, ()):
                mt.data[key] = (tomb, value)
                # re-attribute the record so per-tenant debt attribution
                # (MemTable.tenant_objs -> SST lineage) survives the crash
                mt.writes += 1
                if tenant is not None:
                    mt.tenant_objs[tenant] = \
                        mt.tenant_objs.get(tenant, 0) + 1
                replayed += 1
            tree.immutables.append(mt)
        # the new active generation must exceed every generation ever used,
        # or a later flush could reclaim the new generation's WAL records
        tree.memtable = MemTable(gen=old.memtable.gen + 1)
        self.tree = tree
        # the SLO control plane's rate overrides are volatile controller
        # state, but they live on the (surviving) AdmissionController —
        # without this reset a restarted-from-scratch ControlPlane would
        # inherit the pre-crash throttle levels (regression-tested by
        # tests/test_control_v2.py)
        self.admission.rate_overrides.clear()
        # restart background machinery (placement monitor, migrator loop)
        be.start()
        tree._kick_background()
        if self.metrics is not None:
            # the sampler process died with the crash; gauges over the old
            # tree are rebound to the recovered one, then sampling resumes
            tree.install_metrics(self.metrics)
            self.metrics.restart()
        self._crashed = False
        self.recovery = {"at": sim.now,
                         "live_wal_zones": len(be._wal_records),
                         "replayed_gens": len(gens),
                         "replayed_records": replayed}
        return self.recovery

    def reopen(self) -> dict:
        """Synchronous crash recovery (see :meth:`reopen_gen`)."""
        return self._run(self.reopen_gen())

    # ---- open-loop facade (repro.workloads.runner) --------------------
    @property
    def now(self) -> float:
        """Current virtual time, seconds."""
        return self.sim.now

    def submit(self, gen, tenant: Optional[str] = None):
        """Schedule an op generator without blocking (open-loop dispatch).

        Returns the Process, itself an Event that fires on completion —
        callers track in-flight ops instead of waiting synchronously.

        With ``tenant`` the op goes through the admission-control layer
        (``self.admission``): under policies ``reject``/``token_bucket`` the
        op may be shed, in which case the generator is closed unexecuted
        and ``None`` is returned; under ``delay`` it is held until store
        pressure clears before running.
        """
        if tenant is not None:
            return self.admission.submit(gen, tenant)
        return self.sim.process(gen)

    def run_for(self, seconds: float) -> None:
        """Advance virtual time by ``seconds`` (time-limited open-loop runs)."""
        self.sim.run(until=self.sim.now + seconds)
