"""Simulated LSM-tree KV store (RocksDB-flavoured, §2.2) issuing hints.

Structure: an active MemTable + immutable MemTables (flush when >=
``min_flush_memtables``, stall writes beyond ``max_memtables``), a WAL on
zoned storage via the middleware, levels L0..Ln with exponentially growing
target sizes, leveled compaction (one Li SST merged with the overlapping
Li+1 SSTs; L0 compacts all files because of overlapping ranges), Bloom
filters, and an in-memory LRU block cache whose evictions emit cache hints.

All read/write paths are simulator generators so that device time (and
interference with background jobs) is accounted per operation.
"""
from __future__ import annotations

from bisect import bisect_right
from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Set, Tuple

import numpy as np

from ..core.hints import (CompactionDoneHint, CompactionOutputHint,
                          CompactionTriggerHint, FlushHint)
from ..core.middleware import HybridZonedBackend
from ..zoned.sim import Semaphore, Sim
from . import filters
from .block_cache import BlockCache
from .sstable import SST, merge_runs


@dataclass
class LSMConfig:
    obj_size: int = 1024                 # 24 B key + 1000 B value
    block_size: int = 4096
    sst_size: int = int(1.0112 * (1 << 20))   # scaled 1011.2 MiB -> 1.0112 MiB
    memtable_size: int = int(0.512 * (1 << 20))
    min_flush_memtables: int = 2
    max_memtables: int = 4
    level_targets: Tuple[int, ...] = ()  # bytes per level; set by scenario
    num_levels: int = 5
    bloom_fp_rate: float = 0.01          # injected-FP oracle mode only
    # Bloom filter mode: "real" builds packed bit arrays per SST
    # (repro.lsm.filters, splitmix64-unified with the bloom_probe kernel);
    # "injected" keeps the synthetic-FP differential oracle
    filters: str = "real"
    filter_bits_per_key: int = 10
    # probe implementation for the batched read path: "numpy" (default,
    # always available), "jax" (kernel package's jnp oracle), or "auto"
    # ("jax" when importable, else "numpy") — all bit-identical
    filter_impl: str = "numpy"
    block_cache_blocks: int = 8
    max_background_jobs: int = 12
    l0_stall_files: int = 36
    # RocksDB-style write throttling: slow writes when L0 piles up or the
    # pending compaction debt grows (scaled from the 64 GiB default)
    l0_slowdown_files: int = 20
    soft_pending_bytes: int = int(64 * (1 << 20))
    delayed_write_rate: float = 16 * (1 << 20)   # bytes/s, auto-adjusted
    store_values: bool = False

    @property
    def sst_max_objs(self) -> int:
        return max(1, self.sst_size // self.obj_size)

    @property
    def memtable_max_objs(self) -> int:
        return max(1, self.memtable_size // self.obj_size)

    def target_of(self, level: int) -> int:
        if level < len(self.level_targets):
            return self.level_targets[level]
        # default: 1 GiB-scaled L0/L1 then 10x per level
        base = self.level_targets[-1] if self.level_targets else self.sst_size
        return base * (10 ** (level - len(self.level_targets) + 1))


@dataclass
class MemTable:
    gen: int
    data: Dict[int, Tuple[bool, Optional[bytes]]] = field(default_factory=dict)
    # debt-attribution lineage: write volume into this memtable, total and
    # per originating tenant (puts without a tenant only bump ``writes``)
    writes: int = 0
    tenant_objs: Dict[str, int] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.data)


class LSMTree:
    def __init__(self, sim: Sim, cfg: LSMConfig, backend: HybridZonedBackend):
        self.sim = sim
        self.cfg = cfg
        self.backend = backend
        self.memtable = MemTable(gen=0)
        self.immutables: List[MemTable] = []
        self.levels: List[List[SST]] = [[] for _ in range(cfg.num_levels + 2)]
        # the MANIFEST: durably-installed SSTs (sid -> SST).  RocksDB logs
        # every install/delete to a synced MANIFEST file; this dict is its
        # in-sim equivalent — DB.reopen() rebuilds the store from it, and
        # anything registered but not installed here is lost in a crash.
        self.manifest: Dict[int, SST] = {}
        self._next_sst = 0
        self._next_cid = 0
        self.jobs = Semaphore(sim, cfg.max_background_jobs)
        self._stall_waiters: List = []
        self._flush_running = False
        self._force_flush = False
        self._wal_pressure = False
        self._flushing: List[MemTable] = []   # readable until SSTs install
        self._flush_watchers: List = []
        backend.wal_pressure_cb = self._on_wal_pressure
        self._rr_key: Dict[int, int] = {}    # round-robin compaction cursor
        self._level_bytes: List[int] = [0] * (cfg.num_levels + 2)
        # delayed-write controller (RocksDB WriteController flavour)
        self._delay_rate = float(cfg.delayed_write_rate)
        self._next_delayed_write = 0.0
        self._debt_prev = 0.0
        sim.process(self._delay_controller())
        # SILK-style compaction pacing knob (repro.obs.control): background
        # compaction I/O beyond L0 is stretched by 1/pace, deferring debt
        # work under foreground pressure.  1.0 = full speed (no extra
        # yields, so default behaviour is event-for-event unchanged).
        self.compaction_pace = 1.0
        self.block_cache = BlockCache(cfg.block_cache_blocks, self._on_evict)
        self.stats: Dict[str, float] = {
            "puts": 0, "gets": 0, "hits": 0, "scans": 0,
            "write_stalls": 0, "compactions": 0, "flushes": 0,
            "bloom_fp": 0, "filter_probes": 0, "delayed_writes": 0,
        }
        # per-level read index (sorted candidate arrays + concatenated
        # filter image), rebuilt lazily whenever the level's membership
        # epoch moves — see _level_index
        self._level_epoch: List[int] = [0] * (cfg.num_levels + 2)
        self._ridx: Dict[int, Tuple] = {}

    # ------------------------------------------------------------------
    def _on_evict(self, sst_id: int, block_idx: int) -> None:
        sst = self.backend.ssts.get(sst_id)
        self.backend.on_block_evicted(sst, block_idx)

    def _new_sst_id(self) -> int:
        self._next_sst += 1
        return self._next_sst

    def level_size(self, level: int) -> int:
        return self._level_bytes[level]

    def level_sizes(self) -> List[int]:
        return list(self._level_bytes)

    def _install_sst(self, sst: SST, level: int) -> None:
        self.levels[level].append(sst)
        self._level_bytes[level] += sst.size_bytes
        self.manifest[sst.sid] = sst
        self._level_epoch[level] += 1

    def _remove_sst(self, sst: SST) -> None:
        self.levels[sst.level].remove(sst)
        self._level_bytes[sst.level] -= sst.size_bytes
        self.manifest.pop(sst.sid, None)
        self._level_epoch[sst.level] += 1

    def compaction_debt(self) -> int:
        return sum(max(0, self._level_bytes[l] - self.cfg.target_of(l))
                   for l in range(self.cfg.num_levels))

    def debt_by_tenant(self) -> Dict[str, float]:
        """Per-tenant attribution of :meth:`compaction_debt`.

        Each over-target level's overflow is split by the level's tenant
        byte composition (carried on SSTs through the flush -> compaction
        lineage); bytes written without a tenant tag land in the ``""``
        bucket.  By construction ``sum(values()) == compaction_debt()`` up
        to float rounding — the conservation law the controller (and
        ``tests/test_control_v2.py``) relies on."""
        out: Dict[str, float] = {}
        for lvl in range(self.cfg.num_levels):
            total = self._level_bytes[lvl]
            over = total - self.cfg.target_of(lvl)
            if over <= 0 or total <= 0:
                continue
            attr: Dict[str, float] = {}
            for s in self.levels[lvl]:
                for t, b in getattr(s, "tenant_bytes", {}).items():
                    attr[t] = attr.get(t, 0.0) + b
            tagged = 0.0
            for t, b in attr.items():
                share = over * (b / total)
                out[t] = out.get(t, 0.0) + share
                tagged += share
            rest = over - tagged
            if rest > 0:
                out[""] = out.get("", 0.0) + rest
        return out

    def _delay_controller(self):
        """Adapt the delayed write rate to whether compactions keep up."""
        while True:
            yield self.sim.timeout(1.0, daemon=True)
            debt = self.compaction_debt()
            throttling = (debt > self.cfg.soft_pending_bytes
                          or len(self.levels[0]) >= self.cfg.l0_slowdown_files)
            if throttling and debt >= self._debt_prev:
                self._delay_rate = max(self._delay_rate * 0.7,
                                       self.cfg.delayed_write_rate / 16.0)
            elif debt < self._debt_prev:
                self._delay_rate = min(self._delay_rate * 1.4,
                                       float(self.cfg.delayed_write_rate))
            self._debt_prev = debt

    def total_objs(self) -> int:
        n = sum(len(m) for m in [self.memtable] + self.immutables)
        n += sum(s.num_objs for lvl in self.levels for s in lvl)
        return n

    def write_amplification(self) -> float:
        """Device write bytes per user byte (WAL + flush + compaction +
        migration traffic over ``puts * obj_size``) — the governing
        backpressure quantity of the LSM design space."""
        user = self.stats["puts"] * self.cfg.obj_size
        if user <= 0:
            return 0.0
        dev = (self.backend.ssd.counters.write_bytes
               + self.backend.hdd.counters.write_bytes)
        return dev / user

    # ------------------------------------------------------------------
    # telemetry (repro.obs) — pull gauges over state the tree already
    # maintains; the put/get/flush/compaction hot paths are untouched
    # ------------------------------------------------------------------
    def install_metrics(self, reg, prefix: str = "") -> None:
        """Register the tree's signals on a ``MetricsRegistry``.  These are
        the §3.1 hint quantities as continuous series: compaction debt and
        L0 depth (compaction hints), flush backlog (flush hints), write
        amplification and the delayed-write controller's rate.  Re-invoked
        by ``DB.reopen()`` so the gauges rebind to the recovered tree.
        ``prefix`` namespaces the series (the sharded cluster facade
        installs each shard's tree as ``s{i}.lsm.*``); gauge and collector
        names are replace-on-reinstall, so a shard reopen rebinds its own
        series without touching its neighbours'."""
        p = prefix
        reg.gauge(f"{p}lsm.debt", lambda: float(self.compaction_debt()))
        reg.gauge(f"{p}lsm.l0_files", lambda: float(len(self.levels[0])))
        reg.gauge(f"{p}lsm.flush_backlog",
                  lambda: float(len(self.immutables) + len(self._flushing)))
        reg.gauge(f"{p}lsm.write_amp", self.write_amplification)
        reg.gauge(f"{p}lsm.delay_rate", lambda: self._delay_rate)
        reg.gauge(f"{p}lsm.write_stalls", lambda: self.stats["write_stalls"])
        reg.gauge(f"{p}lsm.block_cache_hit_rate", self.block_cache.hit_rate)
        reg.gauge(f"{p}lsm.compaction_pace",
                  lambda: float(self.compaction_pace))
        reg.collector(lambda: {
            f"{p}lsm.compaction_rate": self.stats["compactions"],
            f"{p}lsm.flush_rate": self.stats["flushes"],
        }, rate=True, name=f"{p}lsm.rates")
        reg.collector(lambda: {
            f"{p}lsm.debt.by_tenant.{t or 'untagged'}": v
            for t, v in self.debt_by_tenant().items()
        }, rate=False, name=f"{p}lsm.debt.by_tenant")

    # ==================================================================
    # write path
    # ==================================================================
    def put(self, key: int, value: Optional[bytes] = None,
            tombstone: bool = False,
            tenant: Optional[str] = None) -> Generator:
        self.stats["puts"] += 1
        # stall while memtables are full or L0 is overwhelmed
        while (len(self.immutables) >= self.cfg.max_memtables - 1
               and len(self.memtable) >= self.cfg.memtable_max_objs) \
                or len(self.levels[0]) >= self.cfg.l0_stall_files:
            ev = self.sim.event()
            self._stall_waiters.append(ev)
            self.stats["write_stalls"] += 1
            self._kick_background()
            yield ev
        # soft slowdown: pace writes while compactions are behind
        if (len(self.levels[0]) >= self.cfg.l0_slowdown_files
                or self.compaction_debt() > self.cfg.soft_pending_bytes):
            target = max(self.sim.now, self._next_delayed_write) \
                + self.cfg.obj_size / self._delay_rate
            self._next_delayed_write = target
            if target > self.sim.now:
                self.stats["delayed_writes"] += 1
                yield target - self.sim.now   # bare-delay: no Event
        wal_recs = yield from self.backend.wal_append(self.cfg.obj_size)
        stored = value if self.cfg.store_values else None
        mt = self.memtable
        mt.data[key] = (tombstone, stored)
        mt.writes += 1
        if tenant is not None:
            mt.tenant_objs[tenant] = mt.tenant_objs.get(tenant, 0) + 1
        # attribute the WAL bytes (and the logical record, for crash
        # replay) to the generation the data actually landed in (the
        # memtable may have rotated while queued)
        self.backend.wal_attribute(wal_recs, mt.gen, key=key,
                                   tomb=tombstone, value=stored,
                                   tenant=tenant)
        if len(self.memtable) >= self.cfg.memtable_max_objs:
            self._rotate_memtable()

    def delete(self, key: int) -> Generator:
        yield from self.put(key, tombstone=True)

    def _rotate_memtable(self) -> None:
        self.immutables.append(self.memtable)
        self.memtable = MemTable(gen=self.memtable.gen + 1)
        self._kick_background()

    # ==================================================================
    # flush
    # ==================================================================
    def _flush_threshold(self) -> int:
        if self._force_flush or self._wal_pressure:
            return 1
        return self.cfg.min_flush_memtables

    def _on_wal_pressure(self) -> None:
        """WAL zones exhausted: force a memtable switch + flush (RocksDB's
        max_total_wal_size behaviour) so live WAL data dies and zones reset."""
        if len(self.memtable.data):
            self._rotate_memtable()
        self._wal_pressure = True
        self._kick_background()

    def _kick_background(self) -> None:
        if (not self._flush_running
                and len(self.immutables) >= self._flush_threshold()):
            self._flush_running = True
            self.sim.process(self._flush_job())
        self._maybe_compact()

    def flush_all(self) -> Generator:
        """Flush everything (clean-reopen semantics between load and run)."""
        if len(self.memtable.data):
            self._rotate_memtable()
        self._force_flush = True
        self._kick_background()
        while self.immutables or self._flush_running:
            ev = self.sim.event()
            self._flush_watchers.append(ev)
            yield ev
        self._force_flush = False

    def _flush_job(self) -> Generator:
        yield self.jobs.acquire()
        try:
            while len(self.immutables) >= self._flush_threshold():
                batch, self.immutables = self.immutables, []
                # the batch stays readable until its SSTs are installed
                # (RocksDB keeps the immutable memtable alive through the
                # flush; without this, gets in flight miss these keys)
                self._flushing = batch
                gens = {m.gen for m in batch}
                runs, tombs, values = [], [], {}
                for m in reversed(batch):   # newest first
                    ks = np.fromiter(m.data.keys(), dtype=np.uint64,
                                     count=len(m.data))
                    order = np.argsort(ks, kind="stable")
                    ks = ks[order]
                    tb = np.fromiter((m.data[int(k)][0] for k in ks),
                                     dtype=np.bool_, count=len(ks))
                    runs.append(ks)
                    tombs.append(tb)
                    if self.cfg.store_values:
                        for k, (t, v) in m.data.items():
                            values.setdefault(k, v)
                keys, tb = merge_runs(runs, tombs)
                # flush->SST lineage: the batch's per-tenant write-volume
                # shares become each output SST's tenant byte composition
                tally: Dict[str, int] = {}
                writes = 0
                for m in batch:
                    writes += m.writes
                    for t, c in m.tenant_objs.items():
                        tally[t] = tally.get(t, 0) + c
                comp = ({t: c / writes for t, c in tally.items()}
                        if writes > 0 else {})
                for ks, tbs in self._split_sst(keys, tb):
                    sst = self._make_sst(ks, tbs, level=0, values=values)
                    if comp:
                        sst.tenant_bytes = {
                            t: f * sst.size_bytes for t, f in comp.items()}
                    self.backend.on_hint(FlushHint(sst_id=sst.sid))
                    yield from self.backend.write_sst(sst, source="flush")
                    self._install_sst(sst, 0)
                self.backend.wal_flushed(gens)
                self._flushing = []
                self.stats["flushes"] += 1
                self._wake_stalled()
        finally:
            self.jobs.release()
            self._flush_running = False
            self._wal_pressure = False
            watchers, self._flush_watchers = self._flush_watchers, []
            for ev in watchers:
                ev.succeed()
        self._kick_background()

    def _split_sst(self, keys: np.ndarray, tombs: np.ndarray):
        n = self.cfg.sst_max_objs
        for i in range(0, len(keys), n):
            yield keys[i:i + n], tombs[i:i + n]

    def _make_sst(self, keys: np.ndarray, tombs: np.ndarray, level: int,
                  values: Optional[dict] = None) -> SST:
        vals = None
        if self.cfg.store_values and values is not None:
            vals = {int(k): values.get(int(k)) for k in keys}
        sst = SST(sid=self._new_sst_id(), level=level, keys=keys,
                  tombs=tombs, obj_size=self.cfg.obj_size,
                  block_size=self.cfg.block_size, birth=self.sim.now,
                  values=vals)
        if self.cfg.filters == "real":
            filters.attach_filter(sst, self.cfg.filter_bits_per_key)
        return sst

    def _wake_stalled(self) -> None:
        waiters, self._stall_waiters = self._stall_waiters, []
        for ev in waiters:
            ev.succeed()

    # ==================================================================
    # compaction
    # ==================================================================
    def _maybe_compact(self) -> None:
        cfg = self.cfg
        scores = []
        for lvl in range(cfg.num_levels):
            tgt = cfg.target_of(lvl)
            size = self.level_size(lvl)
            if tgt > 0 and size > tgt:
                scores.append((size / tgt, lvl))
        scores.sort(reverse=True)
        for _, lvl in scores:
            if self.jobs.in_use >= self.jobs.capacity:
                break
            inputs = self._pick_compaction(lvl)
            if inputs:
                self.sim.process(self._compaction_job(lvl, inputs))

    def _pick_compaction(self, level: int) -> Optional[List[SST]]:
        """Select input SSTs: Li victim(s) + overlapping Li+1, all unlocked."""
        src = [s for s in self.levels[level] if not s.locked]
        if not src:
            return None
        if level == 0:
            # L0 files overlap freely, so L0 compaction must take ALL of
            # them — if any is locked, a previous L0 compaction is still
            # running and a second one over the leftover files would
            # install L1 outputs overlapping the first one's (breaking the
            # disjointness invariant the read path depends on)
            if any(s.locked for s in self.levels[0]):
                return None
            picked = list(src)
            lo = min(s.min_key for s in picked)
            hi = max(s.max_key for s in picked)
        else:
            cursor = self._rr_key.get(level, -1)
            src_sorted = sorted(src, key=lambda s: s.min_key)
            pick = next((s for s in src_sorted if s.min_key > cursor),
                        src_sorted[0])
            picked = [pick]
            lo, hi = pick.min_key, pick.max_key
            self._rr_key[level] = pick.max_key
        overlap = [s for s in self.levels[level + 1] if s.overlaps(lo, hi)]
        if any(s.locked for s in overlap):
            return None
        inputs = picked + overlap
        for s in inputs:
            s.locked = True
        return inputs

    def _compaction_job(self, level: int, inputs: List[SST]) -> Generator:
        yield self.jobs.acquire()
        cid = self._next_cid = self._next_cid + 1
        cfg = self.cfg
        target = level + 1
        try:
            self.backend.on_hint(CompactionTriggerHint(
                cid=cid, selected_sst_ids=tuple(s.sid for s in inputs),
                target_level=target))
            # read inputs sequentially (interleaved with other jobs);
            # beyond L0 each chunk is paced by the controller's knob —
            # stretching I/O by 1/pace defers debt work under foreground
            # pressure (SILK).  L0 compaction is exempt: clearing L0 is
            # what unblocks stalled foreground writes.
            for s in inputs:
                dev = self.backend.device_of(s.tier)
                rem = s.size_bytes
                while rem > 0:
                    n = min(self.backend.io_chunk, rem)
                    t_io = self.sim.now
                    yield dev.read(n, random=False, tag="compact")
                    pace = self.compaction_pace
                    if level > 0 and pace < 1.0:
                        dt = self.sim.now - t_io
                        if dt > 0:
                            yield dt * (1.0 / max(pace, 0.05) - 1.0)
                    rem -= n
            # merge: newest version wins; inputs ordered newest-priority first
            src_lvl = [s for s in inputs if s.level == level]
            dst_lvl = [s for s in inputs if s.level == target]
            ordered = (sorted(src_lvl, key=lambda s: -s.birth) + dst_lvl
                       if level == 0 else src_lvl + dst_lvl)
            keys, tombs = merge_runs([s.keys for s in ordered],
                                     [s.tombs for s in ordered])
            values = None
            if cfg.store_values:
                values = {}
                for s in ordered:
                    if s.values:
                        for k, v in s.values.items():
                            values.setdefault(k, v)
            # drop tombstones when compacting into the last populated level
            bottom = all(not self.levels[l] for l in
                         range(target + 1, len(self.levels)))
            if bottom and len(keys):
                keep = ~tombs
                keys, tombs = keys[keep], tombs[keep]
            # compaction lineage: outputs inherit the inputs' pooled
            # tenant byte composition, scaled to each output's size
            in_attr: Dict[str, float] = {}
            in_bytes = 0
            for s in inputs:
                in_bytes += s.size_bytes
                for t, b in getattr(s, "tenant_bytes", {}).items():
                    in_attr[t] = in_attr.get(t, 0.0) + b
            comp = ({t: b / in_bytes for t, b in in_attr.items()}
                    if in_bytes > 0 else {})
            outputs: List[SST] = []
            for ks, tbs in self._split_sst(keys, tombs):
                if not len(ks):
                    continue
                sst = self._make_sst(ks, tbs, level=target, values=values)
                if comp:
                    sst.tenant_bytes = {
                        t: f * sst.size_bytes for t, f in comp.items()}
                self.backend.on_hint(CompactionOutputHint(
                    cid=cid, sst_id=sst.sid, level=target))
                t_io = self.sim.now
                yield from self.backend.write_sst(sst, source="compaction")
                pace = self.compaction_pace
                if level > 0 and pace < 1.0:
                    dt = self.sim.now - t_io
                    if dt > 0:
                        yield dt * (1.0 / max(pace, 0.05) - 1.0)
                outputs.append(sst)
            # install outputs, delete inputs
            for s in inputs:
                self._remove_sst(s)
                self.block_cache.drop_sst(s.sid)
                self.backend.delete_sst(s)
            for s in outputs:
                self._install_sst(s, target)
            self.levels[target].sort(key=lambda s: s.min_key)
            self.backend.on_hint(CompactionDoneHint(
                cid=cid, target_level=target, num_selected=len(inputs),
                num_generated=len(outputs),
                input_sst_ids=tuple(s.sid for s in inputs),
                output_sst_ids=tuple(s.sid for s in outputs)))
            self.stats["compactions"] += 1
        finally:
            for s in inputs:
                s.locked = False
            self.jobs.release()
            self._wake_stalled()
        self._kick_background()

    # ==================================================================
    # read path
    # ==================================================================
    def _memtable_lookup(self, key: int):
        """Newest-first memtable-tier lookup -> (found, value) or None."""
        for m in [self.memtable] + list(reversed(self.immutables)) \
                + list(reversed(self._flushing)):
            if key in m.data:
                tomb, val = m.data[key]
                if not tomb:
                    self.stats["hits"] += 1
                return (not tomb, val)
        return None

    def _level_index(self, lvl: int):
        """Read index for one level, rebuilt only when the level's
        membership epoch moves (SST install/remove): candidate SSTs in
        lookup order, their key ranges as plain ints / a sorted uint64
        array for bisection, and the level's concatenated filter image
        for the vectorized batch probe.

        L0 files overlap, so they are ordered newest-first by ``birth`` —
        the list's install order is NOT trustworthy (after ``DB.reopen()``
        the manifest rebuild installs by sid, and migrations can reorder
        too); trusting it returned stale versions.  Deeper levels are
        disjoint, so each key has at most one candidate, found by
        bisecting the sorted min-key array."""
        cached = self._ridx.get(lvl)
        if cached is not None and cached[0] == self._level_epoch[lvl]:
            return cached[1]
        if lvl == 0:
            ssts = sorted(self.levels[0], key=lambda s: -s.birth)
            mins: List[int] = []
            mins_np = None
        else:
            ssts = sorted(self.levels[lvl], key=lambda s: s.min_key)
            mins = [s.min_key for s in ssts]
            mins_np = np.array(mins, dtype=np.uint64)
        maxs = [s.max_key for s in ssts]
        bits, offsets = (filters.concat_filters(ssts)
                         if self.cfg.filters == "real" else (None, None))
        idx = (ssts, mins, mins_np, maxs, bits, offsets)
        self._ridx[lvl] = (self._level_epoch[lvl], idx)
        return idx

    def _level_candidates(self, lvl: int, key: int) -> List[SST]:
        """SSTs of level ``lvl`` whose range covers ``key``, in lookup
        order (see _level_index for the ordering contract)."""
        ssts, mins, _, maxs, _, _ = self._level_index(lvl)
        if lvl == 0:
            return [s for s in ssts if s.min_key <= key <= s.max_key]
        j = bisect_right(mins, key) - 1
        if j >= 0 and key <= maxs[j]:
            return [ssts[j]]
        return []

    def _filter_hit(self, sst: SST, key: int) -> bool:
        """One Bloom probe under the configured filter mode."""
        self.stats["filter_probes"] += 1
        if self.cfg.filters == "injected":
            return sst.bloom_maybe_contains(key, self.cfg.bloom_fp_rate)
        if sst.filter_words is None:       # filterless SST: must check
            return True
        return filters.probe_one_np(key, sst.filter_words, sst.filter_k)

    def _probe_sst(self, sst: SST, key: int) -> Generator:
        """Exact lookup in one surviving candidate: block I/O (cache hit
        or device read), logical-read accounting, tombstone check.
        Returns (found, value|None) or None when the key is absent (a
        Bloom false positive)."""
        found, idx = sst.find(key)
        blk = sst.block_of(idx if found else
                           min(idx, max(sst.num_objs - 1, 0)))
        # logical read: the §3.4 popularity signal counts cache hits too —
        # a fully cache-resident hot SST must not look cold to the migrator
        sst.num_reads += 1
        if not self.block_cache.get(sst.sid, blk):
            yield from self.backend.read_block(sst, blk)
            self.block_cache.insert(sst.sid, blk)
        if found:
            if bool(sst.tombs[idx]):
                return (False, None)
            self.stats["hits"] += 1
            val = sst.values.get(key) if sst.values else None
            return (True, val)
        self.stats["bloom_fp"] += 1
        return None

    def get(self, key: int) -> Generator:
        """Generator returning (found, value|None)."""
        self.stats["gets"] += 1
        mem = self._memtable_lookup(key)
        if mem is not None:
            return mem
        for lvl in range(len(self.levels)):
            for sst in self._level_candidates(lvl, key):
                if not self._filter_hit(sst, key):
                    continue
                res = yield from self._probe_sst(sst, key)
                if res is not None:
                    return res
        return (False, None)

    def get_batch(self, keys: List[int]) -> Generator:
        """Service a batch of point reads; returns [(found, value|None)].

        Result-identical to per-key :meth:`get` (asserted across every
        scheme by ``tests/test_differential.py``): the same newest-first
        lookup order, the same block I/O per surviving candidate.  The
        difference is *how* candidates are found and probed — per level,
        the (key x candidate-SST) pairs of all still-unresolved keys are
        filtered in one vectorized Bloom call (numpy fallback or the
        ``bloom_probe`` kernel family, per ``LSMConfig.filter_impl``), and
        only survivors reach the block cache / backend."""
        n = len(keys)
        self.stats["gets"] += n
        results: List[Optional[Tuple[bool, Optional[bytes]]]] = [None] * n
        pending: List[int] = []
        for i, key in enumerate(keys):
            mem = self._memtable_lookup(key)
            if mem is not None:
                results[i] = mem
            else:
                pending.append(i)
        real = self.cfg.filters == "real"
        for lvl in range(len(self.levels)):
            if not pending:
                break
            if not self.levels[lvl]:
                continue
            idx = self._level_index(lvl)
            ssts, _, mins_np, maxs, bits, offsets = idx
            # candidate pairs, grouped per key in lookup order; deeper
            # levels are disjoint, so one searchsorted over the whole
            # batch replaces per-key range scans
            pair_of: List[List[SST]] = []
            if lvl == 0:
                for i in pending:
                    k = keys[i]
                    pair_of.append([s for s in ssts
                                    if s.min_key <= k <= s.max_key])
            else:
                karr = np.fromiter((keys[i] for i in pending),
                                   np.uint64, len(pending))
                pos = np.searchsorted(mins_np, karr, side="right") - 1
                for t, i in enumerate(pending):
                    j = int(pos[t])
                    pair_of.append([ssts[j]] if j >= 0
                                   and keys[i] <= maxs[j] else [])
            flat = [(i, sst) for i, cands in zip(pending, pair_of)
                    for sst in cands]
            if not flat:
                continue
            if real:
                hits = self._probe_pairs_real(
                    np.array([keys[i] for i, _ in flat], dtype=np.uint64),
                    [sst for _, sst in flat], bits, offsets)
            else:
                hits = [sst.bloom_maybe_contains(keys[i],
                                                 self.cfg.bloom_fp_rate)
                        for i, sst in flat]
            # walk survivors per key in candidate order, stopping at the
            # first exact hit — byte-identical I/O to the per-key path
            self.stats["filter_probes"] += len(flat)
            cursor = 0
            still: List[int] = []
            for i, cands in zip(pending, pair_of):
                key = keys[i]
                for j, sst in enumerate(cands):
                    if results[i] is not None or not hits[cursor + j]:
                        continue
                    res = yield from self._probe_sst(sst, key)
                    if res is not None:
                        results[i] = res
                cursor += len(cands)
                if results[i] is None:
                    still.append(i)
            pending = still
        for i in pending:
            results[i] = (False, None)
        return results

    def _probe_pairs_real(self, pair_keys: np.ndarray,
                          pair_ssts: List[SST],
                          bits: Optional[np.ndarray] = None,
                          offsets: Optional[Dict] = None) -> np.ndarray:
        """Vectorized real-filter probe over (key, SST) pairs, against a
        precomputed filter image (``_level_index``) when available."""
        if bits is None:
            bits, offsets = filters.concat_filters(pair_ssts)
        # filterless SSTs (built under another mode) always pass
        hits = np.ones(len(pair_ssts), dtype=bool)
        mask = np.array([s.sid in offsets for s in pair_ssts], dtype=bool)
        if not mask.any():
            return hits
        lo, hi = filters.split_hash(pair_keys[mask])
        sel = [s for s in pair_ssts if s.sid in offsets]
        off = np.array([offsets[s.sid][0] for s in sel], dtype=np.int64)
        nw = np.array([offsets[s.sid][1] for s in sel], dtype=np.int64)
        k = max(s.filter_k for s in sel)
        hits[mask] = filters.probe_pairs(lo, hi, off, nw, bits, k,
                                         impl=self.cfg.filter_impl)
        return hits

    def scan(self, start_key: int, count: int) -> Generator:
        """Range scan over [start, start+count): reads the covering blocks
        per level and returns the number of *live* keys in the range.

        Versions are deduplicated newest-first (memtables, then L0 by
        birth, then deeper levels) and tombstoned keys are skipped, so the
        count is exact — identical across schemes and equal to a dict
        model's, independent of compaction timing.  I/O is still charged
        for every overlapping SST (shadowed versions must be read to be
        discarded, as in a real merging iterator)."""
        self.stats["scans"] += 1
        end_key = start_key + count
        newest: Dict[int, bool] = {}   # key -> newest version is a tombstone
        for m in [self.memtable] + list(reversed(self.immutables)) \
                + list(reversed(self._flushing)):
            for k, (tomb, _) in m.data.items():
                if start_key <= k < end_key:
                    newest.setdefault(k, tomb)
        for lvl in range(len(self.levels)):
            ssts = (sorted(self.levels[0], key=lambda s: -s.birth)
                    if lvl == 0 else self.levels[lvl])
            for sst in ssts:
                if not sst.overlaps(start_key, end_key - 1):
                    continue
                cnt = sst.count_in_range(start_key, end_key)
                if cnt <= 0:
                    continue
                nblocks = -(-cnt // sst.objs_per_block)
                a = int(np.searchsorted(sst.keys, np.uint64(start_key)))
                for b in range(nblocks):
                    blk = sst.block_of(min(a + b * sst.objs_per_block,
                                           sst.num_objs - 1))
                    sst.num_reads += 1   # logical read, cache hit or miss
                    if not self.block_cache.get(sst.sid, blk):
                        yield from self.backend.read_block(sst, blk)
                        self.block_cache.insert(sst.sid, blk)
                for i in range(a, a + cnt):
                    newest.setdefault(int(sst.keys[i]), bool(sst.tombs[i]))
        return sum(1 for tomb in newest.values() if not tomb)
