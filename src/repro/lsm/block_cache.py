"""In-memory LRU block cache (RocksDB-style), emitting cache hints on eviction.

Entries are keyed by (sst_id, block_idx).  On eviction the registered
callback receives the victim — this is the paper's *cache hint* (§3.1): the
HHZS middleware uses it to admit the evicted block into SSD cache zones.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Optional, Tuple

Key = Tuple[int, int]  # (sst_id, block_idx)


class BlockCache:
    def __init__(self, capacity_blocks: int,
                 on_evict: Optional[Callable[[int, int], None]] = None):
        self.capacity = int(capacity_blocks)
        self._od: "OrderedDict[Key, None]" = OrderedDict()
        self.on_evict = on_evict
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._od)

    def __contains__(self, key: Key) -> bool:
        return key in self._od

    def get(self, sst_id: int, block_idx: int) -> bool:
        key = (sst_id, block_idx)
        if key in self._od:
            self._od.move_to_end(key)
            self.hits += 1
            return True
        self.misses += 1
        return False

    def insert(self, sst_id: int, block_idx: int) -> None:
        if self.capacity <= 0:
            # a zero-capacity cache never held the block, so there is
            # nothing to evict: firing the hint here admitted every single
            # read into SSD cache zones in cache-less configs
            return
        key = (sst_id, block_idx)
        if key in self._od:
            self._od.move_to_end(key)
            return
        self._od[key] = None
        while len(self._od) > self.capacity:
            (vic_sst, vic_blk), _ = self._od.popitem(last=False)
            if self.on_evict is not None:
                self.on_evict(vic_sst, vic_blk)

    def drop_sst(self, sst_id: int) -> None:
        """Remove all blocks of a deleted SST (no hints for dead data)."""
        stale = [k for k in self._od if k[0] == sst_id]
        for k in stale:
            del self._od[k]

    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0
