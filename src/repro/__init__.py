"""HHZS reproduction: hinted LSM-tree data management on hybrid zoned
storage (Li/Wang/Lee 2022), as a multi-pod JAX training/serving framework.

Subpackages: core (the paper's contribution), zoned, lsm, workloads
(reproduction); models, sharding, kernels, serving, launch, checkpoint,
data, ft, optim (TPU framework); roofline (dry-run analysis).
"""
__version__ = "1.0.0"
