"""Observability + control subsystem for the HHZS reproduction.

``metrics``  — virtual-time :class:`MetricsRegistry`: counters, pull
gauges, dynamic collectors, windowed rates, and bounded ring-buffer time
series sampled on the DES clock by a daemon process (zero hot-path
overhead: every built-in signal is *pulled* at sample time).  Plus
:class:`Ewma`, the control plane's measurement filter.

``control``  — :class:`ControlPlane` v2: closes the loop from telemetry
to the store's knobs — compaction debt as a pressure signal, pluggable
control laws (AIMD, or :class:`PIController` with anti-windup) driving
per-tenant token-bucket rates toward p99 SLO targets, and — via
``AdmissionConfig.feedback_knobs`` — compaction pacing, migration
aggressiveness and the hinted-cache zone budget, with per-tenant
compaction-debt attribution biasing throttling toward the debt
generator.
"""
from .metrics import Counter, Ewma, MetricsRegistry, TIMELINE_KIND
from .control import KNOBS, ControlPlane, PIController

__all__ = ["Counter", "Ewma", "MetricsRegistry", "TIMELINE_KIND",
           "KNOBS", "ControlPlane", "PIController"]
