"""Observability + control subsystem for the HHZS reproduction.

``metrics``  — virtual-time :class:`MetricsRegistry`: counters, pull
gauges, dynamic collectors, windowed rates, and bounded ring-buffer time
series sampled on the DES clock by a daemon process (zero hot-path
overhead: every built-in signal is *pulled* at sample time).

``control``  — :class:`ControlPlane`: closes the loop from telemetry to
admission decisions — compaction debt as a third pressure signal and an
AIMD feedback controller driving per-tenant token-bucket rates toward
per-tenant p99 SLO targets.
"""
from .metrics import Counter, MetricsRegistry, TIMELINE_KIND
from .control import ControlPlane

__all__ = ["Counter", "MetricsRegistry", "TIMELINE_KIND", "ControlPlane"]
