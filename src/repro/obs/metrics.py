"""Virtual-time metrics registry: the telemetry bus of the reproduction.

The HHZS thesis is that the middleware should act on *signals* from the
LSM-tree and the devices (§3.1 flush / compaction / caching hints); this
module makes every such signal a first-class, queryable time series:

* **Counters** — push-style monotonic accumulators (``c.add(n)``) for the
  rare signal with no existing state to pull from.  One attribute add on
  the hot path; nothing else.
* **Gauges** — *pull* callbacks evaluated only at sample time.  Every
  built-in instrumentation point (device queue depth, zone occupancy,
  compaction debt, WAL pressure, admission counters) is a gauge or a
  collector over state the layers already maintain, so an instrumented
  run executes the exact same hot-path code as an uninstrumented one —
  which is what keeps the ``sim_speed`` gate and the sweep driver's
  byte-identical-rows contract intact with telemetry enabled.
* **Collectors** — gauges with dynamic key sets (per-tenant admission
  counters: tenants appear lazily).  A collector returns a ``{name:
  value}`` dict per sample; with ``rate=True`` the registry stores the
  per-second delta between consecutive samples of each key instead of
  the raw (monotonic) value — the windowed-rate primitive.
* **Series** — every sampled signal lands in a bounded ring buffer
  (capacity ``capacity`` samples, oldest overwritten) keyed to a shared
  ring of sample times, taken every ``sample_period`` *virtual* seconds
  by a daemon process (daemon: sampling never keeps ``Sim.run()`` alive
  and never perturbs the virtual times of real events).

``timeline()`` serializes the rings as the timeline artifact schema
(``results/storage/timelines/*.json``, linted by
``benchmarks/validate_results.py``)::

    {"kind": "timeline", "meta": {...}, "sample_period": 5.0,
     "t": [t0, t1, ...], "series": {"lsm.debt": [v0, v1, ...], ...}}

Series entries are numbers or ``null`` (signal not yet registered at that
sample, e.g. a tenant that had not arrived).
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

TIMELINE_KIND = "timeline"


class Counter:
    """Push-style monotonic counter; ``add()`` is the whole hot-path cost."""

    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0.0

    def add(self, n: float = 1.0) -> None:
        self.value += n


class Ewma:
    """Exponentially-weighted moving average over irregular updates.

    The control plane's measurement filter: per-tick p99/target ratios are
    noisy (a window of a few hundred samples), and feeding them raw into a
    PI law turns measurement noise into actuator jitter.  ``update(x)``
    folds in one observation with weight ``alpha`` (1.0 = no smoothing —
    the filter is transparent) and returns the new smoothed value;
    ``value`` holds the current estimate (``None`` before any update)."""

    __slots__ = ("alpha", "value")

    def __init__(self, alpha: float = 0.5) -> None:
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1]: {alpha}")
        self.alpha = float(alpha)
        self.value: Optional[float] = None

    def update(self, x: float) -> float:
        x = float(x)
        if self.value is None or self.alpha >= 1.0:
            self.value = x
        else:
            self.value += self.alpha * (x - self.value)
        return self.value

    def reset(self) -> None:
        self.value = None


class MetricsRegistry:
    """Bounded ring-buffer time series over DES-clock samples.

    Attach with ``DB.enable_telemetry()`` (which calls every layer's
    ``install_metrics``) or register signals directly; ``start()`` spawns
    the daemon sampler.  ``restart()`` revives sampling after a
    ``DB.crash()`` killed the sampler process along with everything else.
    """

    def __init__(self, sim, sample_period: float = 5.0,
                 capacity: int = 720):
        if sample_period <= 0:
            raise ValueError(f"sample_period must be > 0: {sample_period}")
        if capacity <= 0:
            raise ValueError(f"capacity must be > 0: {capacity}")
        self.sim = sim
        self.sample_period = float(sample_period)
        self.capacity = int(capacity)
        self.counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Callable[[], float]] = {}
        # name -> (fn, rate): named collectors can be rebound (e.g. a new
        # per-run AdmissionController re-installing its tenant counters)
        self._collectors: Dict[str, Tuple[Callable[[], Dict[str, float]],
                                          bool]] = {}
        self._anon = 0
        # shared ring: _t holds sample times; every series list is kept
        # exactly as long as _t (None-padded when registered late)
        self._t: List[float] = []
        self._series: Dict[str, List[Optional[float]]] = {}
        self._head = 0              # next overwrite slot once the ring is full
        # previous raw values of rate-collector keys: (value, sample time)
        self._prev: Dict[str, Tuple[float, float]] = {}
        self.samples = 0
        self._gen = 0               # sampler generation (restart() bumps it)
        self._running = False
        # point-in-time annotations (e.g. drift phase boundaries): pure
        # list appends off the sampling path, never a DES event
        self._marks: List[Tuple[float, str]] = []

    # -- registration ---------------------------------------------------
    def counter(self, name: str) -> Counter:
        c = self.counters.get(name)
        if c is None:
            c = self.counters[name] = Counter()
        return c

    def gauge(self, name: str, fn: Callable[[], float]) -> None:
        """Register (or rebind — e.g. after ``DB.reopen()`` swaps the tree)
        a pull gauge; evaluated only at sample time."""
        self._gauges[name] = fn

    def collector(self, fn: Callable[[], Dict[str, float]],
                  rate: bool = False, name: Optional[str] = None) -> None:
        """Register a dynamic-key gauge.  With ``rate=True`` each key's
        series holds the per-second delta between consecutive samples
        (windowed rate of a monotonic count), not the raw value.  A
        ``name`` makes the registration rebindable — a second call with
        the same name replaces the first (fresh per-run controllers)."""
        if name is None:
            self._anon += 1
            name = f"_anon{self._anon}"
        self._collectors[name] = (fn, rate)

    def aggregate_gauge(self, name: str, part_names: List[str],
                        reduce: str = "sum") -> None:
        """Register a gauge computed from other *registered gauges* at
        sample time — the cluster-rollup primitive (repro.cluster): e.g.
        ``cluster.lsm.debt = sum(s0.lsm.debt, s1.lsm.debt, ...)``.

        Parts are looked up by name on every sample, so a shard reopen
        that rebinds ``s{i}.lsm.*`` to a recovered tree is picked up
        automatically; parts not (yet) registered are skipped.  ``reduce``
        is ``"sum"``, ``"max"`` or ``"mean"``."""
        if reduce not in ("sum", "max", "mean"):
            raise ValueError(f"unknown reduce {reduce!r}; "
                             f"one of ('sum', 'max', 'mean')")
        parts = list(part_names)

        def _agg() -> float:
            vals = [float(self._gauges[p]())
                    for p in parts if p in self._gauges]
            if not vals:
                return 0.0
            if reduce == "sum":
                return float(sum(vals))
            if reduce == "max":
                return float(max(vals))
            return float(sum(vals) / len(vals))

        self.gauge(name, _agg)

    def attach_dict(self, d: Dict[str, float], prefix: str = "",
                    rate: bool = False,
                    name: Optional[str] = None) -> None:
        """Register a plain counter dict (e.g. a manager's ``stats``) as a
        collector: each key becomes a ``prefix + key`` series, sampled by
        reference so later mutations are visible.  With ``rate=True`` the
        series hold windowed per-second deltas (monotonic counters)."""
        self.collector(
            lambda: {prefix + k: float(v) for k, v in d.items()},
            rate=rate, name=name)

    # -- sampling -------------------------------------------------------
    def _store(self, values: Dict[str, float], now: float) -> None:
        n = len(self._t)
        if n < self.capacity:
            self._t.append(now)
            for name, vs in self._series.items():
                vs.append(values.pop(name, None))
            for name, v in values.items():     # newly-seen series
                self._series[name] = [None] * n + [v]
        else:
            i = self._head
            self._head = (i + 1) % self.capacity
            self._t[i] = now
            for name, vs in self._series.items():
                vs[i] = values.pop(name, None)
            for name, v in values.items():
                vs = self._series[name] = [None] * self.capacity
                vs[i] = v

    def sample_now(self) -> None:
        """Take one sample of every registered signal at ``sim.now``."""
        now = self.sim.now
        values: Dict[str, float] = {}
        for name, c in self.counters.items():
            values[name] = c.value
        for name, fn in self._gauges.items():
            values[name] = float(fn())
        for fn, rate in self._collectors.values():
            for name, v in fn().items():
                v = float(v)
                if rate:
                    prev = self._prev.get(name)
                    self._prev[name] = (v, now)
                    if prev is None or now <= prev[1]:
                        values[name] = 0.0
                    else:
                        values[name] = (v - prev[0]) / (now - prev[1])
                else:
                    values[name] = v
        self._store(values, now)
        self.samples += 1

    def start(self) -> None:
        if self._running:
            return
        self._running = True
        self._gen += 1
        self.sim.process(self._sampler(self._gen))

    def restart(self) -> None:
        """Revive sampling after ``DB.crash()`` killed the sampler process
        (bumping the generation retires any survivor from a spurious call)."""
        self._running = False
        self.start()

    def stop(self) -> None:
        self._running = False
        self._gen += 1          # any live sampler loop retires on next tick

    def _sampler(self, gen: int):
        while self._gen == gen:
            yield self.sim.timeout(self.sample_period, daemon=True)
            if self._gen != gen:
                return
            self.sample_now()

    # -- queries --------------------------------------------------------
    def _unrolled(self, vs: List) -> List:
        if len(self._t) < self.capacity:
            return list(vs)
        h = self._head
        return vs[h:] + vs[:h]

    def times(self) -> List[float]:
        return self._unrolled(self._t)

    def series(self, name: str) -> List[Optional[float]]:
        return self._unrolled(self._series.get(name, []))

    def latest(self, name: str) -> Optional[float]:
        vs = self._series.get(name)
        if not vs:
            return None
        i = (self._head - 1) % len(self._t) if len(self._t) >= self.capacity \
            else len(self._t) - 1
        return vs[i]

    def names(self) -> List[str]:
        return sorted(self._series)

    # -- marks ----------------------------------------------------------
    def mark(self, label: str, t: Optional[float] = None) -> None:
        """Record a point-in-time annotation (``t`` defaults to
        ``sim.now``) — e.g. a drift-trace phase boundary.  Marks are not
        a series: they land in the timeline artifact's ``marks`` list so
        plots can segment the run without resampling anything."""
        self._marks.append((float(self.sim.now if t is None else t),
                            str(label)))

    def marks(self) -> List[Tuple[float, str]]:
        return list(self._marks)

    # -- timeline artifact ----------------------------------------------
    @staticmethod
    def _clean(v: Optional[float]) -> Optional[float]:
        if v is None or not math.isfinite(v):
            return None
        return v

    def timeline(self, meta: Optional[Dict[str, Any]] = None) -> Dict:
        """JSON-ready timeline artifact (see the module docstring schema).
        When any :meth:`mark` was recorded the artifact additionally
        carries ``"marks": [{"t": ..., "label": ...}, ...]`` (ascending
        ``t``) — phase-boundary annotations for segmented plots."""
        out = {
            "kind": TIMELINE_KIND,
            "meta": dict(meta or {}),
            "sample_period": self.sample_period,
            "t": self.times(),
            "series": {name: [self._clean(v) for v in self.series(name)]
                       for name in self.names()},
        }
        if self._marks:
            out["marks"] = [{"t": t, "label": lbl}
                            for t, lbl in sorted(self._marks)]
        return out

    def dump_timeline(self, path: Union[str, Path],
                      meta: Optional[Dict[str, Any]] = None) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.timeline(meta), indent=1))
        return path


def timeline_path(out_dir: Union[str, Path], cell_name: str) -> Path:
    """Filesystem-safe artifact path for a cell's timeline (cell names
    contain ``/``)."""
    safe = cell_name.replace("/", "__").replace(" ", "")
    return Path(out_dir) / f"{safe}.json"
