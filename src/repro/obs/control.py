"""Compaction-debt control plane: telemetry-driven admission feedback.

Closes the ROADMAP "smarter admission" item on top of the metrics bus.
Two mechanisms, both keyed on signals the registry already samples:

* **Debt pressure** — ``AdmissionConfig.debt_threshold`` makes compaction
  debt (bytes of level overflow, the governing backpressure quantity of
  LSM write amplification) a *third* admission pressure signal next to
  WAL stalls and service backlog: the controller's ``debt_gauge`` is
  consulted by ``AdmissionController.under_pressure()``, so the PR-2
  ``reject``/``delay`` policies shed *before* the debt turns into write
  stalls.  That wiring lives in the middleware; no ControlPlane needed.

* **SLO feedback (this class)** — under policy ``"feedback"`` the
  admission controller runs per-tenant token buckets whose rates are
  *driven*, not configured: an AIMD loop compares each protected
  tenant's measured sojourn p99 (observed by the multi-tenant runner on
  every completion) against its ``TenantSpec.slo_p99`` target and
  adjusts the non-protected tenants' bucket rates — multiplicative
  decrease while any target is missed *or* compaction debt exceeds the
  threshold, additive increase while every target has headroom.  The
  loop is a daemon process on the DES clock: control actions happen in
  virtual time, reproducibly.

The plane also publishes its own signals into the registry (``ctl.*``:
measured p99 per SLO tenant, targets, instantaneous attainment, the
driven rates), so timeline artifacts show the feedback loop converging.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Callable, Dict, Optional

import numpy as np


class ControlPlane:
    """AIMD feedback from measured per-tenant p99 to token-bucket rates.

    ``ctrl`` is the run's ``AdmissionController`` (policy ``feedback``);
    ``targets`` maps tenant name -> sojourn p99 target in virtual seconds
    (from ``TenantSpec.slo_p99``).  Tenants in ``ctrl.cfg.protected`` are
    never throttled — the plane drives every *other* tenant's rate.
    Feedback constants live on ``AdmissionConfig`` (``feedback_*``) so a
    scenario cell stays a single picklable spec.
    """

    def __init__(self, sim, ctrl, targets: Dict[str, float],
                 debt_gauge: Optional[Callable[[], float]] = None,
                 registry=None):
        self.sim = sim
        self.ctrl = ctrl
        self.targets = {t: float(v) for t, v in targets.items() if v}
        self.debt_gauge = debt_gauge
        self._lat: Dict[str, deque] = {}
        self._p99: Dict[str, float] = {}
        # base rate per controlled tenant: anchors the additive step and
        # the floor.  Configured finite rates anchor directly; an infinite
        # (unconfigured) rate is anchored to the measured admit rate at
        # the first decrease.
        self._base: Dict[str, float] = {}
        self._admitted_prev: Dict[str, float] = {}
        self.adjustments = {"decrease": 0, "increase": 0, "hold": 0}
        self._alive = True
        if registry is not None:
            self._install_metrics(registry)

    @property
    def cfg(self):
        # read through to the controller: runners rebind ``ctrl.cfg``
        # (e.g. to widen the protected set for one run)
        return self.ctrl.cfg

    # -- runner-facing hooks --------------------------------------------
    def observe(self, tenant: str, latency: float) -> None:
        """Record one completed op's sojourn (arrival -> done)."""
        lat = self._lat.get(tenant)
        if lat is None:
            lat = self._lat[tenant] = deque(
                maxlen=int(self.cfg.feedback_window))
        lat.append(latency)

    def start(self) -> None:
        self.sim.process(self._loop())

    def stop(self) -> None:
        """Retire the daemon loop (runs are shorter-lived than the DB)."""
        self._alive = False

    def _loop(self):
        while self._alive:
            yield self.sim.timeout(self.cfg.feedback_interval, daemon=True)
            if not self._alive:
                return
            self._tick()

    # -- the controller --------------------------------------------------
    def measured_p99(self, tenant: str) -> Optional[float]:
        return self._p99.get(tenant)

    def attainment(self) -> float:
        """Fraction of SLO tenants currently meeting their target
        (unmeasured tenants count as meeting it)."""
        if not self.targets:
            return 1.0
        met = sum(1 for t, tgt in self.targets.items()
                  if self._p99.get(t, 0.0) <= tgt)
        return met / len(self.targets)

    def debt_over(self) -> bool:
        return (self.cfg.debt_threshold is not None
                and self.debt_gauge is not None
                and self.debt_gauge() > self.cfg.debt_threshold)

    def _configured(self, tenant: str) -> float:
        rates = self.cfg.bucket_rates or {}
        rate, _ = rates.get(tenant,
                            (self.cfg.bucket_rate, self.cfg.bucket_burst))
        return float(rate)

    def _measured_admit_rate(self, tenant: str) -> float:
        c = self.ctrl.counters.get(tenant)
        admitted = float(c["admitted"]) if c else 0.0
        prev = self._admitted_prev.get(tenant, 0.0)
        return max((admitted - prev) / self.cfg.feedback_interval, 1.0)

    def _tick(self) -> None:
        cfg = self.cfg
        worst = 0.0                 # worst p99/target ratio across SLO tenants
        for t, target in self.targets.items():
            lat = self._lat.get(t)
            if lat and len(lat) >= 8:
                p99 = float(np.percentile(np.asarray(lat), 99))
                self._p99[t] = p99
                worst = max(worst, p99 / target)
        # the rolling p99 lags by its window; the controller's *live*
        # pressure signals (service backlog, WAL stalls, compaction debt
        # over threshold) are instantaneous — react to either, so a burst
        # is cut within one control period instead of one window
        over = (worst > 1.0 or self.debt_over()
                or self.ctrl.under_pressure())
        protected = self.cfg.protected
        controlled = [t for t in self.ctrl.counters if t not in protected]
        for t in controlled:
            cur = self.ctrl.rate_overrides.get(t)
            if cur is None:
                cur = self._configured(t)
            if over:
                # over target (or pressure building): multiplicative
                # decrease
                if not math.isfinite(cur):
                    cur = self._measured_admit_rate(t)
                base = self._base.setdefault(t, cur)
                new = max(cur * cfg.feedback_decrease,
                          cfg.feedback_floor * base)
                self.adjustments["decrease"] += 1
            elif worst < cfg.feedback_headroom and math.isfinite(cur):
                # every target comfortably met (or not yet measurable):
                # additive increase probes capacity back
                base = self._base.setdefault(t, cur)
                new = cur + cfg.feedback_increase * base
                self.adjustments["increase"] += 1
            else:
                self.adjustments["hold"] += 1
                new = cur
            if math.isfinite(new):
                self.ctrl.rate_overrides[t] = new
        for t in self.ctrl.counters:
            c = self.ctrl.counters[t]
            self._admitted_prev[t] = float(c["admitted"])

    # -- telemetry -------------------------------------------------------
    def _install_metrics(self, reg) -> None:
        for t, target in self.targets.items():
            reg.gauge(f"ctl.p99.{t}",
                      lambda t=t: self._p99.get(t, 0.0))
            reg.gauge(f"ctl.target.{t}", lambda v=target: v)
        reg.gauge("ctl.attainment", self.attainment)
        reg.collector(lambda: {
            f"ctl.rate.{t}": v
            for t, v in self.ctrl.rate_overrides.items()
            if math.isfinite(v)}, name="ctl.rates")

    def summary(self) -> Dict[str, float]:
        """JSON-ready controller accounting for result rows / debugging."""
        out: Dict[str, float] = {
            "decreases": self.adjustments["decrease"],
            "increases": self.adjustments["increase"],
        }
        for t, v in self.ctrl.rate_overrides.items():
            if math.isfinite(v):
                out[f"rate.{t}"] = v
        for t, p in self._p99.items():
            out[f"p99.{t}"] = p
        return out
