"""Control plane v2: telemetry-driven feedback over the store's knobs.

Closes the ROADMAP "control plane v2" item on top of the metrics bus.
Three mechanisms, all keyed on signals the registry already samples:

* **Debt pressure** — ``AdmissionConfig.debt_threshold`` makes compaction
  debt (bytes of level overflow, the governing backpressure quantity of
  LSM write amplification) a *third* admission pressure signal next to
  WAL stalls and service backlog: the controller's ``debt_gauge`` is
  consulted by ``AdmissionController.under_pressure()``, so the PR-2
  ``reject``/``delay`` policies shed *before* the debt turns into write
  stalls.  That wiring lives in the middleware; no ControlPlane needed.

* **SLO feedback** — under policy ``"feedback"`` the admission
  controller runs per-tenant token buckets whose rates are *driven*, not
  configured.  Two pluggable control laws
  (``AdmissionConfig.feedback_controller``):

  - ``"aimd"`` (default, the PR-5 loop unchanged): multiplicative
    decrease while any protected tenant misses its ``TenantSpec.slo_p99``
    target *or* compaction debt exceeds the threshold, additive increase
    while every target has headroom.
  - ``"pi"``: a proportional-integral law (:class:`PIController`) on the
    worst protected p99/target ratio — EWMA-smoothed, blended with the
    continuous debt/threshold ratio — with conditional-integration
    anti-windup, emitting one smooth admission multiplier ``u`` in
    ``[feedback_floor, 1]`` instead of AIMD's sawtooth.  Per-tenant
    **debt attribution** (``LSMTree.debt_by_tenant``, the flush ->
    compaction lineage) biases the multiplier: the tenant generating the
    larger share of the compaction debt is throttled harder
    (``u ** (1 + share)``), so the controller targets the debt
    *generator* instead of penalizing all non-protected tenants
    uniformly.

* **Auxiliary knobs** — with a ``db`` binding, ``feedback_knobs`` extends
  actuation beyond admission (SILK-style: schedule internal LSM work,
  don't just shed load).  All knobs derive from the same actuation level
  ``u`` (AIMD tracks an equivalent aggregate), so one pressure signal
  steers the whole store:

  - ``"compaction"``: ``LSMTree.compaction_pace`` — background
    compaction I/O beyond L0 is stretched by ``1/pace``, deferring debt
    work while foreground pressure is high and draining it in lulls.
  - ``"migration"``: scales ``Migrator.rate_limit`` around its
    configured base — aggressive data movement in lulls, out of the way
    under pressure.
  - ``"cache"``: the backend's ``cache_zone_budget`` — shrinks the
    hinted cache's zone footprint under write pressure so reserved SSD
    zones serve the WAL, restores it when reads dominate.

The plane is a daemon process on the DES clock: control actions happen
in virtual time, reproducibly.  It also publishes its own signals into
the registry (``ctl.*``: measured p99 per SLO tenant, targets,
instantaneous attainment, the driven rates, and the knob trajectory
``ctl.u`` / ``ctl.knob.*``), so timeline artifacts show the feedback
loop converging.
"""
from __future__ import annotations

import math
from collections import deque
from typing import Callable, Dict, List, Optional

import numpy as np

from .metrics import Ewma

# actuators the control plane can drive (AdmissionConfig.feedback_knobs)
KNOBS = ("admission", "compaction", "migration", "cache")

# knob shaping constants: compaction pace floor (never stall debt work
# entirely — SILK drains in lulls, it doesn't stop), migration scale range
# around the configured base rate, and the actuation level above which the
# cache budget is released back to "unlimited"
PACE_FLOOR = 0.3
# fraction of the debt threshold at which the pace floor reaches 1.0:
# deferral is a low-debt luxury — above half the threshold the drain
# always runs at full speed (slowing it there just extends the degraded
# phase it is meant to relieve)
PACE_DEBT_GATE = 0.5
MIGRATION_SCALE = (0.25, 1.5)
CACHE_RELEASE_U = 0.9


class PIController:
    """Discrete proportional-integral law with anti-windup.

    ``update(measurement, dt)`` returns the actuation ``u`` clamped to
    ``[lo, hi]`` for error ``e = setpoint - measurement``::

        u = u0 + kp * e + ki * integral,   integral += e * dt

    Anti-windup is conditional integration: the integral is frozen
    whenever the *unsaturated* output is already past a clamp and the
    error would push it further — without this, a long overload winds the
    integral arbitrarily negative and the controller stays pinned at the
    floor long after the pressure clears (the classic windup lag;
    asserted by ``tests/test_control_v2.py``).
    """

    def __init__(self, kp: float, ki: float, setpoint: float = 1.0,
                 lo: float = 0.0, hi: float = 1.0, u0: float = 1.0):
        if lo >= hi:
            raise ValueError(f"need lo < hi, got [{lo}, {hi}]")
        self.kp = float(kp)
        self.ki = float(ki)
        self.setpoint = float(setpoint)
        self.lo = float(lo)
        self.hi = float(hi)
        self.u0 = float(u0)
        self.integral = 0.0
        self.last_u = min(max(self.u0, self.lo), self.hi)

    def update(self, measurement: float, dt: float) -> float:
        e = self.setpoint - float(measurement)
        u_unsat = self.u0 + self.kp * e + self.ki * self.integral
        sat_hi = u_unsat >= self.hi and e > 0.0
        sat_lo = u_unsat <= self.lo and e < 0.0
        if not (sat_hi or sat_lo):
            self.integral += e * float(dt)
        u = self.u0 + self.kp * e + self.ki * self.integral
        self.last_u = min(max(u, self.lo), self.hi)
        return self.last_u

    def reset(self) -> None:
        self.integral = 0.0
        self.last_u = min(max(self.u0, self.lo), self.hi)


class ControlPlane:
    """Feedback from measured per-tenant p99 to the store's knobs.

    ``ctrl`` is the run's ``AdmissionController`` (policy ``feedback``);
    ``targets`` maps tenant name -> sojourn p99 target in virtual seconds
    (from ``TenantSpec.slo_p99``).  Tenants in ``ctrl.cfg.protected`` are
    never throttled — the plane drives every *other* tenant's rate.
    Feedback constants live on ``AdmissionConfig`` (``feedback_*``) so a
    scenario cell stays a single picklable spec.

    ``db`` (optional) binds the plane to the store for the non-admission
    knobs and for per-tenant debt attribution; actuator targets (the
    tree, the migrator, the backend) are re-resolved through it on every
    tick, so a ``DB.reopen()`` that swaps the tree rebinds automatically.
    Without ``db`` the plane is exactly the v1 admission-only loop.
    """

    def __init__(self, sim, ctrl, targets: Dict[str, float],
                 debt_gauge: Optional[Callable[[], float]] = None,
                 registry=None, db=None):
        self.sim = sim
        self.ctrl = ctrl
        self.db = db
        self.targets = {t: float(v) for t, v in targets.items() if v}
        self.debt_gauge = debt_gauge
        self._lat: Dict[str, deque] = {}
        self._p99: Dict[str, float] = {}
        # base rate per controlled tenant: anchors the additive step and
        # the floor.  Configured finite rates anchor directly; an infinite
        # (unconfigured) rate is anchored to the measured admit rate at
        # the first decrease.
        self._base: Dict[str, float] = {}
        self._admitted_prev: Dict[str, float] = {}
        self.adjustments = {"decrease": 0, "increase": 0, "hold": 0}
        self._alive = True
        cfg = ctrl.cfg
        # aggregate actuation level in [0, 1]: the PI law's output, or an
        # AIMD-tracked equivalent; 1.0 = no throttling.  Drives the
        # auxiliary knobs for both control laws.
        self._u = 1.0
        self._filter = Ewma(alpha=cfg.feedback_smooth)
        self._pi = PIController(cfg.feedback_kp, cfg.feedback_ki,
                                setpoint=1.0,
                                lo=max(float(cfg.feedback_floor), 0.0),
                                hi=1.0)
        self._mig_base: Optional[float] = None
        # last applied knob values, for telemetry/rows (cache budget -1.0
        # means "unlimited")
        self.knobs: Dict[str, float] = {
            "pace": 1.0, "migration": 1.0, "cache_budget": -1.0}
        if registry is not None:
            self._install_metrics(registry)

    @property
    def cfg(self):
        # read through to the controller: runners rebind ``ctrl.cfg``
        # (e.g. to widen the protected set for one run)
        return self.ctrl.cfg

    # -- runner-facing hooks --------------------------------------------
    def observe(self, tenant: str, latency: float) -> None:
        """Record one completed op's sojourn (arrival -> done)."""
        lat = self._lat.get(tenant)
        if lat is None:
            lat = self._lat[tenant] = deque(
                maxlen=int(self.cfg.feedback_window))
        lat.append(latency)

    def start(self) -> None:
        # (re)start the daemon loop.  After a DB.crash() the loop died
        # with the store and the admission overrides were cleared by
        # DB.reopen_gen(); the actuation state below is volatile
        # controller memory — reset it so the restarted loop re-derives
        # its trajectory instead of resuming a stale one.
        self._pi.reset()
        self._filter.reset()
        self._u = 1.0
        self._alive = True
        self.sim.process(self._loop())

    def stop(self) -> None:
        """Retire the daemon loop (runs are shorter-lived than the DB)
        and return every auxiliary knob to its *configured* neutral —
        pace 1.0, the migrator's original base rate (not the lull boost),
        unlimited cache — so a later run on the same store starts from
        default actuator state."""
        self._alive = False
        self._restore_neutral()

    def _loop(self):
        while self._alive:
            yield self.sim.timeout(self.cfg.feedback_interval, daemon=True)
            if not self._alive:
                return
            self._tick()

    # -- the controller --------------------------------------------------
    def measured_p99(self, tenant: str) -> Optional[float]:
        return self._p99.get(tenant)

    def attainment(self) -> float:
        """Fraction of SLO tenants currently meeting their target
        (unmeasured tenants count as meeting it)."""
        if not self.targets:
            return 1.0
        met = sum(1 for t, tgt in self.targets.items()
                  if self._p99.get(t, 0.0) <= tgt)
        return met / len(self.targets)

    def debt_over(self) -> bool:
        return (self.cfg.debt_threshold is not None
                and self.debt_gauge is not None
                and self.debt_gauge() > self.cfg.debt_threshold)

    def _configured(self, tenant: str) -> float:
        rates = self.cfg.bucket_rates or {}
        rate, _ = rates.get(tenant,
                            (self.cfg.bucket_rate, self.cfg.bucket_burst))
        return float(rate)

    def _measured_admit_rate(self, tenant: str) -> float:
        c = self.ctrl.counters.get(tenant)
        admitted = float(c["admitted"]) if c else 0.0
        prev = self._admitted_prev.get(tenant, 0.0)
        return max((admitted - prev) / self.cfg.feedback_interval, 1.0)

    def _controlled(self) -> List[str]:
        protected = self.cfg.protected
        return [t for t in self.ctrl.counters if t not in protected]

    def _tick(self) -> None:
        cfg = self.cfg
        worst = 0.0                 # worst p99/target ratio across SLO tenants
        for t, target in self.targets.items():
            lat = self._lat.get(t)
            if lat and len(lat) >= 8:
                p99 = float(np.percentile(np.asarray(lat), 99))
                self._p99[t] = p99
                worst = max(worst, p99 / target)
        # the rolling p99 lags by its window; the controller's *live*
        # pressure signals (service backlog, WAL stalls, compaction debt
        # over threshold) are instantaneous — react to either, so a burst
        # is cut within one control period instead of one window
        over = (worst > 1.0 or self.debt_over()
                or self.ctrl.under_pressure())
        if cfg.feedback_controller == "pi":
            self._tick_pi(worst)
        else:
            self._tick_aimd(worst, over)
        for t in self.ctrl.counters:
            c = self.ctrl.counters[t]
            self._admitted_prev[t] = float(c["admitted"])
        self._apply_knobs(self._u)

    def _tick_aimd(self, worst: float, over: bool) -> None:
        """The PR-5 AIMD law, arithmetic unchanged (asserted by
        ``tests/test_obs.py``), plus tracking of the aggregate actuation
        level ``_u`` that drives the auxiliary knobs."""
        cfg = self.cfg
        for t in self._controlled():
            cur = self.ctrl.rate_overrides.get(t)
            if cur is None:
                cur = self._configured(t)
            if over:
                # over target (or pressure building): multiplicative
                # decrease
                if not math.isfinite(cur):
                    cur = self._measured_admit_rate(t)
                base = self._base.setdefault(t, cur)
                new = max(cur * cfg.feedback_decrease,
                          cfg.feedback_floor * base)
                self.adjustments["decrease"] += 1
            elif worst < cfg.feedback_headroom and math.isfinite(cur):
                # every target comfortably met (or not yet measurable):
                # additive increase probes capacity back
                base = self._base.setdefault(t, cur)
                new = cur + cfg.feedback_increase * base
                self.adjustments["increase"] += 1
            else:
                self.adjustments["hold"] += 1
                new = cur
            if math.isfinite(new):
                self.ctrl.rate_overrides[t] = new
        if over:
            self._u = max(self._u * cfg.feedback_decrease,
                          float(cfg.feedback_floor))
        elif worst < cfg.feedback_headroom:
            self._u = min(1.0, self._u + cfg.feedback_increase)

    def _tick_pi(self, worst: float) -> None:
        """PI law: one smooth actuation level from the blended pressure
        measurement, biased per tenant by its share of the compaction
        debt (the flush -> compaction attribution lineage)."""
        cfg = self.cfg
        m = worst
        # blend in the *continuous* debt ratio — the PI law can respond
        # proportionally to debt building, where AIMD only sees the
        # threshold crossing
        if cfg.debt_threshold and self.debt_gauge is not None:
            m = max(m, self.debt_gauge() / float(cfg.debt_threshold))
        if self.ctrl.under_pressure():
            m = max(m, 1.25)
        m = self._filter.update(m)
        u = self._pi.update(m, cfg.feedback_interval)
        # asymmetric slew: cuts are immediate, recovery is rate-limited
        # so one good p99 window cannot re-admit a full burst (the PI's
        # own anti-windup keeps its integral from running ahead of the
        # slewed output)
        if cfg.feedback_rise is not None:
            u = min(u, self._u + float(cfg.feedback_rise))
        self._u = u
        shares = self.debt_shares()
        for t in self._controlled():
            base = self._base.get(t)
            if base is None:
                base = self._configured(t)
                if not math.isfinite(base):
                    if u >= 0.999:
                        # unconfigured tenant, no throttling needed yet:
                        # nothing to anchor the multiplier to
                        self.adjustments["hold"] += 1
                        continue
                    base = self._measured_admit_rate(t)
                self._base[t] = base
            # debt-share bias: u**(1+share) < u for the tenant generating
            # the debt, so it absorbs more of the throttling
            ut = u ** (1.0 + shares.get(t, 0.0))
            new = max(ut * base, cfg.feedback_floor * base)
            prev = self.ctrl.rate_overrides.get(t)
            if prev is None or math.isclose(new, prev,
                                            rel_tol=1e-9, abs_tol=1e-12):
                self.adjustments["hold"] += 1
            elif new < prev:
                self.adjustments["decrease"] += 1
            else:
                self.adjustments["increase"] += 1
            self.ctrl.rate_overrides[t] = new

    # -- debt attribution -------------------------------------------------
    def _tree(self):
        db = self.db
        if db is None:
            return None
        return getattr(db, "tree", None)

    def debt_shares(self) -> Dict[str, float]:
        """Controlled tenants' shares of the attributed compaction debt
        (``LSMTree.debt_by_tenant``), normalized over controlled tenants
        only; empty when unattributed or no ``db`` binding."""
        tree = self._tree()
        if tree is None or not hasattr(tree, "debt_by_tenant"):
            return {}
        protected = self.cfg.protected
        by = {t: v for t, v in tree.debt_by_tenant().items()
              if t and t not in protected}
        total = sum(by.values())
        if total <= 0.0:
            return {}
        return {t: v / total for t, v in by.items()}

    # -- auxiliary knobs ---------------------------------------------------
    def _restore_neutral(self) -> None:
        """Put every actuator back to its configured default state."""
        tree = self._tree()
        if tree is not None and hasattr(tree, "compaction_pace"):
            tree.compaction_pace = 1.0
        backend = getattr(self.db, "backend", None) if self.db else None
        if backend is not None:
            if getattr(backend, "migrator", None) is not None \
                    and self._mig_base is not None:
                backend.migrator.rate_limit = self._mig_base
            backend.cache_zone_budget = None
        self.knobs.update(pace=1.0, migration=1.0, cache_budget=-1.0)

    def _apply_knobs(self, u: float) -> None:
        """Map the actuation level onto the enabled non-admission knobs.

        ``u = 1`` means no foreground pressure: pace 1.0 and cache budget
        unlimited (their neutral), and migration at the *top* of its
        scale range — the HHZS lull is exactly when data movement should
        be most aggressive.  Admission-only configurations never touch
        any of these, so they behave exactly like v1."""
        if self.db is None:
            return
        knobs = self.cfg.feedback_knobs
        u = min(max(float(u), 0.0), 1.0)
        if "compaction" in knobs:
            tree = self._tree()
            if tree is not None and hasattr(tree, "compaction_pace"):
                pace = PACE_FLOOR + (1.0 - PACE_FLOOR) * u
                # debt gate: deferral is only free while the backlog is
                # comfortable — the pace floor rises linearly with debt,
                # hitting full speed at PACE_DEBT_GATE of the threshold
                if self.cfg.debt_threshold and self.debt_gauge is not None:
                    ratio = self.debt_gauge() / float(self.cfg.debt_threshold)
                    pace = max(pace, min(ratio / PACE_DEBT_GATE, 1.0))
                tree.compaction_pace = pace
                self.knobs["pace"] = pace
        backend = getattr(self.db, "backend", None)
        if "migration" in knobs and backend is not None \
                and getattr(backend, "migrator", None) is not None:
            mig = backend.migrator
            if self._mig_base is None:
                self._mig_base = float(mig.rate_limit)
            lo, hi = MIGRATION_SCALE
            scale = lo + (hi - lo) * u
            mig.rate_limit = self._mig_base * scale
            self.knobs["migration"] = scale
        if "cache" in knobs and backend is not None \
                and getattr(backend, "cache", None) is not None:
            if u >= CACHE_RELEASE_U:
                backend.cache_zone_budget = None
                self.knobs["cache_budget"] = -1.0
            else:
                pool = max(len(backend.reserve_zids) - 1, 0)
                budget = int(round(u * pool))
                backend.cache_zone_budget = budget
                self.knobs["cache_budget"] = float(budget)

    # -- telemetry -------------------------------------------------------
    def _install_metrics(self, reg) -> None:
        for t, target in self.targets.items():
            reg.gauge(f"ctl.p99.{t}",
                      lambda t=t: self._p99.get(t, 0.0))
            reg.gauge(f"ctl.target.{t}", lambda v=target: v)
        reg.gauge("ctl.attainment", self.attainment)
        reg.gauge("ctl.u", lambda: float(self._u))
        reg.gauge("ctl.knob.pace", lambda: self.knobs["pace"])
        reg.gauge("ctl.knob.migration", lambda: self.knobs["migration"])
        reg.gauge("ctl.knob.cache_budget",
                  lambda: self.knobs["cache_budget"])
        reg.collector(lambda: {
            f"ctl.rate.{t}": v
            for t, v in self.ctrl.rate_overrides.items()
            if math.isfinite(v)}, name="ctl.rates")
        reg.collector(lambda: {
            f"ctl.debt_share.{t}": v
            for t, v in self.debt_shares().items()}, name="ctl.debt_shares")

    def knob_summary(self) -> Dict:
        """JSON-ready knob/controller state for result rows."""
        return {
            "controller": self.cfg.feedback_controller,
            "knobs": list(self.cfg.feedback_knobs),
            "u": float(self._u),
            "pace": float(self.knobs["pace"]),
            "migration": float(self.knobs["migration"]),
            "cache_budget": float(self.knobs["cache_budget"]),
        }

    def summary(self) -> Dict[str, float]:
        """JSON-ready controller accounting for result rows / debugging."""
        out: Dict[str, float] = {
            "decreases": self.adjustments["decrease"],
            "increases": self.adjustments["increase"],
        }
        for t, v in self.ctrl.rate_overrides.items():
            if math.isfinite(v):
                out[f"rate.{t}"] = v
        for t, p in self._p99.items():
            out[f"p99.{t}"] = p
        return out
