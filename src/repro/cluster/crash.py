"""Surgical per-shard crash on a shared DES clock.

``DB.crash()`` models whole-store power loss: it clears the entire event
heap, every device queue and ``sim._live`` — correct for one store, but a
cluster shares ONE :class:`~repro.zoned.sim.Sim` across N shard stores
plus cluster-level machinery (workload servers, the metrics sampler,
fault daemons, an in-flight split).  Killing one shard must not touch
any of that, and the kernel is deliberately ignorant of shards.

The trick: *at crash time only* (zero hot-path cost), classify every
pending kernel entry by walking the ``yield from`` frame chain of the
process it would resume.  A process whose chain is suspended inside any
of the shard's objects (``f_locals["self"]`` is the shard's tree /
backend / device / placement / migrator / ...) is executing shard code —
whether it is a shard daemon (delay controller, migrator, WAL writer,
zone-repair poller) or an external client caught mid-op on the shard.
Both must die with the shard; a client parked on cluster-level state
(admission hold, router park events) or a process of another shard never
has a shard-owned frame and survives untouched.

Removal follows the kernel's own crash discipline (see ``DB.crash``):

* entries are removed **in place** (``deque.clear()+extend``,
  ``heap[:] = kept`` + heapify) — the dispatch loops in ``Sim.run`` /
  ``run_until`` hoist queue objects *by identity* and must keep seeing
  the same containers;
* ``sim._live`` drops by one per removed non-daemon heap entry and per
  removed run-queue/transient entry (mono entries are never daemon);
  the shard's own device queues use ``MonotoneQueue.crash_clear()``,
  which does its own accounting;
* everything removed — entries, wait-list events, the dead processes —
  is pinned in ``sim.graveyard``: dropping the last reference to a
  suspended generator runs its ``finally`` blocks (semaphore releases,
  waiter wake-ups) and would resurrect other dead work, but a power
  loss must not execute any further shard code.

``kill_shard(sim, db)`` leaves ``db`` with ``_crashed=True`` and its
volatile state dropped, so the **untouched** ``DB.reopen_gen()`` replays
the shard's WAL exactly as it would after a whole-store crash.
"""
from __future__ import annotations

from heapq import heapify
from typing import List, Set, Tuple

from ..zoned.sim import _FIRED, Event, Process


def _owned_objects(db) -> Set[int]:
    """Identity set of the shard's layer objects; a generator frame whose
    ``self`` is one of these is executing shard code."""
    be, tree = db.backend, db.tree
    objs = [db, tree, be, db.ssd, db.hdd, db.admission,
            be.placement, be.migrator, be.cache,
            tree.block_cache, tree.jobs]
    return {id(o) for o in objs if o is not None}


def _frame_owned(gen, owned: Set[int]) -> bool:
    """Walk ``gen``'s ``yield from`` delegation chain; True if any frame's
    ``self`` is a shard object."""
    g = gen
    while g is not None:
        f = getattr(g, "gi_frame", None)
        if f is None:          # finished/closed generator: nothing to kill
            return False
        if id(f.f_locals.get("self")) in owned:
            return True
        g = getattr(g, "gi_yieldfrom", None)
    return False


def _target_procs(target) -> Tuple[List[Process], bool]:
    """Processes a kernel entry's target would resume when it fires.

    ``target`` is a heap/queue entry's callback slot: an :class:`Event`
    (collect its ``_cb``/``_waiters`` subscribers), a bare bound
    ``Process._step`` callback, or a completion-ticket waiter slot
    (``None`` / ``_FIRED`` / bound step).  The second element is True
    when a non-Process subscriber exists (unknown party — never kill)."""
    if isinstance(target, Event):
        cbs = []
        if target._cb is not None:
            cbs.append(target._cb)
        if target._waiters:
            cbs.extend(target._waiters)
        procs, unknown = [], False
        for cb in cbs:
            s = getattr(cb, "__self__", None)
            if isinstance(s, Process):
                procs.append(s)
            else:
                unknown = True
        return procs, unknown
    s = getattr(target, "__self__", None)
    if isinstance(s, Process):
        return [s], False
    return [], target is not None and target is not _FIRED


def kill_shard(sim, db) -> List[Process]:
    """Power-loss one shard store in place; returns the killed processes.

    The caller (``ShardedDB.crash_shard``) handles cluster-level
    bookkeeping — routing state, in-flight tokens, split rollback.  The
    returned list lets the workload runner respawn exactly the servers it
    lost (membership by identity)."""
    owned = _owned_objects(db)
    graveyard = sim.graveyard
    killed: List[Process] = []
    seen: Set[int] = set()

    def note(procs: List[Process]) -> None:
        for p in procs:
            if id(p) not in seen:
                seen.add(id(p))
                killed.append(p)

    def entry_dies(target) -> bool:
        procs, unknown = _target_procs(target)
        if unknown or not procs:
            # waiter-less events (nobody subscribed yet) stay: firing with
            # no waiters is a no-op, and a non-shard process may still be
            # about to yield one
            return False
        if all(_frame_owned(p.gen, owned) for p in procs):
            note(procs)
            return True
        return False

    # 1. event heap: (at, seq, daemon, target, value) — daemon entries
    #    (shard pollers) never counted in _live
    kept = []
    for e in sim._heap:
        if entry_dies(e[3]):
            graveyard.append(e)
            if not e[2]:
                sim._live -= 1
        else:
            kept.append(e)
    if len(kept) != len(sim._heap):
        sim._heap[:] = kept
        heapify(sim._heap)

    # 2. run queue + transient batches: (at, seq, target, value) tuples /
    #    [at, seq, waiter, value] tickets, all non-daemon.  The shard's
    #    own device queues are crash_clear()ed wholesale in step 3; other
    #    shards' device tickets can only resume processes suspended in
    #    *their* shard's frames, so scanning them is skipped too.
    shard_devq = {id(q) for dev in (db.ssd, db.hdd)
                  for q in (dev._fg_q, dev._bg_q) if q is not None}
    for q in sim._mono:
        if id(q) in shard_devq or not q._q:
            continue
        kept_q, dropped = [], []
        for e in q._q:
            (dropped if entry_dies(e[2]) else kept_q).append(e)
        if dropped:
            q._q.clear()           # in place: dispatch hoists this deque
            q._q.extend(kept_q)
            graveyard.append(dropped)
            sim._live -= len(dropped)

    # 3. the shard's device queues drain with the power; every waiter was
    #    mid-I/O on this shard and dies (crash_clear adjusts _live itself)
    for dev in (db.ssd, db.hdd):
        for q in (dev._fg_q, dev._bg_q):
            if q is None:
                continue
            dropped = q.crash_clear()
            if dropped:
                graveyard.append(dropped)
                for e in dropped:
                    note(_target_procs(e[2])[0])
        dev.restart()

    # 4. volatile wait lists: stall-parked writers, WAL group-commit
    #    waiters, flush watchers and queued flush/compaction jobs hold
    #    no scheduled entry — their wake-up source just died with the
    #    shard, so pin them (and count their processes as killed)
    be, tree = db.backend, db.tree
    for ev in (list(be._wal_waiters) + list(tree._stall_waiters)
               + list(tree._flush_watchers) + list(tree.jobs._queue)):
        note(_target_procs(ev)[0])
        graveyard.append(ev)
    graveyard.extend([be._wal_waiters, be._wal_queue,
                      tree._stall_waiters, tree._flush_watchers,
                      tree.jobs._queue, tree])
    graveyard.extend(killed)

    be.crash_volatile()
    db._crashed = True
    return killed
