"""Sharded multi-DB store: routing, per-shard faults, online splitting.

The paper's hint-driven placement/migration/caching (§3.3-3.5) is a
per-store design; this package scales it horizontally the way production
KV services do — N independent shard stores (each a full ``repro.lsm.DB``
with its own devices, WAL and hint pipeline) on ONE shared DES clock,
fronted by a routing layer that keeps the single-store facade
(``submit/get/get_batch/run_for``) intact.  See
``docs/ARCHITECTURE.md`` ("Sharded cluster layer") for the design.
"""
from .router import HashRouter, RangeRouter
from .sharded import INF, RouterKV, ShardedDB, live_keys_in_range

__all__ = ["ShardedDB", "RouterKV", "HashRouter", "RangeRouter",
           "live_keys_in_range", "INF"]
