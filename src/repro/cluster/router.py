"""Key→shard routing policies for the sharded store.

Two pluggable routers share one tiny protocol:

* ``route(key) -> int`` — owning shard of a point key.
* ``shards_for_range(lo, hi) -> list[int]`` — shards a range op must
  consult.
* ``covering_segments(lo, hi) -> [(lo, hi, owner)]`` — the range split
  into maximal same-owner pieces (range router: exact ownership; hash
  router: every shard owns a slice of every range).

``HashRouter`` scatters keys uniformly with a splitmix64-style mixer —
perfect balance, no locality, and therefore no online splitting (a hash
shard has no contiguous range to hand off).  ``RangeRouter`` owns
contiguous key segments and supports ``reassign(lo, hi, dst)``, the
atomic routing flip at the end of an online split
(:meth:`repro.cluster.ShardedDB.split`).  Both are plain Python state
mutated between DES events, so a flip is atomic in virtual time by
construction.
"""
from __future__ import annotations

from bisect import bisect_right, insort
from typing import List, Tuple

INF = float("inf")

_MASK = (1 << 64) - 1


def _mix64(x: int) -> int:
    """splitmix64 finalizer: deterministic, platform-independent mixing
    (``hash(int)`` is identity in CPython — useless for sharding)."""
    x = (x + 0x9E3779B97F4A7C15) & _MASK
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK
    return x ^ (x >> 31)


class HashRouter:
    """Uniform scatter routing; static by design (no contiguous ranges)."""

    kind = "hash"

    def __init__(self, n_shards: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1: {n_shards}")
        self.n = int(n_shards)

    def route(self, key: int) -> int:
        if self.n == 1:
            return 0
        return _mix64(int(key)) % self.n

    def shards_for_range(self, lo: int, hi) -> List[int]:
        return list(range(self.n))

    def covering_segments(self, lo: int, hi) -> List[Tuple[int, float, int]]:
        # every shard holds a scatter of the range; callers fall back to
        # consulting all shards with the full range
        return [(lo, hi, s) for s in range(self.n)]

    def reassign(self, lo: int, hi, dst: int) -> None:
        raise NotImplementedError(
            "hash routing has no contiguous ranges to reassign; "
            "use routing='range' for online splits")

    def segments_of(self, shard: int) -> List[Tuple[int, float]]:
        return []

    def describe(self) -> dict:
        return {"kind": "hash", "shards": self.n}


class RangeRouter:
    """Contiguous key segments with atomic online reassignment.

    Ownership is a sorted boundary list: ``bounds[i]`` starts the i-th
    segment, owned by ``owners[i]``; the last segment extends to +inf so
    frontier inserts (YCSB ``latest`` / insert-heavy mixes) always route.
    Initial layout splits ``[0, key_space)`` evenly across shards.
    """

    kind = "range"

    def __init__(self, n_shards: int, key_space: int):
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1: {n_shards}")
        if key_space < n_shards:
            raise ValueError(
                f"key_space {key_space} smaller than shard count {n_shards}")
        self.n = int(n_shards)
        self.key_space = int(key_space)
        step = key_space // n_shards
        self.bounds: List[int] = [i * step for i in range(n_shards)]
        self.owners: List[int] = list(range(n_shards))

    # -- lookup ---------------------------------------------------------
    def _seg(self, key: int) -> int:
        return bisect_right(self.bounds, int(key)) - 1

    def route(self, key: int) -> int:
        return self.owners[self._seg(key)]

    def _seg_hi(self, i: int):
        return self.bounds[i + 1] if i + 1 < len(self.bounds) else INF

    def covering_segments(self, lo: int, hi) -> List[Tuple[int, float, int]]:
        """Maximal same-owner pieces of ``[lo, hi)`` (``hi`` may be INF),
        clipped to the query range."""
        if hi is not INF and hi <= lo:
            return []
        out: List[Tuple[int, float, int]] = []
        i = self._seg(lo)
        while i < len(self.bounds) and (hi is INF or self.bounds[i] < hi):
            s_lo = max(self.bounds[i], lo)
            s_hi = self._seg_hi(i) if hi is INF else min(self._seg_hi(i), hi)
            if not out or out[-1][2] != self.owners[i]:
                out.append((s_lo, s_hi, self.owners[i]))
            else:  # merge adjacent same-owner segments of the query
                out[-1] = (out[-1][0], s_hi, self.owners[i])
            i += 1
        return out

    def shards_for_range(self, lo: int, hi) -> List[int]:
        seen: List[int] = []
        for _, _, s in self.covering_segments(lo, hi):
            if s not in seen:
                seen.append(s)
        return seen

    def segments_of(self, shard: int) -> List[Tuple[int, float]]:
        return [(self.bounds[i], self._seg_hi(i))
                for i in range(len(self.bounds)) if self.owners[i] == shard]

    # -- reassignment ---------------------------------------------------
    def _split_at(self, key: int) -> None:
        i = self._seg(key)
        if self.bounds[i] != key:
            insort(self.bounds, int(key))
            self.owners.insert(i + 1, self.owners[i])

    def reassign(self, lo: int, hi, dst: int) -> None:
        """Atomically hand ``[lo, hi)`` (``hi`` may be INF) to ``dst``.
        Plain list surgery between DES events — no sim interaction, so
        in-flight ops observe either the old or the new map, never a mix."""
        if not (0 <= dst < self.n):
            raise ValueError(f"no such shard: {dst}")
        if hi is not INF and hi <= lo:
            raise ValueError(f"empty range [{lo}, {hi})")
        self._split_at(int(lo))
        if hi is not INF:
            self._split_at(int(hi))
        for i in range(len(self.bounds)):
            if self.bounds[i] >= lo and (hi is INF or self._seg_hi(i) <= hi):
                self.owners[i] = dst
        self._coalesce()

    def _coalesce(self) -> None:
        bounds, owners = [self.bounds[0]], [self.owners[0]]
        for b, o in zip(self.bounds[1:], self.owners[1:]):
            if o != owners[-1]:
                bounds.append(b)
                owners.append(o)
        self.bounds, self.owners = bounds, owners

    def describe(self) -> dict:
        return {"kind": "range", "shards": self.n,
                "bounds": list(self.bounds), "owners": list(self.owners)}
