"""Sharded store facade: N shard ``DB``-s on one DES clock.

``ShardedDB`` satisfies the same store interface as ``repro.lsm.DB``
(``sim/now/kv/submit/run_for/drain/flush_all/extras/compaction_debt/
fresh_admission/crash/reopen_gen/scheme/scenario``), so every workload
runner and the scenario matrix drive it unchanged.  Three middleware
mechanisms live here:

* :class:`RouterKV` — the op-generator surface.  Point ops resolve their
  owning shard through the pluggable router (``repro.cluster.router``)
  and delegate to that shard's LSM tree via ``yield from`` (zero extra
  DES events — a 1-shard cluster is event-for-event identical to a bare
  ``DB``, asserted by ``tests/test_sharding.py``).  Ops aimed at a down
  shard or at a range mid-split *park* on an Event and retry when the
  cluster state changes; per-shard routed/completed counters and
  in-flight spans feed availability accounting and the split drain.
* **Online split** (:meth:`ShardedDB.split`) — a middleware operation
  charged in virtual time: drain in-flight ops overlapping the moving
  range (new ones park), enumerate the range's live keys, copy them with
  charged reads on the source and charged writes (WAL + flush pipeline)
  on the target, tombstone stale target copies left by an earlier
  aborted/backward split, then atomically flip the routing map and
  release the parked ops.  A crash of either endpoint mid-split bumps
  the split epoch: the surviving split process observes the bump after
  its next yield and aborts; routing never half-flips.
* **Rebalancer** — a daemon reading the per-shard op-rate series from
  the metrics bus; when the hottest shard's rate exceeds
  ``rebalance_factor ×`` the mean it splits that shard's most populous
  segment at the head-biased sqrt quantile (the mass median of a
  zipf-style hot spot anchored at the segment head) and hands the
  sqrt(W)-key head — half the traffic, a cheap copy — to the coldest
  shard via ``split()``.

Per-shard crash (``crash_shard``/``reopen_shard_gen``) is implemented by
``repro.cluster.crash``: the crashed shard's processes and queue entries
are surgically removed from the shared kernel while every other shard —
and the cluster machinery — keeps serving; recovery replays that shard's
WAL through the untouched ``DB.reopen_gen``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from ..core.middleware import AdmissionController
from ..lsm.db import DB, ScenarioConfig
from ..zoned.sim import Sim
from .crash import kill_shard
from .router import INF, HashRouter, RangeRouter

SPLIT_CHUNK = 256   # keys copied per charged batch read during a split


def live_keys_in_range(tree, lo: int, hi) -> List[int]:
    """Live (non-tombstoned) keys of ``[lo, hi)`` (``hi`` may be INF),
    deduplicated newest-first exactly like ``LSMTree.scan`` — memtables
    (active, immutable, flushing), then L0 newest-first, then deeper
    levels.  Pure in-memory enumeration: the split's *charged* I/O comes
    from the batched reads/writes of the copy phase, not from listing."""
    newest: Dict[int, bool] = {}
    for m in [tree.memtable] + list(reversed(tree.immutables)) \
            + list(reversed(tree._flushing)):
        for k, (tomb, _) in m.data.items():
            if lo <= k and (hi is INF or k < hi):
                newest.setdefault(int(k), tomb)
    for lvl in range(len(tree.levels)):
        ssts = (sorted(tree.levels[0], key=lambda s: -s.birth)
                if lvl == 0 else tree.levels[lvl])
        for sst in ssts:
            a = int(np.searchsorted(sst.keys, np.uint64(lo)))
            b = (len(sst.keys) if hi is INF
                 else int(np.searchsorted(sst.keys, np.uint64(hi))))
            for i in range(a, b):
                newest.setdefault(int(sst.keys[i]), bool(sst.tombs[i]))
    return sorted(k for k, tomb in newest.items() if not tomb)


class RouterKV:
    """Routing op surface; same generator protocol as ``LSMTree``.

    Counters: ``routed[s]``/``completed[s]`` count kv calls begun/finished
    per shard; ``calls`` is their cluster total, so ``sum(routed) ==
    calls`` is an invariant the result validator checks per cell (note
    one *workload op* can be several kv calls: RMW is a get + a put, a
    scan touches every covering shard).  ``inflight[s]`` maps op tokens
    to key spans — the split drain and crash-loss accounting read it.
    """

    def __init__(self, cluster: "ShardedDB"):
        self.cluster = cluster
        n = len(cluster.shards)
        self.inflight: List[Dict[int, Tuple[int, Any, int]]] = \
            [{} for _ in range(n)]
        self.routed = [0] * n
        self.completed = [0] * n
        self.calls = 0
        self._tok = 0

    def snapshot(self) -> Tuple[int, List[int], List[int]]:
        return (self.calls, list(self.routed), list(self.completed))

    # -- admission / parking -------------------------------------------
    def _blocked(self, s: int, lo: int, hi) -> bool:
        c = self.cluster
        if s in c._down:
            return True
        st = c._split_state
        # op spans are finite; st["hi"] may be INF (suffix split)
        return (st is not None and s == st["src"]
                and lo < st["hi"] and hi > st["lo"])

    def _park(self):
        ev = self.cluster.sim.event()
        self.cluster._parked.append(ev)
        return ev

    def _admit(self, key: int):
        c = self.cluster
        while True:
            s = c.router.route(key)
            if not self._blocked(s, key, key + 1):
                return s
            yield self._park()

    def _begin(self, s: int, lo: int, hi, n: int = 1) -> int:
        self._tok += 1
        self.inflight[s][self._tok] = (lo, hi, n)
        self.routed[s] += n
        self.calls += n
        return self._tok

    def _end(self, s: int, tok: int, n: int = 1) -> None:
        if self.inflight[s].pop(tok, None) is not None:
            self.completed[s] += n
            c = self.cluster
            if c._split_state is not None and s == c._split_state["src"]:
                c._split_drain_check()

    # -- ops ------------------------------------------------------------
    def put(self, key: int, value: Optional[bytes] = None,
            tombstone: bool = False, tenant: Optional[str] = None):
        s = yield from self._admit(key)
        tok = self._begin(s, key, key + 1)
        try:
            res = yield from self.cluster.shards[s].tree.put(
                key, value, tombstone=tombstone, tenant=tenant)
        finally:
            self._end(s, tok)
        return res

    def delete(self, key: int):
        s = yield from self._admit(key)
        tok = self._begin(s, key, key + 1)
        try:
            res = yield from self.cluster.shards[s].tree.delete(key)
        finally:
            self._end(s, tok)
        return res

    def get(self, key: int):
        s = yield from self._admit(key)
        tok = self._begin(s, key, key + 1)
        try:
            res = yield from self.cluster.shards[s].tree.get(key)
        finally:
            self._end(s, tok)
        return res

    def get_batch(self, keys):
        """Batched point reads, re-grouped by owning shard; per-shard
        sub-batches keep the caller's key order, so a 1-shard cluster
        issues the identical single ``LSMTree.get_batch`` call."""
        keys = list(keys)
        c = self.cluster
        results: List[Any] = [None] * len(keys)
        remaining = list(range(len(keys)))
        while remaining:
            s = c.router.route(keys[remaining[0]])
            idxs = [i for i in remaining if c.router.route(keys[i]) == s]
            lo = min(keys[i] for i in idxs)
            hi = max(keys[i] for i in idxs) + 1
            if self._blocked(s, lo, hi):
                # routing may change while parked: re-group from scratch
                yield self._park()
                continue
            sub = [keys[i] for i in idxs]
            tok = self._begin(s, lo, hi, n=len(sub))
            try:
                res = yield from c.shards[s].tree.get_batch(sub)
            finally:
                self._end(s, tok, n=len(sub))
            for i, r in zip(idxs, res):
                results[i] = r
            drop = set(idxs)
            remaining = [i for i in remaining if i not in drop]
        return results

    def scan(self, start_key: int, count: int):
        """Range scan; returns the summed live-key count.

        Range routing consults only the shards *owning* a piece of the
        range — stale copies left on a shard by an aborted split are
        shadowed by ownership and never counted.  Hash routing scatters
        every range over all shards (disjoint key sets, exact sum)."""
        c = self.cluster
        end = start_key + count
        total = 0
        if c.router.kind == "range":
            while True:
                segs = c.router.covering_segments(start_key, end)
                if not any(self._blocked(s, lo, hi) for lo, hi, s in segs):
                    break
                yield self._park()
            for lo, hi, s in segs:
                tok = self._begin(s, int(lo), int(hi))
                try:
                    n = yield from c.shards[s].tree.scan(
                        int(lo), int(hi) - int(lo))
                finally:
                    self._end(s, tok)
                total += n
        else:
            for s in range(len(c.shards)):
                while self._blocked(s, start_key, end):
                    yield self._park()
                tok = self._begin(s, start_key, end)
                try:
                    n = yield from c.shards[s].tree.scan(start_key, count)
                finally:
                    self._end(s, tok)
                total += n
        return total


class ShardedDB:
    """Shard router fronting N per-shard ``DB`` instances (own devices,
    WAL, hint pipeline each) behind the single-store facade."""

    def __init__(self, scheme: str = "HHZS",
                 scenario: Optional[ScenarioConfig] = None,
                 shards: int = 2, routing: str = "hash",
                 key_space: Optional[int] = None,
                 rebalance: bool = False,
                 rebalance_period: float = 30.0,
                 rebalance_factor: float = 2.0,
                 store_values: bool = False,
                 admission: Any = "none",
                 telemetry: "bool | float" = False):
        if shards < 1:
            raise ValueError(f"shards must be >= 1: {shards}")
        self.sim = Sim()
        self.shards: List[DB] = [
            DB(scheme, scenario, store_values=store_values, sim=self.sim)
            for _ in range(shards)]
        self.scheme = scheme
        self.scenario = self.shards[0].scenario
        self.routing = routing
        if routing == "hash":
            self.router: "HashRouter | RangeRouter" = HashRouter(shards)
        elif routing == "range":
            ks = key_space if key_space is not None \
                else self.scenario.paper_keys
            self.router = RangeRouter(shards, ks)
        else:
            raise ValueError(
                f"unknown routing {routing!r}; one of ('hash', 'range')")
        self.kv = RouterKV(self)
        # cluster-level admission: no single backend — per-shard WAL
        # pressure callbacks feed the controller instead
        self.admission = AdmissionController(self.sim, None, admission)
        self.admission.shard_pressure = [
            db.backend.wal_pressure for db in self.shards]
        self.admission.debt_gauge = lambda: float(self.compaction_debt())
        self._down: Set[int] = set()
        self._parked: List = []
        self._split_state: Optional[Dict[str, Any]] = None
        self._split_epoch = 0
        self.splits: List[Dict[str, Any]] = []
        self._crashed = False
        self.recovery: Optional[dict] = None
        self.metrics = None
        self.rebalance = bool(rebalance)
        self.rebalance_period = float(rebalance_period)
        self.rebalance_factor = float(rebalance_factor)
        if telemetry:
            self.enable_telemetry(
                5.0 if telemetry is True else float(telemetry))
        if rebalance:
            if routing != "range":
                raise ValueError("rebalance requires routing='range' "
                                 "(hash shards have no ranges to move)")
            if self.metrics is None:
                self.enable_telemetry()
            self.sim.process(self._rebalance_loop())

    # ---- single-store facade ------------------------------------------
    @property
    def now(self) -> float:
        return self.sim.now

    def submit(self, gen, tenant: Optional[str] = None):
        if tenant is not None:
            return self.admission.submit(gen, tenant)
        return self.sim.process(gen)

    def run_for(self, seconds: float) -> None:
        self.sim.run(until=self.sim.now + seconds)

    def drain(self) -> None:
        self.sim.run()

    def _run(self, gen):
        return self.sim.run_until(self.sim.process(gen))

    def put(self, key: int, value: Optional[bytes] = None):
        return self._run(self.kv.put(key, value))

    def get(self, key: int):
        return self._run(self.kv.get(key))

    def get_batch(self, keys):
        return self._run(self.kv.get_batch(list(keys)))

    def delete(self, key: int):
        return self._run(self.kv.delete(key))

    def scan(self, start_key: int, count: int):
        return self._run(self.kv.scan(start_key, count))

    def flush_all(self):
        def gen():
            for db in self.shards:
                yield from db.tree.flush_all()
        return self._run(gen())

    def compaction_debt(self) -> float:
        return float(sum(db.tree.compaction_debt() for db in self.shards))

    _RATE_EXTRAS = ("block_cache_hit_rate",)

    def extras(self) -> dict:
        parts = [db.extras() for db in self.shards]
        if len(parts) == 1:
            return parts[0]
        keys: List[str] = []
        for part in parts:
            for k in part:
                if k not in keys:
                    keys.append(k)
        out: Dict[str, Any] = {}
        for k in keys:
            vals = [p[k] for p in parts if k in p]
            out[k] = (sum(vals) / len(vals) if k in self._RATE_EXTRAS
                      else sum(vals))
        return out

    def fresh_admission(self, policy=None) -> AdmissionController:
        orig_base = self.admission.base_cfg
        self.admission = AdmissionController(
            self.sim, None, policy if policy is not None else orig_base)
        self.admission.base_cfg = orig_base
        self.admission.shard_pressure = [
            db.backend.wal_pressure for db in self.shards]
        self.admission.debt_gauge = lambda: float(self.compaction_debt())
        if self.metrics is not None:
            self.admission.install_metrics(self.metrics)
        return self.admission

    # ---- telemetry -----------------------------------------------------
    def enable_telemetry(self, sample_period: float = 5.0,
                         capacity: int = 720):
        """Per-shard signals under ``s{i}.``, cluster rollups under
        ``cluster.*`` (aggregated at sample time so shard reopens that
        rebind gauges are picked up), and the per-shard op-rate series
        the rebalancer reads.  Idempotent."""
        if self.metrics is not None:
            return self.metrics
        from ..obs import MetricsRegistry
        reg = MetricsRegistry(self.sim, sample_period, capacity)
        self.metrics = reg
        n = len(self.shards)
        for i, db in enumerate(self.shards):
            db.ssd.install_metrics(reg, f"s{i}.ssd")
            db.hdd.install_metrics(reg, f"s{i}.hdd")
            db.backend.install_metrics(reg, f"s{i}.")
            db.tree.install_metrics(reg, f"s{i}.")
        for name, red in (("lsm.debt", "sum"), ("lsm.l0_files", "sum"),
                          ("lsm.flush_backlog", "sum"),
                          ("lsm.write_amp", "mean"),
                          ("mw.wal_pressure", "max"),
                          ("ssd.util", "mean"), ("hdd.util", "mean")):
            reg.aggregate_gauge(f"cluster.{name}",
                                [f"s{i}.{name}" for i in range(n)], red)
        reg.collector(self._shard_op_rates, rate=True,
                      name="cluster.shard_ops")
        self.admission.install_metrics(reg)
        reg.start()
        return reg

    def _shard_op_rates(self) -> Dict[str, float]:
        return {f"cluster.s{i}.op_rate": float(v)
                for i, v in enumerate(self.kv.routed)}

    # ---- per-shard crash / recovery ------------------------------------
    def crash_shard(self, idx: int) -> Dict[str, Any]:
        """Power-loss shard ``idx`` only; every other shard keeps serving.

        In-flight ops on the shard die with it (their processes are
        surgically removed from the shared kernel and pinned); ops routed
        to it afterwards park and complete after ``reopen_shard``.  An
        active split touching the shard rolls back (routing unchanged)."""
        db = self.shards[idx]
        if db._crashed:
            raise RuntimeError(f"shard {idx} already crashed")
        killed = kill_shard(self.sim, db)
        lost = sum(n for (_, _, n) in self.kv.inflight[idx].values())
        # killed processes never run their finally blocks: clear their
        # tokens here so routed - completed = lost ops, exactly
        self.kv.inflight[idx].clear()
        self._down.add(idx)
        self._abort_split_for(idx)
        return {"shard": idx, "lost_in_flight": lost,
                "killed_processes": killed}

    def reopen_shard_gen(self, idx: int):
        """Generator: recover shard ``idx`` (charged WAL replay via the
        untouched ``DB.reopen_gen``), then release parked ops."""
        db = self.shards[idx]
        rec = dict((yield from db.reopen_gen()))
        self._down.discard(idx)
        self._release_parked()
        if self.metrics is not None:
            # rebind the per-shard tree gauges to the recovered tree (the
            # registry replaces by name; devices/backend survived intact)
            db.tree.install_metrics(self.metrics, f"s{idx}.")
        rec["shard"] = idx
        self.recovery = rec
        return rec

    def reopen_shard(self, idx: int) -> dict:
        return self._run(self.reopen_shard_gen(idx))

    # ---- whole-cluster crash / recovery (DB.crash parity) --------------
    def crash(self) -> None:
        """Whole-cluster power loss: the ``DB.crash`` protocol applied to
        every shard at once (single heap clear; see that docstring)."""
        sim = self.sim
        g = sim.graveyard
        g.append(list(sim._heap))
        for db in self.shards:
            g.extend([db.backend._wal_waiters, db.backend._wal_queue,
                      db.tree._stall_waiters, db.tree._flush_watchers,
                      db.tree.jobs._queue, db.tree])
        g.append(self._parked)
        self._parked = []
        for q in sim._mono:
            g.append(q.crash_clear())
        sim._heap.clear()
        sim._live = 0
        for db in self.shards:
            for dev in (db.ssd, db.hdd):
                dev.restart()
            db.backend.crash_volatile()
            db._crashed = True
        for d in self.kv.inflight:
            d.clear()
        if self._split_state is not None:
            self._split_epoch += 1
            self._split_state = None
        self._down = set()
        self._crashed = True

    def reopen_gen(self):
        recs = []
        for i, db in enumerate(self.shards):
            recs.append((yield from db.reopen_gen()))
            if self.metrics is not None:
                db.tree.install_metrics(self.metrics, f"s{i}.")
        if self.metrics is not None:
            self.metrics.restart()
        self._crashed = False
        self.recovery = {
            "at": self.sim.now,
            "live_wal_zones": sum(r["live_wal_zones"] for r in recs),
            "replayed_gens": sum(r["replayed_gens"] for r in recs),
            "replayed_records": sum(r["replayed_records"] for r in recs)}
        return self.recovery

    def reopen(self) -> dict:
        return self._run(self.reopen_gen())

    # ---- online split ---------------------------------------------------
    def split(self, lo: int, hi, dst: int):
        """Spawn the online move of range ``[lo, hi)`` (``hi`` may be
        ``INF``) to shard ``dst``; returns the Process."""
        return self.sim.process(self._split_proc(lo, hi, dst))

    def _split_proc(self, lo: int, hi, dst: int):
        if self.router.kind != "range":
            raise ValueError("online splits require routing='range'")
        if self._split_state is not None:
            return {"completed": False, "reason": "split already active"}
        owners = self.router.shards_for_range(lo, hi)
        if len(owners) != 1:
            return {"completed": False,
                    "reason": f"range spans shards {owners}"}
        src = owners[0]
        if src == dst:
            return {"completed": False, "reason": "src == dst"}
        if src in self._down or dst in self._down:
            return {"completed": False, "reason": "endpoint shard is down"}
        epoch = self._split_epoch
        st: Dict[str, Any] = {"src": src, "dst": dst, "lo": lo, "hi": hi,
                              "drain_ev": None}
        self._split_state = st
        t0 = self.sim.now
        aborted = {"completed": False, "reason": "aborted by shard crash"}
        # phase 1 — drain: in-flight ops overlapping the range finish
        # (ops on the retained range keep flowing; new overlapping ops
        # park at the router until the flip or the abort)
        while self._overlapping_inflight(st):
            ev = self.sim.event()
            st["drain_ev"] = ev
            yield ev
            if self._split_epoch != epoch:
                return aborted
        src_db, dst_db = self.shards[src], self.shards[dst]
        # phase 2 — copy, charged in virtual time: batched reads on the
        # source, full write path (WAL, memtable, flush) on the target
        keys = live_keys_in_range(src_db.tree, lo, hi)
        have = set(keys)
        moved = 0
        for off in range(0, len(keys), SPLIT_CHUNK):
            chunk = keys[off:off + SPLIT_CHUNK]
            vals = yield from src_db.tree.get_batch(chunk)
            if self._split_epoch != epoch:
                return aborted
            for k, (found, val) in zip(chunk, vals):
                yield from dst_db.tree.put(int(k), val)
                if self._split_epoch != epoch:
                    return aborted
            moved += len(chunk)
        # phase 3 — reconcile: a key live on the target but absent from
        # the source's live set is residue of an earlier aborted/backward
        # split; tombstone it or it would resurrect after the flip
        tombs = 0
        for k in live_keys_in_range(dst_db.tree, lo, hi):
            if k not in have:
                yield from dst_db.tree.delete(int(k))
                if self._split_epoch != epoch:
                    return aborted
                tombs += 1
        # phase 4 — atomic flip (plain state mutation between DES
        # events) and release of the parked ops
        self.router.reassign(lo, hi, dst)
        self._split_state = None
        self._release_parked()
        rec = {"completed": True, "src": src, "dst": dst, "lo": int(lo),
               "hi": None if hi is INF else int(hi), "moved_keys": moved,
               "reconciled": tombs, "t0": t0, "t1": self.sim.now}
        self.splits.append(rec)
        return rec

    def _overlapping_inflight(self, st: Dict[str, Any]) -> bool:
        lo, hi = st["lo"], st["hi"]
        for (a, b, _n) in self.kv.inflight[st["src"]].values():
            if a < hi and b > lo:
                return True
        return False

    def _split_drain_check(self) -> None:
        st = self._split_state
        if st is not None and st["drain_ev"] is not None \
                and not self._overlapping_inflight(st):
            ev = st["drain_ev"]
            st["drain_ev"] = None
            ev.succeed()

    def _abort_split_for(self, idx: int) -> None:
        """Roll back an active split touching crashed shard ``idx``:
        routing stays on the source (never half-flipped); copies already
        written to the target are shadowed by ownership and reconciled
        by the next successful split of that range."""
        st = self._split_state
        if st is None or idx not in (st["src"], st["dst"]):
            return
        self._split_epoch += 1
        self._split_state = None
        self.splits.append({
            "completed": False, "src": st["src"], "dst": st["dst"],
            "lo": int(st["lo"]),
            "hi": None if st["hi"] is INF else int(st["hi"]),
            "reason": f"shard {idx} crashed mid-split", "at": self.sim.now})
        ev = st["drain_ev"]
        if ev is not None and not ev.triggered:
            # the split process survives a source crash during drain
            # (it is suspended in cluster code); wake it to observe the
            # epoch bump and abort
            ev.succeed()
        self._release_parked()

    def _release_parked(self) -> None:
        parked, self._parked = self._parked, []
        for ev in parked:
            ev.succeed()

    # ---- rebalancer -----------------------------------------------------
    def _rebalance_loop(self):
        while True:
            yield self.sim.timeout(self.rebalance_period, daemon=True)
            self._maybe_rebalance()

    def _maybe_rebalance(self) -> None:
        if self._split_state is not None or len(self.shards) < 2:
            return
        reg = self.metrics
        rates = []
        for i in range(len(self.shards)):
            v = reg.latest(f"cluster.s{i}.op_rate")
            rates.append(0.0 if v is None else float(v))
        total = sum(rates)
        if total <= 0.0:
            return
        n = len(rates)
        hot = max(range(n), key=rates.__getitem__)
        cold = min(range(n), key=rates.__getitem__)
        if hot == cold or hot in self._down or cold in self._down:
            return
        if rates[hot] < self.rebalance_factor * (total / n):
            return
        # shed the *head* of the hot shard's most populous segment, cut
        # at the sqrt quantile: skewed range traffic (a zipf-popular hot
        # spot anchored at the segment head) has its mass median around
        # the sqrt(W)-th key, so the handed-off head [lo, mid) carries
        # ~half the traffic while containing only ~sqrt(W) keys — a
        # cheap bulk copy with a large routing effect.  A key-median
        # split would strand nearly all of the zipf head on the source
        # shard, and handing off the tail instead would bulk-copy
        # W - sqrt(W) keys for the same traffic relief.
        best: Optional[Tuple[int, Any, List[int]]] = None
        for lo, hi in self.router.segments_of(hot):
            keys = live_keys_in_range(self.shards[hot].tree, lo, hi)
            if best is None or len(keys) > len(best[2]):
                best = (lo, hi, keys)
        if best is None or len(best[2]) < 2:
            return
        lo, hi, keys = best
        cut = max(1, math.isqrt(len(keys)))
        mid = int(keys[cut])
        if mid <= lo or not (hi is INF or mid < hi):
            return
        self.split(lo, mid, cold)

    # ---- result-row helpers ---------------------------------------------
    def shard_stats(self, baseline: Optional[Tuple[int, List[int],
                                                   List[int]]] = None
                    ) -> List[Dict[str, Any]]:
        """Per-shard accounting rows; ``baseline`` (a ``RouterKV.snapshot``
        taken before the measured phase) subtracts load-phase traffic."""
        n = len(self.shards)
        _, routed0, completed0 = baseline or (0, [0] * n, [0] * n)
        rows = []
        for i, db in enumerate(self.shards):
            r = self.kv.routed[i] - routed0[i]
            done = self.kv.completed[i] - completed0[i]
            rows.append({
                "shard": i,
                "kv_ops": r,
                "kv_completed": done,
                "availability": (done / r) if r else 1.0,
                "ssd_read_bytes": db.ssd.counters.read_bytes,
                "ssd_write_bytes": db.ssd.counters.write_bytes,
                "hdd_read_bytes": db.hdd.counters.read_bytes,
                "hdd_write_bytes": db.hdd.counters.write_bytes,
                "compaction_debt": float(db.tree.compaction_debt()),
            })
        return rows
