"""Hymba-1.5B: parallel attention + mamba heads per layer; SWA except a
few full-attention layers; ssm_state=16 [arXiv:2411.13676; hf].

Hymba meta-tokens are omitted (see DESIGN.md §Arch-applicability)."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
    d_ff=5504, vocab_size=32001, head_dim=64,
    ssm_state=16, d_inner=3200,
    sliding_window=1024, full_attn_layers=(0, 16, 31),
    source="arXiv:2411.13676; hf",
)
