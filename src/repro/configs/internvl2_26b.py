"""InternVL2-26B backbone (InternLM2-20B side): the InternViT frontend is
a stub — input_specs provides precomputed patch embeddings occupying the
first vision_prefix positions [arXiv:2404.16821; hf]."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=16384, vocab_size=92553, head_dim=128,
    vision_prefix=256,
    source="arXiv:2404.16821; hf",
)
