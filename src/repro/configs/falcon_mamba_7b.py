"""Falcon-Mamba-7B: attention-free Mamba-1, d_ff=0
[arXiv:2410.05355; unverified]."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    num_layers=64, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=65024, head_dim=64,
    ssm_state=16, d_inner=8192,
    source="arXiv:2410.05355; unverified",
)
