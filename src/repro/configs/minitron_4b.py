"""Minitron-4B: pruned Nemotron dense [arXiv:2407.14679; hf]."""
from ..config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    d_ff=9216, vocab_size=256000, head_dim=128,
    source="arXiv:2407.14679; hf",
)
