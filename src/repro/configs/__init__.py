"""Architecture registry: ``get_config("olmoe-1b-7b")`` etc.

Each module exports CONFIG (the exact public-literature configuration) and
the registry maps dashed arch ids to them.  ``CONFIG.smoke()`` gives the
reduced same-family config used by CPU smoke tests.
"""
from __future__ import annotations

from typing import Dict, List

from ..config import ModelConfig
from . import (olmoe_1b_7b, mixtral_8x22b, whisper_base, qwen2_5_14b,
               granite_34b, qwen3_1_7b, minitron_4b, hymba_1_5b,
               falcon_mamba_7b, internvl2_26b)

_MODULES = [olmoe_1b_7b, mixtral_8x22b, whisper_base, qwen2_5_14b,
            granite_34b, qwen3_1_7b, minitron_4b, hymba_1_5b,
            falcon_mamba_7b, internvl2_26b]

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return REGISTRY[name[:-len("-smoke")]].smoke()
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs() -> List[str]:
    return sorted(REGISTRY)
